"""Markdown link check: every relative link target must exist on disk.

External (scheme://) and mailto links are skipped — CI must not depend
on network reachability; anchors are stripped before the existence
check.  Directory arguments are searched recursively for ``*.md``, so
new documentation pages are covered the moment they land.  Exit code 1
lists every broken link.

  python scripts/check_markdown_links.py README.md DESIGN.md docs ...
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — target captured up to the closing paren; images too.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")


def check_file(path: Path) -> list[str]:
    errors = []
    for n, line in enumerate(path.read_text().splitlines(), 1):
        for target in _LINK.findall(line):
            if re.match(r"^[a-zA-Z][a-zA-Z0-9+.-]*:", target):
                continue                      # http:, https:, mailto:
            rel = target.split("#", 1)[0]
            if not rel:                       # pure in-page anchor
                continue
            if not (path.parent / rel).exists():
                errors.append(f"{path}:{n}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_markdown_links.py FILE.md|DIR [...]")
        return 2
    errors: list[str] = []
    files: list[Path] = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
        elif p.is_dir():
            found = sorted(p.rglob("*.md"))
            if not found:
                errors.append(f"{name}: directory holds no .md files")
            files.extend(found)
        else:
            files.append(p)
    for p in files:
        errors.extend(check_file(p))
    for e in errors:
        print(e)
    if errors:
        print(f"{len(errors)} broken link(s)")
        return 1
    print(f"ok: {len(files)} file(s), all relative links resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
