"""End-to-end LM training driver example.

Trains the smollm-135m *family* (reduced width by default — CPU container)
for a few hundred steps with the full production stack: deterministic
sharded data pipeline, AdamW, cosine schedule, async checksummed
checkpoints, restart-exactness.

  PYTHONPATH=src python examples/train_lm.py               # ~20 M params
  PYTHONPATH=src python examples/train_lm.py --full        # 135 M params
"""

import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="checkpoints/example_lm")
    args = ap.parse_args()
    out = train(
        "smollm-135m",
        steps=args.steps,
        batch=8 if not args.full else 16,
        seq_len=128 if not args.full else 1024,
        lr=1e-3,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        config_set="full" if args.full else "smoke",
    )
    first = sum(out["losses"][:10]) / 10
    last = sum(out["losses"][-10:]) / 10
    print(f"[example] loss {first:.3f} -> {last:.3f} over "
          f"{out['final_step']} steps "
          f"(median step {out['median_step_s']*1e3:.0f} ms, "
          f"{out['stragglers']} stragglers)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
