"""Quickstart: the paper's three contributions in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import optimizer, scheduler, sparse, spectral

key = jax.random.PRNGKey(0)

# 1. Spectral convolution (FFT tiling + Hadamard + OaA) ---------------------
x = jax.random.normal(key, (1, 8, 56, 56))          # NCHW activations
w = jax.random.normal(key, (16, 8, 3, 3))           # OIHW kernel
y_spec = spectral.spectral_conv2d(x, w, fft_size=8)
y_ref = spectral.spatial_conv2d(x, w)
print(f"spectral == spatial:  max|err| = "
      f"{float(jnp.abs(y_spec - y_ref).max()):.2e}")

# 2. Sparse spectral kernels + flexible dataflow (Alg 1) --------------------
wf = spectral.spectral_kernel(w, 8)
sk = sparse.prune_magnitude(wf, alpha=4.0)          # K^2/4 nnz per kernel
print(f"pruned kernels: {sk.nnz}/{8 * 8} non-zeros each (alpha=4)")

plan = optimizer.optimize(arch_candidates=[(9, 64)])
print(f"Alg 1: P'={plan.p_par} N'={plan.n_par}  "
      f"max bandwidth {plan.bw_max_gbps:.1f} GB/s @ 20 ms")
lp = plan.layers[0]
print(f"  {lp.layer}: stream params Ps={lp.ps} Ns={lp.ns} "
      f"({lp.n_bram} BRAMs, {lp.transfers_words / 1e6:.1f} Mwords)")

# 3. Exact-cover memory-access scheduling (Alg 2) ---------------------------
rng = np.random.default_rng(0)
idx = np.stack([np.sort(rng.choice(64, 16, replace=False))
                for _ in range(64)])                # 64 sparse kernels
for method in ("exact_cover", "lowest_index", "random"):
    s = scheduler.SCHEDULERS[method](idx, 64, r=10)
    scheduler.verify_schedule(s, idx, 64)
    print(f"  {method:12s}: {s.n_cycles:3d} cycles, "
          f"PE utilization {s.pe_utilization:.1%}")

# The schedule compiles to the Fig-6 INDEX/VALUE tables and executes on
# the Pallas sparse-Hadamard kernel — see tests/test_kernels.py.
