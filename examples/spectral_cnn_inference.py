"""End-to-end sparse spectral CNN inference (the paper's pipeline).

Runs the (reduced) VGG16-family spectral CNN: offline kernel transform +
pruning, Alg-1 dataflow plan (FPGA model), Alg-1-on-TPU fused-kernel
autotune, Alg-2 schedules, then batched inference through the selected
backend, validating the spectral path against the dense spatial oracle.

  PYTHONPATH=src python examples/spectral_cnn_inference.py [--full]
      [--backend einsum|pallas_staged|pallas_fused]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import vgg16_spectral
from repro.core import autotune, optimizer, scheduler
from repro.models import cnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 224x224 VGG16 (slow on CPU)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--backend", default="einsum", choices=cnn.BACKENDS,
                    help="conv-stack implementation (pallas_* run "
                    "interpret-mode off-TPU)")
    args = ap.parse_args()
    cfg = vgg16_spectral.CONFIG if args.full else vgg16_spectral.SMOKE

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    print(f"[1/5] transform + prune kernels (K={cfg.fft_size}, "
          f"alpha={cfg.alpha})")
    sks = cnn.transform_kernels(params, cfg)

    print("[2/5] Alg 1 dataflow plan (FPGA cost model)")
    plan = optimizer.optimize(layers=list(cfg.layers)[1:],
                              fft_size=cfg.fft_size, alpha=cfg.alpha,
                              arch_candidates=[(9, 64)])
    print(f"      max layer bandwidth {plan.bw_max_gbps:.2f} GB/s, "
          f"total transfers {plan.total_transfers_words / 1e6:.1f} Mwords")

    print("[3/5] Alg 1 on TPU: fused-kernel flow + block autotune")
    tuning = autotune.autotune_network(cfg.layers, cfg.fft_size, cfg.alpha,
                                       batch=args.batch)
    for name in list(tuning)[:4]:
        tn = tuning[name]
        print(f"      {name}: {tn.flow} bn={tn.block_n} bm={tn.block_m} "
              f"bp={tn.block_p} ({tn.hbm_bytes / 1e6:.1f} MB HBM/call)")

    print("[4/5] Alg 2 schedules (PE utilization per layer)")
    for layer, sk in list(zip(cfg.layers, sks))[1:4]:
        mu = scheduler.simulate_layer_utilization(
            np.asarray(sk.indices), cfg.fft_size ** 2, r=10,
            n_par=min(64, sk.n_out), channel_sample=2)
        print(f"      {layer.name}: mu = {mu:.1%}")

    print(f"[5/5] inference (backend={args.backend})")
    x = jax.random.normal(key, (args.batch, 3, cfg.image_size,
                                cfg.image_size))
    t0 = time.time()
    logits = cnn.forward_spectral(params, sks, cfg, x,
                                  backend=args.backend, tuning=tuning)
    logits.block_until_ready()
    dt = time.time() - t0
    dense = cnn.forward_spatial(params, cfg, x)
    agree = float(jnp.mean(
        (jnp.argsort(logits, -1)[:, -5:] ==
         jnp.argsort(dense, -1)[:, -5:]).astype(jnp.float32)))
    print(f"      logits {logits.shape} in {dt*1e3:.0f} ms; "
          f"top-5 agreement with dense spatial model: {agree:.0%} "
          f"(alpha={cfg.alpha} pruning changes logits, as in the paper)")


if __name__ == "__main__":
    main()
