"""End-to-end sparse spectral CNN inference (the paper's pipeline).

Runs the (reduced) VGG16-family spectral CNN the compile-once way:
``build_network_plan`` performs ALL offline work in one pass — kernel
transform + per-layer pruning, Alg-2 schedules + active-bin compaction,
Alg-1-on-TPU fused-kernel autotune, fused-epilogue wiring — and the
resulting NetworkPlan is then reused across every inference call, which
is exactly what makes repeated calls hit the jit cache (call 2 is
orders of magnitude faster than call 1).

  PYTHONPATH=src python examples/spectral_cnn_inference.py [--full]
      [--backend einsum|pallas_staged|pallas_fused]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import vgg16_spectral
from repro.core import optimizer
from repro.core.plan import build_network_plan
from repro.models import cnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 224x224 VGG16 (slow on CPU)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--backend", default="einsum", choices=cnn.BACKENDS,
                    help="conv-stack implementation (pallas_* run "
                    "interpret-mode off-TPU)")
    ap.add_argument("--calls", type=int, default=3,
                    help="inference calls against the same plan")
    args = ap.parse_args()
    cfg = vgg16_spectral.CONFIG if args.full else vgg16_spectral.SMOKE

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)

    print("[1/4] Alg 1 dataflow plan (FPGA cost model, paper baseline)")
    plan_fpga = optimizer.optimize(layers=list(cfg.layers)[1:],
                                   fft_size=cfg.fft_size,
                                   alpha=float(jnp.asarray(cfg.alpha).mean())
                                   if not isinstance(cfg.alpha, (int, float))
                                   else cfg.alpha,
                                   arch_candidates=[(9, 64)])
    print(f"      max layer bandwidth {plan_fpga.bw_max_gbps:.2f} GB/s, "
          f"total transfers {plan_fpga.total_transfers_words / 1e6:.1f} "
          "Mwords")

    print("[2/4] build NetworkPlan ONCE (prune + Alg 2 + compaction + "
          "Alg-1-on-TPU autotune + epilogue wiring)")
    t0 = time.time()
    plan = build_network_plan(params, cfg, batch=args.batch)
    print(f"      built in {time.time() - t0:.2f}s "
          f"(K={cfg.fft_size}, alpha={cfg.alpha})")

    print("[3/4] per-layer plan: flow / Hadamard mode / input mode / "
          "nnz / active bins / Alg-2 cycles")
    print(f"      {'layer':>9} {'flow':>18} {'hadamard':>9} {'input':>8} "
          f"{'blocks':>12} {'nnz':>4} {'Fa':>3} {'cycles':>6} {'mu':>6}")
    for row in plan.summary():
        blocks = f"{row['block_n']}/{row['block_m']}/{row['block_p']}"
        mu = ("  --" if row["pe_utilization"] is None
              else f"{row['pe_utilization']:.1%}")
        cyc = row["schedule_cycles"] if row["schedule_cycles"] else "--"
        print(f"      {row['layer']:>9} {row['flow']:>18} "
              f"{row['hadamard']:>9} {row['input_mode']:>8} {blocks:>12} "
              f"{row['nnz']:>4} {row['active_bins']:>3} {cyc!s:>6} {mu:>6}")

    print(f"[4/4] inference x{args.calls} reusing the SAME plan "
          f"(backend={args.backend})")
    x = jax.random.normal(key, (args.batch, 3, cfg.image_size,
                                cfg.image_size))
    logits = None
    for i in range(args.calls):
        t0 = time.time()
        logits = cnn.forward_spectral(params, plan, x,
                                      backend=args.backend)
        logits.block_until_ready()
        note = " (includes jit compile)" if i == 0 else " (jit cache hit)"
        print(f"      call {i + 1}: {(time.time() - t0) * 1e3:7.0f} ms"
              f"{note}")
    dense = cnn.forward_spatial(params, cfg, x)
    agree = float(jnp.mean(
        (jnp.argsort(logits, -1)[:, -5:] ==
         jnp.argsort(dense, -1)[:, -5:]).astype(jnp.float32)))
    print(f"      logits {logits.shape}; top-5 agreement with dense "
          f"spatial model: {agree:.0%} (alpha={cfg.alpha} pruning changes "
          "logits, as in the paper)")


if __name__ == "__main__":
    main()
