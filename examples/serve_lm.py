"""End-to-end serving driver: the FULL smollm-135m with batched requests.

Continuous-batching greedy decoding on CPU — the 'serve a small model
with batched requests' end-to-end deliverable.  Reports per-tick decode
latency (the paper's figure of merit is single-stream latency).

  PYTHONPATH=src python examples/serve_lm.py [--smoke] [--seed N]
      [--json OUT]

``--seed`` drives model init and the synthetic prompts; ``--json``
emits the drained-run stats ('-' for stdout) so CI can gate on them
deterministically.
"""

import argparse
import json

import numpy as np

from repro.launch.serve import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (fast CI)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds model init + synthetic prompts")
    ap.add_argument("--json", default=None,
                    help="write drained-run stats JSON ('-' = stdout)")
    args = ap.parse_args()

    srv = Server("smollm-135m", slots=args.slots, max_len=128,
                 config_set="smoke" if args.smoke else "full",
                 seed=args.seed)
    n_params = sum(x.size for x in
                   __import__("jax").tree.leaves(srv.params))
    print(f"[serve] model {srv.cfg.name} ({n_params/1e6:.0f}M params), "
          f"{args.slots} slots, {args.requests} requests")

    rng = np.random.default_rng(args.seed)
    done = []
    for rid in range(args.requests):
        prompt = rng.integers(1, srv.cfg.vocab, size=6).astype(np.int32)
        req = Request(rid, prompt, args.new_tokens)
        srv.submit(req)
        done.append(req)
    stats = srv.run_until_drained()
    for req in done[:3]:
        print(f"  req {req.rid}: prompt {req.prompt[:4].tolist()}... -> "
              f"{req.out[:8]}...")
    print(f"[serve] drained in {stats['ticks']} ticks | per-tick decode "
          f"latency mean {stats['mean_tick_ms']:.1f} ms, "
          f"p95 {stats['p95_tick_ms']:.1f} ms")
    assert all(len(r.out) == args.new_tokens for r in done)
    if args.json:
        payload = json.dumps({"seed": args.seed,
                              "requests": args.requests, **stats},
                             indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")


if __name__ == "__main__":
    main()
