"""Compile-once LayerPlan IR: construction, execution, epilogue fusion.

Covers the PR-3 tentpole (core/plan.py): per-layer alpha threading,
Alg-2 active-bin compaction feeding the fused kernel, sparsity-aware
autotuning, the bias+ReLU epilogue inside the kernel flush, and the
compile-once property (nothing is re-derived inside the forward pass).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import vgg16_spectral
from repro.core import autotune, dataflow as df
from repro.core import scheduler as sch
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.core.plan import EpilogueSpec, build_network_plan
from repro.kernels.fused_spectral_conv import (execute_layer_plan,
                                               fused_spectral_conv2d)
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _plan(cfg, batch=1, **kw):
    params = cnn.init(KEY, cfg)
    return params, build_network_plan(params, cfg, batch=batch, **kw)


class TestConstruction:
    def test_layer_plans_complete(self):
        cfg = vgg16_spectral.SMOKE
        params, plan = _plan(cfg)
        assert len(plan.layers) == len(cfg.layers)
        for lp, layer in zip(plan.layers, cfg.layers):
            assert lp.layer.name == layer.name
            k2 = cfg.fft_size ** 2
            fa = lp.wr.shape[0]
            assert lp.wr.shape == (fa, layer.c_out, layer.c_in)
            assert lp.dfr.shape == (fa, k2)
            assert lp.dvr.shape == (lp.geo.tile ** 2, fa)
            assert lp.bias.shape == (1, layer.c_out)
            assert lp.epilogue.pool == (layer.name in cfg.pool_after)
            if lp.active is not None:
                assert len(lp.active) % 8 == 0 and len(lp.active) < k2
            assert lp.schedule_cycles is not None
            assert 0.0 < lp.pe_utilization <= 1.0

    def test_per_layer_alpha_threads_through(self):
        alphas = tuple([1.0, 2.0] + [4.0] * 11)
        cfg = dataclasses.replace(vgg16_spectral.SMOKE, alpha=alphas)
        _, plan = _plan(cfg)
        k2 = cfg.fft_size ** 2
        for lp, a in zip(plan.layers, alphas):
            assert lp.alpha == a
            assert lp.kernels.nnz == int(round(k2 / a))

    def test_per_layer_alpha_wrong_length_raises(self):
        cfg = dataclasses.replace(vgg16_spectral.SMOKE, alpha=(4.0, 2.0))
        with pytest.raises(ValueError):
            _plan(cfg)

    def test_plan_is_hardware_safe(self):
        cfg = vgg16_spectral.SMOKE
        _, plan = _plan(cfg)
        for lp in plan.layers:
            tn = lp.tuning
            if tn.flow == "weight_stationary":
                assert tn.block_p >= lp.layer.tiles(cfg.fft_size)
            if tn.flow == "input_stationary":
                assert tn.block_n >= lp.layer.c_out


class TestCompileOnce:
    def test_forward_never_rederives_plan_state(self, monkeypatch):
        """The acceptance claim 'plan construction happens once': after
        the plan is built, pruning / scheduling / autotune / geometry
        must never run again — forwards only execute precomputed state."""
        cfg = vgg16_spectral.SMOKE
        params, plan = _plan(cfg, batch=2)

        def boom(name):
            def _raise(*a, **k):
                raise AssertionError(f"{name} called inside forward")
            return _raise

        monkeypatch.setattr(sp, "prune_magnitude", boom("prune_magnitude"))
        monkeypatch.setattr(sp, "compacted_active_bins",
                            boom("compacted_active_bins"))
        monkeypatch.setattr(sch, "schedule_exact_cover",
                            boom("schedule_exact_cover"))
        monkeypatch.setattr(autotune, "autotune_layer",
                            boom("autotune_layer"))
        monkeypatch.setattr(spec, "make_geometry", boom("make_geometry"))

        x = jax.random.normal(KEY, (2, 3, cfg.image_size, cfg.image_size))
        for backend in cnn.BACKENDS:
            out = cnn.forward_spectral(params, plan, x, backend=backend)
            assert bool(jnp.isfinite(out).all())

    def test_plan_input_mismatch_raises(self):
        cfg = vgg16_spectral.SMOKE
        params, plan = _plan(cfg)
        bad = jax.random.normal(KEY, (1, 3, cfg.image_size // 2,
                                      cfg.image_size // 2))
        with pytest.raises(ValueError, match="plan/input mismatch"):
            cnn.forward_spectral(params, plan, bad)


class TestFusedEpilogue:
    """bias+ReLU inside the kernel flush == relu(conv + b) oracle."""

    @pytest.mark.parametrize("alpha", [2.0, 4.0, 16.0])
    def test_execute_layer_plan_matches_epilogue_oracle(self, alpha):
        cfg = dataclasses.replace(vgg16_spectral.SMOKE, alpha=alpha)
        params = cnn.init(KEY, cfg)
        # non-zero biases so the epilogue actually has work to do
        for i, conv in enumerate(params["convs"]):
            conv["b"] = 0.1 * jax.random.normal(
                jax.random.PRNGKey(i), conv["b"].shape)
        plan = build_network_plan(params, cfg, batch=1)
        lp = plan.layers[2]
        x = jax.random.normal(jax.random.PRNGKey(9),
                              (1, lp.layer.c_in, lp.layer.h_in,
                               lp.layer.w_in))
        y = execute_layer_plan(x, lp)
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, lp.kernels, lp.geo)
            + lp.bias[0][None, :, None, None])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        # ReLU really fired: no negatives survive
        assert float(jnp.min(y)) >= 0.0

    def test_vgg16_shaped_layer_parity(self):
        """Acceptance: fused-sparse backend == sparse-aware einsum oracle
        to <= 1e-4 on a VGG16-shaped layer, bias+ReLU in-kernel."""
        self._layer_parity(df.ConvLayer("conv3_1", 128, 256, 56, 56))

    @pytest.mark.slow
    @pytest.mark.parametrize("layer", [
        df.ConvLayer("conv4_3", 512, 512, 28, 28),
        df.ConvLayer("conv5_1", 512, 512, 14, 14),
    ], ids=lambda l: l.name)
    def test_vgg16_shaped_layer_parity_full(self, layer):
        self._layer_parity(layer)

    @staticmethod
    def _layer_parity(layer, alpha=4.0):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal(
            (1, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        wk = jnp.asarray(0.05 * rng.standard_normal(
            (layer.c_out, layer.c_in, 3, 3)), jnp.float32)
        b = jnp.asarray(rng.standard_normal(layer.c_out), jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, 3, 8)
        sk = sp.prune_magnitude(spec.spectral_kernel(wk, 8), alpha)
        tn = autotune.autotune_layer(layer, 8, alpha)
        y = fused_spectral_conv2d(x, sk, geo, bias=b, relu=True,
                                  interpret=True, **tn.kwargs())
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(y - y_ref).max())
        assert err <= 1e-4, (layer.name, err)


class TestSparsityAwareCost:
    def test_kernel_bytes_scale_with_alpha(self):
        """Acceptance: analytic kernel-HBM bytes of kernel-reuse layers
        drop by ~alpha vs the dense fused path."""
        for layer in df.VGG16_LAYERS:
            dense = df.tpu_fused_flow_cost(layer, 8, 1.0, 64, 128, 64,
                                           "weight_stationary")
            sparse4 = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                             "weight_stationary")
            ratio = dense["kernel_hbm_bytes"] / sparse4["kernel_hbm_bytes"]
            assert abs(ratio - 4.0) < 1e-6

    def test_active_bins_shrink_vmem_and_flops(self):
        layer = df.VGG16_LAYERS[5]
        full = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                      "output_stationary", active_bins=64)
        half = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                      "output_stationary", active_bins=32)
        assert half["vmem_bytes"] < full["vmem_bytes"]
        assert half["flops"] < full["flops"]

    def test_autotune_consumes_active_bins(self):
        layer = df.VGG16_LAYERS[3]
        tn = autotune.autotune_layer(layer, 8, 4.0, active_bins=32)
        c = df.tpu_fused_flow_cost(layer, 8, 4.0, tn.block_n, tn.block_p,
                                   tn.block_m, tn.flow, active_bins=32)
        assert tn.vmem_bytes == c["vmem_bytes"]


class TestScheduleDrivenCompaction:
    def test_schedule_bins_equal_mask_union(self):
        """Exact cover => the bins the schedule touches are exactly the
        union of non-zero kernel bins — the set the plan compacts to."""
        rng = np.random.default_rng(0)
        wf = (rng.standard_normal((16, 4, 8, 8))
              + 1j * rng.standard_normal((16, 4, 8, 8)))
        sk = sp.prune_magnitude(jnp.asarray(wf), 16.0)
        idx = np.asarray(sk.indices)
        vals = np.asarray(sk.values).reshape(16, 4, 64)
        tables = []
        for m in range(4):
            s = sch.schedule_exact_cover(idx[:, m, :], 64, r=8)
            tables.append(sch.build_tables(s, vals[:, m, :], idx[:, m, :]))
        bins = sch.active_bins_from_tables(tables)
        np.testing.assert_array_equal(bins, np.asarray(sk.active_bins))

    def test_dense_fallback_when_nnz_near_k2(self):
        rng = np.random.default_rng(1)
        wf = jnp.asarray(rng.standard_normal((4, 4, 8, 8))
                         + 1j * rng.standard_normal((4, 4, 8, 8)))
        sk = sp.prune_magnitude(wf, 1.0)
        assert sp.compacted_active_bins(sk) is None

    def test_epilogue_spec_defaults(self):
        e = EpilogueSpec()
        assert e.bias and e.relu and not e.pool
