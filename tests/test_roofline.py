"""Roofline machinery: trip-count-aware HLO parsing vs analytic counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis, hlo_parse


def test_scan_flops_counted_with_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    a = hlo_parse.parse(comp.as_text(), 1)
    expected = 2 * 9 * 64 ** 3
    np.testing.assert_allclose(a.dot_flops, expected, rtol=1e-6)
    # raw cost_analysis undercounts by the trip count — the bug this
    # module exists to fix
    raw = analysis.cost_analysis_dict(comp)["flops"]
    assert raw < expected / 4


def test_nested_scan_flops():
    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    a = hlo_parse.parse(comp.as_text(), 1)
    np.testing.assert_allclose(a.dot_flops, 2 * 15 * 32 ** 3, rtol=1e-6)


def test_unrolled_matches_scanned():
    """Property: dot FLOPs parsed from the scanned program == FLOPs
    parsed from the equivalent unrolled program."""
    ws_v = jnp.stack([jnp.eye(16)] * 4)

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(4):
            x = x @ ws[i]
        return x

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 16, 16), jnp.float32)
    a1 = hlo_parse.parse(jax.jit(scanned).lower(x, ws).compile().as_text(),
                         1)
    a2 = hlo_parse.parse(jax.jit(unrolled).lower(x, ws).compile().as_text(),
                         1)
    np.testing.assert_allclose(a1.dot_flops, a2.dot_flops, rtol=1e-6)


def test_collective_parse_synthetic():
    hlo = """
ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256]{1,0} parameter(0)
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%a), channel_id=1, replica_groups=[16,16]<=[256], to_apply=%add
  ROOT %all-gather.2 = f32[128,256]{1,0} all-gather(%all-reduce.1), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
}
"""
    a = hlo_parse.parse(hlo, 256)
    assert a.collectives.counts == {"all-reduce": 1, "all-gather": 1}
    bytes_ = 128 * 256 * 4
    np.testing.assert_allclose(
        a.collectives.operand_bytes["all-reduce"], bytes_)
    np.testing.assert_allclose(
        a.collectives.operand_bytes["all-gather"], bytes_ / 16)
    wire = 2 * bytes_ * 15 / 16 + bytes_ * 15 / 16
    np.testing.assert_allclose(a.collectives.wire_bytes_per_chip, wire)


def test_collective_inside_loop_multiplied():
    hlo = """
%body (t: (s32[], f32[64])) -> (s32[], f32[64]) {
  %t = (s32[], f32[64]{0}) parameter(0)
  %g = f32[64]{0} get-tuple-element(%t), index=1
  %all-reduce.9 = f32[64]{0} all-reduce(%g), replica_groups={{0,1}}, to_apply=%add
  ROOT %tup = (s32[], f32[64]{0}) tuple(%g, %all-reduce.9)
}
%cond (t: (s32[], f32[64])) -> pred[] {
  %t = (s32[], f32[64]{0}) parameter(0)
  ROOT %lt = pred[] compare(%t, %t), direction=LT
}
ENTRY %main (x: f32[64]) -> f32[64] {
  %x = f32[64]{0} parameter(0)
  %tup = (s32[], f32[64]{0}) tuple(%x, %x)
  %w = (s32[], f32[64]{0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    a = hlo_parse.parse(hlo, 2)
    assert a.collectives.counts["all-reduce"] == 12
    np.testing.assert_allclose(
        a.collectives.operand_bytes["all-reduce"], 12 * 64 * 4)


def test_roofline_terms_and_bottleneck():
    coll = analysis.CollectiveStats({}, {}, wire_bytes_per_chip=1e9)
    cost = {"flops": 1e12, "bytes accessed": 1e9}
    r = analysis.roofline_terms(cost, coll, 256, model_flops_total=2.56e14)
    np.testing.assert_allclose(r.compute_s, 1e12 / analysis.PEAK_FLOPS)
    np.testing.assert_allclose(r.memory_s, 1e9 / analysis.HBM_BW)
    np.testing.assert_allclose(
        r.collective_s, 1e9 / (analysis.ICI_LINKS * analysis.ICI_BW))
    assert r.bottleneck == "collective"
    np.testing.assert_allclose(r.useful_flops_frac, 1.0)


def test_model_flops_definitions():
    from repro import configs
    cfg = configs.get_config("kimi-k2-1t-a32b")
    train = analysis.model_flops(cfg, configs.SHAPES["train_4k"])
    # 6 * N_active * D
    expected = 6.0 * cfg.active_param_count() * 4096 * 256
    np.testing.assert_allclose(train, expected)
    dec = analysis.model_flops(cfg, configs.SHAPES["decode_32k"])
    np.testing.assert_allclose(dec, 2.0 * cfg.active_param_count() * 128)
