"""Element-granular scheduled sparse Hadamard inside the fused kernel.

Covers the PR-4 tentpole: Alg-2 INDEX/VALUE tables compiled per layer
(``scheduler.compile_layer_tables``), executed inside the single
pallas_call (``fused_spectral_pipeline_scheduled``), selected per layer
by the mode-aware cost model / autotuner, and precompiled into the
LayerPlan — built once, reused forever (monkeypatch-enforced, same
style as tests/test_plan.py).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import vgg16_spectral
from repro.core import autotune, dataflow as df
from repro.core import scheduler as sch
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.core.plan import build_network_plan
from repro.kernels.fused_spectral_conv import (
    FLOWS, fused_spectral_conv2d_scheduled)
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _layer_case(h=13, w=12, cin=4, cout=6, alpha=4.0, seed=3):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, cin, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((cout, cin, 3, 3)), jnp.float32)
    b = jnp.asarray(0.1 * rng.standard_normal(cout), jnp.float32)
    geo = spec.make_geometry(h, w, 3, 8)
    sk = sp.prune_magnitude(spec.spectral_kernel(wk, 8), alpha)
    return x, sk, b, geo


class TestScheduledKernelParity:
    """Scheduled-fused == masked-einsum oracle, all flows, <= 1e-5."""

    @pytest.mark.parametrize("flow", FLOWS)
    @pytest.mark.parametrize("alpha", [2.0, 4.0, 8.0])
    def test_vs_einsum_oracle(self, flow, alpha):
        x, sk, b, geo = _layer_case(alpha=alpha)
        y = fused_spectral_conv2d_scheduled(
            x, sk, geo, n_par=4, r=6, flow=flow, block_m=2, block_p=8,
            bias=b, relu=True)
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(y - y_ref).max())
        assert err <= 1e-5, (flow, alpha, err)

    def test_flows_agree(self):
        x, sk, b, geo = _layer_case(alpha=4.0, seed=5)
        outs = [fused_spectral_conv2d_scheduled(
            x, sk, geo, n_par=4, r=6, flow=fl, block_m=2, block_p=8,
            bias=b) for fl in FLOWS]
        for y in outs[1:]:
            np.testing.assert_allclose(np.asarray(y), np.asarray(outs[0]),
                                       atol=1e-5, rtol=1e-5)

    def test_group_remainder_and_oversized_blocks(self):
        """c_out not a multiple of n_par; blocks larger than dims."""
        x, sk, b, geo = _layer_case(cout=7, alpha=4.0, seed=9)
        y = fused_spectral_conv2d_scheduled(
            x, sk, geo, n_par=3, r=6, block_m=512, block_p=512, bias=b)
        y_ref = (spec.spectral_conv2d_pretransformed(x, sk, geo)
                 + b[None, :, None, None])
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5, rtol=1e-5)


class TestScheduledPlanParity:
    """Plan-level: forward_spectral executes the scheduled datapath."""

    def test_per_layer_alphas_with_dense_fallback(self):
        """Acceptance: scheduled-fused == einsum oracle <= 1e-5 across
        per-layer alphas, including the alpha=1 layer that must fall
        back to the dense plane datapath."""
        alphas = tuple([1.0, 2.0] + [4.0] * 11)
        cfg = dataclasses.replace(vgg16_spectral.SMOKE, alpha=alphas)
        params = cnn.init(KEY, cfg)
        for i, conv in enumerate(params["convs"]):
            conv["b"] = 0.1 * jax.random.normal(
                jax.random.PRNGKey(i), conv["b"].shape)
        plan = build_network_plan(params, cfg, batch=1,
                                  hadamard="scheduled")
        assert plan.layers[0].hadamard == "dense"     # alpha=1 fallback
        assert plan.layers[0].tables is None
        assert all(lp.hadamard == "scheduled" and lp.tables is not None
                   for lp in plan.layers[1:])
        x = jax.random.normal(KEY, (1, 3, cfg.image_size, cfg.image_size))
        ref = cnn.forward_spectral(params, plan, x, backend="einsum")
        out = cnn.forward_spectral(params, plan, x,
                                   backend="pallas_fused")
        err = float(jnp.abs(out - ref).max())
        assert err <= 1e-5, err

    def test_auto_mode_plan_runs_and_records_modes(self):
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(KEY, cfg)
        plan = build_network_plan(params, cfg, batch=1)   # hadamard=auto
        for lp in plan.layers:
            assert lp.hadamard in df.HADAMARD_MODES
            assert (lp.tables is not None) == (lp.hadamard == "scheduled")
            assert lp.hadamard == lp.tuning.hadamard
        x = jax.random.normal(KEY, (1, 3, cfg.image_size, cfg.image_size))
        ref = cnn.forward_spectral(params, plan, x, backend="einsum")
        out = cnn.forward_spectral(params, plan, x,
                                   backend="pallas_fused")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_exact_schedule_stats_for_scheduled_layers(self):
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(KEY, cfg)
        plan = build_network_plan(params, cfg, batch=1,
                                  hadamard="scheduled")
        for lp in plan.layers:
            if lp.hadamard != "scheduled":
                continue
            assert lp.schedule_cycles is not None
            assert 0.0 < lp.pe_utilization <= 1.0
            assert lp.stats()["table_bytes"] == lp.tables.nbytes > 0


class TestTablesBuiltOnce:
    """Satellite: scheduled tables are compiled at plan-build time and
    REUSED — no scheduling work ever runs inside a forward pass."""

    def test_forward_never_recompiles_tables(self, monkeypatch):
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(KEY, cfg)
        plan = build_network_plan(params, cfg, batch=2,
                                  hadamard="scheduled")
        assert any(lp.hadamard == "scheduled" for lp in plan.layers)

        def boom(name):
            def _raise(*a, **k):
                raise AssertionError(f"{name} called inside forward")
            return _raise

        monkeypatch.setattr(sch, "compile_layer_tables",
                            boom("compile_layer_tables"))
        monkeypatch.setattr(sch, "schedule_exact_cover",
                            boom("schedule_exact_cover"))
        monkeypatch.setattr(sch, "build_tables", boom("build_tables"))

        x = jax.random.normal(KEY, (2, 3, cfg.image_size, cfg.image_size))
        for _ in range(2):                 # second call: jit cache hit
            out = cnn.forward_spectral(params, plan, x,
                                       backend="pallas_fused")
            assert bool(jnp.isfinite(out).all())


class TestCompileLayerTables:
    def test_shapes_padding_and_remap(self):
        rng = np.random.default_rng(0)
        wf = jnp.asarray(rng.standard_normal((6, 5, 8, 8))
                         + 1j * rng.standard_normal((6, 5, 8, 8)))
        sk = sp.prune_magnitude(wf, 16.0)
        active = sp.compacted_active_bins(sk)
        assert active is not None          # high alpha leaves empty bins
        vals = np.asarray(sk.values).reshape(6, 5, 64)
        lt = sch.compile_layer_tables(np.asarray(sk.indices), vals, 64,
                                      r=6, n_par=4, active=active,
                                      m_pad_to=3)
        assert lt.n_groups == 2 and lt.n_par == 4      # ceil(6/4)
        assert lt.m_pad == 6                           # 5 -> pad_to 3
        assert lt.idx.max() < len(active)              # compacted coords
        assert np.all(lt.vr[:, 5:] == 0)               # padded channels
        assert 0.0 < lt.pe_utilization <= 1.0
        assert lt.total_cycles > 0
        # exact cover: every non-zero weight appears exactly once
        got = np.sort(np.abs(lt.vr + 1j * lt.vi)[np.abs(
            lt.vr + 1j * lt.vi) > 0])
        want = np.sort(np.abs(vals)[np.abs(vals) > 0])
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_dense_coordinates_when_no_active_set(self):
        rng = np.random.default_rng(1)
        wf = jnp.asarray(rng.standard_normal((4, 2, 8, 8))
                         + 1j * rng.standard_normal((4, 2, 8, 8)))
        sk = sp.prune_magnitude(wf, 4.0)
        vals = np.asarray(sk.values).reshape(4, 2, 64)
        lt = sch.compile_layer_tables(np.asarray(sk.indices), vals, 64,
                                      r=8, n_par=4, active=None)
        assert lt.idx.max() < 64


class TestModeAwareCostModel:
    def test_scheduled_kernel_bytes_le_bin_on_vgg16(self):
        """Acceptance: scheduled kernel-operand HBM bytes <= the
        bin-compacted plane stream on every sparse VGG16 layer."""
        for layer in df.VGG16_LAYERS:
            kw = dict(batch=1, active_bins=None)
            bin_c = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                           "output_stationary",
                                           hadamard="bin", **kw)
            sched = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                           "output_stationary",
                                           hadamard="scheduled", **kw)
            assert (sched["kernel_hbm_bytes"]
                    <= bin_c["kernel_hbm_bytes"]), layer.name

    def test_mode_flops_ordering(self):
        """bin MACs scale with Fa <= K^2 <= dense; scheduled counts the
        HONEST one-hot realization, above the paper's element count."""
        layer = df.VGG16_LAYERS[5]
        c = {m: df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                       "output_stationary", hadamard=m,
                                       active_bins=56)
             for m in df.HADAMARD_MODES}
        assert c["bin"]["had_flops"] < c["dense"]["had_flops"]
        t = layer.tiles(8)
        paper_elems = 8 * t * 16 * layer.c_in * layer.c_out
        assert c["scheduled"]["had_flops"] > paper_elems

    def test_legacy_default_unchanged(self):
        layer = df.VGG16_LAYERS[3]
        legacy = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                        "weight_stationary")
        nnz = 16
        want = layer.c_out * layer.c_in * nnz * 2 * 4
        assert legacy["kernel_hbm_bytes"] == want

    def test_bad_mode_raises(self):
        with pytest.raises(ValueError, match="hadamard"):
            df.tpu_fused_flow_cost(df.VGG16_LAYERS[0], 8, 4.0, 64, 128,
                                   64, "output_stationary",
                                   hadamard="nope")


class TestModeAwareAutotune:
    def test_mode_axis_returns_a_searched_mode(self):
        layer = df.VGG16_LAYERS[-1]
        tn = autotune.autotune_layer(layer, 8, 4.0,
                                     hadamard_modes=("bin", "scheduled"))
        assert tn.hadamard in ("bin", "scheduled")

    def test_legacy_call_has_no_mode(self):
        tn = autotune.autotune_layer(df.VGG16_LAYERS[3], 8, 4.0)
        assert tn.hadamard is None

    def test_late_layers_prefer_scheduled_early_prefer_planes(self):
        """The per-layer flexibility story: kernel-bound late layers
        pick the table stream, activation-bound early layers keep the
        plane GEMM."""
        modes = {}
        for layer in df.VGG16_LAYERS:
            tn = autotune.autotune_layer(
                layer, 8, 4.0, hadamard_modes=("bin", "scheduled"))
            modes[layer.name] = tn.hadamard
        assert modes["conv5_1"] == "scheduled"
        assert modes["conv1_2"] == "bin"
