"""Int8 KV-cache quantization: numerics + memory accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, attention as attn


def _decode_chain(cfg, params, toks, steps=10):
    cache = api.init_cache(cfg, toks.shape[0], 16)
    outs = []
    for t in range(steps):
        lg, cache = api.decode(params, cfg, toks[:, t:t + 1], cache,
                               jnp.int32(t))
        outs.append(lg)
    return jnp.concatenate(outs, 1), cache


@pytest.mark.parametrize("arch", ["qwen3-8b", "h2o-danube-1.8b"])
def test_quant_decode_close_to_fp(arch):
    cfg = configs.get_smoke_config(arch)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    fp, _ = _decode_chain(cfg, params, toks)
    q, cache = _decode_chain(cfg.replace(kv_quant=True), params, toks)
    p_fp = jax.nn.softmax(fp.astype(jnp.float32), -1)
    p_q = jax.nn.softmax(q.astype(jnp.float32), -1)
    assert float(jnp.abs(p_fp - p_q).max()) < 0.02
    assert float((fp.argmax(-1) == q.argmax(-1)).mean()) > 0.9


def test_quant_cache_halves_bytes():
    cfg = configs.get_smoke_config("qwen3-8b")
    fp = api.init_cache(cfg, 2, 64)
    qt = api.init_cache(cfg.replace(kv_quant=True), 2, 64)
    b_fp = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(fp))
    b_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(qt))
    assert b_q < 0.7 * b_fp


def test_quantize_rows_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 2, 8, 32)) * 5, jnp.float32)
    q, s = attn._quantize_rows(x)
    back = q.astype(jnp.float32) * s
    err = jnp.abs(back - x)
    assert float((err <= s / 2 + 1e-6).all())


def test_quant_ring_buffer_swa():
    """Quantized SWA ring cache stays consistent past the window."""
    cfg = configs.get_smoke_config("h2o-danube-1.8b").replace(
        window=4, kv_quant=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)
    full = api.forward(params, cfg, {"tokens": toks})
    q, _ = _decode_chain(cfg, params, toks, steps=12)
    p_full = jax.nn.softmax(full[0, -1].astype(jnp.float32))
    p_q = jax.nn.softmax(q[0, -1].astype(jnp.float32))
    assert float(jnp.abs(p_full - p_q).max()) < 0.02
