"""Batch as an Alg-1 axis — the PR-8 repricing + manual-DMA kernels.

The fused kernel's manual-DMA psum accumulators make every
flow x input-mode combination legal at batch > 1, and the cost model
amortizes per-call kernel bytes over the batch, so the tuner's choice
is a real function of the serving bucket.  Covered here:

  * batch parity matrix: B in {1, 2, 4, 8} x 3 flows x 3 Hadamard
    modes stays <= 1e-5 of the einsum oracle (with the fused
    bias+ReLU epilogue) on the in-kernel halo path;
  * amortization monotonicity: per-image predicted cost is
    non-increasing along the doubling chain B in {1, 2, 4, 8} for
    every VGG16 layer — provable because ``_layer_candidates`` seeds
    the p-block axis with the doubling multiples of the per-image
    tile count, so every batch-B winner is reachable at batch 2B
    (property-based variant runs when hypothesis is installed);
  * the bucket axis is live: B=1 and B=8 tunings differ on at least
    one VGG16 layer (empirically: the conv5 block flips from
    output- to input-stationary once kernel bytes amortize).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import autotune, dataflow as df
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.kernels.fused_spectral_conv import (
    FLOWS, fused_spectral_conv2d, fused_spectral_conv2d_scheduled)

BATCHES = (1, 2, 4, 8)


def _case(batch, h=12, w=11, cin=3, cout=4, k=3, K=8, seed=7):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, cin, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((cout, cin, k, k)), jnp.float32)
    b = jnp.asarray(0.1 * rng.standard_normal(cout), jnp.float32)
    geo = spec.make_geometry(h, w, k, K)
    return x, wk, b, geo


class TestBatchParityMatrix:
    """fused(halo) == oracle at every bucket, flows x Hadamard modes."""

    @pytest.mark.parametrize("batch", BATCHES)
    @pytest.mark.parametrize("flow", FLOWS)
    @pytest.mark.parametrize("mode", df.HADAMARD_MODES)
    def test_matrix(self, batch, flow, mode):
        x, wk, b, geo = _case(batch)
        sk = sp.prune_magnitude(spec.spectral_kernel(wk, 8), 4.0)
        if mode == "scheduled":
            y = fused_spectral_conv2d_scheduled(
                x, sk, geo, n_par=4, r=6, flow=flow, block_m=2,
                block_p=7, bias=b, relu=True, input_mode="halo")
        else:
            w_f = sk.values if mode == "dense" else sk
            y = fused_spectral_conv2d(
                x, w_f, geo, flow=flow, block_n=4, block_m=2,
                block_p=7, bias=b, relu=True, input_mode="halo")
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(y - y_ref).max())
        assert err <= 1e-5, (batch, flow, mode, err)


def _best_per_image_s(layer, batch):
    tn = autotune.autotune_layer(layer, 8, 4.0, batch=batch,
                                 input_modes=df.INPUT_MODES)
    c = df.tpu_fused_flow_cost(layer, 8, 4.0, tn.block_n, tn.block_p,
                               tn.block_m, tn.flow, batch=batch,
                               input_mode=tn.input_mode or "windowed")
    return c["per_image_s"]


class TestAmortizationMonotone:
    """per-image predicted cost never rises along the doubling chain.

    Proof sketch the code must uphold: ``_layer_candidates`` always
    offers full-T p blocks for every doubling multiple of the
    per-image tile count, so any config priced at batch B is
    reachable at batch 2B, where the same blocks cost at most the sum
    of two batch-B calls (grid ceilings only merge) — hence the best
    per-image cost cannot increase.
    """

    @pytest.mark.parametrize(
        "layer", df.VGG16_LAYERS, ids=[l.name for l in df.VGG16_LAYERS])
    def test_vgg16_doubling_chain(self, layer):
        costs = [_best_per_image_s(layer, b) for b in BATCHES]
        for b_prev, b_next, c_prev, c_next in zip(
                BATCHES, BATCHES[1:], costs, costs[1:]):
            assert c_next <= c_prev * (1 + 1e-9), (
                layer.name, b_prev, b_next, c_prev, c_next)

    @settings(max_examples=25, deadline=None)
    @given(cin=st.sampled_from([3, 16, 64]),
           cout=st.sampled_from([8, 64, 256]),
           hw=st.sampled_from([14, 28, 56]))
    def test_random_layers(self, cin, cout, hw):
        layer = df.ConvLayer(f"rand{cin}x{cout}x{hw}", cin, cout, hw, hw)
        costs = [_best_per_image_s(layer, b) for b in BATCHES]
        for c_prev, c_next in zip(costs, costs[1:]):
            assert c_next <= c_prev * (1 + 1e-9), (layer.name, costs)

    def test_candidates_include_doubling_multiples(self):
        """The structural fact the proof rests on: at batch B the
        p-block axis offers t_img * 2^i for every 2^i <= B."""
        layer = df.VGG16_LAYERS[5]
        t_img = layer.tiles(8)
        for batch in BATCHES:
            bps = {bp for _, _, _, bp in autotune._layer_candidates(
                layer, 8, batch, autotune.BLOCK_CANDIDATES, True)}
            for i in range(batch.bit_length()):
                want = t_img * (1 << i)
                if want <= t_img * batch:
                    assert want in bps, (batch, want, sorted(bps))


class TestBucketAxisIsLive:
    def test_tuning_differs_between_b1_and_b8(self):
        """Batch must actually steer Alg 1: at least one VGG16 layer
        tunes differently at B=8 than at B=1 (kernel-byte amortization
        flips the conv5 block away from output-stationary)."""
        def key(tn):
            return (tn.flow, tn.block_n, tn.block_m, tn.block_p,
                    tn.input_mode)
        diffs = []
        for layer in df.VGG16_LAYERS:
            t1 = autotune.autotune_layer(layer, 8, 4.0, batch=1,
                                         input_modes=df.INPUT_MODES)
            t8 = autotune.autotune_layer(layer, 8, 4.0, batch=8,
                                         input_modes=df.INPUT_MODES)
            if key(t1) != key(t8):
                diffs.append((layer.name, key(t1), key(t8)))
        assert diffs, "B=1 and B=8 chose identical configs everywhere"

    def test_flow_flips_on_conv5(self):
        """The concrete amortization story from DATAFLOW.md S1b."""
        layer = next(l for l in df.VGG16_LAYERS if l.name == "conv5_1")
        t1 = autotune.autotune_layer(layer, 8, 4.0, batch=1,
                                     input_modes=df.INPUT_MODES)
        t8 = autotune.autotune_layer(layer, 8, 4.0, batch=8,
                                     input_modes=df.INPUT_MODES)
        assert t1.flow != t8.flow, (t1, t8)
