"""Sharded lower+compile inside pytest (8 host devices, subprocess).

The full 512-device matrix runs via ``repro.launch.dryrun``; this test
proves the same machinery (planner -> specs -> jit -> lower -> compile ->
HLO analysis) end to end on a small mesh so CI catches regressions
without the big compile bill.  XLA device count must be set before jax
initializes, hence the subprocess.
"""

import pathlib
import subprocess
import sys
import textwrap

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.distributed import ctx, planner, sharding
    from repro.launch import steps
    from repro.roofline import hlo_parse
    from repro.roofline.analysis import cost_analysis_dict

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    msd = {"data": 2, "model": 4}
    cfg = configs.get_smoke_config("qwen3-8b").replace(remat=True)
    shape = configs.ShapeConfig("t", seq_len=32, global_batch=4,
                                kind="train")
    plan = sharding.ShardingPlan(batch_axes=("data",))
    with mesh, ctx.use(ctx.ShardCtx(("data",))):
        fn, args = steps.cell_lowerable(cfg, shape, mesh, plan)
        compiled = fn.lower(*args).compile()
    cost = cost_analysis_dict(compiled)
    assert cost["flops"] > 0
    a = hlo_parse.parse(compiled.as_text(), 8)
    assert a.dot_flops > cost["flops"], (a.dot_flops, cost["flops"])
    assert a.collectives.wire_bytes_per_chip > 0
    # decode path too
    dshape = configs.ShapeConfig("d", seq_len=64, global_batch=2,
                                 kind="decode")
    with mesh:
        fn, args = steps.cell_lowerable(cfg, dshape, mesh, plan)
        compiled = fn.lower(*args).compile()
    assert cost_analysis_dict(compiled)["flops"] > 0
    print("LOWERING_OK")
""")


def test_sharded_lowering_8_devices():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "LOWERING_OK" in r.stdout
