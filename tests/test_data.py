"""Data pipeline: determinism, host slicing, prefetch."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataConfig, Prefetcher, batch_at


def test_deterministic_by_step():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    a = batch_at(cfg, 7)
    b = batch_at(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_at(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    b = batch_at(cfg, 0)
    # the underlying stream is contiguous: labels[t] == tokens[t+1]
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_slicing_partitions_global_batch():
    """Two hosts together produce exactly the single-host global batch —
    the property that makes host replacement exact."""
    whole = batch_at(DataConfig(vocab=50, seq_len=8, global_batch=4), 3)
    h0 = batch_at(DataConfig(vocab=50, seq_len=8, global_batch=4,
                             host_id=0, n_hosts=2), 3)
    h1 = batch_at(DataConfig(vocab=50, seq_len=8, global_batch=4,
                             host_id=1, n_hosts=2), 3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), whole["tokens"])


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 1000), n_hosts=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 100))
def test_property_hosts_disjoint_and_deterministic(step, n_hosts, seed):
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=8, seed=seed,
                     n_hosts=n_hosts)
    rows = []
    for h in range(n_hosts):
        b = batch_at(DataConfig(vocab=64, seq_len=8, global_batch=8,
                                seed=seed, host_id=h, n_hosts=n_hosts),
                     step)
        assert b["tokens"].shape == (8 // n_hosts, 8)
        rows.append(b["tokens"])
    stacked = np.concatenate(rows)
    again = batch_at(cfg._replace_host(0, 1) if False else DataConfig(
        vocab=64, seq_len=8, global_batch=8, seed=seed), step)
    np.testing.assert_array_equal(stacked, again["tokens"])


def test_frames_variant():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2, frames_dim=32)
    b = batch_at(cfg, 0)
    assert b["frames"].shape == (2, 8, 32)
    assert b["frames"].dtype == np.float32


def test_prefetcher_yields_in_order_and_matches():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=5)
    try:
        for expect in (5, 6, 7):
            step, batch = next(pf)
            assert step == expect
            np.testing.assert_array_equal(batch["tokens"],
                                          batch_at(cfg, expect)["tokens"])
    finally:
        pf.close()


def test_prefetcher_resume_mid_stream():
    """Restarting at step k yields the same batches a continuous run saw
    — checkpoint/restart exactness for the input pipeline."""
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=0)
    seen = {}
    try:
        for _ in range(6):
            s, b = next(pf)
            seen[s] = b["tokens"]
    finally:
        pf.close()
    pf2 = Prefetcher(cfg, start_step=3)
    try:
        s, b = next(pf2)
        assert s == 3
        np.testing.assert_array_equal(b["tokens"], seen[3])
    finally:
        pf2.close()
