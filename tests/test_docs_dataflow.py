"""docs/DATAFLOW.md stays truthful: its per-flow byte formulas are
extracted from the page's ``doc-formulas`` fenced block, executed, and
compared against ``dataflow.tpu_fused_flow_cost`` over layers x flows x
Hadamard modes x input modes.  If the cost model changes without the
document, or vice versa, this fails.
"""

import re
from pathlib import Path

import pytest

from repro.core import dataflow as df
from repro.core import spectral as spec

DOC = Path(__file__).resolve().parent.parent / "docs" / "DATAFLOW.md"

_BLOCK = re.compile(r"```python doc-formulas\n(.*?)```", re.DOTALL)
_BLOCK_ICI = re.compile(r"```python doc-formulas-ici\n(.*?)```",
                        re.DOTALL)


def _doc_namespace() -> dict:
    m = _BLOCK.search(DOC.read_text())
    assert m, "docs/DATAFLOW.md lost its ```python doc-formulas block"
    ns: dict = {}
    exec(compile(m.group(1), str(DOC), "exec"), ns)  # noqa: S102
    for fn in ("input_bytes", "kernel_bytes", "output_bytes",
               "per_image_bytes", "step_seconds"):
        assert fn in ns, f"doc-formulas block lost {fn}()"
    return ns


def _doc_ici_namespace() -> dict:
    m = _BLOCK_ICI.search(DOC.read_text())
    assert m, "docs/DATAFLOW.md lost its ```python doc-formulas-ici " \
              "block (section 8)"
    ns: dict = {}
    exec(compile(m.group(1), str(DOC), "exec"), ns)  # noqa: S102
    for fn in ("ici_bytes", "ici_seconds", "sharded_seconds"):
        assert fn in ns, f"doc-formulas-ici block lost {fn}()"
    return ns


CASES = [(layer, flow, mode, imode, batch)
         for layer in (df.VGG16_LAYERS[1], df.VGG16_LAYERS[5],
                       df.VGG16_LAYERS[-1])
         for flow in df.FLOWS
         for mode in df.HADAMARD_MODES
         for imode in df.INPUT_MODES
         for batch in (1, 8)]


class TestDocFormulasMatchCode:
    ns = _doc_namespace()

    @pytest.mark.parametrize("layer,flow,mode,imode,batch", CASES,
                             ids=[f"{l.name}-{f}-{m}-{i}-b{b}"
                                  for l, f, m, i, b in CASES])
    def test_shares_and_total(self, layer, flow, mode, imode, batch):
        fft, alpha = 8, 4.0
        block_n, block_p, block_m = 64, 128, 64
        step_overhead_s = 1e-4
        c = df.tpu_fused_flow_cost(layer, fft, alpha, block_n, block_p,
                                   block_m, flow, batch=batch,
                                   hadamard=mode, input_mode=imode,
                                   step_overhead_s=step_overhead_s)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft, layer.pad)
        hg = spec.halo_block_geometry(geo, block_p)
        T = geo.n_tiles * batch
        k2 = fft * fft
        nnz = max(1, round(k2 / alpha))
        bn = min(block_n, layer.c_out)
        bm = min(block_m, layer.c_in)
        gn = max(1, -(-layer.c_out // block_n))
        gm = max(1, -(-layer.c_in // block_m))
        if imode == "halo":
            gp = max(1, batch * hg.n_blocks)   # the actual p grid
        else:
            gp = max(1, -(-T // block_p))
        mp = gm * bm

        x = self.ns["input_bytes"](
            flow, layer.c_in, layer.h_in, layer.w_in, fft, T, batch,
            imode, hg.nbh, hg.nbw, hg.rh, hg.rw, hg.bth, hg.btw, gn, gm)
        w = self.ns["kernel_bytes"](
            flow, layer.c_out, layer.c_in, fft, k2, nnz,
            df.SCHEDULE_MU, df.SCHEDULE_R, bn, mp, gp, mode)
        y = self.ns["output_bytes"](flow, layer.c_out, geo.tile, T, gm)

        assert x == pytest.approx(c["input_hbm_bytes"]), "input share"
        assert w == pytest.approx(c["kernel_hbm_bytes"]), "kernel share"
        assert x + w + y == pytest.approx(c["hbm_bytes"]), "total"

        # batch amortization (S1b): per-image shares divide by B
        pt, pk = self.ns["per_image_bytes"](x + w + y, w, batch)
        assert pt == pytest.approx(c["per_image_hbm_bytes"]), "per-image"
        assert pk == pytest.approx(c["per_image_kernel_hbm_bytes"]), \
            "per-image kernel"

        # interpret-mode step pricing: step_s = gn*gm*gp * overhead
        assert self.ns["step_seconds"](
            gn, gm, gp, step_overhead_s) == pytest.approx(c["step_s"]), \
            "step_s"
        assert c["grid_steps"] == pytest.approx(gn * gm * gp), \
            "grid_steps"

    def test_doc_is_linked(self):
        """README and ARCHITECTURE must point at the walkthrough."""
        root = DOC.parent.parent
        assert "docs/DATAFLOW.md" in (root / "README.md").read_text()
        assert "DATAFLOW.md" in (root / "docs" /
                                 "ARCHITECTURE.md").read_text()


ICI_CASES = [(layer, strategy, n_shards, batch)
             for layer in (df.VGG16_LAYERS[1], df.VGG16_LAYERS[5],
                           df.VGG16_LAYERS[-1])
             for strategy in df.SHARD_STRATEGIES
             for n_shards in (2, 4, 8)
             for batch in (1, 8)]


class TestDocIciFormulasMatchCode:
    """Section 8's two-level formulas (wire bytes per strategy, ICI
    serialization, the sharded objective) against the code."""

    ns = _doc_ici_namespace()

    @pytest.mark.parametrize("layer,strategy,n_shards,batch", ICI_CASES,
                             ids=[f"{l.name}-{s}-D{d}-b{b}"
                                  for l, s, d, b in ICI_CASES])
    def test_ici_and_objective(self, layer, strategy, n_shards, batch):
        fft, alpha = 8, 4.0
        doc_wire = self.ns["ici_bytes"](
            strategy, n_shards, layer.c_out, layer.c_in, layer.h_in,
            layer.w_in, layer.ksize, layer.pad, batch)
        assert doc_wire == pytest.approx(df.shard_ici_bytes(
            layer, n_shards, strategy, batch)), "wire bytes"
        assert self.ns["ICI_BYTES_PER_S"] == df.TPU_ICI_GBPS

        c = df.tpu_sharded_flow_cost(
            layer, fft, alpha, 64, 128, 64, "output_stationary",
            n_shards=n_shards, strategy=strategy, batch=batch,
            step_overhead_s=1e-4)
        if c is None:       # infeasible split: doc feasibility matches
            assert strategy != "replicate"
            if strategy == "channel":
                assert layer.c_in % n_shards != 0
            else:
                geo = spec.make_geometry(layer.h_in, layer.w_in,
                                         layer.ksize, fft, layer.pad)
                assert n_shards > geo.n_tiles_h
            return
        assert c["ici_bytes"] == pytest.approx(doc_wire)
        assert c["ici_s"] == pytest.approx(
            self.ns["ici_seconds"](doc_wire))
        assert c["sharded_s"] == pytest.approx(self.ns["sharded_seconds"](
            c["serial_s"], c["step_s"], c["hbm_s"], c["compute_s"],
            c["ici_s"])), "two-level objective"
