"""End-to-end system behaviour: the paper's pipeline + the LM framework
pipeline, each exercised through their public APIs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import vgg16_spectral
from repro.core import optimizer as alg1
from repro.core import scheduler as alg2
from repro.core import sparse, spectral
from repro.kernels import ops
from repro.models import cnn


def test_paper_pipeline_end_to_end():
    """Offline: transform + prune + Alg1 plan + Alg2 tables.
    Online: overlap-save FFT -> scheduled sparse Hadamard -> IFFT ->
    valid-tile assembly.  The scheduled sparse result must equal the
    masked dense spectral conv for every kernel group — i.e. the paper's
    entire datapath computes the right convolution."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 4, 12, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 4, 3, 3)), jnp.float32)
    geo = spectral.make_geometry(12, 12, 3, 8)
    wf = spectral.spectral_kernel(w, 8)
    sk = sparse.prune_magnitude(wf, 4.0)

    # reference: masked-dense spectral conv
    y_ref = spectral.spectral_conv2d_pretransformed(x, sk.values, geo)

    # scheduled path: per-group INDEX/VALUE execution, IFFT, assembly
    windows = spectral.extract_tiles_overlapping(x, geo)
    xf = jnp.fft.fft2(windows.astype(jnp.float32))
    y_f, stats = ops.scheduled_sparse_conv_group(
        np.asarray(sk.values), np.asarray(sk.indices), xf, r=6)
    y_tiles = jnp.fft.ifft2(y_f[None]).real.astype(jnp.float32)
    ov = geo.ksize - 1
    y = spectral.assemble_valid_tiles(y_tiles[..., ov:, ov:], geo)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    assert stats["utilization"] > 0.5


def test_alg1_plus_alg2_consistency():
    """The Alg-1 plan's (P', N') feeds Alg-2 scheduling; utilization and
    bandwidth from the combined system respect the paper's envelope."""
    plan = alg1.optimize(arch_candidates=[(9, 64)])
    assert plan.bw_max_gbps < 19.0
    rng = np.random.default_rng(0)
    idx = np.stack([np.sort(rng.choice(64, 16, replace=False))
                    for _ in range(plan.n_par)])
    s = alg2.schedule_exact_cover(idx, 64, r=10)
    alg2.verify_schedule(s, idx, 64)
    assert s.pe_utilization > 0.8


def test_spectral_cnn_with_scheduler_stats():
    from repro.core.plan import build_network_plan
    cfg = dataclasses.replace(vgg16_spectral.SMOKE, alpha=2.0)
    params = cnn.init(jax.random.PRNGKey(0), cfg)
    plan = build_network_plan(params, cfg, batch=1)
    # Alg-2 stats are baked into the plan at build time
    for lp in plan.layers:
        assert lp.schedule_cycles is not None
        assert 0.0 < lp.pe_utilization <= 1.0
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, 3, cfg.image_size, cfg.image_size))
    logits = cnn.forward_spectral(params, plan, x)
    assert bool(jnp.isfinite(logits).all())
    # alpha=2 keeps more energy: spectral top-1 should often match dense
    dense = cnn.forward_spatial(params, cfg, x)
    assert logits.shape == dense.shape


def test_lm_framework_end_to_end(tmp_path):
    """Train a few steps, checkpoint, restore, serve tokens — the whole
    LM substrate through public entry points."""
    from repro.launch.serve import Request, Server
    from repro.launch.train import train

    out = train("smollm-135m", steps=6, batch=2, seq_len=16,
                ckpt_dir=str(tmp_path), ckpt_every=3)
    assert out["final_step"] == 6
    srv = Server("smollm-135m", slots=2, max_len=32)
    srv.submit(Request(0, np.asarray([5, 6, 7], np.int32), 3))
    stats = srv.run_until_drained()
    assert stats["ticks"] >= 3
