"""Test-suite bootstrap: degrade gracefully when ``hypothesis`` is absent.

Six test modules use property-based tests via ``hypothesis``.  The package
is a dev-only dependency (see requirements-dev.txt); when it is not
installed we register a stub module *before collection* so that

  * the example-based tests in those modules still run, and
  * every ``@given`` property test reports as SKIPPED (not ERROR).

This is the "or equivalent" variant of guarding each module with
``pytest.importorskip`` — it keeps ~90% of the suite running instead of
skipping whole files.
"""

from __future__ import annotations

import sys
import types

import pytest

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401

    # Pinned CI profile (ISSUE 10): derandomize gives a FIXED example
    # sequence (no flaky shrink sessions in CI), deadline=None because
    # interpret-mode jax calls blow any per-example wall clock.
    hypothesis.settings.register_profile(
        "repro-ci",
        hypothesis.settings(derandomize=True, deadline=None,
                            max_examples=60))
    hypothesis.settings.load_profile("repro-ci")
except ImportError:
    def _given(*_args, **_kwargs):
        def deco(fn):
            # Deliberately NOT functools.wraps: pytest must see the
            # (*args, **kwargs) signature, or it would try to inject the
            # hypothesis strategy kwargs as fixtures.
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy_factory(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy_factory  # PEP 562

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
