"""Fault-injection ladder walk: every demotion rung under injected faults.

Acceptance (ISSUE 6): under any single injected fault,
``forward_spectral`` either returns a parity-bounded result (<= 1e-5 vs
the einsum oracle) through a demoted plan, or raises a structured
``ResilienceError`` naming the layer and site — never a silent wrong
answer, never a raw Pallas traceback.

Each test drives one edge:

  lowering @ input_mode=halo   -> rung 1  (halo -> windowed)
  lowering @ hadamard=scheduled-> rung 2  (scheduled -> dense plane)
  lowering @ backend=fused     -> rung 3  (fused -> staged)
  lowering unmatched (all)     -> rung 4  (terminal einsum)
  vmem_overflow                -> ladder walk to staged
  oob_index                    -> rejected at plan BUILD
  corrupt_value                -> runtime parity guard (policies)
  nan_activations              -> runtime NaN scan (policies)
"""

import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import dataflow as df
from repro.core import resilience as res
from repro.models import cnn
from repro.testing import faults

LAYERS = (
    df.ConvLayer("c1", 3, 8, 32, 32),
    df.ConvLayer("c2", 8, 8, 16, 16),
    df.ConvLayer("c3", 8, 8, 8, 8),
    df.ConvLayer("c4", 8, 8, 4, 4),
    df.ConvLayer("c5", 8, 8, 2, 2),
)
CFG = cnn.SpectralCNNConfig(
    name="mini-faults", layers=LAYERS, alpha=4.0, n_classes=4,
    image_size=32, fc_dim=8,
    pool_after=frozenset({"c1", "c2", "c3", "c4", "c5"}))
TOL = 1e-5


@pytest.fixture(scope="module")
def params():
    return cnn.init(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def plan(params):
    """Most aggressive datapath: scheduled Hadamard + halo input."""
    return cnn.build_plan(params, CFG, batch=1, hadamard="scheduled",
                          input_mode="halo")


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (1, 3, 32, 32),
                             jnp.float32)


@pytest.fixture(scope="module")
def ref(params, plan, x):
    """The einsum oracle every demoted output must stay within TOL of."""
    return cnn.forward_spectral(params, plan, x, backend="einsum")


def _modes(p):
    return [(lp.input_mode, lp.hadamard, lp.backend) for lp in p.layers]


def _parity(params, hard, x, ref):
    out = cnn.forward_spectral(params, hard, x, backend="pallas_fused")
    return float(jnp.abs(out - ref).max())


def test_no_fault_leaks_between_tests(plan):
    """inject() must uninstall on exit — a leaked fault would poison
    every later test in the session."""
    with faults.inject("lowering") as f:
        assert res._FAULTS
    assert f not in res._FAULTS and not res._FAULTS


def test_rung1_halo_demotes_to_windowed(params, plan, x, ref):
    with faults.inject("lowering", input_mode="halo") as f:
        hard = res.harden_network_plan(plan)
    assert f.fires > 0
    for lp in hard.layers:
        assert lp.input_mode == "windowed"
        assert lp.backend == "fused"            # only ONE rung taken
        assert any("halo->windowed" in p for p in lp.provenance)
    assert _parity(params, hard, x, ref) <= TOL


def test_rung2_scheduled_demotes_to_plane(params, plan, x, ref):
    with faults.inject("lowering", hadamard="scheduled") as f:
        hard = res.harden_network_plan(plan)
    assert f.fires > 0
    for lp in hard.layers:
        assert lp.hadamard in ("dense", "bin")
        assert lp.tables is None
        assert lp.backend == "fused"
        assert any("hadamard scheduled->" in p for p in lp.provenance)
    assert _parity(params, hard, x, ref) <= TOL


def test_rung3_fused_demotes_to_staged(params, plan, x, ref):
    with faults.inject("lowering", backend="fused") as f:
        hard = res.harden_network_plan(plan)
    assert f.fires > 0
    for lp in hard.layers:
        assert lp.backend == "staged"
        assert any("fused->staged" in p for p in lp.provenance)
    assert _parity(params, hard, x, ref) <= TOL


def test_rung4_terminal_einsum_always_executes(params, plan, x, ref):
    """An unmatched lowering fault fails halo, windowed, plane, fused
    AND staged variants; the ladder must land every layer on einsum and
    the output must be exact (einsum IS the oracle)."""
    with faults.inject("lowering") as f:
        hard = res.harden_network_plan(plan)
    assert f.fires > 0
    # every ladder rung that APPLIES to this plan fires exactly once;
    # the epilogue residual-fused->residual-add rung is a no-op on the
    # linear smoke net (no residual edges), so it leaves no provenance
    applicable = [r for r in res.DEMOTION_LADDER if r[0] != "epilogue"]
    for lp in hard.layers:
        assert lp.backend == "einsum"
        assert len(lp.provenance) == len(applicable)
    assert _parity(params, hard, x, ref) == 0.0
    hr = hard.health_report()
    assert hr["healthy"] is False
    assert hr["demoted_layers"] == [lp.layer.name for lp in hard.layers]


def test_vmem_overflow_walks_ladder(params, plan, x, ref):
    """RESOURCE_EXHAUSTED-style failures at the fused dispatch demote
    through the fused rungs and settle on staged."""
    with faults.inject("vmem_overflow") as f:
        hard = res.harden_network_plan(plan)
    assert f.fires > 0
    for lp in hard.layers:
        assert lp.backend == "staged"
        # provenance records the raw error the rung translated
        assert any("RESOURCE_EXHAUSTED" in p for p in lp.provenance)
    assert _parity(params, hard, x, ref) <= TOL


def test_oob_index_rejected_at_build(params):
    """A corrupted INDEX table produced during schedule compilation is
    caught by build-time validation, not at kernel launch."""
    with pytest.raises(res.PlanValidationError) as ei:
        with faults.inject("oob_index") as f:
            cnn.build_plan(params, CFG, batch=1, hadamard="scheduled")
    assert f.fires > 0
    assert any(d.check == "tables/idx-bounds" for d in
               ei.value.diagnostics)


def test_corrupt_value_caught_by_parity_guard(params, plan, x, ref):
    """A finite-but-wrong VALUE plane sails through static validation;
    the sampled parity guard catches it and (policy=demote) recomputes
    the layer through the oracle so the answer stays parity-bounded."""
    with faults.inject("corrupt_value") as f:
        bad_plan = cnn.build_plan(params, CFG, batch=1,
                                  hadamard="scheduled")
    assert f.fires > 0
    guards = res.NumericGuards(parity=True, policy="demote")
    out = cnn.forward_spectral(params, bad_plan, x,
                               backend="pallas_fused", guards=guards)
    assert guards.events and guards.events[0]["check"] == "parity"
    assert float(jnp.abs(out - ref).max()) <= TOL
    # without guards the corruption WOULD be a silent wrong answer —
    # that is exactly the hole the parity guard plugs
    raw = cnn.forward_spectral(params, bad_plan, x,
                               backend="pallas_fused")
    assert float(jnp.abs(raw - ref).max()) > TOL


def test_nan_activations_guard_policies(params, plan, x, ref):
    """The NaN scan names the poisoned layer; each policy behaves as
    documented."""
    # raise
    g = res.NumericGuards(policy="raise")
    with faults.inject("nan_activations", layer="c2"):
        with pytest.raises(res.NumericGuardError) as ei:
            cnn.forward_spectral(params, plan, x,
                                 backend="pallas_fused", guards=g)
    assert ei.value.layer == "c2" and ei.value.site == "nan_scan"
    assert g.events and g.events[0]["layer"] == "c2"

    # demote: oracle recompute of the poisoned layer, bounded answer
    g2 = res.NumericGuards(policy="demote")
    with faults.inject("nan_activations", layer="c2"):
        out = cnn.forward_spectral(params, plan, x,
                                   backend="pallas_fused", guards=g2)
    assert g2.events
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out - ref).max()) <= TOL

    # warn: suspect output kept, warning emitted, event recorded
    g3 = res.NumericGuards(policy="warn")
    with faults.inject("nan_activations", layer="c2"):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out3 = cnn.forward_spectral(params, plan, x,
                                        backend="pallas_fused",
                                        guards=g3)
    assert any("numeric-guard" in str(wi.message) for wi in w)
    assert g3.events
    assert not bool(jnp.isfinite(out3).all())


def test_unhardened_fused_failure_is_structured(params, plan, x):
    """Skipping harden_network_plan must still never surface a raw
    backend traceback: forward_spectral wraps the failure in
    KernelLoweringError naming the layer."""
    with faults.inject("lowering", backend="fused"):
        with pytest.raises(res.KernelLoweringError) as ei:
            cnn.forward_spectral(params, plan, x,
                                 backend="pallas_fused")
    assert ei.value.layer == "c1" and ei.value.site == "forward"
    assert "backend=" in str(ei.value)


def test_demotion_repriced_costs_stay_honest(plan):
    """Each rung re-prices the tuning through the cost model; the
    recorded numbers change with the variant instead of going stale."""
    lp = plan.layers[0]
    demoted = res.demote_layer(lp, reason="test")
    assert demoted.input_mode == "windowed"
    assert demoted.tuning.input_mode == "windowed"
    assert demoted.tuning.hbm_bytes != lp.tuning.hbm_bytes
    assert demoted.provenance[-1].startswith("input_mode halo->windowed")
    # terminal rung: nothing below einsum
    lp_e = demoted
    for _ in range(len(res.DEMOTION_LADDER)):
        nxt = res.demote_layer(lp_e, reason="test")
        if nxt is None:
            break
        lp_e = nxt
    assert lp_e.backend == "einsum"
    assert res.demote_layer(lp_e, reason="test") is None
