"""Resilient execution layer: plan validation, taxonomy, health report.

The companion fault-injection ladder walk lives in test_faults.py; this
module covers the STATIC half — ``validate_plan`` invariants, the error
taxonomy's back-compat contract, corrupted Alg-2 tables rejected at plan
BUILD time (not kernel launch), the hypothesis property that the Alg-2
compiler's own output always validates, and ``health_report``.
"""

import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dataflow as df
from repro.core import resilience as res
from repro.core import scheduler as sch
from repro.core import sparse as sp
from repro.models import cnn
from repro.testing import faults

MINI_LAYERS = (
    df.ConvLayer("c1", 3, 8, 32, 32),
    df.ConvLayer("c2", 8, 8, 16, 16),
    df.ConvLayer("c3", 8, 8, 8, 8),
)
MINI = cnn.SpectralCNNConfig(
    name="mini-res", layers=MINI_LAYERS, alpha=4.0, n_classes=4,
    image_size=32, fc_dim=8, pool_after=frozenset({"c1", "c2", "c3"}))


@pytest.fixture(scope="module")
def mini_params():
    return cnn.init(jax.random.PRNGKey(0), MINI)


@pytest.fixture(scope="module")
def mini_plan(mini_params):
    return cnn.build_plan(mini_params, MINI, batch=1,
                          hadamard="scheduled", input_mode="halo")


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

def test_taxonomy_backcompat_subclassing():
    """Structured errors must keep pre-taxonomy except clauses working:
    validation errors are ValueErrors, lowering errors are
    NotImplementedErrors (the old _check_hw_safe contract)."""
    assert issubclass(res.PlanValidationError, ValueError)
    assert issubclass(res.KernelLoweringError, NotImplementedError)
    assert issubclass(res.NumericGuardError, ValueError)
    for klass in (res.PlanValidationError, res.KernelLoweringError,
                  res.NumericGuardError):
        assert issubclass(klass, res.ResilienceError)


def test_error_carries_structure():
    diags = [res.Diagnostic("c1", "tables/idx-bounds", "boom"),
             res.Diagnostic("c2", "vmem-budget", "big", "warn")]
    err = res.PlanValidationError("plan failed", layer="c1",
                                  site="validate_plan", diagnostics=diags)
    assert err.layer == "c1" and err.site == "validate_plan"
    assert len(err.diagnostics) == 2
    msg = str(err)
    assert "tables/idx-bounds" in msg and "[c2] vmem-budget" in msg


def test_dma_accumulator_geometry_validated(mini_plan):
    """PR 8 replaces the hardware-safety gate with manual-DMA
    accumulator geometry checks: a healthy plan carries no dma/*
    errors, a degenerate block size is caught at validate time, and
    split-p weight-stationary (illegal pre-PR-8) is now clean."""
    for lp in mini_plan.layers:
        diags = res.validate_layer_plan(lp, batch=mini_plan.batch)
        assert not [d for d in diags if d.check.startswith("dma/")
                    and d.severity == "error"]
    lp = mini_plan.layers[0]
    bad = dataclasses.replace(
        lp, tuning=dataclasses.replace(lp.tuning, block_n=0))
    diags = res.validate_layer_plan(bad)
    assert any(d.check == "dma/tile-bounds" and d.severity == "error"
               for d in diags)
    split = dataclasses.replace(
        lp, tuning=dataclasses.replace(lp.tuning,
                                       flow="weight_stationary",
                                       block_p=1))
    diags = res.validate_layer_plan(split)
    assert not [d for d in diags if d.severity == "error"]


def test_guard_policy_validated():
    with pytest.raises(ValueError):
        res.NumericGuards(policy="explode")


# ---------------------------------------------------------------------------
# Plan validation
# ---------------------------------------------------------------------------

def test_validate_plan_healthy(mini_plan):
    """A freshly built plan has no error-severity diagnostics on the
    scheduled+halo datapath (the most aggressive variant)."""
    diags = res.validate_plan(mini_plan)
    assert not [d for d in diags if d.severity == "error"]


def test_build_plan_validates_by_default(mini_params):
    """build_network_plan runs validate_plan unless told not to."""
    plan = cnn.build_plan(mini_params, MINI, batch=1, validate=False)
    assert res.validate_plan(plan, raise_on_error=False) is not None
    # default path already validated mini_plan without raising


def test_oob_index_rejected_at_plan_build_not_launch(mini_plan):
    """A mutated OOB INDEX table is rejected by static validation —
    before any kernel launch could gather against the bad address."""
    bad = faults.corrupt_plan_tables(mini_plan, kind="oob_index")
    with pytest.raises(res.PlanValidationError) as ei:
        res.validate_plan(bad)
    err = ei.value
    assert err.site == "validate_plan"
    checks = {d.check for d in err.diagnostics}
    assert "tables/idx-bounds" in checks
    assert str(faults.OOB_INDEX) in str(err)
    # the failing layer is named — no traceback archaeology needed
    assert err.layer in {lp.layer.name for lp in mini_plan.layers}


def test_corrupt_value_is_invisible_to_static_validation(mini_plan):
    """A finite-but-wrong VALUE plane passes the static validator —
    catching it is the runtime parity guard's job (test_faults.py)."""
    bad = faults.corrupt_plan_tables(mini_plan, kind="corrupt_value")
    diags = res.validate_plan(bad)
    assert not [d for d in diags if d.severity == "error"]


def test_validate_layer_plan_flags_bad_modes(mini_plan):
    lp = dataclasses.replace(mini_plan.layers[0], input_mode="telepathy")
    diags = res.validate_layer_plan(lp)
    assert any(d.check == "modes/input" for d in diags)
    lp2 = dataclasses.replace(mini_plan.layers[0], backend="quantum")
    diags2 = res.validate_layer_plan(lp2)
    assert any(d.check == "modes/backend" for d in diags2)


def test_validate_layer_plan_flags_bad_bias(mini_plan):
    lp = mini_plan.layers[0]
    bad_bias = jnp.asarray(np.full((1, lp.layer.c_out), np.nan,
                                   np.float32))
    lp = dataclasses.replace(lp, bias=bad_bias)
    diags = res.validate_layer_plan(lp)
    assert any(d.check == "epilogue/bias-finite" for d in diags)


def test_vmem_budget_is_warn_severity(mini_plan):
    """An over-budget working set is advisory (the autotuner's
    documented smallest-footprint fallback), not a hard error — but it
    must be reported."""
    diags = res.validate_plan(mini_plan, vmem_budget=1)
    vmem = [d for d in diags if d.check == "vmem-budget"]
    assert vmem and all(d.severity == "warn" for d in vmem)


# ---------------------------------------------------------------------------
# Property: the Alg-2 compiler's own output always validates
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n_kernels=st.sampled_from([4, 8, 16]),
    m_ch=st.sampled_from([3, 4, 8]),
    alpha=st.sampled_from([2.0, 4.0]),
    block_m=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_compiled_tables_always_validate(n_kernels, m_ch, alpha,
                                         block_m, seed):
    """Property: for any random sparsity pattern, the tables
    ``scheduler.compile_layer_tables`` emits pass ``validate_tables``
    clean — bounds, dtypes, shape alignment, padding.  The validator
    rejects only *corrupted* tables, never fresh ones."""
    k = 8
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((n_kernels, m_ch, k, k))
         + 1j * rng.standard_normal((n_kernels, m_ch, k, k))
         ).astype(np.complex64)
    sk = sp.prune_random(w, alpha, seed=seed)
    active = sp.compacted_active_bins(sk)
    n_bins = len(active) if active is not None else k * k
    bm = min(block_m, m_ch)
    tables = sch.compile_layer_tables(
        np.asarray(sk.indices), np.asarray(sk.values).reshape(
            n_kernels, m_ch, k * k),
        k * k, df.SCHEDULE_R, n_par=min(8, n_kernels),
        active=active, m_pad_to=bm)
    diags = res.validate_tables(
        tables, n_bins=n_bins, r=df.SCHEDULE_R, c_out=n_kernels,
        c_in=m_ch, block_m=block_m, layer="prop")
    assert not diags, [str(d) for d in diags]


def test_validate_tables_catches_all_corruptions(mini_plan):
    """Each corruption class maps to its named check."""
    lp = next(l for l in mini_plan.layers if l.tables is not None)
    kw = dict(n_bins=lp.n_active_bins, r=df.SCHEDULE_R,
              c_out=lp.layer.c_out, c_in=lp.layer.c_in,
              block_m=lp.tuning.block_m, layer=lp.layer.name)
    tb = lp.tables

    def checks(**overrides):
        fields = {"idx": tb.idx, "sel": tb.sel, "vr": tb.vr,
                  "vi": tb.vi}
        fields.update(overrides)
        mut = types.SimpleNamespace(**fields)
        return {d.check for d in res.validate_tables(mut, **kw)}

    assert not checks()                                   # pristine
    bad_idx = np.array(tb.idx, copy=True)
    bad_idx.flat[0] = -3
    assert "tables/idx-bounds" in checks(idx=bad_idx)
    bad_sel = np.array(tb.sel, copy=True)
    bad_sel.flat[0] = 10**6
    assert "tables/sel-bounds" in checks(sel=bad_sel)
    bad_vr = np.array(tb.vr, copy=True)
    bad_vr.flat[0] = np.inf
    assert "tables/value-finite" in checks(vr=bad_vr)
    assert "tables/idx-dtype" in checks(
        idx=np.asarray(tb.idx, np.int64))


# ---------------------------------------------------------------------------
# Health report + harden on a healthy plan
# ---------------------------------------------------------------------------

def test_health_report_healthy(mini_plan):
    hr = mini_plan.health_report()
    assert hr["healthy"] is True
    assert hr["demoted_layers"] == []
    assert hr["issues"]["error"] == 0
    # rows key by stable node id over the execution DAG: one row per
    # conv layer PLUS one per pool node
    conv_rows = [r for r in hr["layers"] if r["kind"] == "conv"]
    assert len(conv_rows) == len(mini_plan.layers)
    row = conv_rows[0]
    for key in ("node", "layer", "backend", "flow", "hadamard",
                "input_mode", "demotions"):
        assert key in row
    assert row["backend"] == "fused" and row["demotions"] == []


def test_harden_is_noop_on_healthy_plan(mini_plan):
    """No fault installed: every layer keeps its chosen variant and no
    provenance is recorded."""
    hard = res.harden_network_plan(mini_plan)
    assert all(not lp.provenance for lp in hard.layers)
    assert [(lp.input_mode, lp.hadamard, lp.backend)
            for lp in hard.layers] == \
           [(lp.input_mode, lp.hadamard, lp.backend)
            for lp in mini_plan.layers]


def test_stats_surface_backend_and_demotions(mini_plan):
    s = mini_plan.layers[0].stats()
    assert s["backend"] == "fused" and s["demotions"] == 0


# ---------------------------------------------------------------------------
# Backend-axis rungs (serving ladder)
# ---------------------------------------------------------------------------

def test_demote_layer_backend_walks_rungs(mini_plan):
    lp = mini_plan.layers[0]
    assert lp.backend == "fused"
    staged = res.demote_layer_backend(lp, reason="test")
    assert staged.backend == "staged"
    assert any("fused->staged" in p for p in staged.provenance)
    einsum = res.demote_layer_backend(staged, reason="test")
    assert einsum.backend == "einsum"
    # einsum is terminal: no further rung
    assert res.demote_layer_backend(einsum) is None
    # hadamard / input_mode untouched (backend axis only)
    assert (einsum.hadamard, einsum.input_mode) == \
        (lp.hadamard, lp.input_mode)


def test_plan_at_backend_rung(mini_plan):
    # already at the top rung: the very same object comes back
    assert res.plan_at_backend_rung(mini_plan, "fused") is mini_plan
    down = res.plan_at_backend_rung(mini_plan, "einsum",
                                    reason="load ladder")
    assert all(lp.backend == "einsum" for lp in down.layers)
    assert all(any("load ladder" in p for p in lp.provenance)
               for lp in down.layers)
    # idempotent: demoting an already-demoted plan is a no-op
    assert res.plan_at_backend_rung(down, "staged") is down
    with pytest.raises(ValueError):
        res.plan_at_backend_rung(mini_plan, "nonsense")


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_breaker_opens_after_consecutive_failures():
    clock = _Clock()
    brk = res.CircuitBreaker(name="fused", failure_threshold=3,
                             cooldown_s=1.0, clock=clock)
    assert brk.state == "closed" and brk.allow()
    brk.record_failure("boom")
    brk.record_failure("boom")
    assert brk.state == "closed"        # under threshold
    brk.record_failure("boom")
    assert brk.state == "open" and brk.n_opens == 1
    assert not brk.allow()              # cooldown not elapsed


def test_breaker_success_resets_failure_streak():
    brk = res.CircuitBreaker(name="fused", failure_threshold=2,
                             clock=_Clock())
    brk.record_failure("a")
    brk.record_success()                # streak broken
    brk.record_failure("b")
    assert brk.state == "closed"        # failures must be CONSECUTIVE
    brk.record_failure("c")
    assert brk.state == "open"


def test_breaker_half_open_to_closed_recovery():
    """The ISSUE-7 satellite: open -> (cooldown) -> half_open probe ->
    closed, with every transition recorded."""
    clock = _Clock()
    brk = res.CircuitBreaker(name="staged", failure_threshold=1,
                             cooldown_s=2.0, recovery_successes=1,
                             clock=clock)
    brk.record_failure("boom")
    assert brk.state == "open"
    assert not brk.allow()              # still cooling down
    clock.t = 5.0
    assert brk.allow()                  # cooldown elapsed: probe allowed
    assert brk.state == "half_open"
    brk.record_success()
    assert brk.state == "closed" and brk.failures == 0
    assert [t["to"] for t in brk.transitions] == \
        ["open", "half_open", "closed"]
    snap = brk.snapshot()
    assert snap["state"] == "closed" and snap["n_opens"] == 1


def test_breaker_half_open_failure_reopens():
    clock = _Clock()
    brk = res.CircuitBreaker(name="staged", failure_threshold=1,
                             cooldown_s=1.0, clock=clock)
    brk.record_failure("boom")
    clock.t = 2.0
    assert brk.allow() and brk.state == "half_open"
    brk.record_failure("still broken")
    assert brk.state == "open" and brk.n_opens == 2
    assert not brk.allow()              # fresh cooldown from reopen
    clock.t = 4.0
    assert brk.allow() and brk.state == "half_open"


# ---------------------------------------------------------------------------
# Plan cache (serving front end)
# ---------------------------------------------------------------------------

def test_plan_cache_warm_get_invalidate(mini_params):
    from repro.core.plan import PlanCache, plan_cache_key

    built = []

    def builder(params, cfg, *, batch, **kw):
        built.append((batch, tuple(sorted(kw))))
        return types.SimpleNamespace(batch=batch)

    cache = PlanCache(builder=builder)
    keys = cache.warm(mini_params, MINI, (1, 2))
    assert set(keys) == {1, 2} and len(cache) == 2
    assert cache.builds == 2 and cache.build_s >= 0.0
    # hits never touch the builder
    p1 = cache.get(mini_params, MINI, 1)
    assert p1.batch == 1 and cache.hits == 1 and cache.builds == 2
    # different build kwargs -> different entry, never a collision
    cache.get(mini_params, MINI, 1, hadamard="scheduled")
    assert cache.builds == 3 and len(cache) == 3
    # invalidation forces exactly one rebuild
    assert cache.invalidate(keys[1])
    assert not cache.invalidate(keys[1])        # already gone
    cache.get(mini_params, MINI, 1)
    assert cache.builds == 4 and cache.invalidations == 1
    st = cache.stats()
    assert st["entries"] == 3 and st["builds"] == 4
    # scalar vs per-layer alpha normalize to the same key
    seq = dataclasses.replace(MINI, alpha=(4.0,) * len(MINI.layers))
    assert plan_cache_key(MINI, 1) == plan_cache_key(seq, 1)
