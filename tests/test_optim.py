"""Optimizers, schedules, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim import adamw as O
from repro.optim import compression as C
from repro.optim.schedule import cosine_with_warmup


def _quad_problem(d=8, seed=0):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.standard_normal(d), jnp.float32)
    params = {"w": jnp.zeros((d,), jnp.float32)}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    params, loss, target = _quad_problem()
    cfg = O.OptimizerConfig(name=name, lr=0.1, weight_decay=0.0)
    state = O.init(cfg, params)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = O.update(cfg, grads, state, params)
    # adafactor's update clipping slows the last decade near the optimum
    tol = 1e-2 if name == "adamw" else 5e-2
    assert float(loss(params)) < tol


def test_adamw_moments_shapes():
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((3,))}
    st_ = O.adamw_init(params)
    assert st_["mu"]["a"].shape == (4, 8)
    assert st_["nu"]["b"].shape == (3,)


def test_adafactor_factored_states_are_small():
    """The 1 T-param justification: factored stats are O(d_in + d_out)."""
    params = {"w": jnp.zeros((512, 1024))}
    st_ = O.adafactor_init(params)
    v = st_["v"]["w"]
    assert set(v) == {"vr", "vc"}
    assert v["vr"].shape == (512,)
    assert v["vc"].shape == (1024,)
    full = 512 * 1024
    factored = 512 + 1024
    assert factored < full / 100


def test_adafactor_small_tensors_unfactored():
    st_ = O.adafactor_init({"b": jnp.zeros((64,))})
    assert set(st_["v"]["b"]) == {"v"}


def test_grad_clip_global_norm():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = O.clip_by_global_norm(grads, 1.0)
    assert float(norm) > 1.0
    n2 = O.global_norm(clipped)
    np.testing.assert_allclose(float(n2), 1.0, rtol=1e-5)


def test_schedule_warmup_and_decay():
    lr0 = cosine_with_warmup(0, peak_lr=1.0, warmup=10, total=100)
    lr_peak = cosine_with_warmup(10, peak_lr=1.0, warmup=10, total=100)
    lr_end = cosine_with_warmup(100, peak_lr=1.0, warmup=10, total=100)
    assert float(lr0) == 0.0
    np.testing.assert_allclose(float(lr_peak), 1.0)
    np.testing.assert_allclose(float(lr_end), 0.1, rtol=1e-5)


class TestCompression:
    def test_roundtrip_error_bound(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((128, 64)) * 3,
                              jnp.float32)}
        res = C.init_residual(g)
        comp, new_res = C.compress(g, res)
        back = C.decompress(comp)
        scale = float(comp["w"].scale)
        err = float(jnp.abs(back["w"] - g["w"]).max())
        assert err <= scale / 2 + 1e-6

    def test_error_feedback_accumulates(self):
        """Residual carries quantization error; the sum of decompressed
        gradients converges to the sum of true gradients."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        res = C.init_residual({"w": g_true})
        total = jnp.zeros_like(g_true)
        for _ in range(50):
            comp, res = C.compress({"w": g_true}, res)
            total = total + C.decompress(comp)["w"]
        np.testing.assert_allclose(np.asarray(total / 50),
                                   np.asarray(g_true), atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
    def test_property_int8_range(self, seed, scale):
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.standard_normal(64) * scale,
                              jnp.float32)}
        comp, _ = C.compress(g, C.init_residual(g))
        q = np.asarray(comp["w"].q)
        assert q.dtype == np.int8
        assert q.min() >= -127 and q.max() <= 127

    def test_wire_savings(self):
        g = {"w": jnp.zeros((1000,))}
        full, small = C.wire_bytes(g)
        assert small * 3.9 < full


def test_training_with_compressed_grads_converges():
    """End-to-end: int8 error-feedback compression in the optimizer loop
    still converges (the distributed-optimization trick is usable)."""
    params, loss, _ = _quad_problem(seed=2)
    cfg = O.OptimizerConfig(lr=0.1, weight_decay=0.0)
    state = O.init(cfg, params)
    res = C.init_residual(params)
    for _ in range(300):
        grads = jax.grad(loss)(params)
        comp, res = C.compress(grads, res)
        grads = C.decompress(comp)
        params, state, _ = O.update(cfg, grads, state, params)
    assert float(loss(params)) < 1e-2
