"""Per-arch smoke tests + decode/forward consistency for each family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api, cnn
from repro.models import encdec as encdec_lib

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(KEY, (b, s, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_grad(arch):
    """Reduced config: one forward + one grad step, shapes + finiteness."""
    cfg = configs.get_smoke_config(arch)
    params = api.init(KEY, cfg)
    batch = _batch(cfg)
    logits = api.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, grads = jax.value_and_grad(api.loss_fn)(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in leaves)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke_config(arch)
    params = api.init(KEY, cfg)
    cache = api.init_cache(cfg, 2, 32, enc_len=8)
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (2, 8, cfg.d_model))
        cache = encdec_lib.precompute_cross(params, cfg, frames, cache)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = api.decode(params, cfg, tok, cache, jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure is preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("cache shape changed"), cache, cache2)


def _teacher_forced_decode(cfg, params, tokens, enc_frames=None, s_max=12):
    b = tokens.shape[0]
    cache = api.init_cache(cfg, b, s_max,
                           enc_len=0 if enc_frames is None
                           else enc_frames.shape[1])
    if enc_frames is not None:
        cache = encdec_lib.precompute_cross(params, cfg, enc_frames, cache)
    outs = []
    for t in range(tokens.shape[1]):
        lg, cache = api.decode(params, cfg, tokens[:, t:t + 1], cache,
                               jnp.int32(t))
        outs.append(lg)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("arch", ["qwen3-8b", "h2o-danube-1.8b",
                                  "xlstm-350m", "zamba2-7b",
                                  "whisper-medium"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode chain == full forward (per family)."""
    cfg = configs.get_smoke_config(arch)
    params = api.init(KEY, cfg)
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens}
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (b, s, cfg.d_model))
        batch["frames"] = frames
    full = api.forward(params, cfg, batch)
    step = _teacher_forced_decode(cfg, params, tokens, frames)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_moe_decode_matches_forward_when_no_drops():
    cfg = configs.get_smoke_config("moonshot-v1-16b-a3b").replace(
        capacity_factor=8.0)
    params = api.init(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab)
    full = api.forward(params, cfg, {"tokens": tokens})
    step = _teacher_forced_decode(cfg, params, tokens)
    np.testing.assert_allclose(np.asarray(step, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_limits_attention():
    """Tokens beyond the window must not influence the output."""
    cfg = configs.get_smoke_config("h2o-danube-1.8b").replace(window=4)
    params = api.init(KEY, cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab)
    # perturb a token far outside the window of the last position
    t2 = t1.at[0, 2].set((t1[0, 2] + 1) % cfg.vocab)
    l1 = api.forward(params, cfg, {"tokens": t1})
    l2 = api.forward(params, cfg, {"tokens": t2})
    # last position attends to keys > 11-4=7 only -> unchanged
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-5)
    # a position inside the perturbed token's window must change
    assert float(jnp.abs(l1[0, 3] - l2[0, 3]).max()) > 1e-4


def test_swa_ring_cache_long_decode():
    """Ring-buffer SWA cache: decode far past the window stays finite and
    matches the full forward logits at the same position."""
    cfg = configs.get_smoke_config("h2o-danube-1.8b").replace(window=4)
    params = api.init(KEY, cfg)
    s = 20
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, s), 0, cfg.vocab)
    full = api.forward(params, cfg, {"tokens": tokens})
    step = _teacher_forced_decode(cfg, params, tokens, s_max=s)
    np.testing.assert_allclose(np.asarray(step[0, -1], np.float32),
                               np.asarray(full[0, -1], np.float32),
                               atol=2e-3, rtol=2e-3)


def test_param_counts_match_published_sizes():
    """Analytic parameter counts are in the right ballpark for the
    published model names (catches config transcription errors)."""
    expect = {
        "qwen3-8b": (7e9, 10e9),
        "yi-6b": (5e9, 7e9),
        "smollm-135m": (0.1e9, 0.2e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "chameleon-34b": (30e9, 40e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        # assignment specifies uniform MoE in all 48 layers -> ~28B total
        # (the HF release mixes dense layers; we follow the assignment)
        "moonshot-v1-16b-a3b": (20e9, 32e9),
        "whisper-medium": (0.6e9, 0.9e9),   # medium is ~769M
        "zamba2-7b": (6e9, 9e9),
        # our mLSTM blocks carry slightly larger q/k/v projections than
        # the release; the analytic count lands at ~0.56B
        "xlstm-350m": (0.25e9, 0.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert 25e9 <= active <= 45e9, active / 1e9   # "a32b"


def test_spectral_cnn_smoke():
    from repro.configs import vgg16_spectral
    from repro.core.plan import build_network_plan
    cfg = vgg16_spectral.SMOKE
    params = cnn.init(KEY, cfg)
    plan = build_network_plan(params, cfg, batch=2)
    x = jax.random.normal(KEY, (2, 3, cfg.image_size, cfg.image_size))
    logits = cnn.forward_spectral(params, plan, x)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())


def test_spectral_cnn_dense_matches_spatial():
    """With alpha=1 (no pruning) the spectral CNN == spatial CNN."""
    from repro.configs import vgg16_spectral
    from repro.core.plan import build_network_plan
    import dataclasses
    cfg = dataclasses.replace(vgg16_spectral.SMOKE, alpha=1.0)
    params = cnn.init(KEY, cfg)
    plan = build_network_plan(params, cfg, batch=1)
    x = jax.random.normal(jax.random.PRNGKey(5),
                          (1, 3, cfg.image_size, cfg.image_size))
    a = cnn.forward_spectral(params, plan, x)
    b = cnn.forward_spatial(params, cfg, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-2, rtol=2e-3)
