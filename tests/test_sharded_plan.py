"""Multi-device sharded spectral inference (ISSUE 9).

Four test families:

  1. Parity — channel- and spatial-sharded forward passes under
     ``shard_map`` vs the single-device einsum oracle, per layer across
     all 3 flows x 3 Hadamard modes and end-to-end on mixed-strategy
     networks.  The in-process tests need a multi-device mesh and skip
     on single-device hosts (the CI ``sharded`` job forces 8 host
     devices); a subprocess smoke test sets XLA_FLAGS itself so the
     default tier always exercises the collectives.
  2. Halo-exchange geometry — the cross-shard property suite: exactly
     k-1 raw rows cross each boundary (bit-exact), every shard-local
     gather selector stays in bounds, band windows equal the
     full-image windows bit for bit, and concatenated shard band
     canvases reconstruct the unsharded canvas to float-accumulation
     tolerance.  Runs under hypothesis when installed, plus a seeded
     deterministic sweep of the same property in every environment.
  3. Cache-key regression — ``plan_cache_key`` folds the mesh shape, so
     plans built for different meshes can never poison each other in a
     ``PlanCache`` (the silent-wrong-math hazard for spectral_serve).
  4. Shard-fault degradation — an injected per-shard fault
     ('shard_tables') is caught HOST-side by the hardening ladder and
     turns into a structured plan-level demotion, never a collective
     hang; a corrupted shard's tables are caught by per-shard
     validation while its siblings stay healthy.

All host-side tests (2-4) run on any machine: plan building,
validation, hardening and probing never enter a shard_map.
"""

from __future__ import annotations

import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as at
from repro.core import dataflow as df
from repro.core import plan as pl
from repro.core import resilience as res
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.models import cnn
from repro.testing import faults

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

MULTI_DEVICE = len(jax.devices()) >= 2
needs_mesh = pytest.mark.skipif(
    not MULTI_DEVICE,
    reason="needs >= 2 devices (run under XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


class TinyCfg:
    """2-layer spectral net small enough for interpret-mode sweeps:
    channel sharding feasible at D in {2, 4} (c_in 4 then 8), spatial
    at D <= 3 (n_tiles_h = 3 for 16x16 / fft 8 / k 3)."""
    name = "tiny-shard"
    fft_size = 8
    alpha = 4.0
    layers = (df.ConvLayer("c1", 4, 8, 16, 16, 3, 1),
              df.ConvLayer("c2", 8, 8, 16, 16, 3, 1))
    pool_after = frozenset({"c2"})


def _tiny_params(key):
    params = {"convs": []}
    for lay in TinyCfg.layers:
        k1, k2, key = jax.random.split(key, 3)
        params["convs"].append({
            "w": jax.random.normal(
                k1, (lay.c_out, lay.c_in, 3, 3), jnp.float32) * 0.1,
            "b": jax.random.normal(k2, (lay.c_out,), jnp.float32) * 0.1})
    feat = 8 * 8 * 8                    # c_out * (16/2)^2 after one pool
    k1, k2, k3, key = jax.random.split(key, 4)
    params["fc1"] = jax.random.normal(k1, (feat, 16), jnp.float32) * 0.05
    params["fc2"] = jax.random.normal(k2, (16, 16), jnp.float32) * 0.05
    params["fc3"] = jax.random.normal(k3, (16, 4), jnp.float32) * 0.05
    return params, key


# ---------------------------------------------------------------------------
# 1. Parity vs the single-device einsum oracle
# ---------------------------------------------------------------------------

@needs_mesh
class TestShardedParity:

    @pytest.fixture(scope="class")
    def net(self):
        params, key = _tiny_params(jax.random.PRNGKey(0))
        x = jax.random.normal(key, (2, 4, 16, 16), jnp.float32)
        base = pl.build_network_plan(params, TinyCfg, batch=2)
        ref = cnn.forward_spectral(params, base, x, backend="einsum")
        return params, x, base, ref

    @pytest.mark.parametrize("n_shards,strategies,extra", [
        (4, ("channel",), {}),
        (2, ("spatial",), {}),
        (2, None, {}),                       # two-level tuner decides
        (4, ("channel",), {"hadamard": "scheduled"}),
        (2, ("spatial",), {"hadamard": "scheduled"}),
        (2, ("spatial",), {"input_mode": "halo"}),
        (4, ("channel",), {"input_mode": "halo"}),
    ])
    def test_network_parity(self, net, n_shards, strategies, extra):
        from repro.distributed.executor import forward_spectral_sharded
        from repro.launch.mesh import make_spectral_mesh

        if len(jax.devices()) < n_shards:
            pytest.skip(f"needs {n_shards} devices")
        params, x, base, ref = net
        splan = pl.build_sharded_network_plan(
            params, TinyCfg, n_shards=n_shards, batch=2,
            strategies=strategies, **extra)
        mesh = make_spectral_mesh(n_shards)
        y = forward_spectral_sharded(params, splan, x, mesh=mesh,
                                     interpret=True)
        err = float(jnp.abs(y - ref).max())
        assert err <= 1e-5, (strategies, extra, err)
        if strategies is not None:
            # every layer where the forced strategy is feasible uses it
            want = strategies[0]
            for name, got in splan.strategies.items():
                layer = next(l for l in TinyCfg.layers if l.name == name)
                local = df.shard_local_layer(layer, TinyCfg.fft_size,
                                             n_shards, want)
                if local is not None:
                    assert got == want, (name, got)

    @pytest.mark.parametrize("flow", df.FLOWS)
    @pytest.mark.parametrize("hadamard", df.HADAMARD_MODES)
    @pytest.mark.parametrize("strategy", ("channel", "spatial"))
    def test_layer_parity_matrix(self, net, flow, hadamard, strategy):
        """Every (strategy, flow, Hadamard mode) cell of the shard-local
        kernel grid matches the einsum oracle <= 1e-5 on a real mesh."""
        from repro.distributed.executor import execute_sharded_layer
        from repro.launch.mesh import make_spectral_mesh

        params, x, base, _ = net
        # build the base under the matching forced Hadamard mode so the
        # base LayerPlan carries tables when the cell needs them
        plan = pl.build_network_plan(params, TinyCfg, batch=2,
                                     hadamard=hadamard)
        lp = plan.layers[0]                  # c1: 4 -> 8 channels
        if hadamard == "scheduled" and lp.hadamard != "scheduled":
            pytest.skip("schedule degenerated on this layer")
        n_shards = 2
        st = at.autotune_layer_sharded(
            lp.layer, plan.fft_size, lp.alpha, n_shards=n_shards,
            strategies=(strategy,), batch=2, flows=(flow,),
            hadamard_modes=[lp.hadamard],
            input_modes=[lp.input_mode or "windowed"],
            active_bins=(len(lp.active) if lp.active is not None
                         else None))
        assert st.strategy == strategy
        assert st.base.flow == flow
        slp = pl.make_sharded_layer_plan(lp, st, n_shards)
        assert slp.strategy == strategy and slp.shards
        mesh = make_spectral_mesh(n_shards)
        y = execute_sharded_layer(x, slp, mesh, interpret=True)
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, lp.kernels, lp.geo)
            + jnp.reshape(lp.bias, (1, -1, 1, 1)))
        err = float(jnp.abs(y - y_ref).max())
        assert err <= 1e-5, (strategy, flow, hadamard, err)


def test_sharded_parity_subprocess_smoke():
    """Default-tier proof on any host: force an 8-device CPU mesh in a
    subprocess (XLA_FLAGS must precede the jax import) and check both
    collective strategies against the einsum oracle end to end."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core import dataflow as df
        from repro.core import plan as pl
        from repro.distributed.executor import forward_spectral_sharded
        from repro.launch.mesh import make_spectral_mesh
        from repro.models import cnn

        class Cfg:
            name = "tiny-shard"
            fft_size = 8
            alpha = 4.0
            layers = (df.ConvLayer("c1", 4, 8, 16, 16, 3, 1),
                      df.ConvLayer("c2", 8, 8, 16, 16, 3, 1))
            pool_after = frozenset({"c2"})

        key = jax.random.PRNGKey(0)
        params = {"convs": []}
        for lay in Cfg.layers:
            k1, k2, key = jax.random.split(key, 3)
            params["convs"].append({
                "w": jax.random.normal(
                    k1, (lay.c_out, lay.c_in, 3, 3), jnp.float32) * 0.1,
                "b": jax.random.normal(k2, (lay.c_out,),
                                       jnp.float32) * 0.1})
        k1, k2, k3, key = jax.random.split(key, 4)
        params["fc1"] = jax.random.normal(k1, (512, 16),
                                          jnp.float32) * 0.05
        params["fc2"] = jax.random.normal(k2, (16, 16),
                                          jnp.float32) * 0.05
        params["fc3"] = jax.random.normal(k3, (16, 4),
                                          jnp.float32) * 0.05
        x = jax.random.normal(key, (2, 4, 16, 16), jnp.float32)
        base = pl.build_network_plan(params, Cfg, batch=2)
        ref = cnn.forward_spectral(params, base, x, backend="einsum")
        for D, strats in [(4, ("channel",)), (2, ("spatial",))]:
            splan = pl.build_sharded_network_plan(
                params, Cfg, n_shards=D, batch=2, strategies=strats)
            y = forward_spectral_sharded(
                params, splan, x, mesh=make_spectral_mesh(D),
                interpret=True)
            err = float(jnp.abs(y - ref).max())
            assert err <= 1e-5, (strats, err)
        print("SHARDED_PARITY_OK")
    """)
    r = subprocess.run([sys.executable, "-c", script],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARDED_PARITY_OK" in r.stdout


# ---------------------------------------------------------------------------
# 2. Cross-chip halo-exchange geometry (property test)
# ---------------------------------------------------------------------------

def _check_halo_property(h: int, ksize: int, fft_size: int,
                         n_shards: int, seed: int) -> None:
    """The pinned-down property for one (H, k, t, D) draw:

      a. the exchange ships EXACTLY k-1 raw rows per interior boundary
         (band d's halo == last k-1 rows of band d-1; zeros on shard 0)
         — BIT-exact, it is pure data movement;
      b. every shard-local gather selector indexes in bounds and each
         one-hot row has weight <= 1;
      c. concatenated shard band canvases reconstruct the unsharded
         full-conv canvas, and the global 'same' crop matches the
         unsharded oracle.  Checked to float-accumulation tolerance,
         not bit-exactly: the band inputs/windows ARE bit-identical,
         but XLA schedules the Hadamard contraction differently at
         band vs full tile extents (~1e-6 noise on identical inputs).
    """
    rng = np.random.default_rng(seed)
    w = h                                     # square images
    geo = spec.make_geometry(h, w, ksize, fft_size)
    if n_shards > geo.n_tiles_h:
        n_shards = geo.n_tiles_h              # keep the draw feasible
    ov = ksize - 1
    tr = spec.shard_band_rows(geo, n_shards)
    hb = tr * geo.tile
    c_in, c_out = 2, 3
    x = jnp.asarray(rng.standard_normal((1, c_in, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((c_out, c_in, ksize, ksize)),
                     jnp.float32)
    wf = spec.spectral_kernel(wk, fft_size)

    # a. exactly k-1 rows per boundary
    bands = spec.halo_exchange_reference(x, geo, n_shards)
    xp = np.zeros((1, c_in, n_shards * hb, w), np.float32)
    xp[:, :, :h] = np.asarray(x)
    for d, band in enumerate(bands):
        band = np.asarray(band)
        assert band.shape[2] == ov + hb, (d, band.shape)
        if d == 0:
            assert not band[:, :, :ov].any()
        else:
            np.testing.assert_array_equal(
                band[:, :, :ov],
                xp[:, :, d * hb - ov: d * hb])
        np.testing.assert_array_equal(
            band[:, :, ov:], xp[:, :, d * hb:(d + 1) * hb])

    # b. shard-local gather selectors in bounds
    bgeo = spec.make_band_geometry(geo, tr)
    for block_p in (1, 4, 16):
        hg = spec.halo_block_geometry(bgeo, block_p)
        sh, sw = spec.halo_block_starts(bgeo, hg)
        assert (sh >= 0).all() and (sh + hg.rh <= bgeo.h_in).all()
        assert (sw >= 0).all() and (sw + hg.rw <= bgeo.w_in).all()
        gr, gc = spec.halo_gather_matrices(bgeo, hg)
        for g in (gr, gc):
            s = g.sum(axis=-1)
            assert ((s == 0) | (s == 1)).all()   # one-hot or zero-pad

    # c. reconstruction of the unsharded canvas (tolerance: see
    #    docstring — the windows are bit-identical, the contraction's
    #    schedule is not)
    full = _full_canvas(x, wf, geo)
    parts = [spec.spectral_band_conv2d_pretransformed(b, wf, bgeo)
             for b in bands]
    stitched = jnp.concatenate(parts, axis=2)[:, :, :geo.h_pad]
    np.testing.assert_allclose(np.asarray(stitched), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(spec.crop_canvas_same(stitched, geo)),
        np.asarray(spec.spectral_conv2d_pretransformed(x, wf, geo)),
        rtol=1e-4, atol=1e-4)
    # ... and the windows themselves ARE bit-identical: every band
    # tile equals the corresponding full-image overlap-save window.
    win_full = np.asarray(spec.extract_tiles_overlapping(x, geo))
    b_, m_ = win_full.shape[:2]
    k = geo.fft_size
    wins = [np.asarray(spec.extract_tiles_overlapping(bd, bgeo))
            .reshape(b_, m_, tr, geo.n_tiles_w, k, k) for bd in bands]
    win_cat = np.concatenate(wins, axis=2)[:, :, :geo.n_tiles_h]
    np.testing.assert_array_equal(
        win_cat.reshape(win_full.shape), win_full)


def _full_canvas(x, wf, geo):
    """Unsharded uncropped full-conv canvas via the same einsum path
    the band oracle uses (windows -> FFT -> Hadamard -> IFFT -> valid
    corner -> canvas relayout)."""
    windows = spec.extract_tiles_overlapping(x, geo)
    x_f = jnp.fft.fft2(windows.astype(jnp.float32))
    y_f = jnp.einsum("bmtuv,nmuv->bntuv", x_f, wf)
    y_sp = jnp.fft.ifft2(y_f).real
    ov = geo.ksize - 1
    return spec.assemble_tile_canvas(
        y_sp[..., ov:, ov:].astype(jnp.float32), geo)


# hypothesis explores the draw space when installed; conftest.py ships
# a stub when it is not, so this test SKIPS (never fails to import) on
# bare images — the deterministic sweep below carries the property
# unconditionally in every environment.
from hypothesis import given, settings
from hypothesis import strategies as hst


@settings(max_examples=20, deadline=None)
@given(h=hst.integers(8, 40), ksize=hst.sampled_from([3, 5]),
       fft_size=hst.sampled_from([8]),
       n_shards=hst.integers(2, 5),
       seed=hst.integers(0, 2 ** 16))
def test_halo_exchange_geometry(h, ksize, fft_size, n_shards, seed):
    _check_halo_property(h, ksize, fft_size, n_shards, seed)


@pytest.mark.parametrize("case", range(20))
def test_halo_exchange_geometry_sweep(case):
    """Seeded deterministic sweep of the same property — runs whether
    or not hypothesis is installed (the @given twin skips under the
    conftest stub)."""
    rng = np.random.default_rng(1234 + case)
    h = int(rng.integers(8, 41))
    ksize = int(rng.choice([3, 5]))
    n_shards = int(rng.integers(2, 6))
    _check_halo_property(h, ksize, 8, n_shards,
                         seed=int(rng.integers(0, 2 ** 16)))


# ---------------------------------------------------------------------------
# 3. Mesh-aware plan-cache keys (regression)
# ---------------------------------------------------------------------------

class TestMeshCacheKey:

    def test_key_folds_mesh_shape(self):
        cfg = TinyCfg
        k_none = pl.plan_cache_key(cfg, 1)
        k1 = pl.plan_cache_key(cfg, 1, mesh_shape=(1,))
        k4 = pl.plan_cache_key(cfg, 1, mesh_shape=(4,))
        k8 = pl.plan_cache_key(cfg, 1, mesh_shape=(8,))
        k24 = pl.plan_cache_key(cfg, 1, mesh_shape=(2, 4))
        assert len({k_none, k1, k4, k8, k24}) == 5
        # same mesh -> same key, list/tuple normalized
        assert k4 == pl.plan_cache_key(cfg, 1, mesh_shape=[4])

    def test_plan_cache_separates_meshes(self):
        built = []

        def builder(params, cfg, *, batch, **kw):
            built.append(batch)
            return ("plan", len(built))

        cache = pl.PlanCache(builder=builder)
        a = cache.get({}, TinyCfg, 1)
        b = cache.get({}, TinyCfg, 1, mesh_shape=(8,))
        c = cache.get({}, TinyCfg, 1, mesh_shape=(4,))
        assert len(built) == 3                # one build per mesh
        assert a != b and b != c
        # hits on re-get, still per mesh
        assert cache.get({}, TinyCfg, 1, mesh_shape=(8,)) == b
        assert cache.get({}, TinyCfg, 1) == a
        assert len(built) == 3
        assert cache.stats()["hits"] == 2

    def test_server_threads_mesh_shape(self):
        """SpectralServer must key every cache access by its mesh."""
        import inspect

        from repro.launch.spectral_serve import SpectralServer
        sig = inspect.signature(SpectralServer.__init__)
        assert "mesh_shape" in sig.parameters


# ---------------------------------------------------------------------------
# 4. Shard-scoped faults: structured demotion, not a collective hang
# ---------------------------------------------------------------------------

class TestShardFaultDegradation:

    @pytest.fixture(scope="class")
    def splan(self):
        params, _ = _tiny_params(jax.random.PRNGKey(0))
        return pl.build_sharded_network_plan(
            params, TinyCfg, n_shards=2, batch=2,
            strategies=("channel",))

    def test_healthy_plan_hardens_to_itself(self, splan):
        out = res.harden_sharded_plan(splan, interpret=True)
        assert [s.strategy for s in out.layers] \
            == [s.strategy for s in splan.layers]
        assert all(not s.provenance for s in out.layers)

    def test_injected_shard_fault_demotes_structurally(self, splan):
        """A fault pinned to ONE shard of ONE layer makes the hardening
        ladder demote that layer's BASE plan (plan-level, uniform across
        devices) — the plan that comes back has non-empty provenance and
        a degraded rung, and the walk terminates (no hang: everything is
        host-side)."""
        name = splan.layers[0].base.layer.name
        with faults.inject("shard_tables", layer=name, shard=1) as fault:
            out = res.harden_sharded_plan(splan, interpret=True)
        assert fault.fires > 0
        demoted = out.layers[0]
        assert demoted.provenance, "demotion must be recorded"
        base0 = splan.layers[0].base
        rung_moved = (
            demoted.base.backend != base0.backend
            or demoted.base.hadamard != base0.hadamard
            or demoted.base.input_mode != base0.input_mode
            or demoted.strategy != splan.layers[0].strategy)
        assert rung_moved
        # untouched layers keep their strategy and stay clean
        assert out.layers[1].strategy == splan.layers[1].strategy
        assert not out.layers[1].provenance

    def test_persistent_shard_fault_collapses_to_replicate(self, splan):
        """A fault that keeps firing at the fused shard kernels walks
        the layer to a non-fused backend, whose sharded form is
        'replicate' — the terminal rung that never enters a shard_map."""
        name = splan.layers[0].base.layer.name
        with faults.inject("shard_tables", layer=name) as fault:
            out = res.harden_sharded_plan(splan, interpret=True)
        assert fault.fires > 0
        demoted = out.layers[0]
        # the fault matches any shard of the layer, so demotion walks
        # until the layer leaves the fused backend entirely
        assert demoted.strategy == "replicate"
        assert demoted.base.backend != "fused"
        assert not demoted.shards

    def test_corrupt_shard_tables_caught_by_validation(self):
        params, _ = _tiny_params(jax.random.PRNGKey(0))
        splan = pl.build_sharded_network_plan(
            params, TinyCfg, n_shards=2, batch=2,
            strategies=("channel",), hadamard="scheduled")
        assert any(s.shards and s.shards[0].tables is not None
                   for s in splan.layers), "need a scheduled layer"
        bad = faults.corrupt_shard_tables(splan, shard=1,
                                          kind="oob_index")
        with pytest.raises(res.PlanValidationError):
            res.validate_sharded_plan(bad)
        diags = res.validate_sharded_plan(bad, raise_on_error=False)
        assert any(d.severity == "error" for d in diags)
        # siblings stay healthy: the unmodified plan still validates
        res.validate_sharded_plan(splan)
