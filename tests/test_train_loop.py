"""Training driver: convergence, checkpoint/restart exactness, failure."""

import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.launch.train import train

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_loss_decreases(tmp_path):
    """The synthetic stream has learnable bigram structure (see
    data/pipeline.py), so cross-entropy must drop below its t=0 plateau
    of ~log(vocab) within a few dozen steps."""
    out = train("smollm-135m", steps=40, batch=4, seq_len=32,
                ckpt_dir=str(tmp_path), ckpt_every=100, lr=3e-3)
    first = np.mean(out["losses"][:4])
    last = np.mean(out["losses"][-4:])
    assert last < first - 0.05, (first, last)


def test_restart_is_bit_exact(tmp_path):
    """Run 12 steps straight vs 6 + restart + 6: identical final loss."""
    a = train("smollm-135m", steps=12, batch=2, seq_len=16,
              ckpt_dir=str(tmp_path / "a"), ckpt_every=100)
    b1 = train("smollm-135m", steps=6, batch=2, seq_len=16,
               ckpt_dir=str(tmp_path / "b"), ckpt_every=6)
    assert b1["final_step"] == 6
    b2 = train("smollm-135m", steps=12, batch=2, seq_len=16,
               ckpt_dir=str(tmp_path / "b"), ckpt_every=6)
    np.testing.assert_allclose(a["losses"][-1], b2["losses"][-1],
                               rtol=1e-5)


@pytest.mark.slow
def test_simulated_failure_and_recovery(tmp_path):
    """Kill the trainer mid-run (exit 42), restart, reach the target —
    the fleet-scale crash/restart path end to end."""
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "smollm-135m", "--steps", "10", "--batch", "2",
           "--seq-len", "16", "--ckpt-dir", str(tmp_path),
           "--ckpt-every", "4", "--simulate-failure", "5"]
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"}
    r1 = subprocess.run(cmd, env=env, capture_output=True, text=True)
    assert r1.returncode == 42, r1.stderr[-500:]
    cmd_resume = cmd[:cmd.index("--simulate-failure")]
    r2 = subprocess.run(cmd_resume, env=env, capture_output=True,
                        text=True)
    assert r2.returncode == 0, r2.stderr[-500:]
    assert "resumed from step 4" in r2.stdout
    assert "done: step 10" in r2.stdout
