"""shard_map expert-parallel MoE: equality with the reference layer.

Multi-device equality runs in a subprocess (XLA device count must be set
pre-init); the 1-device case runs inline.
"""

import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_ep_matches_reference_one_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = moe.MoEConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                        capacity_factor=4.0)
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
    y_ref, _ = moe.forward(params, cfg, x)
    with mesh:
        y_ep, _ = moe.forward_ep(params, cfg, x, mesh=mesh,
                                 data_axes=("data",))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                               atol=1e-5)


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import moe

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = moe.MoEConfig(d_model=16, d_ff=32, n_experts=8, top_k=2,
                        capacity_factor=4.0)
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 24, 16))
    y_ref, _ = moe.forward(params, cfg, x)
    ps = dict(params)
    for k in ("w_gate", "w_up"):
        ps[k] = jax.device_put(params[k],
                               NamedSharding(mesh, P("model", None, None)))
    ps["w_down"] = jax.device_put(params["w_down"],
                                  NamedSharding(mesh, P("model", None,
                                                        None)))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    with mesh:
        fn = jax.jit(lambda p, xx: moe.forward_ep(
            p, cfg, xx, mesh=mesh, data_axes=("data",))[0])
        y = fn(ps, xs)
        g = jax.jit(jax.grad(lambda p, xx: jnp.sum(moe.forward_ep(
            p, cfg, xx, mesh=mesh, data_axes=("data",))[0] ** 2)))(ps, xs)
    import numpy as np
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    assert float(jnp.abs(g["w_gate"]).max()) > 0
    print("EP8_OK")
""")


def test_ep_matches_reference_eight_devices():
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP8_OK" in r.stdout
