"""Spectral convolution == spatial oracle; sparse machinery invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sparse as sp
from repro.core import spectral as spec

jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize(
    "h,w,k,K,cin,cout",
    [
        (12, 12, 3, 8, 3, 5),
        (14, 14, 3, 8, 4, 4),     # VGG conv5 spatial size
        (11, 13, 3, 8, 2, 3),     # non-divisible, rectangular
        (16, 16, 5, 8, 2, 2),     # k=5
        (24, 24, 3, 16, 2, 2),    # K=16
        (6, 6, 3, 8, 1, 1),       # single tile
    ],
)
def test_spectral_equals_spatial(h, w, k, K, cin, cout):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((2, cin, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((cout, cin, k, k)), jnp.float32)
    y_ref = spec.spatial_conv2d(x, wk)
    y = spec.spectral_conv2d(x, wk, fft_size=K)
    assert y.shape == y_ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    h=st.integers(7, 30),
    w=st.integers(7, 30),
    cin=st.integers(1, 4),
    cout=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_spectral_equals_spatial_property(h, w, cin, cout, seed):
    """Property: for any geometry, FFT-tiled OaA conv == direct conv."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, cin, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((cout, cin, 3, 3)), jnp.float32)
    y_ref = spec.spatial_conv2d(x, wk)
    y = spec.spectral_conv2d(x, wk, fft_size=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=3e-4, rtol=3e-4)


def test_geometry_invariants():
    geo = spec.make_geometry(224, 224, 3, 8, 1)
    assert geo.tile == 6
    assert geo.n_tiles_h == 38 and geo.n_tiles_w == 38
    assert geo.h_pad >= geo.h_in + geo.pad


def test_spectral_kernel_is_fft_of_flipped():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((2, 3, 3, 3)), jnp.float32)
    wf = spec.spectral_kernel(w, 8)
    assert wf.shape == (2, 3, 8, 8)
    # DC bin equals the kernel sum (flip does not change the sum).
    np.testing.assert_allclose(np.asarray(wf[..., 0, 0].real),
                               np.asarray(w.sum((-1, -2))), rtol=1e-5)


class TestSparse:
    def _wf(self, n=8, m=4, K=8, seed=0):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.standard_normal((n, m, 3, 3)), jnp.float32)
        return spec.spectral_kernel(w, K)

    @pytest.mark.parametrize("alpha", [2.0, 4.0, 8.0])
    def test_uniform_nnz(self, alpha):
        sk = sp.prune_magnitude(self._wf(), alpha)
        nnz = int(round(64 / alpha))
        assert sk.nnz == nnz
        counts = np.asarray(sk.mask).reshape(8, 4, -1).sum(-1)
        assert (counts == nnz).all(), "compression must be uniform per kernel"

    def test_magnitude_keeps_largest(self):
        wf = self._wf()
        sk = sp.prune_magnitude(wf, 4.0)
        mag = np.abs(np.asarray(wf))
        kept_min = np.where(np.asarray(sk.mask), mag, np.inf).min((-1, -2))
        dropped_max = np.where(~np.asarray(sk.mask), mag, 0).max((-1, -2))
        assert (kept_min >= dropped_max - 1e-6).all()

    def test_indices_match_mask(self):
        sk = sp.prune_random(self._wf(), 4.0, seed=3)
        mask = np.asarray(sk.mask).reshape(8, 4, 64)
        for n in range(8):
            for m in range(4):
                np.testing.assert_array_equal(
                    np.sort(np.asarray(sk.indices[n, m])),
                    np.nonzero(mask[n, m])[0])

    def test_sparse_hadamard_reference(self):
        rng = np.random.default_rng(1)
        wf = self._wf()
        sk = sp.prune_magnitude(wf, 4.0)
        x = jnp.asarray(rng.standard_normal((2, 4, 3, 8, 8))
                        + 1j * rng.standard_normal((2, 4, 3, 8, 8)))
        y = sp.sparse_hadamard_reference(x, sk)
        ref = jnp.einsum("bmtuv,nmuv->bntuv", x, wf * sk.mask)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5)


def test_sparse_spectral_conv_end_to_end():
    """Pruned spectral conv == spectral conv with the masked kernel."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((1, 4, 12, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((6, 4, 3, 3)), jnp.float32)
    geo = spec.make_geometry(12, 12, 3, 8)
    wf = spec.spectral_kernel(w, 8)
    sk = sp.prune_magnitude(wf, 4.0)
    y = spec.spectral_conv2d_pretransformed(x, sk.values, geo)
    y_ref = spec.spectral_conv2d_pretransformed(x, wf * sk.mask, geo)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    assert y.shape == (1, 6, 12, 12)
