"""In-kernel halo gather (input_mode='halo') — the PR-5 tentpole.

The fused kernel's halo input path reads the RAW NCHW activation
through overlapping (element-offset) input blocks and gathers the
overlap-save windows in VMEM with one-hot matmuls, eliminating the
host-materialized [B, M, T, K, K] window tensor.  Covered here:

  * halo == windowed parity per flow x Hadamard mode (BIT-exact: the
    gather is a 0/1 matmul selecting one value per output), and <= 1e-5
    vs the einsum oracle with the fused bias+ReLU epilogue;
  * the halo-block geometry property: the clamped blocks + gather
    matrices reproduce ``extract_tiles_overlapping`` for every
    (H, W, k, K, block_p) the plan can emit (hypothesis);
  * the repriced cost model (``tpu_fused_flow_cost(input_mode=...)``):
    halo input bytes < windowed on every VGG16 layer and flow;
  * the autotune input-mode axis (halo + weight_stationary is legal at
    any batch since the PR-8 manual-DMA accumulators);
  * plan-level integration: ``build_network_plan(input_mode=...)``
    threads the mode into ``LayerPlan`` and ``execute_layer_plan``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import vgg16_spectral
from repro.core import autotune, dataflow as df
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.core.plan import build_network_plan
from repro.kernels.fused_spectral_conv import (
    FLOWS, fused_spectral_conv2d, fused_spectral_conv2d_scheduled)
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _case(h=13, w=12, cin=4, cout=6, k=3, K=8, batch=2, seed=3):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, cin, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((cout, cin, k, k)), jnp.float32)
    b = jnp.asarray(0.1 * rng.standard_normal(cout), jnp.float32)
    geo = spec.make_geometry(h, w, k, K)
    return x, wk, b, geo


class TestHaloParity:
    """halo == windowed (exact) == oracle (<= 1e-5), flows x modes."""

    @pytest.mark.parametrize("flow", FLOWS)
    @pytest.mark.parametrize("mode", df.HADAMARD_MODES)
    def test_flow_mode_matrix(self, flow, mode):
        x, wk, b, geo = _case()
        sk = sp.prune_magnitude(spec.spectral_kernel(wk, 8), 4.0)
        w_f = sk.values if mode == "dense" else sk
        run = {}
        for imode in df.INPUT_MODES:
            if mode == "scheduled":
                run[imode] = fused_spectral_conv2d_scheduled(
                    x, sk, geo, n_par=4, r=6, flow=flow, block_m=2,
                    block_p=8, bias=b, relu=True, input_mode=imode)
            else:
                run[imode] = fused_spectral_conv2d(
                    x, w_f, geo, flow=flow, block_n=4, block_m=2,
                    block_p=5, bias=b, relu=True, input_mode=imode)
        # one-hot gather => the halo path is numerically identical
        np.testing.assert_array_equal(np.asarray(run["halo"]),
                                      np.asarray(run["windowed"]))
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(run["halo"] - y_ref).max())
        assert err <= 1e-5, (flow, mode, err)

    def test_dense_vs_spatial(self):
        """Un-pruned halo path equals the spatial conv oracle."""
        x, wk, b, geo = _case(h=18, w=17, cin=3, cout=5)
        y = fused_spectral_conv2d(x, spec.spectral_kernel(wk, 8), geo,
                                  block_n=4, block_m=2, block_p=7,
                                  input_mode="halo")
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spec.spatial_conv2d(x, wk)),
                                   atol=2e-4, rtol=2e-4)

    @pytest.mark.parametrize("block_p", [1, 3, 9, 128])
    def test_block_split_invariance(self, block_p):
        """Any block_p split of the tile grid gives the same output."""
        x, wk, b, geo = _case(h=14, w=14)
        wf = spec.spectral_kernel(wk, 8)
        y = fused_spectral_conv2d(x, wf, geo, block_n=4, block_m=2,
                                  block_p=block_p, input_mode="halo")
        y_ref = fused_spectral_conv2d(x, wf, geo, block_n=4, block_m=2,
                                      block_p=block_p)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))

    def test_ws_halo_batch2_runs_and_matches(self):
        """halo weight_stationary at batch > 1 — hardware-illegal before
        the manual-DMA accumulators (PR 8) — now runs and matches the
        spatial reference."""
        x, wk, b, geo = _case(h=12, w=12, batch=2)
        y = fused_spectral_conv2d(x, spec.spectral_kernel(wk, 8), geo,
                                  flow="weight_stationary", block_p=512,
                                  input_mode="halo")
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spec.spatial_conv2d(x, wk)),
                                   atol=2e-4, rtol=2e-4)

    def test_bad_input_mode_raises(self):
        x, wk, b, geo = _case()
        with pytest.raises(ValueError, match="input_mode"):
            fused_spectral_conv2d(x, spec.spectral_kernel(wk, 8), geo,
                                  input_mode="nope")


class TestHaloGeometry:
    """The clamped halo blocks + one-hot gather tile every geometry."""

    @settings(max_examples=40, deadline=None)
    @given(h=st.integers(2, 34), w=st.integers(2, 34),
           k=st.sampled_from([3, 5]), K=st.sampled_from([8, 16]),
           block_p=st.integers(1, 64))
    def test_reference_equals_windowed_extraction(self, h, w, k, K,
                                                  block_p):
        geo = spec.make_geometry(h, w, k, K)
        hg = spec.halo_block_geometry(geo, block_p)
        rng = np.random.default_rng(h * 100 + w)
        x = jnp.asarray(rng.standard_normal((1, 2, h, w)), jnp.float32)
        ref = spec.extract_tiles_overlapping(x, geo)
        got = spec.halo_window_reference(x, geo, hg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_blocks_cover_tile_grid(self):
        for (h, w, k, K, bp) in [(224, 224, 3, 8, 128), (14, 14, 3, 8, 9),
                                 (11, 7, 5, 8, 3), (2, 2, 3, 8, 16)]:
            geo = spec.make_geometry(h, w, k, K)
            hg = spec.halo_block_geometry(geo, bp)
            assert hg.nbh * hg.bth >= geo.n_tiles_h
            assert hg.nbw * hg.btw >= geo.n_tiles_w
            assert hg.block_tiles <= max(1, bp)
            assert hg.rh <= geo.h_in and hg.rw <= geo.w_in
            sh, sw = spec.halo_block_starts(geo, hg)
            assert (sh >= 0).all() and (sh + hg.rh <= geo.h_in).all()
            assert (sw >= 0).all() and (sw + hg.rw <= geo.w_in).all()


class TestRepricedCostModel:
    def test_halo_input_bytes_below_windowed_all_layers(self):
        """Acceptance: raw-plus-halo input words beat the materialized
        window stream on every VGG16 layer and flow."""
        for layer in df.VGG16_LAYERS:
            for flow in df.FLOWS:
                w = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                           flow, input_mode="windowed")
                h = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                           flow, input_mode="halo")
                assert (h["input_hbm_bytes"]
                        < w["input_hbm_bytes"]), (layer.name, flow)
                assert h["hbm_bytes"] < w["hbm_bytes"], (layer.name, flow)

    def test_input_share_accounted(self):
        """input + kernel shares never exceed the total."""
        layer = df.VGG16_LAYERS[5]
        for imode in df.INPUT_MODES:
            c = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                       "output_stationary",
                                       input_mode=imode)
            assert c["input_mode"] == imode
            assert (c["input_hbm_bytes"] + c["kernel_hbm_bytes"]
                    <= c["hbm_bytes"])

    def test_legacy_default_is_windowed(self):
        layer = df.VGG16_LAYERS[3]
        legacy = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                        "output_stationary")
        windowed = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                          "output_stationary",
                                          input_mode="windowed")
        assert legacy == windowed

    def test_bad_input_mode_raises(self):
        with pytest.raises(ValueError, match="input_mode"):
            df.tpu_fused_flow_cost(df.VGG16_LAYERS[0], 8, 4.0, 64, 128,
                                   64, "output_stationary",
                                   input_mode="nope")


class TestInputModeAutotune:
    def test_axis_picks_halo_on_vgg16(self):
        """With both modes offered, the repriced input bytes make halo
        the winner on every VGG16 layer."""
        for layer in df.VGG16_LAYERS:
            tn = autotune.autotune_layer(
                layer, 8, 4.0, input_modes=df.INPUT_MODES)
            assert tn.input_mode == "halo", layer.name

    def test_ws_halo_eligible_at_batch_gt_1(self):
        """Manual-DMA accumulators (PR 8) lift the batch-1 limit on halo
        weight-stationary: the tuner may now pick it at batch 2, and
        whatever it picks must validate in a built plan (hw_safe is a
        no-op)."""
        layer = df.ConvLayer("tiny", 4, 8, 12, 12)
        tn = autotune.autotune_layer(
            layer, 8, 4.0, batch=2, flows=("weight_stationary",),
            input_modes=df.INPUT_MODES)
        assert tn.input_mode in df.INPUT_MODES
        # halo is no longer excluded from the candidate set
        cands = [
            (f, bn, bm, bp)
            for f, bn, bm, bp in autotune._layer_candidates(
                layer, 8, 2, autotune.BLOCK_CANDIDATES, True)]
        assert any(f == "weight_stationary" for f, *_ in cands)

    def test_legacy_mode_is_none(self):
        tn = autotune.autotune_layer(df.VGG16_LAYERS[3], 8, 4.0)
        assert tn.input_mode is None


class TestPlanIntegration:
    def test_auto_plan_records_mode_and_matches_oracle(self):
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(KEY, cfg)
        plan = build_network_plan(params, cfg, batch=1)
        assert all(lp.input_mode in df.INPUT_MODES for lp in plan.layers)
        assert any(lp.input_mode == "halo" for lp in plan.layers)
        for lp in plan.layers:
            assert lp.stats()["input_mode"] == lp.input_mode
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, 3, cfg.image_size, cfg.image_size))
        ref = cnn.forward_spectral(params, plan, x)
        out = cnn.forward_spectral(params, plan, x, backend="pallas_fused")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3)

    def test_forced_halo_equals_forced_windowed(self):
        """The windowed path stays available as the halo oracle: forcing
        either mode produces identical logits."""
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(KEY, cfg)
        x = jax.random.normal(jax.random.PRNGKey(2),
                              (1, 3, cfg.image_size, cfg.image_size))
        outs = {}
        for imode in df.INPUT_MODES:
            plan = build_network_plan(params, cfg, batch=1,
                                      input_mode=imode)
            assert all(lp.input_mode == imode for lp in plan.layers)
            outs[imode] = cnn.forward_spectral(params, plan, x,
                                               backend="pallas_fused")
        err = float(jnp.abs(outs["halo"] - outs["windowed"]).max())
        assert err <= 1e-6, err

    def test_bad_input_mode_raises(self):
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(KEY, cfg)
        with pytest.raises(ValueError, match="input_mode"):
            build_network_plan(params, cfg, input_mode="nope")
