"""Sharding rules + planner (the paper's Alg 1 at mesh scale)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import planner, sharding
from repro.models import api

MESH_1POD = {"data": 16, "model": 16}
MESH_2POD = {"pod": 2, "data": 16, "model": 16}


def _plan(fsdp=(), opt="adamw"):
    return sharding.ShardingPlan(batch_axes=("data",), fsdp=bool(fsdp),
                                 fsdp_axes=tuple(fsdp), optimizer=opt)


class TestParamSpecs:
    @pytest.mark.parametrize("arch", configs.ARCHS)
    def test_specs_mirror_params_and_divide(self, arch):
        """Every leaf gets a spec; every sharded dim divides evenly on the
        production mesh (the validation NamedSharding enforces)."""
        cfg = configs.get_config(arch)
        aparams = api.init_abstract(cfg)
        specs = sharding.params_pspec(_plan(), aparams, MESH_1POD)
        flat_p = jax.tree_util.tree_leaves(aparams)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for i, entry in enumerate(tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                ways = 1
                for a in axes:
                    ways *= MESH_1POD[a]
                assert leaf.shape[i] % ways == 0, (arch, leaf.shape, spec)

    def test_moe_experts_sharded_over_model(self):
        cfg = configs.get_config("kimi-k2-1t-a32b")
        aparams = api.init_abstract(cfg)
        specs = sharding.params_pspec(_plan(), aparams, MESH_1POD)
        gate_spec = specs["blocks"]["moe"]["w_gate"]
        assert tuple(gate_spec)[1] == "model"      # [L, E, d, f]

    def test_fsdp_adds_batch_axis_sharding(self):
        cfg = configs.get_config("qwen3-8b")
        aparams = api.init_abstract(cfg)
        tp = sharding.params_pspec(_plan(), aparams, MESH_1POD)
        fs = sharding.params_pspec(_plan(fsdp=("data",)), aparams,
                                   MESH_1POD)
        wq_tp = tuple(tp["blocks"]["attn"]["wq"])
        wq_fs = tuple(fs["blocks"]["attn"]["wq"])
        assert "data" not in str(wq_tp)
        assert ("data",) == wq_fs[1] or "data" == wq_fs[1]

    def test_divisibility_guard_replicates_odd_dims(self):
        spec = sharding._divisibility_guard(
            P("model", None), (51865, 64), MESH_1POD)
        assert tuple(spec) == (None, None)
        spec = sharding._divisibility_guard(
            P("model", None), (64, 64), MESH_1POD)
        assert tuple(spec)[0] == "model"


class TestOptStateSpecs:
    def test_adamw_mirrors_params(self):
        cfg = configs.get_smoke_config("qwen3-8b")
        aparams = api.init_abstract(cfg)
        pspecs = sharding.params_pspec(_plan(), aparams, MESH_1POD)
        ospecs = sharding.opt_state_pspec(_plan(), pspecs, aparams,
                                          "adamw")
        assert ospecs["mu"] == pspecs
        assert tuple(ospecs["count"]) == ()

    def test_adafactor_drops_reduced_axis(self):
        aparams = {"w": jax.ShapeDtypeStruct((512, 1024), jnp.float32)}
        pspecs = {"w": P("data", "model")}
        ospecs = sharding.opt_state_pspec(_plan(), pspecs, aparams,
                                          "adafactor")
        assert tuple(ospecs["v"]["w"]["vr"]) == ("data",)
        assert tuple(ospecs["v"]["w"]["vc"]) == ("model",)


class TestPlanner:
    def test_kimi_needs_fsdp_and_factored_opt(self):
        """The 1 T-param arch cannot train on 512 chips with plain
        TP+AdamW; the planner must stream weights (Flow-#2 analogue)."""
        cfg = configs.get_config("kimi-k2-1t-a32b")
        shape = configs.SHAPES["train_4k"]
        best, costs = planner.plan_cell(cfg, shape, MESH_2POD)
        assert best.fits
        assert best.plan.fsdp
        assert best.plan.optimizer == "adafactor"
        tp_adamw = next(c for c in costs
                        if not c.plan.fsdp and c.plan.optimizer == "adamw")
        assert not tp_adamw.fits

    def test_small_arch_train_prefers_weight_streaming(self):
        """At 1M-token batches a small model's weights are far cheaper to
        stream than its activations: the planner answers the title with
        Flow #2 (reuse activations, stream kernels = pure FSDP)."""
        cfg = configs.get_config("smollm-135m")
        best, _ = planner.plan_cell(cfg, configs.SHAPES["train_4k"],
                                    MESH_1POD)
        assert best.fits
        assert not best.plan.tp and best.plan.fsdp

    def test_decode_prefers_weight_residency(self):
        """One-token steps flip the answer: streaming weights per step
        would dwarf everything (Flow #1: reuse kernels)."""
        cfg = configs.get_config("qwen3-8b")
        best, _ = planner.plan_cell(cfg, configs.SHAPES["decode_32k"],
                                    MESH_1POD)
        assert best.plan.tp
        assert not best.plan.fsdp

    def test_decode_cells_fit_all_archs(self):
        for arch in configs.ARCHS:
            cfg = configs.get_config(arch)
            best, _ = planner.plan_cell(cfg, configs.SHAPES["decode_32k"],
                                        MESH_1POD)
            assert best.fits, arch

    def test_long_context_uses_seq_shard(self):
        cfg = configs.get_config("h2o-danube-1.8b")
        best, _ = planner.plan_cell(cfg, configs.SHAPES["long_500k"],
                                    MESH_1POD)
        assert best.plan.seq_shard

    def test_alg1_structure_feasibility_then_min_traffic(self):
        """Planner == Alg 1: reject over-capacity, minimize bandwidth."""
        cfg = configs.get_config("qwen3-8b")
        best, costs = planner.plan_cell(cfg, configs.SHAPES["train_4k"],
                                        MESH_1POD)
        feasible = [c for c in costs if c.fits]
        assert best.collective_bytes_per_step == min(
            c.collective_bytes_per_step for c in feasible)


def test_batch_pspec_shards_leading_dim():
    plan = _plan()
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = sharding.batch_pspec(plan, batch)
    assert tuple(specs["tokens"]) in ((("data",), None), ("data", None))
