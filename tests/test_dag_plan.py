"""Residual-DAG plan IR (ISSUE 10): oracle-diff harness + negative paths.

Five test families:

  1. Oracle diff — the spatial-domain reference of the full residual
     graph (``cnn.forward_spatial`` walks the SAME DAG: stride-2
     subsample, max/avg pools, shortcut adds before the ReLU) diffed
     against every spectral backend at <= 1e-5.  Parity runs at
     alpha = 1 (the spatial oracle does not prune; at alpha = 4 the
     deviation is pruning loss, not a DAG bug), parameterized across
     Hadamard modes and batch buckets; the 'scheduled' mode — which
     requires pruning — rides an einsum-oracle diff at alpha = 4, where
     both sides consume the same pruned kernels.
  2. Fault-driven demotion — an injected 'lowering' fault matched on
     ``residual='fused'`` must walk every residual node down the NEW
     ladder rung (residual-fused -> residual-add) and the hardened plan
     must still match the spatial oracle; the backend-axis ladder
     (``demote_layer_backend``) must flip the residual mode in the same
     step with its own provenance entry.
  3. Forced-mesh sharding — channel- and spatial-FORCED DAG execution
     under shard_map vs the spatial oracle.  In-process tests need >= 2
     devices (the CI sharded job forces 8); a subprocess smoke sets
     XLA_FLAGS itself so the default tier always exercises the
     residual-DAG collectives.
  4. Negative-path matrix — one test per ``PlanValidationError`` raise
     site of the DAG checks (duplicate/reserved id, unknown edge,
     cycle, conv-node/layer mismatch, producer-shape mismatch,
     shape-mismatched residual, unresolvable pool input), each
     asserting ``.layer`` AND ``.site``; plus the ``validate_graph``
     diagnostics on a corrupted built plan.
  5. Regressions — ``plan_cache_key`` golden snapshot (graph signature
     folded in) and ``health_report`` keyed by stable node ids.
"""

from __future__ import annotations

import dataclasses
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import resnet18_spectral
from repro.core import dataflow as df
from repro.core import plan as pl
from repro.core import resilience as res
from repro.models import cnn
from repro.testing import faults

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

MULTI_DEVICE = len(jax.devices()) >= 2
needs_mesh = pytest.mark.skipif(
    not MULTI_DEVICE,
    reason="needs >= 2 devices (run under XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")

SMOKE = resnet18_spectral.SMOKE
# Parity vs the spatial oracle is only defined dense: the oracle does
# not prune, so alpha = 4 would measure pruning loss (~2.4 abs), not
# DAG correctness.
DENSE = dataclasses.replace(SMOKE, alpha=1.0)

RESIDUAL_IDS = ("s1b1b", "s1b2b", "s2b1b", "s2b2b")


@pytest.fixture(scope="module")
def dense_setup():
    key = jax.random.PRNGKey(0)
    params = cnn.init(key, DENSE)
    x = jax.random.normal(key, (1, 3, DENSE.image_size,
                                DENSE.image_size), jnp.float32)
    plan = pl.build_network_plan(params, DENSE, batch=1)
    ref = cnn.forward_spatial(params, DENSE, x)
    return params, x, plan, ref


# ---------------------------------------------------------------------------
# 1. Oracle diff: spatial DAG reference vs every backend
# ---------------------------------------------------------------------------

def test_graph_composition(dense_setup):
    """The ResNet smoke DAG carries everything the acceptance criteria
    name: residual-FUSED epilogues, a stride-2 conv, max AND avg pool
    nodes, and a recorded ShortcutFusion reuse verdict per edge."""
    _, _, plan, _ = dense_setup
    graph = plan.execution_graph
    residual = [n for n in graph if n.residual_from is not None]
    assert sorted(n.id for n in residual) == sorted(RESIDUAL_IDS)
    for n in residual:
        lp = plan.layers[n.layer_index]
        assert lp.epilogue.residual == "fused"
        assert isinstance(n.shortcut_on_chip, bool)
        assert n.relu is True and lp.epilogue.relu is True
    strides = [plan.layers[n.layer_index].layer.stride
               for n in graph if n.kind == "conv"]
    assert strides.count(2) == 1
    assert sorted(n.pool for n in graph if n.kind == "pool") == \
        ["avg", "max"]


@pytest.mark.parametrize("backend",
                         ("einsum", "pallas_staged", "pallas_fused"))
def test_backend_parity_vs_spatial_oracle(dense_setup, backend):
    params, x, plan, ref = dense_setup
    y = cnn.forward_spectral(params, plan, x, backend=backend)
    assert float(jnp.abs(y - ref).max()) <= 1e-5


@pytest.mark.parametrize("hadamard", ("dense", "bin"))
def test_forced_hadamard_parity(dense_setup, hadamard):
    """The DAG walk is mode-agnostic: forcing the Hadamard stage keeps
    spatial-oracle parity through the residual epilogues."""
    params, x, _, ref = dense_setup
    plan = pl.build_network_plan(params, DENSE, batch=1,
                                 hadamard=hadamard)
    y = cnn.forward_spectral(params, plan, x, backend="pallas_fused")
    assert float(jnp.abs(y - ref).max()) <= 1e-5


def test_batch_bucket_parity(dense_setup):
    """A batch-tuned plan (its own Alg-1 block choices) walks the same
    DAG: parity holds at a serving bucket > 1 for both the fused kernel
    and the einsum rung."""
    params, _, _, _ = dense_setup
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (2, 3, DENSE.image_size,
                                DENSE.image_size), jnp.float32)
    ref = cnn.forward_spatial(params, DENSE, x)
    plan = pl.build_network_plan(params, DENSE, batch=2)
    for backend in ("einsum", "pallas_fused"):
        y = cnn.forward_spectral(params, plan, x, backend=backend)
        assert float(jnp.abs(y - ref).max()) <= 1e-5, backend


def test_scheduled_dag_parity_alpha4():
    """'scheduled' needs pruned kernels (Alg-2 tables exist only for
    alpha > 1), so its DAG parity is einsum-oracle: both sides consume
    the SAME pruned kernels and the diff isolates the datapath."""
    key = jax.random.PRNGKey(1)
    params = cnn.init(key, SMOKE)
    x = jax.random.normal(key, (1, 3, SMOKE.image_size,
                                SMOKE.image_size), jnp.float32)
    plan = pl.build_network_plan(params, SMOKE, batch=1,
                                 hadamard="scheduled")
    ref = cnn.forward_spectral(params, plan, x, backend="einsum")
    y = cnn.forward_spectral(params, plan, x, backend="pallas_fused")
    assert float(jnp.abs(y - ref).max()) <= 1e-5


def test_feature_dim_follows_graph_sink():
    """``cnn.feature_dim`` sizes the FC head from the DAG sink shape
    (head:pool), not the legacy pool_after count."""
    order = pl._topo_order_specs(SMOKE.graph)
    shapes = pl.node_output_shapes(list(SMOKE.layers), order)
    c, h, w = shapes[pl.graph_sink(order)]
    assert cnn.feature_dim(SMOKE) == c * h * w


# ---------------------------------------------------------------------------
# 2. Fault-driven demotion to the residual-add rung
# ---------------------------------------------------------------------------

def test_residual_demotion_rung_and_parity(dense_setup):
    """An injected lowering fault on every residual-FUSED variant walks
    the NEW ladder rung; the hardened plan answers like the oracle."""
    params, x, plan, ref = dense_setup
    with faults.inject("lowering", residual="fused") as fault:
        hard = res.harden_network_plan(plan)
    assert fault.fires > 0
    for node in hard.execution_graph:
        if node.residual_from is None:
            continue
        lp = hard.layers[node.layer_index]
        assert lp.epilogue.residual == "add"
        assert lp.epilogue.relu is False          # relu moves post-add
        assert any("residual-fused->residual-add" in p
                   for p in lp.provenance), lp.provenance
    y = cnn.forward_spectral(params, hard, x, backend="pallas_fused")
    assert float(jnp.abs(y - ref).max()) <= 1e-5
    hr = hard.health_report()
    assert set(RESIDUAL_IDS) <= set(hr["demotions_by_node"])


def test_backend_ladder_flips_residual(dense_setup):
    """The load ladder (backend axis) cannot keep an in-kernel add off
    the fused backend: leaving 'fused' flips residual-fused -> add in
    the same step, with its own provenance entry."""
    _, _, plan, _ = dense_setup
    lp = next(lp for lp in plan.layers
              if lp.epilogue.residual == "fused")
    demoted = res.demote_layer_backend(lp, reason="load test")
    assert demoted.backend == "staged"
    assert demoted.epilogue.residual == "add"
    assert any("residual-fused->residual-add (backend demotion)" in p
               for p in demoted.provenance)


# ---------------------------------------------------------------------------
# 3. Forced-mesh sharded DAG execution
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("strategy", ("channel", "spatial"))
def test_forced_strategy_dag_parity(dense_setup, strategy):
    from repro.distributed.executor import forward_spectral_sharded
    from repro.launch.mesh import make_spectral_mesh

    params, x, _, ref = dense_setup
    splan = pl.build_sharded_network_plan(
        params, DENSE, n_shards=2, strategies=(strategy,), batch=1)
    y = forward_spectral_sharded(params, splan, x,
                                 mesh=make_spectral_mesh(2))
    assert float(jnp.abs(y - ref).max()) <= 1e-5


def test_sharded_residual_dag_subprocess_smoke():
    """Always-on collective coverage: a subprocess forces 8 host
    devices and runs a tiny residual DAG (conv -> conv+shortcut ->
    pool) under both forced strategies vs the spatial oracle."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core import dataflow as df
        from repro.core import plan as pl
        from repro.distributed.executor import forward_spectral_sharded
        from repro.launch.mesh import make_spectral_mesh
        from repro.models import cnn

        cfg = cnn.SpectralCNNConfig(
            name="tiny-residual", alpha=1.0, n_classes=4,
            image_size=16, fc_dim=16,
            layers=(df.ConvLayer("c1", 4, 8, 16, 16),
                    df.ConvLayer("c2", 8, 8, 16, 16)),
            pool_after=frozenset(),
            graph=(df.NodeSpec(id="c1"),
                   df.NodeSpec(id="c2", inputs=("c1",),
                               residual_from="c1"),
                   df.NodeSpec(id="c2:pool", kind="pool",
                               inputs=("c2",))))
        key = jax.random.PRNGKey(0)
        params = cnn.init(key, cfg)
        x = jax.random.normal(key, (2, 4, 16, 16), jnp.float32)
        ref = cnn.forward_spatial(params, cfg, x)
        for D, strats in [(4, ("channel",)), (2, ("spatial",))]:
            splan = pl.build_sharded_network_plan(
                params, cfg, n_shards=D, batch=2, strategies=strats)
            y = forward_spectral_sharded(
                params, splan, x, mesh=make_spectral_mesh(D),
                interpret=True)
            err = float(jnp.abs(y - ref).max())
            assert err <= 1e-5, (strats, err)
        print("RESIDUAL_DAG_SHARDED_OK")
    """)
    r = subprocess.run([sys.executable, "-c", script],
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RESIDUAL_DAG_SHARDED_OK" in r.stdout


# ---------------------------------------------------------------------------
# 4. Negative-path matrix: one test per PlanValidationError site
# ---------------------------------------------------------------------------

def _spec(id, **kw):
    return df.NodeSpec(id=id, **kw)


def test_site_graph_duplicate_id():
    with pytest.raises(res.PlanValidationError) as ei:
        pl._topo_order_specs([_spec("a"), _spec("a", inputs=("a",))])
    assert ei.value.site == "graph" and ei.value.layer == "a"


def test_site_graph_reserved_id():
    with pytest.raises(res.PlanValidationError) as ei:
        pl._topo_order_specs([_spec("input")])
    assert ei.value.site == "graph" and ei.value.layer == "input"


def test_site_graph_unknown_reference():
    with pytest.raises(res.PlanValidationError) as ei:
        pl._topo_order_specs([_spec("a", inputs=("ghost",))])
    assert ei.value.site == "graph" and ei.value.layer == "a"


def test_site_graph_unknown_residual_reference():
    with pytest.raises(res.PlanValidationError) as ei:
        pl._topo_order_specs([_spec("a", residual_from="ghost")])
    assert ei.value.site == "graph" and ei.value.layer == "a"


def test_site_graph_cycle():
    with pytest.raises(res.PlanValidationError) as ei:
        pl._topo_order_specs([_spec("a", inputs=("b",)),
                              _spec("b", inputs=("a",))])
    assert ei.value.site == "graph"
    assert ei.value.layer in ("a", "b")


def test_site_graph_conv_nodes_must_cover_layers():
    """A config graph that omits (or invents) a conv layer fails at
    build, before any spectral work happens."""
    cfg = cnn.SpectralCNNConfig(
        name="bad-cover", alpha=1.0, n_classes=4, image_size=16,
        fc_dim=16,
        layers=(df.ConvLayer("c1", 3, 4, 16, 16),
                df.ConvLayer("c2", 4, 4, 16, 16)),
        pool_after=frozenset(),
        graph=(df.NodeSpec(id="c1"),))     # c2 missing
    key = jax.random.PRNGKey(0)
    params = cnn.init(key, dataclasses.replace(cfg, graph=None))
    with pytest.raises(res.PlanValidationError) as ei:
        pl.build_network_plan(params, cfg, batch=1)
    assert ei.value.site == "graph"


def test_site_graph_input_shape_mismatch():
    layers = [df.ConvLayer("c1", 3, 4, 16, 16),
              df.ConvLayer("c2", 8, 4, 16, 16)]   # wants 8ch, gets 4
    with pytest.raises(res.PlanValidationError) as ei:
        pl.node_output_shapes(
            layers, [_spec("c1"), _spec("c2", inputs=("c1",))])
    assert ei.value.site == "graph/input-shape"
    assert ei.value.layer == "c2"


def test_site_graph_residual_shape_mismatch():
    layers = [df.ConvLayer("c1", 3, 4, 16, 16),
              df.ConvLayer("c2", 4, 8, 16, 16)]   # 8ch out vs 4ch sc
    with pytest.raises(res.PlanValidationError) as ei:
        pl.node_output_shapes(
            layers, [_spec("c1"),
                     _spec("c2", inputs=("c1",), residual_from="c1")])
    assert ei.value.site == "graph/residual-shape"
    assert ei.value.layer == "c2"


def test_site_graph_stride_breaks_residual_shape():
    """A stride-2 conv halves its output: an identity shortcut from the
    full-resolution producer must be rejected, not silently broadcast."""
    layers = [df.ConvLayer("c1", 3, 4, 16, 16),
              df.ConvLayer("c2", 4, 4, 16, 16, stride=2)]
    with pytest.raises(res.PlanValidationError) as ei:
        pl.node_output_shapes(
            layers, [_spec("c1"),
                     _spec("c2", inputs=("c1",), residual_from="c1")])
    assert ei.value.site == "graph/residual-shape"


def test_site_graph_pool_without_resolvable_input():
    with pytest.raises(res.PlanValidationError) as ei:
        pl.node_output_shapes([], [_spec("p", kind="pool")])
    assert ei.value.site == "graph/input-shape"
    assert ei.value.layer == "p"


def test_site_graph_conv_without_layer():
    with pytest.raises(res.PlanValidationError) as ei:
        pl.node_output_shapes([], [_spec("ghost")])
    assert ei.value.site == "graph/input-shape"
    assert ei.value.layer == "ghost"


def test_validate_plan_flags_corrupt_graph(dense_setup):
    """A built plan whose stored graph rots (here: a duplicated node
    id) fails ``validate_plan`` with site='validate_plan' and a
    graph/node-id diagnostic carrying the node id."""
    _, _, plan, _ = dense_setup
    graph = plan.execution_graph
    bad = dataclasses.replace(
        plan, graph=graph + (dataclasses.replace(graph[0]),))
    with pytest.raises(res.PlanValidationError) as ei:
        res.validate_plan(bad)
    assert ei.value.site == "validate_plan"
    assert any(d.check == "graph/node-id" for d in ei.value.diagnostics)


def test_validate_graph_rejects_residual_fused_off_fused_backend(
        dense_setup):
    """residual='fused' is an in-kernel epilogue: on any other backend
    the plan must carry a graph/residual-fused error diagnostic."""
    _, _, plan, _ = dense_setup
    idx, lp = next(
        (i, lp) for i, lp in enumerate(plan.layers)
        if lp.epilogue.residual == "fused")
    layers = list(plan.layers)
    layers[idx] = dataclasses.replace(lp, backend="staged")
    bad = dataclasses.replace(plan, layers=tuple(layers))
    diags = res.validate_plan(bad, raise_on_error=False)
    mine = [d for d in diags if d.check == "graph/residual-fused"]
    assert mine and mine[0].layer == lp.layer.name
    assert mine[0].severity == "error"


def test_validate_graph_rejects_bad_topo_order(dense_setup):
    _, _, plan, _ = dense_setup
    graph = plan.execution_graph
    bad = dataclasses.replace(plan, graph=graph[::-1])
    diags = res.validate_plan(bad, raise_on_error=False)
    assert any(d.check == "graph/order" for d in diags)


def test_validate_graph_rejects_bad_layer_index(dense_setup):
    _, _, plan, _ = dense_setup
    graph = list(plan.execution_graph)
    conv = next(i for i, n in enumerate(graph) if n.kind == "conv")
    graph[conv] = dataclasses.replace(graph[conv], layer_index=999)
    bad = dataclasses.replace(plan, graph=tuple(graph))
    diags = res.validate_plan(bad, raise_on_error=False)
    assert any(d.check == "graph/layer-index" and d.layer ==
               graph[conv].id for d in diags)


# ---------------------------------------------------------------------------
# 5. Regressions: cache-key golden snapshot + node-id health report
# ---------------------------------------------------------------------------

class _GoldCfg:
    name = "golden"
    fft_size = 8
    alpha = 4.0
    layers = (df.ConvLayer("c1", 4, 8, 16, 16),)
    pool_after = frozenset()
    graph = (df.NodeSpec(id="c1"),)


def test_plan_cache_key_golden_snapshot():
    """The exact key tuple is a compatibility contract (serving caches
    persist across plan rebuilds): any field added to or reordered in
    the key invalidates every cache — change this snapshot ONLY with a
    deliberate cache-version bump."""
    key = pl.plan_cache_key(_GoldCfg, 2, mesh_shape=(2,),
                            hadamard="scheduled")
    assert key == (
        "golden", 8, (4.0,), 2,
        ("mesh", (2,)),
        ("graph", (("c1", "conv", ("input",), "max", None, True),)),
        (("hadamard", "'scheduled'"),),
    )


def test_plan_cache_key_axes_distinct():
    """Every axis the issue names — backend-ish build kwargs, hadamard,
    input_mode, batch, mesh_shape and the DAG fields — must produce a
    distinct key."""
    base = pl.plan_cache_key(_GoldCfg, 1)

    class NoGraph(_GoldCfg):
        graph = None

    class Rewired(_GoldCfg):
        graph = (df.NodeSpec(id="c1", residual_from="input"),)

    class NoRelu(_GoldCfg):
        graph = (df.NodeSpec(id="c1", relu=False),)

    variants = [
        pl.plan_cache_key(_GoldCfg, 2),
        pl.plan_cache_key(_GoldCfg, 1, mesh_shape=(2,)),
        pl.plan_cache_key(_GoldCfg, 1, mesh_shape=(1,)),
        pl.plan_cache_key(_GoldCfg, 1, hadamard="dense"),
        pl.plan_cache_key(_GoldCfg, 1, input_mode="halo"),
        pl.plan_cache_key(NoGraph, 1),
        pl.plan_cache_key(Rewired, 1),
        pl.plan_cache_key(NoRelu, 1),
    ]
    keys = [base] + variants
    assert len(set(keys)) == len(keys)


def test_health_report_keyed_by_node_ids(dense_setup):
    """Rows (and demotion provenance) key by STABLE node id — pool
    nodes included — so a DAG rebuild that reorders layers can never
    misattribute a demotion (the ISSUE 10 health_report fix)."""
    _, _, plan, _ = dense_setup
    hr = plan.health_report()
    ids = [r["node"] for r in hr["layers"]]
    assert ids == [n.id for n in plan.execution_graph]
    assert "stem:pool" in ids and "head:pool" in ids
    pool_rows = [r for r in hr["layers"] if r["kind"] == "pool"]
    assert {r["pool"] for r in pool_rows} == {"max", "avg"}
    with faults.inject("lowering", residual="fused"):
        hard = res.harden_network_plan(plan)
    hr2 = hard.health_report()
    assert set(RESIDUAL_IDS) <= set(hr2["demotions_by_node"])
    for nid in RESIDUAL_IDS:
        assert any("residual-fused->residual-add" in p
                   for p in hr2["demotions_by_node"][nid])
