"""Overload-resilient spectral serving: admission control, deadlines,
batch bucketing over the warmed plan cache, the load-triggered
degradation ladder, per-backend circuit breakers, serve-level fault
sites and the chaos soak."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import vgg16_spectral
from repro.core import resilience as res
from repro.core.plan import PlanCache
from repro.launch import spectral_serve as ss
from repro.models import cnn
from repro.testing import faults

CFG = vgg16_spectral.SMOKE
BUCKETS = (1, 2)
PLAN_KW = {"hadamard": "scheduled"}   # gives serve_plan_cache a table


@pytest.fixture(scope="module")
def shared_cache():
    """One PlanCache for the whole module — plan builds are the
    expensive part, and every server here uses the same (cfg, buckets,
    build kwargs), so they can share compiled plans."""
    return PlanCache()


def make_server(shared_cache, **kw):
    clock = ss.ManualClock()
    kw.setdefault("buckets", BUCKETS)
    kw.setdefault("queue_limit", 4)
    kw.setdefault("plan_kwargs", dict(PLAN_KW))
    srv = ss.SpectralServer(CFG, clock=clock, plan_cache=shared_cache,
                            **kw)
    return srv, clock


def oracle(srv, images):
    """Einsum-oracle logits for a stack of [C,H,W] images."""
    b = len(images)
    plan = srv.plans.get(srv.params, CFG, srv._bucket_for(b),
                         **srv.plan_kwargs)
    x = np.zeros((srv._bucket_for(b),) + srv.image_shape, np.float32)
    for i, img in enumerate(images):
        x[i] = img
    y = cnn.forward_spectral(srv.params, plan, jnp.asarray(x),
                             backend="einsum")
    return np.asarray(y)[:b]


def test_admission_control_and_shedding(shared_cache):
    """Queue is bounded: excess requests shed immediately with a
    structured 'overloaded' response; malformed images fail
    structurally; nothing queues unboundedly."""
    srv, _ = make_server(shared_cache, queue_limit=2)
    reqs = ss.synthetic_requests(5, CFG, seed=0)
    bad = ss.InferenceRequest(rid=99, image=np.zeros((1, 4, 4),
                                                     np.float32))
    for r in reqs:
        srv.submit(r)
    srv.submit(bad)
    assert [r.code for r in reqs] == [None, None, "overloaded",
                                      "overloaded", "overloaded"]
    assert all("queue full" in r.error for r in reqs[2:])
    assert bad.code == "failed" and "bad_request" in bad.error
    assert len(srv.queue) == 2
    stats = srv.run_until_drained()
    assert all(r.terminal for r in reqs)
    assert stats["counters"]["ok"] == 2
    assert stats["counters"]["overloaded"] == 3
    assert stats["loop_deaths"] == 0


def test_deadline_expiry_before_execution(shared_cache):
    """A queued request whose deadline passes retires with
    'deadline_exceeded' and never touches a kernel; requests with
    slack execute normally."""
    srv, clock = make_server(shared_cache)
    tight = ss.synthetic_requests(2, CFG, seed=1, deadline_s=1.0)
    loose = ss.synthetic_requests(1, CFG, seed=7, rid0=10)[0]
    for r in tight:
        srv.submit(r)
    srv.submit(loose)
    clock.advance(2.0)          # past the tight deadlines, pre-exec
    srv.run_until_drained()
    assert [r.code for r in tight] == ["deadline_exceeded"] * 2
    assert all(r.logits is None for r in tight)
    assert loose.code == "ok"
    ref = oracle(srv, [loose.image])
    assert float(np.abs(ref[0] - loose.logits).max()) <= 1e-5


def test_bucketing_parity_and_warm_cache(shared_cache):
    """Requests are padded into the smallest fitting bucket and the
    answers match the einsum oracle; serving never triggers a plan
    build (the cache was warmed at startup)."""
    srv, _ = make_server(shared_cache, queue_limit=8)
    builds_before = srv.plans.builds
    reqs = ss.synthetic_requests(3, CFG, seed=2)
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained()
    assert stats["counters"]["ok"] == 3
    # 3 requests over buckets (1, 2): one batch of 2, one of 1
    assert stats["batches"] == 2
    assert all(r.rung == "fused" for r in reqs)
    ref = oracle(srv, [r.image for r in reqs[:2]])
    for r, y in zip(reqs[:2], ref):
        assert float(np.abs(y - r.logits).max()) <= 1e-5
    assert srv.plans.builds == builds_before   # zero request-path builds


def test_load_ladder_demotes_and_promotes(shared_cache):
    """Queue pressure >= demote_pressure demotes the serving rung one
    step; pressure clearing promotes back, and every transition (with
    the pressure that drove it) is in health_report()."""
    srv, _ = make_server(shared_cache, queue_limit=4,
                         demote_patience=1, promote_patience=1)
    reqs = ss.synthetic_requests(4, CFG, seed=3)
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained()
    health = srv.health_report()
    assert stats["demotions"] >= 1 and stats["promotions"] >= 1
    dirs = [t["direction"] for t in health["transitions"]]
    assert "demote" in dirs and "promote" in dirs
    assert all({"tick", "t", "from", "to", "reason", "pressure"}
               <= set(t) for t in health["transitions"])
    # first transition: full queue -> one rung down
    first = health["transitions"][0]
    assert (first["direction"], first["from"], first["to"]) == \
        ("demote", "fused", "staged")
    assert first["pressure"] >= srv.demote_pressure
    # pressure cleared -> back on the fast path
    assert health["rung"] == "fused"
    assert all(r.code == "ok" for r in reqs)


def test_serve_kernel_fault_retries_down_and_breaker_recovers(
        shared_cache):
    """A kernel fault mid-request fails onto the next rung within the
    same tick (no dropped request), opens the backend's breaker, and
    the breaker walks open -> half_open -> closed once the fault
    clears and the cooldown elapses."""
    srv, clock = make_server(shared_cache, breaker_failures=1,
                             breaker_cooldown_s=1.0)
    r1 = ss.synthetic_requests(1, CFG, seed=4)[0]
    with faults.inject("serve_kernel", backend="fused") as fault:
        srv.submit(r1)
        srv.run_until_drained(cooldown_ticks=0)
    assert fault.fires == 1
    assert r1.code == "ok" and r1.rung == "staged"
    ref = oracle(srv, [r1.image])
    assert float(np.abs(ref[0] - r1.logits).max()) <= 1e-5
    brk = srv.breakers["fused"]
    assert brk.state == "open" and brk.n_opens == 1

    # still inside the cooldown: fused is skipped without an attempt
    r2 = ss.synthetic_requests(1, CFG, seed=5, rid0=1)[0]
    srv.submit(r2)
    srv.run_until_drained(cooldown_ticks=0)
    assert r2.code == "ok" and r2.rung == "staged"
    assert brk.state == "open"

    # cooldown elapsed: half-open probe succeeds and closes the breaker
    clock.advance(2.0)
    r3 = ss.synthetic_requests(1, CFG, seed=6, rid0=2)[0]
    srv.submit(r3)
    srv.run_until_drained(cooldown_ticks=0)
    assert r3.code == "ok" and r3.rung == "fused"
    assert brk.state == "closed"
    states = [t["to"] for t in brk.transitions]
    assert states == ["open", "half_open", "closed"]


def test_all_rungs_failing_is_a_structured_failure(shared_cache):
    """Even when every rung (einsum included) faults, the request gets
    a terminal 'failed' response and the loop survives."""
    srv, _ = make_server(shared_cache)
    req = ss.synthetic_requests(1, CFG, seed=8)[0]
    with faults.inject("serve_kernel"):        # no match: all backends
        srv.submit(req)
        stats = srv.run_until_drained(cooldown_ticks=0)
    assert req.code == "failed"
    assert "einsum" in req.error
    assert stats["loop_deaths"] == 0
    # and the server still works afterwards
    ok = ss.synthetic_requests(1, CFG, seed=9, rid0=1)[0]
    srv.submit(ok)
    srv.run_until_drained(cooldown_ticks=0)
    assert ok.code == "ok"


def test_plan_cache_corruption_served_by_einsum(shared_cache):
    """A corrupted plan coming out of the cache is caught by
    validate_plan on fetch and the batch is served via the einsum
    terminal rung (which never reads the tables) — exact answers, no
    silent execution of a bad plan."""
    srv, _ = make_server(shared_cache)
    req = ss.synthetic_requests(1, CFG, seed=10)[0]
    with faults.inject("serve_plan_cache") as fault:
        srv.submit(req)
        srv.run_until_drained(cooldown_ticks=0)
        assert 1 in srv.health_report()["plan_cache"]["corrupt_buckets"]
    assert fault.fires >= 1
    assert req.code == "ok" and req.rung == "einsum"
    ref = oracle(srv, [req.image])
    assert float(np.abs(ref[0] - req.logits).max()) == 0.0
    assert srv.counters["plan_cache_corruptions"] >= 1
    # corruption cleared: next fetch validates pristine and recovers
    ok = ss.synthetic_requests(1, CFG, seed=11, rid0=1)[0]
    srv.submit(ok)
    srv.run_until_drained(cooldown_ticks=0)
    assert ok.code == "ok" and ok.rung == "fused"
    assert srv.health_report()["plan_cache"]["corrupt_buckets"] == []


def test_slow_injection_advances_clock_and_counts(shared_cache):
    """serve_slow adds service seconds on the virtual clock (deadline
    pressure without wall-clock sleeps) and is counted."""
    srv, clock = make_server(shared_cache)
    req = ss.synthetic_requests(1, CFG, seed=12)[0]
    t0 = clock()
    with faults.inject("serve_slow"):
        srv.submit(req)
        srv.run_until_drained(cooldown_ticks=0)
    assert req.code == "ok"
    assert clock() - t0 == pytest.approx(faults.SLOW_EXTRA_S)
    assert srv.counters["slow_injections"] == 1
    assert req.latency_s >= faults.SLOW_EXTRA_S


def test_loop_death_is_contained(shared_cache, monkeypatch):
    """A tick-level exception (outside per-request isolation) is
    counted as a loop death, fails at most the queue head, and the
    drain continues for everyone else."""
    srv, _ = make_server(shared_cache, queue_limit=8)
    reqs = ss.synthetic_requests(4, CFG, seed=13)
    for r in reqs:
        srv.submit(r)
    real = srv._take_batch
    calls = {"n": 0}

    def explode_once(now):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected tick explosion")
        return real(now)

    monkeypatch.setattr(srv, "_take_batch", explode_once)
    stats = srv.run_until_drained()
    assert stats["loop_deaths"] == 1
    assert all(r.terminal for r in reqs)
    assert sum(r.code == "failed" for r in reqs) == 1
    assert sum(r.code == "ok" for r in reqs) == 3


def test_chaos_soak_drains_with_all_gates(shared_cache):
    """ISSUE 7 acceptance: the deterministic 4x-capacity fault-injected
    burst drains with zero loop deaths, every request terminal, excess
    shed, >= 1 load demotion AND promotion, every fault site exercised
    and every completed answer within 1e-5 of the einsum oracle."""
    rep = faults.chaos_soak(queue_limit=8, seed=0)
    assert rep["failed_gates"] == [], rep["gates"]
    assert rep["requests"] >= 4 * rep["queue_limit"]
    assert rep["stats"]["loop_deaths"] == 0
    assert rep["oracle_max_abs_err"] <= 1e-5
    health = rep["health"]
    dirs = [t["direction"] for t in health["transitions"]]
    assert "demote" in dirs and "promote" in dirs


def test_synthetic_requests_deterministic():
    a = ss.synthetic_requests(3, CFG, seed=42)
    b = ss.synthetic_requests(3, CFG, seed=42)
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.image, rb.image)
    c = ss.synthetic_requests(1, CFG, seed=43)
    assert float(np.abs(a[0].image - c[0].image).max()) > 0
