"""Geometry property suite (ISSUE 10 satellite).

Pins the closed-form invariants of the three geometry helpers the DAG
executors lean on — ``crop_canvas_same``, ``make_band_geometry`` and
``halo_block_starts`` — at AWKWARD extents (odd H/W, tile size not
dividing H, halo overlap k-1 comparable to the band height), plus the
stride-2 / pool output-shape algebra that ``node_output_shapes`` walks.

Every property runs twice: a seeded deterministic sweep over a fixed
awkward-extent grid (always on, any environment), and a ``hypothesis``
``@given`` version over the same ranges when the package is installed
(the conftest stub turns those into skips otherwise; the CI profile is
pinned — fixed seed via ``derandomize``, no deadline).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dataflow as df
from repro.core import plan as pl
from repro.core import spectral as spec

K, KSIZE = 8, 3
# Odd extents, extents the t=6 tile does not divide, sub-tile images,
# and rectangles — every past off-by-one in the crop/halo/band algebra
# lived at one of these.
AWKWARD_HW = [(7, 7), (13, 9), (17, 31), (33, 20), (31, 31), (12, 40),
              (5, 23), (25, 6)]


# ---------------------------------------------------------------------------
# Properties (shared by the seeded sweep and the hypothesis versions)
# ---------------------------------------------------------------------------

def _crop_property(h: int, w: int) -> None:
    """'same' crop: output is exactly H x W and row/col (i, j) of the
    output reads canvas (i + k-1-pad, j + k-1-pad) — checked on an
    arange canvas, so any off-by-one shifts a value, not just a shape."""
    geo = spec.make_geometry(h, w, KSIZE, K)
    canvas = np.arange(geo.h_pad * geo.w_pad, dtype=np.float32)
    canvas = canvas.reshape(1, 1, geo.h_pad, geo.w_pad)
    out = np.asarray(spec.crop_canvas_same(canvas, geo))
    assert out.shape == (1, 1, h, w)
    start = KSIZE - 1 - geo.pad
    np.testing.assert_array_equal(
        out[0, 0], canvas[0, 0, start:start + h, start:start + w])


def _band_property(h: int, w: int, n_shards: int) -> None:
    """Band geometry: h_in counts the k-1 halo rows on top of whole
    tile rows, the canvas is exactly the band's tiles, pre_halo_h marks
    the halo, and the W axis is inherited untouched — including bands
    short enough that the halo dominates (k-1 >= band rows)."""
    geo = spec.make_geometry(h, w, KSIZE, K)
    tr = spec.shard_band_rows(geo, n_shards)
    band = spec.make_band_geometry(geo, tr)
    ov = KSIZE - 1
    assert band.h_in == ov + tr * geo.tile
    assert band.h_pad == tr * geo.tile
    assert band.pre_halo_h == ov
    assert band.n_tiles_h == tr
    assert (band.w_in, band.w_pad, band.n_tiles_w) == \
        (geo.w_in, geo.w_pad, geo.n_tiles_w)
    assert (band.fft_size, band.tile, band.ksize, band.pad) == \
        (geo.fft_size, geo.tile, geo.ksize, geo.pad)


def _halo_starts_property(h: int, w: int, block_p: int) -> None:
    """Halo block starts stay inside the raw image after clamping, are
    monotonically non-decreasing, and the block grid covers the whole
    tile canvas."""
    geo = spec.make_geometry(h, w, KSIZE, K)
    hg = spec.halo_block_geometry(geo, block_p)
    sh, sw = spec.halo_block_starts(geo, hg)
    assert sh.shape == (hg.nbh,) and sw.shape == (hg.nbw,)
    assert sh.min() >= 0 and sh.max() + hg.rh <= geo.h_in
    assert sw.min() >= 0 and sw.max() + hg.rw <= geo.w_in
    assert (np.diff(sh) >= 0).all() and (np.diff(sw) >= 0).all()
    assert hg.nbh * hg.bth >= geo.n_tiles_h
    assert hg.nbw * hg.btw >= geo.n_tiles_w
    assert hg.rh <= geo.h_in and hg.rw <= geo.w_in


def _stride_pool_property(h: int, w: int, stride: int) -> None:
    """The DAG shape algebra: a stride-s conv emits ceil(h1/s) rows of
    the stride-1 'same' extent h1 (the executor subsamples
    ``[::stride]``), and a 2x2 pool floors — odd edge rows drop.
    ``node_output_shapes`` must agree with ``ConvLayer.out_hw`` and
    with the executor's actual slicing."""
    c1 = df.ConvLayer("c1", 3, 4, h, w)
    c2 = df.ConvLayer("c2", 4, 4, *c1.out_hw, stride=stride)
    h1 = h + 2 * c2.pad - c2.ksize + 1
    w1 = w + 2 * c2.pad - c2.ksize + 1
    assert c2.out_hw == (-(-h1 // stride), -(-w1 // stride))
    # the subsample the executor performs produces exactly out_hw
    assert len(range(0, h1, stride)) == c2.out_hw[0]
    assert len(range(0, w1, stride)) == c2.out_hw[1]
    shapes = pl.node_output_shapes(
        [c1, c2],
        [df.NodeSpec(id="c1"),
         df.NodeSpec(id="c2", inputs=("c1",)),
         df.NodeSpec(id="c2:pool", kind="pool", inputs=("c2",))])
    assert shapes["c2"] == (4, *c2.out_hw)
    assert shapes["c2:pool"] == (4, c2.out_hw[0] // 2,
                                 c2.out_hw[1] // 2)


# ---------------------------------------------------------------------------
# Seeded deterministic sweeps (always on)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("h,w", AWKWARD_HW)
def test_crop_canvas_same_awkward_extents(h, w):
    _crop_property(h, w)


@pytest.mark.parametrize("h,w", AWKWARD_HW)
@pytest.mark.parametrize("n_shards", (1, 2, 3))
def test_band_geometry_awkward_extents(h, w, n_shards):
    _band_property(h, w, n_shards)


@pytest.mark.parametrize("h,w", AWKWARD_HW)
@pytest.mark.parametrize("block_p", (1, 3, 7, 64))
def test_halo_starts_awkward_extents(h, w, block_p):
    _halo_starts_property(h, w, block_p)


@pytest.mark.parametrize("h,w", AWKWARD_HW)
@pytest.mark.parametrize("stride", (1, 2, 3))
def test_stride_pool_shapes_awkward_extents(h, w, stride):
    _stride_pool_property(h, w, stride)


# ---------------------------------------------------------------------------
# Hypothesis versions (skip when hypothesis is absent; pinned profile)
# ---------------------------------------------------------------------------

@settings(deadline=None, derandomize=True, max_examples=60)
@given(h=st.integers(5, 64), w=st.integers(5, 64))
def test_crop_canvas_same_property(h, w):
    _crop_property(h, w)


@settings(deadline=None, derandomize=True, max_examples=60)
@given(h=st.integers(5, 64), w=st.integers(5, 64),
       n_shards=st.integers(1, 4))
def test_band_geometry_property(h, w, n_shards):
    _band_property(h, w, n_shards)


@settings(deadline=None, derandomize=True, max_examples=60)
@given(h=st.integers(5, 64), w=st.integers(5, 64),
       block_p=st.integers(1, 128))
def test_halo_starts_property(h, w, block_p):
    _halo_starts_property(h, w, block_p)


@settings(deadline=None, derandomize=True, max_examples=60)
@given(h=st.integers(5, 64), w=st.integers(5, 64),
       stride=st.integers(1, 4))
def test_stride_pool_shapes_property(h, w, stride):
    _stride_pool_property(h, w, stride)
