"""Serving driver: continuous batching correctness + slot reuse."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import Request, Server
from repro.models import api


def _sequential_greedy(cfg, params, prompt, n_new, max_len=64):
    """Single-request oracle: plain decode loop."""
    cache = api.init_cache(cfg, 1, max_len)
    pos = 0
    for t, tok in enumerate(prompt[:-1]):
        _, cache = api.decode(params, cfg,
                              jnp.asarray([[int(tok)]], jnp.int32), cache,
                              jnp.int32(t))
        pos = t + 1
    out = []
    cur = int(prompt[-1])
    for _ in range(n_new):
        logits, cache = api.decode(params, cfg,
                                   jnp.asarray([[cur]], jnp.int32), cache,
                                   jnp.int32(pos))
        cur = int(jnp.argmax(logits[0, -1]))
        out.append(cur)
        pos += 1
    return out


def test_batched_matches_sequential():
    """Continuous-batching server output == single-request decode."""
    srv = Server("smollm-135m", slots=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, srv.cfg.vocab, size=5).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r, p in zip(reqs, prompts):
        expect = _sequential_greedy(srv.cfg, srv.params, p, 6)
        assert r.out == expect, (r.rid, r.out, expect)


def test_slot_reuse_after_retire():
    """More requests than slots: retired slots must serve new requests
    without contamination from the previous occupant."""
    srv = Server("smollm-135m", slots=1, max_len=64)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(i, p, 4) for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r, p in zip(reqs, prompts):
        expect = _sequential_greedy(srv.cfg, srv.params, p, 4)
        assert r.out == expect
