"""Serving driver: continuous batching correctness + slot reuse."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import Request, Server
from repro.models import api


def _sequential_greedy(cfg, params, prompt, n_new, max_len=64):
    """Single-request oracle: plain decode loop."""
    cache = api.init_cache(cfg, 1, max_len)
    pos = 0
    for t, tok in enumerate(prompt[:-1]):
        _, cache = api.decode(params, cfg,
                              jnp.asarray([[int(tok)]], jnp.int32), cache,
                              jnp.int32(t))
        pos = t + 1
    out = []
    cur = int(prompt[-1])
    for _ in range(n_new):
        logits, cache = api.decode(params, cfg,
                                   jnp.asarray([[cur]], jnp.int32), cache,
                                   jnp.int32(pos))
        cur = int(jnp.argmax(logits[0, -1]))
        out.append(cur)
        pos += 1
    return out


def test_batched_matches_sequential():
    """Continuous-batching server output == single-request decode."""
    srv = Server("smollm-135m", slots=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, srv.cfg.vocab, size=5).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r, p in zip(reqs, prompts):
        expect = _sequential_greedy(srv.cfg, srv.params, p, 6)
        assert r.out == expect, (r.rid, r.out, expect)


def test_bad_request_isolated():
    """A malformed request among good ones retires with a structured
    failure response; the good requests complete correctly and the
    serve loop survives."""
    srv = Server("smollm-135m", slots=2, max_len=64)
    rng = np.random.default_rng(2)
    good = [rng.integers(1, srv.cfg.vocab, size=5).astype(np.int32)
            for _ in range(2)]
    bad_empty = Request(10, np.asarray([], np.int32), 4)
    bad_vocab = Request(11, np.asarray([0, srv.cfg.vocab + 7], np.int32), 4)
    reqs = [Request(0, good[0], 4), bad_empty, bad_vocab,
            Request(1, good[1], 4)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained()
    assert stats["completed"] == 2 and stats["failed"] == 2
    assert bad_empty.failed and bad_empty.error["code"] == "bad_request"
    assert bad_vocab.failed and bad_vocab.error["code"] == "bad_request"
    for r, p in zip([reqs[0], reqs[3]], good):
        assert not r.failed
        expect = _sequential_greedy(srv.cfg, srv.params, p, 4)
        assert r.out == expect, (r.rid, r.out, expect)


def test_prefill_failure_isolated():
    """An exception inside prefill (not just validation) retires only
    the offending request; the slot serves the next one."""
    srv = Server("smollm-135m", slots=1, max_len=64)
    rng = np.random.default_rng(3)
    p_ok = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
    p_bad = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)

    real_decode = srv._decode
    calls = {"n": 0}

    def flaky(params, cache, token, pos):
        calls["n"] += 1
        if calls["n"] == 1:      # first call == bad's first prefill step
            raise RuntimeError("injected prefill failure")
        return real_decode(params, cache, token, pos)

    srv._decode = flaky
    bad = Request(0, p_bad, 4)
    ok = Request(1, p_ok, 4)
    srv.submit(bad)
    srv.submit(ok)
    stats = srv.run_until_drained()
    assert bad.failed and bad.error["code"] == "prefill_error"
    assert stats["failed"] == 1 and stats["completed"] == 1
    expect = _sequential_greedy(srv.cfg, srv.params, p_ok, 4)
    assert ok.out == expect


def test_request_timeout():
    """request_timeout_s retires a straggler with a 'timeout' failure
    response and the loop drains."""
    srv = Server("smollm-135m", slots=1, max_len=64,
                 request_timeout_s=0.0)
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
    req = Request(0, prompt, 1000)
    srv.submit(req)
    stats = srv.run_until_drained(max_ticks=50)
    assert req.done and req.failed
    assert req.error["code"] == "timeout"
    assert stats["failed"] == 1
    assert stats["ticks"] < 50          # drained, not tick-starved


def test_slot_reuse_after_retire():
    """More requests than slots: retired slots must serve new requests
    without contamination from the previous occupant."""
    srv = Server("smollm-135m", slots=1, max_len=64)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
               for _ in range(2)]
    reqs = [Request(i, p, 4) for i, p in enumerate(prompts)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r, p in zip(reqs, prompts):
        expect = _sequential_greedy(srv.cfg, srv.params, p, 4)
        assert r.out == expect


class _Clock:
    """Deterministic time source for the injectable ``clock`` knob."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_timeout_during_multi_slot_drain():
    """A timeout firing mid-drain retires only the straggler; the
    other slot's request keeps decoding and finishes correctly."""
    clock = _Clock()
    srv = Server("smollm-135m", slots=2, max_len=64,
                 request_timeout_s=10.0, clock=clock)
    rng = np.random.default_rng(5)
    p_fast = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
    p_slow = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
    fast = Request(0, p_fast, 3)
    slow = Request(1, p_slow, 1000)     # cannot finish before timeout
    srv.submit(fast)
    srv.submit(slow)
    for _ in range(3):                  # fast completes within budget
        srv.tick()
    assert fast.done and not fast.failed
    clock.t = 100.0                     # past the straggler's budget
    stats = srv.run_until_drained(max_ticks=20)
    assert slow.failed and slow.error["code"] == "timeout"
    assert stats["ticks"] < 20          # drained, not tick-starved
    expect = _sequential_greedy(srv.cfg, srv.params, p_fast, 3)
    assert fast.out == expect


def test_slot_reuse_after_expired_request():
    """A slot freed by a timeout must serve the next queued request
    without contamination from the expired occupant."""
    clock = _Clock()
    srv = Server("smollm-135m", slots=1, max_len=64,
                 request_timeout_s=5.0, clock=clock)
    rng = np.random.default_rng(6)
    p_stuck = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
    p_next = rng.integers(1, srv.cfg.vocab, size=4).astype(np.int32)
    stuck = Request(0, p_stuck, 1000)
    nxt = Request(1, p_next, 4)
    srv.submit(stuck)
    srv.submit(nxt)
    srv.tick()                          # stuck occupies the only slot
    clock.t = 10.0                      # expire it
    srv.run_until_drained(max_ticks=50)
    assert stuck.failed and stuck.error["code"] == "timeout"
    assert not nxt.failed
    expect = _sequential_greedy(srv.cfg, srv.params, p_next, 4)
    assert nxt.out == expect


def test_all_invalid_queue_does_not_starve():
    """When every queued request fails validation, the admit loop must
    retire them all and drain immediately — not spin forever offering
    the slot to an always-failing queue."""
    srv = Server("smollm-135m", slots=2, max_len=64)
    reqs = [Request(i, np.asarray([], np.int32), 4) for i in range(5)]
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained(max_ticks=10)
    assert stats["failed"] == 5 and stats["completed"] == 0
    assert stats["ticks"] <= 2          # no starvation / spin
    assert all(r.error["code"] == "bad_request" for r in reqs)
    assert not srv.queue and not any(srv.active)


def test_tick_times_bounded():
    """tick_times is a fixed-size window: a long-running server must
    not accumulate unbounded per-tick history."""
    srv = Server("smollm-135m", slots=1, max_len=64, tick_window=4)
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, srv.cfg.vocab, size=3).astype(np.int32)
    req = Request(0, prompt, 12)        # 12 decode ticks > window
    srv.submit(req)
    stats = srv.run_until_drained()
    assert req.done and not req.failed
    assert len(srv.tick_times) == 4     # trailing window only
    assert np.isfinite(stats["mean_tick_ms"])
