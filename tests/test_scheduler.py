"""Alg 2 exact-cover scheduler: correctness, baselines, tables (Fig 6)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scheduler as sch


def _random_indices(n_kernels, k2, nnz, seed):
    rng = np.random.default_rng(seed)
    return np.stack([np.sort(rng.choice(k2, nnz, replace=False))
                     for _ in range(n_kernels)])


@pytest.mark.parametrize("method", list(sch.SCHEDULERS))
@pytest.mark.parametrize("alpha", [2, 4, 8])
def test_schedule_is_exact_cover(method, alpha):
    idx = _random_indices(64, 64, 64 // alpha, seed=alpha)
    s = sch.SCHEDULERS[method](idx, 64, r=10)
    sch.verify_schedule(s, idx, 64)


@settings(max_examples=30, deadline=None)
@given(
    n_kernels=st.integers(2, 32),
    k2=st.sampled_from([16, 64]),
    r=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_exact_cover_property(n_kernels, k2, r, seed):
    """Property: for any sparse pattern and replica count, the greedy
    schedule serves every non-zero exactly once within C1/C2."""
    rng = np.random.default_rng(seed)
    nnz = int(rng.integers(1, k2 // 2))
    idx = _random_indices(n_kernels, k2, nnz, seed)
    s = sch.schedule_exact_cover(idx, k2, r)
    sch.verify_schedule(s, idx, k2)
    # lower bound: every kernel needs nnz cycles (C1)
    assert s.n_cycles >= nnz


def test_exact_cover_beats_baselines():
    """Fig 8/9/10: exact-cover >= lowest-index-first >> random."""
    idx = _random_indices(64, 64, 16, seed=0)
    utils = {m: sch.SCHEDULERS[m](idx, 64, r=10).pe_utilization
             for m in sch.SCHEDULERS}
    assert utils["exact_cover"] >= utils["lowest_index"]
    assert utils["exact_cover"] > utils["random"]
    assert utils["exact_cover"] > 0.8   # paper: >80% @ r=10, alpha=4


def test_full_replicas_is_one_pass():
    """With r >= K^2 there is no conflict: cycles == nnz, util == 1."""
    idx = _random_indices(16, 64, 8, seed=1)
    s = sch.schedule_exact_cover(idx, 64, r=64)
    assert s.n_cycles == 8
    assert s.pe_utilization == 1.0


def test_r1_serializes_by_index():
    """r=1: each cycle serves a single address; util = avg sharing."""
    idx = np.array([[0, 1], [0, 1], [0, 2]])
    s = sch.schedule_exact_cover(idx, 4, r=1)
    sch.verify_schedule(s, idx, 4)
    # indices {0:3 kernels, 1:2, 2:1} -> 3 cycles optimal
    assert s.n_cycles == 3


def test_identical_kernels_fully_shared():
    """All kernels share one pattern: nnz cycles regardless of r."""
    idx = np.tile(np.array([[3, 9, 17, 33]]), (64, 1))
    s = sch.schedule_exact_cover(idx, 64, r=2)
    assert s.n_cycles == 4
    assert s.pe_utilization == 1.0


def test_monotone_in_replicas():
    idx = _random_indices(64, 64, 16, seed=2)
    utils = [sch.schedule_exact_cover(idx, 64, r=r).pe_utilization
             for r in (2, 4, 8, 16)]
    assert all(b >= a - 1e-9 for a, b in zip(utils, utils[1:]))


class TestTables:
    def _setup(self, seed=0, n=32, k2=64, nnz=16, r=8):
        rng = np.random.default_rng(seed)
        idx = _random_indices(n, k2, nnz, seed)
        vals = np.zeros((n, k2), np.complex64)
        for i in range(n):
            vals[i, idx[i]] = (rng.standard_normal(nnz)
                               + 1j * rng.standard_normal(nnz))
        s = sch.schedule_exact_cover(idx, k2, r)
        return idx, vals, s, sch.build_tables(s, vals, idx)

    def test_table_shapes(self):
        idx, vals, s, t = self._setup()
        assert t.index_table.shape == (s.n_cycles, s.r)
        assert t.sel.shape == t.valid.shape == t.values.shape \
            == (s.n_cycles, 32)

    def test_sel_routes_correct_replica(self):
        _, _, _, t = self._setup()
        routed = np.take_along_axis(t.index_table, t.sel, axis=1)
        np.testing.assert_array_equal(routed[t.valid], t.out_index[t.valid])

    def test_execution_matches_masked_dense(self):
        """Replaying INDEX/VALUE tables == dense masked Hadamard — the
        datapath-level correctness claim behind Fig 6."""
        _, vals, _, t = self._setup(seed=5)
        rng = np.random.default_rng(9)
        x = (rng.standard_normal(64)
             + 1j * rng.standard_normal(64)).astype(np.complex64)
        out = sch.execute_tables(t, x)
        np.testing.assert_allclose(out, vals * x[None, :], atol=1e-5)

    def test_valid_count_equals_nnz(self):
        idx, _, _, t = self._setup()
        assert t.valid.sum() == idx.size


@settings(max_examples=25, deadline=None)
@given(
    n_kernels=st.integers(2, 32),
    r=st.integers(1, 12),
    alpha=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_execute_tables_matches_masked_dense_property(n_kernels, r, alpha,
                                                      seed):
    """Property (satellite, PR 3): for ANY random pruned layer — varying
    N', r, alpha — replaying the compiled INDEX/VALUE tables equals the
    masked-dense Hadamard oracle element-for-element."""
    k2 = 64
    nnz = max(1, k2 // alpha)
    rng = np.random.default_rng(seed)
    idx = _random_indices(n_kernels, k2, nnz, seed)
    vals = np.zeros((n_kernels, k2), np.complex64)
    for i in range(n_kernels):
        vals[i, idx[i]] = (rng.standard_normal(nnz)
                           + 1j * rng.standard_normal(nnz))
    s = sch.schedule_exact_cover(idx, k2, r)
    sch.verify_schedule(s, idx, k2)
    t = sch.build_tables(s, vals, idx)
    x = (rng.standard_normal(k2)
         + 1j * rng.standard_normal(k2)).astype(np.complex64)
    np.testing.assert_allclose(sch.execute_tables(t, x), vals * x[None, :],
                               atol=1e-4)


@pytest.mark.parametrize("n_pe,r,alpha,m,seed", [
    (8, 4, 4, 3, 0),
    (16, 10, 8, 2, 1),
    (5, 3, 2, 4, 2),
    (12, 1, 16, 2, 3),
])
def test_scheduled_sparse_hadamard_matches_masked_einsum(n_pe, r, alpha,
                                                         m, seed):
    """The Pallas one-hot-matmul executor of the same tables, across
    channels and parallel tiles, equals the masked-dense einsum oracle
    (the second half of the satellite parity requirement)."""
    from repro.kernels import sparse_hadamard as sh
    import jax.numpy as jnp

    k2 = 64
    p = 5
    nnz = max(1, k2 // alpha)
    rng = np.random.default_rng(seed)
    vals = np.zeros((n_pe, m, k2), np.complex64)
    idx_all = []
    tables = []
    for mm in range(m):
        idx = _random_indices(n_pe, k2, nnz, seed * 10 + mm)
        idx_all.append(idx)
        for i in range(n_pe):
            vals[i, mm, idx[i]] = (rng.standard_normal(nnz)
                                   + 1j * rng.standard_normal(nnz))
        s = sch.schedule_exact_cover(idx, k2, r)
        tables.append(sch.build_tables(s, vals[:, mm, :], idx))
    x = (rng.standard_normal((m, k2, p))
         + 1j * rng.standard_normal((m, k2, p)))
    yr, yi = sh.scheduled_sparse_hadamard(
        *sh.stack_tables(tables),
        jnp.asarray(x.real, jnp.float32), jnp.asarray(x.imag, jnp.float32))
    y = np.asarray(yr) + 1j * np.asarray(yi)
    y_ref = np.einsum("nmf,mfp->nfp", vals, x)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)


def test_layer_utilization_sampling():
    rng = np.random.default_rng(0)
    c_out, c_in, nnz = 32, 8, 16
    idx = np.stack([
        np.stack([np.sort(rng.choice(64, nnz, replace=False))
                  for _ in range(c_in)]) for _ in range(c_out)])
    mu = sch.simulate_layer_utilization(idx, 64, r=10, n_par=16,
                                        channel_sample=4)
    assert 0.5 < mu <= 1.0
