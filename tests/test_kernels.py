"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import scheduler as sch
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.kernels import fft8, ops, ref
from repro.kernels import sparse_hadamard as shk
from repro.kernels.flash_attention import flash_attention
from repro.kernels.spectral_hadamard import FLOWS, spectral_hadamard


def _rand(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


class TestSpectralHadamard:
    @pytest.mark.parametrize("flow", FLOWS)
    @pytest.mark.parametrize(
        "f,n,m,p,bn,bm,bp",
        [
            (4, 48, 24, 40, 16, 8, 16),      # non-multiples of block
            (2, 128, 128, 128, 128, 128, 128),
            (1, 7, 3, 5, 8, 8, 8),           # blocks larger than dims
            (64, 64, 64, 9, 64, 64, 8),      # paper geometry K^2=64, P'=9
        ],
    )
    def test_vs_ref(self, flow, f, n, m, p, bn, bm, bp):
        rng = np.random.default_rng(f * 1000 + n)
        wr, wi = _rand(rng, (f, n, m)), _rand(rng, (f, n, m))
        xr, xi = _rand(rng, (f, m, p)), _rand(rng, (f, m, p))
        yr, yi = spectral_hadamard(wr, wi, xr, xi, flow=flow,
                                   block_n=bn, block_m=bm, block_p=bp)
        rr, ri = ref.spectral_hadamard_ref(wr, wi, xr, xi)
        np.testing.assert_allclose(np.asarray(yr), np.asarray(rr),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(yi), np.asarray(ri),
                                   atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        args = [_rand(rng, (2, 16, 8), dtype) for _ in range(2)] + \
               [_rand(rng, (2, 8, 16), dtype) for _ in range(2)]
        yr, yi = spectral_hadamard(*args, block_n=8, block_m=8, block_p=8)
        rr, ri = ref.spectral_hadamard_ref(*[a.astype(jnp.float32)
                                             for a in args])
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(yr), np.asarray(rr), atol=tol,
                                   rtol=tol)

    @settings(max_examples=15, deadline=None)
    @given(f=st.integers(1, 8), n=st.integers(1, 40), m=st.integers(1, 40),
           p=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
    def test_property_any_shape(self, f, n, m, p, seed):
        rng = np.random.default_rng(seed)
        wr, wi = _rand(rng, (f, n, m)), _rand(rng, (f, n, m))
        xr, xi = _rand(rng, (f, m, p)), _rand(rng, (f, m, p))
        yr, yi = spectral_hadamard(wr, wi, xr, xi, block_n=16, block_m=16,
                                   block_p=16)
        rr, ri = ref.spectral_hadamard_ref(wr, wi, xr, xi)
        np.testing.assert_allclose(np.asarray(yr), np.asarray(rr),
                                   atol=1e-3, rtol=1e-3)

    def test_flows_agree(self):
        """All three dataflow variants compute the same function."""
        rng = np.random.default_rng(5)
        args = ([_rand(rng, (3, 32, 16)) for _ in range(2)]
                + [_rand(rng, (3, 16, 24)) for _ in range(2)])
        outs = [spectral_hadamard(*args, flow=fl, block_n=16, block_m=8,
                                  block_p=8) for fl in FLOWS]
        for yr, yi in outs[1:]:
            np.testing.assert_allclose(np.asarray(yr), np.asarray(outs[0][0]),
                                       atol=1e-4)


class TestFFT8:
    @pytest.mark.parametrize("fft_size,tile,batch", [(8, 6, 37), (8, 8, 64),
                                                     (16, 14, 5)])
    def test_fft_vs_ref(self, fft_size, tile, batch):
        rng = np.random.default_rng(1)
        x = _rand(rng, (batch, tile, tile))
        yr, yi = fft8.fft2_tiles(x, fft_size=fft_size, block_b=16)
        rr, ri = ref.fft2_tiles_ref(x, fft_size)
        np.testing.assert_allclose(np.asarray(yr), np.asarray(rr), atol=1e-3)
        np.testing.assert_allclose(np.asarray(yi), np.asarray(ri), atol=1e-3)

    def test_roundtrip(self):
        rng = np.random.default_rng(2)
        x = _rand(rng, (12, 8, 8))
        yr, yi = fft8.fft2_tiles(x, fft_size=8, block_b=8)
        back = fft8.ifft2_tiles(yr, yi, block_b=8)
        np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


class TestScheduledSparse:
    @pytest.mark.parametrize("alpha,r", [(4, 4), (4, 10), (8, 6)])
    def test_group_vs_masked_dense(self, alpha, r):
        rng = np.random.default_rng(alpha * 10 + r)
        x = _rand(rng, (1, 4, 12, 12))
        w = _rand(rng, (16, 4, 3, 3))
        geo = spec.make_geometry(12, 12, 3, 8)
        sk = sp.prune_magnitude(spec.spectral_kernel(w, 8), float(alpha))
        xf = spec.fft_tiles(spec.extract_tiles(x, geo), geo)
        y, stats = ops.scheduled_sparse_conv_group(
            np.asarray(sk.values), np.asarray(sk.indices), xf, r=r)
        y_ref = jnp.einsum("bmtuv,nmuv->bntuv", xf, sk.values)[0]
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4)
        assert 0 < stats["utilization"] <= 1.0

    def test_stack_tables_padding_inert(self):
        """Channels with fewer cycles are padded; padding must be inert."""
        rng = np.random.default_rng(3)
        k2, n_pe = 16, 8
        tables = []
        for m in range(2):
            nnz = 4 if m == 0 else 2   # different cycle counts
            idx = np.stack([np.sort(rng.choice(k2, nnz, replace=False))
                            for _ in range(n_pe)])
            vals = np.zeros((n_pe, k2), np.complex64)
            for i in range(n_pe):
                vals[i, idx[i]] = rng.standard_normal(nnz)
            s = sch.schedule_exact_cover(idx, k2, r=4)
            tables.append(sch.build_tables(s, vals, idx))
        packed = shk.stack_tables(tables)
        assert packed[0].shape[0] == 2
        assert packed[0].shape[1] == max(t.n_cycles for t in tables)
        # valid rows beyond a channel's cycle count are all zero
        t_short = min(t.n_cycles for t in tables)
        short_ch = int(np.argmin([t.n_cycles for t in tables]))
        assert float(packed[2][short_ch, t_short:].sum()) == 0.0


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,s,d,bq,bk",
        [
            (2, 4, 2, 64, 16, 32, 32),
            (1, 8, 1, 100, 32, 32, 32),   # MQA, padded seq
            (1, 2, 2, 128, 64, 128, 64),
        ],
    )
    def test_causal_vs_ref(self, b, hq, hkv, s, d, bq, bk):
        rng = np.random.default_rng(s)
        q = _rand(rng, (b, hq, s, d))
        k = _rand(rng, (b, hkv, s, d))
        v = _rand(rng, (b, hkv, s, d))
        o = flash_attention(q, k, v, block_q=bq, block_k=bk)
        rep = hq // hkv
        o_ref = ref.attention_ref(q, jnp.repeat(k, rep, 1),
                                  jnp.repeat(v, rep, 1))
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-4, rtol=2e-4)

    @pytest.mark.parametrize("window", [8, 32])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(window)
        q = _rand(rng, (1, 2, 96, 16))
        k = _rand(rng, (1, 2, 96, 16))
        v = _rand(rng, (1, 2, 96, 16))
        o = flash_attention(q, k, v, window=window, block_q=32, block_k=32)
        o_ref = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-4, rtol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(s=st.integers(4, 80), d=st.sampled_from([8, 16]),
           seed=st.integers(0, 2**31 - 1))
    def test_property(self, s, d, seed):
        rng = np.random.default_rng(seed)
        q = _rand(rng, (1, 2, s, d))
        k = _rand(rng, (1, 1, s, d))
        v = _rand(rng, (1, 1, s, d))
        o = flash_attention(q, k, v, block_q=16, block_k=16)
        o_ref = ref.attention_ref(q, jnp.repeat(k, 2, 1),
                                  jnp.repeat(v, 2, 1))
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=5e-4, rtol=5e-4)

    def test_bf16(self):
        rng = np.random.default_rng(0)
        q = _rand(rng, (1, 2, 64, 32), jnp.bfloat16)
        k = _rand(rng, (1, 2, 64, 32), jnp.bfloat16)
        v = _rand(rng, (1, 2, 64, 32), jnp.bfloat16)
        o = flash_attention(q, k, v, block_q=32, block_k=32)
        o_ref = ref.attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32))
        assert o.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(o, dtype=np.float32),
                                   np.asarray(o_ref), atol=5e-2)


def test_pallas_conv_matches_spatial_end_to_end():
    """fft8 -> hadamard -> ifft8 -> OaA == direct spatial conv."""
    rng = np.random.default_rng(11)
    x = _rand(rng, (2, 3, 13, 13))
    w = _rand(rng, (5, 3, 3, 3))
    geo = spec.make_geometry(13, 13, 3, 8)
    y = ops.spectral_conv2d_pallas(x, spec.spectral_kernel(w, 8), geo)
    y_ref = spec.spatial_conv2d(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4,
                               rtol=5e-4)
