"""MoE layer invariants (capacity dispatch, routing, aux losses)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import moe

KEY = jax.random.PRNGKey(0)


def _cfg(e=8, k=2, d=16, f=32, cf=1.25):
    return moe.MoEConfig(d_model=d, d_ff=f, n_experts=e, top_k=k,
                         capacity_factor=cf)


def test_no_drops_at_high_capacity_matches_dense_mixture():
    """With capacity >> demand, the layer equals the explicit dense
    mixture sum_k p_k * FFN_{e_k}(x)."""
    cfg = _cfg(cf=16.0)
    params = moe.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    y, aux = moe.forward(params, cfg, x)
    assert float(aux["dropped_frac"]) == 0.0

    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    def ffn(e, v):
        gate = jax.nn.silu(v @ params["w_gate"][e])
        up = v @ params["w_up"][e]
        return (gate * up) @ params["w_down"][e]

    ref = jnp.zeros_like(x)
    for g in range(2):
        for s in range(12):
            acc = jnp.zeros((16,))
            for k in range(cfg.top_k):
                e = int(top_i[g, s, k])
                acc += float(top_p[g, s, k]) * ffn(e, x[g, s])
            ref = ref.at[g, s].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def test_capacity_drops_are_bounded():
    cfg = _cfg(cf=0.5)        # force drops
    params = moe.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 16))
    y, aux = moe.forward(params, cfg, x)
    assert 0.0 < float(aux["dropped_frac"]) < 1.0
    assert bool(jnp.isfinite(y).all())


@settings(max_examples=15, deadline=None)
@given(e=st.sampled_from([4, 8, 16]), k=st.integers(1, 3),
       s=st.integers(4, 40), seed=st.integers(0, 2**31 - 1))
def test_property_finite_and_shaped(e, k, s, seed):
    cfg = _cfg(e=e, k=min(k, e))
    params = moe.init(jax.random.PRNGKey(seed % 100), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, 16))
    y, aux = moe.forward(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["lb_loss"]) >= 0.99   # >= 1 at/near uniform routing


def test_lb_loss_penalizes_imbalance():
    """Routing everything to one expert must raise the aux loss well
    above the balanced value of ~1."""
    cfg = _cfg(e=4, k=1)
    params = moe.init(KEY, cfg)
    # bias the router catastrophically toward expert 0 (positive inputs
    # so the weight-column bias is a uniform logit shift)
    params = dict(params)
    params["router"] = params["router"].at[:, 0].add(100.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (1, 32, 16))) + 0.1
    _, aux = moe.forward(params, cfg, x)
    assert float(aux["lb_loss"]) > 2.0


def test_grads_flow_to_router_and_experts():
    cfg = _cfg()
    params = moe.init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 16))

    def loss(p):
        y, aux = moe.forward(p, cfg, x)
        return jnp.sum(y ** 2) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_gate"]).max()) > 0
