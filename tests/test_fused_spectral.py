"""Fused single-pallas_call spectral conv == einsum oracle == spatial conv.

Covers the tentpole kernel (kernels/fused_spectral_conv.py): FFT ->
Hadamard -> IFFT in one kernel, across fft sizes, non-divisible
geometries (tile-padding edge), all three residency flows, pruned
kernels, and the autotuner that configures it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core import dataflow as df
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.kernels.fused_spectral_conv import FLOWS, fused_spectral_conv2d


def _conv_case(h, w, k, K, cin, cout, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, cin, h, w)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((cout, cin, k, k)), jnp.float32)
    geo = spec.make_geometry(h, w, k, K)
    return x, wk, geo


class TestFusedVsOracles:
    @pytest.mark.parametrize("flow", FLOWS)
    @pytest.mark.parametrize(
        "h,w,k,K,cin,cout,blocks",
        [
            (12, 12, 3, 8, 3, 5, (4, 2, 16)),     # blocks divide nothing
            (14, 14, 3, 8, 4, 4, (4, 4, 9)),      # VGG conv5 spatial size
            (11, 13, 3, 8, 2, 3, (8, 8, 8)),      # non-divisible, rect
            (16, 16, 5, 8, 2, 2, (2, 2, 32)),     # k=5
            (24, 24, 3, 16, 2, 2, (2, 2, 4)),     # K=16
            (6, 6, 3, 8, 1, 1, (8, 8, 8)),        # single tile
        ],
    )
    def test_vs_spatial(self, flow, h, w, k, K, cin, cout, blocks):
        x, wk, geo = _conv_case(h, w, k, K, cin, cout)
        bn, bm, bp = blocks
        y = fused_spectral_conv2d(x, spec.spectral_kernel(wk, K), geo,
                                  flow=flow, block_n=bn, block_m=bm,
                                  block_p=bp)
        y_spatial = spec.spatial_conv2d(x, wk)
        y_spectral = spec.spectral_conv2d(x, wk, fft_size=K)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_spatial),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_spectral),
                                   atol=2e-4, rtol=2e-4)

    @pytest.mark.parametrize("flow", FLOWS)
    @pytest.mark.parametrize("alpha", [2.0, 4.0])
    def test_pruned_vs_einsum_oracle(self, flow, alpha):
        """Pruned (alpha > 1) kernels: fused == sparse-aware oracle."""
        x, wk, geo = _conv_case(13, 12, 3, 8, 4, 6, seed=3)
        sk = sp.prune_magnitude(spec.spectral_kernel(wk, 8), alpha)
        y = fused_spectral_conv2d(x, sk, geo, flow=flow,
                                  block_n=4, block_m=4, block_p=16)
        y_ref = spec.spectral_conv2d_pretransformed(x, sk, geo)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-4, rtol=2e-4)

    def test_flows_agree(self):
        x, wk, geo = _conv_case(18, 18, 3, 8, 3, 4, seed=5)
        wf = spec.spectral_kernel(wk, 8)
        outs = [fused_spectral_conv2d(x, wf, geo, flow=fl, block_n=2,
                                      block_m=2, block_p=8)
                for fl in FLOWS]
        for y in outs[1:]:
            np.testing.assert_allclose(np.asarray(y), np.asarray(outs[0]),
                                       atol=1e-4, rtol=1e-4)

    def test_oversized_blocks_clamped(self):
        x, wk, geo = _conv_case(10, 10, 3, 8, 2, 3, batch=1)
        y = fused_spectral_conv2d(x, spec.spectral_kernel(wk, 8), geo,
                                  block_n=512, block_m=512, block_p=512)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(spec.spatial_conv2d(x, wk)),
                                   atol=2e-4, rtol=2e-4)


class TestSparseOracle:
    """The einsum oracle's masked (active-bin) path (satellite fix)."""

    def test_sparse_equals_dense_values(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal((1, 4, 12, 12)), jnp.float32)
        wk = jnp.asarray(rng.standard_normal((6, 4, 3, 3)), jnp.float32)
        geo = spec.make_geometry(12, 12, 3, 8)
        sk = sp.prune_magnitude(spec.spectral_kernel(wk, 8), 8.0)
        # the high-alpha magnitude pattern leaves whole bins empty, so
        # the gather path is actually exercised
        active = np.asarray(sk.mask).any(axis=(0, 1)).reshape(-1).sum()
        assert active < 64
        y = spec.spectral_conv2d_pretransformed(x, sk, geo)
        y_ref = spec.spectral_conv2d_pretransformed(x, sk.values, geo)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5)


class TestAutotune:
    def test_plan_fits_budget(self):
        plan = autotune.autotune_network(df.VGG16_LAYERS, 8, 4.0)
        assert set(plan) == {l.name for l in df.VGG16_LAYERS}
        for tn in plan.values():
            assert tn.flow in FLOWS
            assert tn.vmem_bytes <= df.TPU_VMEM_BYTES

    def test_plan_covers_layer_dims(self):
        """Manual-DMA accumulators (PR 8) lift the consecutive-revisit
        restriction, so RMW flows may split p/n freely; the invariant
        that remains is coverage — the block grid must tile the full
        layer dims (validated by core.resilience 'dma/tile-bounds')."""
        layers = {l.name: l for l in df.VGG16_LAYERS}
        plan = autotune.autotune_network(df.VGG16_LAYERS, 8, 4.0)
        for name, tn in plan.items():
            layer = layers[name]
            assert 1 <= tn.block_n and 1 <= tn.block_m and 1 <= tn.block_p
            gn = -(-layer.c_out // tn.block_n)
            assert gn * tn.block_n >= layer.c_out

    def test_split_rmw_runs_without_guard(self):
        """block_p < tiles on weight_stationary — rejected by the old
        hardware guard — now runs and matches the full-p result."""
        x, wk, geo = _conv_case(24, 24, 3, 8, 2, 3, batch=1)
        wf = spec.spectral_kernel(wk, 8)
        y = fused_spectral_conv2d(x, wf, geo, flow="weight_stationary",
                                  block_p=4)
        y_ref = fused_spectral_conv2d(x, wf, geo,
                                      flow="weight_stationary")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5, rtol=1e-5)

    def test_cost_model_consistency(self):
        """Fused kernel's HBM bytes <= the staged pipeline's
        output-stationary prediction — the whole point of fusing."""
        for layer in df.VGG16_LAYERS:
            fused = df.tpu_fused_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                           "output_stationary")
            staged = df.tpu_flow_cost(layer, 8, 4.0, 64, 128, 64,
                                      "output_stationary")
            assert fused["hbm_bytes"] <= staged["hbm_bytes"]

    def test_measured_autotune_smoke(self):
        layer = df.ConvLayer("tiny", 4, 8, 12, 12)
        tn = autotune.autotune_layer(
            layer, 8, 4.0,
            blocks=(4, 8),
            measure_fn=autotune._make_measure_fn(layer, 8, 4.0, 1, True),
            measure_top_k=2)
        assert tn.measured_s is not None and tn.measured_s > 0

    def test_tuned_plan_runs_through_model(self):
        from repro.configs import vgg16_spectral
        from repro.core.plan import build_network_plan
        from repro.models import cnn
        cfg = vgg16_spectral.SMOKE
        params = cnn.init(jax.random.PRNGKey(0), cfg)
        plan = build_network_plan(params, cfg, batch=1)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, 3, cfg.image_size, cfg.image_size))
        ref = cnn.forward_spectral(params, plan, x)
        out = cnn.forward_spectral(params, plan, x, backend="pallas_fused")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3, rtol=1e-3)
