"""Checkpointer: atomicity, checksums, retention, elastic restore."""

import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


@pytest.fixture
def tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,))},
        "opt": {"mu": {"w": jnp.zeros((3, 4))}, "count": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path, tree):
    ck = Checkpointer(tmp_path)
    ck.save(10, tree, blocking=True)
    step, restored = ck.restore(None, tree)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_async_save_then_wait(tmp_path, tree):
    ck = Checkpointer(tmp_path)
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_retention_keeps_newest(tmp_path, tree):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.all_steps() == [3, 4]


def test_atomic_no_partial_visible(tmp_path, tree):
    """A temp dir from a dead writer must not count as a checkpoint."""
    ck = Checkpointer(tmp_path)
    ck.save(5, tree, blocking=True)
    (tmp_path / ".tmp-9-0").mkdir()          # simulated dead writer
    assert ck.latest_step() == 5


def test_corruption_detected(tmp_path, tree):
    ck = Checkpointer(tmp_path)
    ck.save(3, tree, blocking=True)
    # flip bits in the payload
    f = tmp_path / "step_00000003" / "host0.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises((IOError, ValueError, Exception)):
        ck.restore(3, tree)


def test_missing_array_detected(tmp_path, tree):
    ck = Checkpointer(tmp_path)
    ck.save(3, tree, blocking=True)
    extra = dict(tree)
    extra["new_thing"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(3, extra)


def test_elastic_restore_onto_sharded_mesh(tmp_path, tree):
    """Restore re-shards onto whatever mesh exists now (1 host device
    here; the sharding argument path is the same at any scale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(tmp_path)
    ck.save(2, tree, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(
        lambda a: NamedSharding(mesh, P()), tree)
    step, restored = ck.restore(2, tree, shardings=shardings)
    assert step == 2
    w = restored["params"]["w"]
    assert w.sharding == NamedSharding(mesh, P())
    np.testing.assert_array_equal(np.asarray(w),
                                  np.asarray(tree["params"]["w"]))


def test_restore_shape_mismatch_raises(tmp_path, tree):
    ck = Checkpointer(tmp_path)
    ck.save(1, tree, blocking=True)
    bad = jax.tree.map(lambda a: a, tree)
    bad["params"]["w"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        ck.restore(1, bad)
