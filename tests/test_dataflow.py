"""Complexity models (Eqs 6-13) + Alg 1 dataflow optimizer."""

import numpy as np
import pytest

from repro.core import dataflow as df
from repro.core import optimizer as opt

P_PAR, N_PAR, R, K, ALPHA = 9, 64, 10, 8, 4.0


def test_vgg16_layer_table():
    names = [l.name for l in df.VGG16_LAYERS]
    assert names[0] == "conv1_1" and names[-1] == "conv5_3"
    assert len(df.VGG16_LAYERS) == 13
    l = df.VGG16_LAYERS[1]
    assert (l.c_in, l.c_out, l.h_in) == (64, 64, 224)
    # tile = K - k + 1 = 6, canvas 228 -> 38x38 tiles
    assert l.tiles(8) == 38 * 38


def test_flow1_bram_explodes_on_early_layers():
    """Fig 2: streaming input tiles (Flow #1) needs huge #BRAMs early."""
    conv1_2 = df.VGG16_LAYERS[1]
    conv5_1 = df.VGG16_LAYERS[10]
    b_early = df.bram_flow1(conv1_2, K, ALPHA, P_PAR, N_PAR, R)
    b_late = df.bram_flow1(conv5_1, K, ALPHA, P_PAR, N_PAR, R)
    assert b_early > 2160, "early layers must exceed the U200 BRAM budget"
    assert b_late < 2160
    assert b_early > 4 * b_late


def test_flow2_fewer_brams_more_traffic():
    """Fig 2: streaming kernels = fewer BRAMs, higher communication.
    (On late small-image layers all operands fit one BRAM depth and the
    flows tie in storage; the separation binds on the early layers.)"""
    for layer in df.VGG16_OPT_LAYERS[:3]:
        b1 = df.bram_flow1(layer, K, ALPHA, P_PAR, N_PAR, R)
        b2 = df.bram_flow2(layer, K, ALPHA, P_PAR, N_PAR, R)
        t1 = df.transfers_flow1(layer, K, ALPHA, N_PAR)
        t2 = df.transfers_flow2(layer, K, ALPHA, P_PAR)
        assert b2 <= b1
        assert t2 > t1
    conv1_2 = df.VGG16_OPT_LAYERS[0]
    assert df.bram_flow2(conv1_2, K, ALPHA, P_PAR, N_PAR, R) \
        < df.bram_flow1(conv1_2, K, ALPHA, P_PAR, N_PAR, R)


def test_flow3_never_advantageous():
    """Fig 2: streaming partial sums 'brings no advantages at all'."""
    for layer in df.VGG16_OPT_LAYERS:
        t3 = df.transfers_flow3(layer, K, ALPHA)
        t1 = df.transfers_flow1(layer, K, ALPHA, N_PAR)
        t2 = df.transfers_flow2(layer, K, ALPHA, P_PAR)
        assert t3 > min(t1, t2)


def test_flexible_interpolates_pure_flows():
    """Eq 13 == Eq 9 at (Ns=N', Ps=T); == Eq 10 at (Ns=N, Ps=P')."""
    layer = df.VGG16_LAYERS[4]
    t = layer.tiles(K)
    f1 = df.transfers_flow1(layer, K, ALPHA, N_PAR)
    flex1 = df.transfers_flexible(layer, K, ALPHA, ns=N_PAR, ps=t)
    # flexible with all tiles resident ~ flow1 modulo the in-tile padding
    # (flow1 counts h*w raw pixels; flexible re-load factor is identical)
    assert abs(f1 - flex1) / f1 < 0.05
    f2 = df.transfers_flow2(layer, K, ALPHA, P_PAR)
    flex2 = df.transfers_flexible(layer, K, ALPHA, ns=layer.c_out, ps=P_PAR)
    assert abs(f2 - flex2) / f2 < 0.05


def test_latency_budget_partitions_tau():
    taus = df.layer_latency_budget(df.VGG16_OPT_LAYERS, K, ALPHA, 20e-3)
    assert len(taus) == 12
    np.testing.assert_allclose(sum(taus.values()), 20e-3, rtol=1e-9)
    # conv3_2/3 and conv4_2/3 carry the largest spectral compute share
    top = max(taus, key=taus.get)
    assert top in {"conv3_2", "conv3_3", "conv4_2", "conv4_3"}
    assert taus["conv1_2"] > taus["conv5_1"]


class TestAlg1:
    @pytest.fixture(scope="class")
    def plan(self):
        return opt.optimize(arch_candidates=[(9, 64)])

    def test_all_layers_planned(self, plan):
        assert [l.layer for l in plan.layers] == \
            [l.name for l in df.VGG16_OPT_LAYERS]
        assert plan.p_par == 9 and plan.n_par == 64

    def test_bram_cap_respected(self, plan):
        assert all(l.n_bram < 2160 for l in plan.layers)

    def test_beats_pure_flows(self, plan):
        """Flow opt transfers fewer words than the best feasible pure flow
        in (almost) every layer — the paper's 42% reduction claim."""
        pure = opt.pure_flow_transfers(df.VGG16_OPT_LAYERS, K, ALPHA,
                                       plan.p_par, plan.n_par)
        total_opt = plan.total_transfers_words
        total_flow2 = sum(v["flow2"] for v in pure.values())
        assert total_opt < total_flow2
        reduction = 1 - total_opt / total_flow2
        # paper reports 42% vs the baseline flow; require a substantial cut
        assert reduction > 0.25, f"only {reduction:.1%} reduction"

    def test_streaming_params_monotone(self, plan):
        """Later (small-image) layers afford more resident kernels Ns."""
        ns = {l.layer: l.ns for l in plan.layers}
        assert ns["conv5_1"] >= ns["conv1_2"]

    def test_bandwidth_under_ddr(self, plan):
        """Paper: Flow opt keeps VGG16 under a single DDR's ~12-19 GB/s."""
        assert plan.bw_max_gbps < 19.0


def test_optimize_searches_arch_space():
    plan = opt.optimize(arch_candidates=[(4, 32), (9, 64), (16, 64)])
    assert (plan.p_par, plan.n_par) in {(4, 32), (9, 64), (16, 64)}


def test_infeasible_cap_raises():
    with pytest.raises(ValueError):
        opt.optimize(arch_candidates=[(9, 64)], n_bram_cap=10)
