"""Serving benchmark for ``launch.spectral_serve`` — throughput, tail
latency and resilience counters under a 4x-capacity burst, plus the
chaos soak as a CI gate.

  PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--chaos]
      [--json BENCH_serve.json] [--merge-into BENCH_e2e.json]

Two sections:

  load   real-clock load benchmark: warm the plan cache + jit, run two
         steady waves, then slam a 4x-``queue_limit`` burst into the
         bounded queue and drain it.  Reports throughput (img/s) and
         p50/p95/p99 latency alongside the shed/demotion counters —
         the tail numbers the paper's single-stream latency claim has
         to survive.
  chaos  (``--chaos``) ``testing.faults.chaos_soak``: the deterministic
         fault-injected burst on a virtual clock (kernel faults,
         plan-cache corruption, slow-service windows, tight deadlines).
         Its gates — zero loop deaths, zero silent wrong answers,
         demotion AND promotion observed — fail this process nonzero.

BENCH_serve.json schema
-----------------------
  bench / backend / interpret_mode / model / quick     run metadata.
  load.requests / load.queue_limit / load.buckets      offered load.
  load.warm_s
      startup cost: plan builds for every bucket + one jit warm
      forward per bucket.  Paid once, BEFORE serving — the
      ``plan_cache_warm_only`` gate asserts no request ever triggered
      a plan build.
  load.stats
      the server's drained-run stats: terminal-outcome counters,
      throughput_img_s, latency_ms {mean, p50, p95, p99}, demotions /
      promotions, served_by_rung, loop_deaths.
  load.health
      final ``health_report()`` — ladder transitions with the pressure
      that drove them, breaker snapshots, plan-cache counters.
  chaos
      the full ``chaos_soak`` report (present with ``--chaos``).
  batch_sweep
      the per-bucket serving table: one plan per bucket, tuned AT that
      batch (``dataflow.INTERPRET_STEP_S`` priced in — calibrated to
      zero, see its comment), fused vs einsum wall clock at
      every bucket, and the GATING acceptance boolean
      ``fused_le_einsum_all_buckets``.  This graduated from the old
      ``known_gaps`` batch-8 entry (fused 92.9 ms vs einsum 81.3 ms
      when batch-8 buckets inherited batch-1 block choices — ROADMAP
      items 1+2, fixed by the batch-aware autotune + manual-DMA
      accumulators).
  gates / failed_gates
      pass/fail booleans; any False exits nonzero AFTER the report is
      written (CI blocks, artifact stays inspectable).

``--merge-into BENCH_e2e.json`` additionally folds a summary (load
stats + gate status + batch_sweep) into the e2e report under a
``serve`` key, atomically, so the serving columns live next to the
latency/traffic ones.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax


def load_bench(*, queue_limit: int = 16, seed: int = 0,
               quick: bool = False) -> dict:
    """Real-clock serving benchmark: steady waves, then a 4x-capacity
    burst into the bounded queue."""
    from repro.configs import vgg16_spectral
    from repro.launch import spectral_serve as ss

    cfg = vgg16_spectral.SMOKE
    t0 = time.perf_counter()
    srv = ss.SpectralServer(cfg, queue_limit=queue_limit, seed=seed,
                            warm_forward=True)
    warm_s = time.perf_counter() - t0
    print(f"      warm: {len(srv.buckets)} bucket plans + jit in "
          f"{warm_s:.1f}s")

    reqs: list = []

    def burst(n: int) -> None:
        wave = ss.synthetic_requests(n, cfg, seed=seed + len(reqs),
                                     rid0=len(reqs))
        for r in wave:
            srv.submit(r)
        reqs.extend(wave)

    steady = max(2, queue_limit // (4 if quick else 2))
    for _ in range(1 if quick else 2):
        burst(steady)
        srv.run_until_drained()
    burst(4 * queue_limit)
    srv.run_until_drained()

    stats = srv.stats()
    health = srv.health_report()
    cache = srv.plans.stats()
    gates = {
        "all_terminal": all(r.terminal for r in reqs),
        "zero_loop_deaths": stats["loop_deaths"] == 0,
        "shed_nonzero": stats["counters"]["overloaded"] > 0,
        "demotion_and_promotion": (stats["demotions"] >= 1
                                   and stats["promotions"] >= 1),
        "latency_reported": ("latency_ms" in stats
                             and "throughput_img_s" in stats),
        # every plan build happened during warm(), never on a request
        "plan_cache_warm_only": cache["builds"] == len(srv.buckets),
    }
    return {
        "requests": len(reqs),
        "queue_limit": queue_limit,
        "buckets": list(srv.buckets),
        "warm_s": warm_s,
        "stats": stats,
        "health": health,
        "gates": gates,
        "failed_gates": sorted(k for k, v in gates.items() if not v),
    }


def batch_sweep(*, buckets=(1, 2, 4, 8), seed: int = 0, iters: int = 3,
                quick: bool = False) -> dict:
    """GATING per-bucket sweep: one plan per serving bucket, tuned AT
    that batch (step overhead priced for the interpret backend), fused
    vs einsum wall clock.  The fused rung must beat or match the
    oracle it degrades to at EVERY bucket — otherwise the serving
    ladder's best rung would be slower than its own fallback."""
    from repro.configs import vgg16_spectral
    from repro.core import dataflow as df
    from repro.core.plan import build_network_plan
    from repro.models import cnn
    import jax.numpy as jnp

    cfg = vgg16_spectral.SMOKE
    key = jax.random.PRNGKey(seed)
    params = cnn.init(key, cfg)
    step_s = (df.INTERPRET_STEP_S if jax.default_backend() != "tpu"
              else 0.0)
    iters = 1 if quick else iters
    per_bucket = {}
    for b in buckets:
        plan = build_network_plan(params, cfg, batch=b,
                                  step_overhead_s=step_s)
        x = jax.random.normal(key, (b, 3, cfg.image_size,
                                    cfg.image_size), jnp.float32)

        def timed(backend):
            fn = lambda: cnn.forward_spectral(params, plan, x,
                                              backend=backend)
            jax.block_until_ready(fn())          # compile
            t0 = time.perf_counter()
            for _ in range(iters):
                out = fn()
            jax.block_until_ready(out)
            return 1e3 * (time.perf_counter() - t0) / iters

        fused_ms = timed("pallas_fused")
        einsum_ms = timed("einsum")
        per_bucket[f"batch{b}"] = {
            "fused_ms": fused_ms,
            "einsum_ms": einsum_ms,
            "fused_le_einsum": bool(fused_ms <= einsum_ms),
            "tuned_flows": sorted({lp.tuning.flow
                                   for lp in plan.layers}),
            "tuned_input_modes": sorted({lp.input_mode
                                         for lp in plan.layers}),
        }
    return {
        "buckets": list(buckets),
        "iters": iters,
        "step_overhead_s": step_s,
        "per_bucket": per_bucket,
        "fused_le_einsum_all_buckets": all(
            r["fused_le_einsum"] for r in per_bucket.values()),
    }


def _write_report_atomic(report: dict, path: str) -> None:
    """tmp + os.replace, same contract as benchmarks.e2e_latency."""
    dirname = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".bench_serve_",
                               suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _merge_into_e2e(report: dict, path: str) -> None:
    """Fold the serve summary into BENCH_e2e.json under ``serve``."""
    with open(path) as f:
        e2e = json.load(f)
    load = report["load"]
    e2e["serve"] = {
        "requests": load["requests"],
        "queue_limit": load["queue_limit"],
        "buckets": load["buckets"],
        "warm_s": load["warm_s"],
        "throughput_img_s": load["stats"].get("throughput_img_s"),
        "latency_ms": load["stats"].get("latency_ms"),
        "counters": load["stats"]["counters"],
        "demotions": load["stats"]["demotions"],
        "promotions": load["stats"]["promotions"],
        "loop_deaths": load["stats"]["loop_deaths"],
        "chaos_failed_gates": report.get("chaos", {}).get(
            "failed_gates"),
        "failed_gates": report["failed_gates"],
        "batch_sweep": report["batch_sweep"],
    }
    _write_report_atomic(e2e, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="output path for the JSON report")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke path: smaller steady phase")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the fault-injected chaos soak "
                    "(virtual clock, deterministic) and gate on it")
    ap.add_argument("--queue-limit", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--merge-into", default=None, metavar="E2E_JSON",
                    help="also fold a serve summary into this "
                    "BENCH_e2e.json (atomic rewrite)")
    args = ap.parse_args()

    n_steps = 2 + bool(args.chaos)
    report: dict = {
        "bench": "serve_bench",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "model": "vgg16-spectral-smoke",
        "quick": bool(args.quick),
        "seed": args.seed,
    }

    print(f"[1/{n_steps}] load bench: steady waves + 4x-capacity burst "
          f"(queue_limit={args.queue_limit})")
    report["load"] = load_bench(queue_limit=args.queue_limit,
                                seed=args.seed, quick=args.quick)
    st = report["load"]["stats"]
    lm = st.get("latency_ms", {})
    print(f"      {report['load']['requests']} requests: "
          f"{st['counters']['ok']} ok / {st['counters']['overloaded']} "
          f"shed / {st['counters']['failed']} failed; "
          f"{st.get('throughput_img_s', float('nan')):.1f} img/s; "
          f"latency ms p50 {lm.get('p50', float('nan')):.1f} / p95 "
          f"{lm.get('p95', float('nan')):.1f} / p99 "
          f"{lm.get('p99', float('nan')):.1f}; "
          f"{st['demotions']} demotions, {st['promotions']} promotions,"
          f" {st['loop_deaths']} loop deaths")

    if args.chaos:
        print(f"[2/{n_steps}] chaos soak: fault-injected burst on a "
              "virtual clock")
        from repro.testing import faults
        report["chaos"] = faults.chaos_soak(
            queue_limit=args.queue_limit, seed=args.seed,
            log=lambda m: print(f"      {m}"))

    print(f"[{n_steps}/{n_steps}] batch sweep: per-bucket fused vs "
          f"einsum, batch-tuned plans (GATING)")
    report["batch_sweep"] = batch_sweep(seed=args.seed, quick=args.quick)
    for name, row in sorted(report["batch_sweep"]["per_bucket"].items()):
        mark = "<=" if row["fused_le_einsum"] else "> !!"
        print(f"      {name}: fused {row['fused_ms']:.1f} ms {mark} "
              f"einsum {row['einsum_ms']:.1f} ms "
              f"(flows {','.join(row['tuned_flows'])}; input "
              f"{','.join(row['tuned_input_modes'])})")

    failed = [f"load.{g}" for g in report["load"]["failed_gates"]]
    if "chaos" in report:
        failed += [f"chaos.{g}" for g in report["chaos"]["failed_gates"]]
    if not report["batch_sweep"]["fused_le_einsum_all_buckets"]:
        failed.append("batch_sweep.fused_le_einsum_all_buckets")
    report["gates"] = {
        "load": report["load"]["gates"],
        **({"chaos": report["chaos"]["gates"]} if "chaos" in report
           else {}),
        "batch_sweep": {
            "fused_le_einsum_all_buckets":
                report["batch_sweep"]["fused_le_einsum_all_buckets"]},
    }
    report["failed_gates"] = failed

    _write_report_atomic(report, args.json)
    print(f"wrote {args.json}")
    if args.merge_into:
        _merge_into_e2e(report, args.merge_into)
        print(f"merged serve summary into {args.merge_into}")

    if failed:
        print("[gates] FAILED:", file=sys.stderr)
        for name in failed:
            print(f"  - {name}", file=sys.stderr)
        sys.exit(1)
    print("[gates] all serving gates pass")


if __name__ == "__main__":
    main()
