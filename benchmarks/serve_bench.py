"""Serving benchmark for ``launch.spectral_serve`` — throughput, tail
latency and resilience counters under a 4x-capacity burst, plus the
chaos soak as a CI gate.

  PYTHONPATH=src python -m benchmarks.serve_bench [--quick] [--chaos]
      [--json BENCH_serve.json] [--merge-into BENCH_e2e.json]

Two sections:

  load   real-clock load benchmark: warm the plan cache + jit, run two
         steady waves, then slam a 4x-``queue_limit`` burst into the
         bounded queue and drain it.  Reports throughput (img/s) and
         p50/p95/p99 latency alongside the shed/demotion counters —
         the tail numbers the paper's single-stream latency claim has
         to survive.
  chaos  (``--chaos``) ``testing.faults.chaos_soak``: the deterministic
         fault-injected burst on a virtual clock (kernel faults,
         plan-cache corruption, slow-service windows, tight deadlines).
         Its gates — zero loop deaths, zero silent wrong answers,
         demotion AND promotion observed — fail this process nonzero.

BENCH_serve.json schema
-----------------------
  bench / backend / interpret_mode / model / quick     run metadata.
  load.requests / load.queue_limit / load.buckets      offered load.
  load.warm_s
      startup cost: plan builds for every bucket + one jit warm
      forward per bucket.  Paid once, BEFORE serving — the
      ``plan_cache_warm_only`` gate asserts no request ever triggered
      a plan build.
  load.stats
      the server's drained-run stats: terminal-outcome counters,
      throughput_img_s, latency_ms {mean, p50, p95, p99}, demotions /
      promotions, served_by_rung, loop_deaths.
  load.health
      final ``health_report()`` — ladder transitions with the pressure
      that drove them, breaker snapshots, plan-cache counters.
  chaos
      the full ``chaos_soak`` report (present with ``--chaos``).
  known_gaps[]
      tracked, NON-gating regressions.  Currently: smoke batch-8
      fused latency trails the einsum oracle (BENCH_e2e.json
      latency.smoke.batch8) — the baseline for ROADMAP item 1's
      batch-aware autotune work.
  gates / failed_gates
      pass/fail booleans; any False exits nonzero AFTER the report is
      written (CI blocks, artifact stays inspectable).

``--merge-into BENCH_e2e.json`` additionally folds a summary (load
stats + gate status + known_gaps) into the e2e report under a
``serve`` key, atomically, so the serving columns live next to the
latency/traffic ones.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax

# fallback to the committed full-run numbers if BENCH_e2e.json is absent
_BATCH8_FUSED_MS_FALLBACK = 92.9
_BATCH8_EINSUM_MS_FALLBACK = 81.3


def load_bench(*, queue_limit: int = 16, seed: int = 0,
               quick: bool = False) -> dict:
    """Real-clock serving benchmark: steady waves, then a 4x-capacity
    burst into the bounded queue."""
    from repro.configs import vgg16_spectral
    from repro.launch import spectral_serve as ss

    cfg = vgg16_spectral.SMOKE
    t0 = time.perf_counter()
    srv = ss.SpectralServer(cfg, queue_limit=queue_limit, seed=seed,
                            warm_forward=True)
    warm_s = time.perf_counter() - t0
    print(f"      warm: {len(srv.buckets)} bucket plans + jit in "
          f"{warm_s:.1f}s")

    reqs: list = []

    def burst(n: int) -> None:
        wave = ss.synthetic_requests(n, cfg, seed=seed + len(reqs),
                                     rid0=len(reqs))
        for r in wave:
            srv.submit(r)
        reqs.extend(wave)

    steady = max(2, queue_limit // (4 if quick else 2))
    for _ in range(1 if quick else 2):
        burst(steady)
        srv.run_until_drained()
    burst(4 * queue_limit)
    srv.run_until_drained()

    stats = srv.stats()
    health = srv.health_report()
    cache = srv.plans.stats()
    gates = {
        "all_terminal": all(r.terminal for r in reqs),
        "zero_loop_deaths": stats["loop_deaths"] == 0,
        "shed_nonzero": stats["counters"]["overloaded"] > 0,
        "demotion_and_promotion": (stats["demotions"] >= 1
                                   and stats["promotions"] >= 1),
        "latency_reported": ("latency_ms" in stats
                             and "throughput_img_s" in stats),
        # every plan build happened during warm(), never on a request
        "plan_cache_warm_only": cache["builds"] == len(srv.buckets),
    }
    return {
        "requests": len(reqs),
        "queue_limit": queue_limit,
        "buckets": list(srv.buckets),
        "warm_s": warm_s,
        "stats": stats,
        "health": health,
        "gates": gates,
        "failed_gates": sorted(k for k, v in gates.items() if not v),
    }


def known_gaps(e2e_path: str = "BENCH_e2e.json") -> list[dict]:
    """Tracked non-gating regressions, with live numbers when the e2e
    report is on disk."""
    fused_ms, einsum_ms = (_BATCH8_FUSED_MS_FALLBACK,
                           _BATCH8_EINSUM_MS_FALLBACK)
    source = "fallback (committed full-run values)"
    try:
        with open(e2e_path) as f:
            row = json.load(f)["latency"]["smoke"]["batch8"]
        fused_ms = row["pallas_fused_ms"]
        einsum_ms = row["einsum_ms"]
        source = f"{e2e_path}:latency.smoke.batch8"
    except (OSError, KeyError, ValueError):
        pass
    return [{
        "id": "batch8-fused-slower-than-einsum",
        "gating": False,
        "fused_ms": fused_ms,
        "einsum_ms": einsum_ms,
        "source": source,
        "detail": "smoke batch-8 fused latency trails the einsum "
                  "oracle — the Alg-1 cost model tunes blocks per "
                  "layer but not per batch, so large-batch buckets "
                  "inherit batch-1 block choices.  Tracked baseline "
                  "for ROADMAP item 1 (batch-aware autotune); the "
                  "serving ladder sidesteps it today by demoting to "
                  "einsum under pressure.",
    }]


def _write_report_atomic(report: dict, path: str) -> None:
    """tmp + os.replace, same contract as benchmarks.e2e_latency."""
    dirname = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".bench_serve_",
                               suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _merge_into_e2e(report: dict, path: str) -> None:
    """Fold the serve summary into BENCH_e2e.json under ``serve``."""
    with open(path) as f:
        e2e = json.load(f)
    load = report["load"]
    e2e["serve"] = {
        "requests": load["requests"],
        "queue_limit": load["queue_limit"],
        "buckets": load["buckets"],
        "warm_s": load["warm_s"],
        "throughput_img_s": load["stats"].get("throughput_img_s"),
        "latency_ms": load["stats"].get("latency_ms"),
        "counters": load["stats"]["counters"],
        "demotions": load["stats"]["demotions"],
        "promotions": load["stats"]["promotions"],
        "loop_deaths": load["stats"]["loop_deaths"],
        "chaos_failed_gates": report.get("chaos", {}).get(
            "failed_gates"),
        "failed_gates": report["failed_gates"],
        "known_gaps": report["known_gaps"],
    }
    _write_report_atomic(e2e, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="output path for the JSON report")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke path: smaller steady phase")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the fault-injected chaos soak "
                    "(virtual clock, deterministic) and gate on it")
    ap.add_argument("--queue-limit", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--merge-into", default=None, metavar="E2E_JSON",
                    help="also fold a serve summary into this "
                    "BENCH_e2e.json (atomic rewrite)")
    args = ap.parse_args()

    n_steps = 2 + bool(args.chaos)
    report: dict = {
        "bench": "serve_bench",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "model": "vgg16-spectral-smoke",
        "quick": bool(args.quick),
        "seed": args.seed,
    }

    print(f"[1/{n_steps}] load bench: steady waves + 4x-capacity burst "
          f"(queue_limit={args.queue_limit})")
    report["load"] = load_bench(queue_limit=args.queue_limit,
                                seed=args.seed, quick=args.quick)
    st = report["load"]["stats"]
    lm = st.get("latency_ms", {})
    print(f"      {report['load']['requests']} requests: "
          f"{st['counters']['ok']} ok / {st['counters']['overloaded']} "
          f"shed / {st['counters']['failed']} failed; "
          f"{st.get('throughput_img_s', float('nan')):.1f} img/s; "
          f"latency ms p50 {lm.get('p50', float('nan')):.1f} / p95 "
          f"{lm.get('p95', float('nan')):.1f} / p99 "
          f"{lm.get('p99', float('nan')):.1f}; "
          f"{st['demotions']} demotions, {st['promotions']} promotions,"
          f" {st['loop_deaths']} loop deaths")

    if args.chaos:
        print(f"[2/{n_steps}] chaos soak: fault-injected burst on a "
              "virtual clock")
        from repro.testing import faults
        report["chaos"] = faults.chaos_soak(
            queue_limit=args.queue_limit, seed=args.seed,
            log=lambda m: print(f"      {m}"))

    print(f"[{n_steps}/{n_steps}] known gaps (non-gating)")
    report["known_gaps"] = known_gaps()
    for gap in report["known_gaps"]:
        print(f"      {gap['id']}: fused {gap['fused_ms']:.1f} ms vs "
              f"einsum {gap['einsum_ms']:.1f} ms ({gap['source']})")

    failed = [f"load.{g}" for g in report["load"]["failed_gates"]]
    if "chaos" in report:
        failed += [f"chaos.{g}" for g in report["chaos"]["failed_gates"]]
    report["gates"] = {
        "load": report["load"]["gates"],
        **({"chaos": report["chaos"]["gates"]} if "chaos" in report
           else {}),
    }
    report["failed_gates"] = failed

    _write_report_atomic(report, args.json)
    print(f"wrote {args.json}")
    if args.merge_into:
        _merge_into_e2e(report, args.merge_into)
        print(f"merged serve summary into {args.merge_into}")

    if failed:
        print("[gates] FAILED:", file=sys.stderr)
        for name in failed:
            print(f"  - {name}", file=sys.stderr)
        sys.exit(1)
    print("[gates] all serving gates pass")


if __name__ == "__main__":
    main()
