"""End-to-end spectral-VGG16 inference latency + HBM-traffic benchmark.

Compares the three conv-stack backends of ``models.cnn.forward_spectral``
— pure-jnp einsum oracle, staged Pallas (3 pallas_calls/layer with
spectral intermediates round-tripping through HBM), and the fused single
pallas_call executing a compile-once ``core.plan.NetworkPlan`` — and
emits ``BENCH_e2e.json`` with:

  * wall-clock latency at batch 1 and batch 8 (smoke VGG16 by default;
    the Pallas kernels run interpret-mode off-TPU, so off-TPU wall time
    is a correctness-path trend signal, not a perf claim — the analytic
    HBM/roofline numbers below are the hardware-portable signal), plus
    the one-off plan-construction time (everything per-layer is derived
    there, never inside the jitted forward),
  * per-layer kernel-launch counts (fused: 1, staged: 3), analytic HBM
    bytes of the tuned fused kernel (sparse-aware, alpha = 4) vs the
    dense fused path at the same configuration — kernel bytes drop by
    ~alpha — and vs the ``output_stationary`` staged-Hadamard prediction
    of ``dataflow.tpu_flow_cost``, plus the Eq-14 mean PE utilization of
    each layer's Alg-2 schedule (from the plan),
  * numerical parity of the fused kernel against the *spatial* oracle
    (alpha = 1, unpruned) and against the sparse-aware einsum oracle
    with the bias+ReLU epilogue fused in-kernel (alpha = 4) on every
    full-resolution VGG16 layer at batch 1.

  PYTHONPATH=src python -m benchmarks.e2e_latency [--full] [--json OUT]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

STAGED_LAUNCHES_PER_LAYER = 3     # fft8 + spectral_hadamard + ifft8
FUSED_LAUNCHES_PER_LAYER = 1


def _time(fn, iters: int = 3) -> float:
    out = fn()
    jax.block_until_ready(out)            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def latency_table(cfg, batches=(1, 8), backends=("einsum", "pallas_staged",
                                                 "pallas_fused"),
                  iters: int = 3) -> dict:
    from repro.core.plan import build_network_plan
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    out: dict = {}
    for batch in batches:
        t0 = time.perf_counter()
        plan = build_network_plan(params, cfg, batch=batch)
        plan_s = time.perf_counter() - t0
        x = jax.random.normal(key, (batch, 3, cfg.image_size,
                                    cfg.image_size), jnp.float32)
        row = {"plan_build_ms": 1e3 * plan_s}
        for backend in backends:
            row[f"{backend}_ms"] = 1e3 * _time(
                lambda b=backend: cnn.forward_spectral(
                    params, plan, x, backend=b),
                iters=iters)
        out[f"batch{batch}"] = row
    return out


def per_layer_traffic(plan, fft_size: int, batch: int = 1) -> list[dict]:
    """Analytic per-layer HBM bytes from the plan's tuned fused config:
    sparse-aware vs dense at the SAME config (the alpha saving), vs the
    staged pipeline's output-stationary Hadamard prediction (the fusion
    saving), plus Alg-2 PE utilization."""
    from repro.core import autotune
    from repro.core import dataflow as df

    def best_staged_os(layer, alpha):
        """Give the staged baseline its own best block sizes under the
        SAME selection policy as the fused tuner (not a straw man)."""
        tn = autotune.autotune_layer(
            layer, fft_size, alpha, batch=batch, hw_safe=False,
            flows=("output_stationary",), cost_fn=df.tpu_flow_cost)
        return df.tpu_flow_cost(layer, fft_size, alpha, tn.block_n,
                                tn.block_p, tn.block_m, tn.flow,
                                batch=batch)

    rows = []
    for lp in plan.layers:
        layer, tn = lp.layer, lp.tuning
        fa = lp.n_active_bins
        cost = lambda a, bins: df.tpu_fused_flow_cost(
            layer, fft_size, a, tn.block_n, tn.block_p, tn.block_m,
            tn.flow, batch=batch, active_bins=bins)
        fused_sparse = cost(lp.alpha, fa)
        fused_dense = cost(1.0, None)
        staged_os = best_staged_os(layer, lp.alpha)
        # staged pipeline additionally round-trips tiles through the
        # separate FFT/IFFT kernels (real in, 2 f32 planes out and back)
        k2 = fft_size * fft_size
        t = layer.tiles(fft_size) * batch
        fft_io = (layer.c_in * t * (k2 + 2 * k2)
                  + layer.c_out * t * (2 * k2 + k2)) * 4
        rows.append({
            "layer": layer.name,
            "launches_fused": FUSED_LAUNCHES_PER_LAYER,
            "launches_staged": STAGED_LAUNCHES_PER_LAYER,
            "flow": tn.flow,
            "block_n": tn.block_n, "block_m": tn.block_m,
            "block_p": tn.block_p,
            "alpha": lp.alpha,
            "nnz": lp.kernels.nnz,
            "active_bins": fa,
            "pe_utilization": lp.pe_utilization,
            "schedule_cycles": lp.schedule_cycles,
            "fused_hbm_bytes": fused_sparse["hbm_bytes"],
            "fused_hbm_bytes_dense": fused_dense["hbm_bytes"],
            "kernel_hbm_bytes": fused_sparse["kernel_hbm_bytes"],
            "kernel_hbm_bytes_dense": fused_dense["kernel_hbm_bytes"],
            "kernel_bytes_reduction": (
                fused_dense["kernel_hbm_bytes"]
                / fused_sparse["kernel_hbm_bytes"]),
            "staged_os_hadamard_hbm_bytes": staged_os["hbm_bytes"],
            "staged_fft_io_hbm_bytes": float(fft_io),
            "fused_le_staged_os": bool(
                fused_sparse["hbm_bytes"] <= staged_os["hbm_bytes"]),
            "fused_predicted_us": 1e6 * max(fused_sparse["hbm_s"],
                                            fused_sparse["compute_s"]),
            "staged_hadamard_predicted_us": 1e6 * max(staged_os["hbm_s"],
                                                      staged_os["compute_s"]),
        })
    return rows


def fused_parity_vs_spatial(layers, fft_size: int, batch: int = 1,
                            seed: int = 0) -> dict:
    """Per-layer fused-vs-spatial max abs error at full resolution,
    unpruned (alpha = 1) so the spectral path is numerically equivalent."""
    from repro.core import autotune
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import fused_spectral_conv2d

    rng = np.random.default_rng(seed)
    tuning = autotune.autotune_network(layers, fft_size, 1.0, batch=batch)
    per_layer = {}
    worst = 0.0
    for layer in layers:
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
            * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft_size, layer.pad)
        tn = tuning[layer.name]
        y = fused_spectral_conv2d(x, spec.spectral_kernel(w, fft_size),
                                  geo, **tn.kwargs())
        y_ref = spec.spatial_conv2d(x, w)
        err = float(jnp.abs(y - y_ref).max())
        per_layer[layer.name] = err
        worst = max(worst, err)
    return {"batch": batch, "alpha": 1.0, "max_abs_err": worst,
            "per_layer": per_layer,
            "passes_1e-3": bool(worst <= 1e-3)}


def fused_sparse_parity_vs_oracle(layers, fft_size: int, alpha: float = 4.0,
                                  batch: int = 1, seed: int = 0) -> dict:
    """Acceptance check: the fused-sparse backend (active-bin compaction
    + in-kernel bias+ReLU epilogue) matches the sparse-aware einsum
    oracle to <= 1e-4 on every full-resolution VGG16 layer."""
    from repro.core import autotune, sparse as sp
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import fused_spectral_conv2d

    rng = np.random.default_rng(seed)
    per_layer = {}
    worst = 0.0
    for layer in layers:
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
            * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
        b = jnp.asarray(0.1 * rng.standard_normal(layer.c_out), jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft_size, layer.pad)
        sk = sp.prune_magnitude(spec.spectral_kernel(w, fft_size), alpha)
        tn = autotune.autotune_layer(layer, fft_size, alpha, batch=batch)
        y = fused_spectral_conv2d(x, sk, geo, bias=b, relu=True,
                                  **tn.kwargs())
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(y - y_ref).max())
        per_layer[layer.name] = err
        worst = max(worst, err)
    return {"batch": batch, "alpha": alpha, "epilogue": "bias+relu",
            "max_abs_err": worst, "per_layer": per_layer,
            "passes_1e-4": bool(worst <= 1e-4)}


def main() -> None:
    from repro.configs import vgg16_spectral
    from repro.core import dataflow as df
    from repro.core.plan import build_network_plan
    from repro.models import cnn

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_e2e.json",
                    help="output path for the JSON report")
    ap.add_argument("--full", action="store_true",
                    help="also time the full 224x224 model (slow on CPU)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    report: dict = {
        "bench": "e2e_latency",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "model": "vgg16-spectral",
        "fft_size": 8,
        "alpha": 4.0,
    }

    print("[1/4] latency: oracle vs staged Pallas vs fused Pallas "
          "(plan built once per batch)")
    report["latency"] = {"smoke": latency_table(
        vgg16_spectral.SMOKE, iters=args.iters)}
    if args.full:
        report["latency"]["full"] = latency_table(
            vgg16_spectral.CONFIG, batches=(1,), iters=1)
    for scale, tbl in report["latency"].items():
        for b, row in tbl.items():
            pretty = ", ".join(f"{k}={v:.1f}" for k, v in row.items())
            print(f"      {scale}/{b}: {pretty}")

    print("[2/4] full-VGG16 NetworkPlan (compile once: prune + Alg 2 + "
          "compaction + autotune)")
    t0 = time.perf_counter()
    params_full = cnn.init(jax.random.PRNGKey(0), vgg16_spectral.CONFIG)
    plan_full = build_network_plan(params_full, vgg16_spectral.CONFIG,
                                   batch=1)
    report["plan_build_s"] = time.perf_counter() - t0
    print(f"      built in {report['plan_build_s']:.1f}s")

    print("[3/4] per-layer launches + analytic HBM traffic "
          "(sparse vs dense vs staged) + Alg-2 PE utilization")
    layer_rows = per_layer_traffic(plan_full, 8, batch=1)
    report["layers"] = layer_rows
    tot_fused = sum(r["fused_hbm_bytes"] for r in layer_rows)
    tot_fused_dense = sum(r["fused_hbm_bytes_dense"] for r in layer_rows)
    tot_staged = sum(r["staged_os_hadamard_hbm_bytes"]
                     + r["staged_fft_io_hbm_bytes"] for r in layer_rows)
    tot_k_sparse = sum(r["kernel_hbm_bytes"] for r in layer_rows)
    tot_k_dense = sum(r["kernel_hbm_bytes_dense"] for r in layer_rows)
    mus = [r["pe_utilization"] for r in layer_rows
           if r["pe_utilization"] is not None]
    report["totals"] = {
        "fused_hbm_mb": tot_fused / 1e6,
        "fused_dense_hbm_mb": tot_fused_dense / 1e6,
        "staged_hbm_mb": tot_staged / 1e6,
        "hbm_reduction_vs_staged_pct": 100 * (1 - tot_fused / tot_staged),
        "kernel_hbm_mb": tot_k_sparse / 1e6,
        "kernel_dense_hbm_mb": tot_k_dense / 1e6,
        "kernel_bytes_reduction": tot_k_dense / tot_k_sparse,
        "mean_pe_utilization": float(np.mean(mus)) if mus else None,
        "launches_fused": FUSED_LAUNCHES_PER_LAYER * len(layer_rows),
        "launches_staged": STAGED_LAUNCHES_PER_LAYER * len(layer_rows),
        "all_layers_fused_le_staged_os": all(
            r["fused_le_staged_os"] for r in layer_rows),
    }
    t = report["totals"]
    print(f"      fused {t['fused_hbm_mb']:.1f} MB (dense "
          f"{t['fused_dense_hbm_mb']:.1f} MB) vs staged "
          f"{t['staged_hbm_mb']:.1f} MB HBM "
          f"({t['hbm_reduction_vs_staged_pct']:.0f}% less than staged); "
          f"kernel bytes {t['kernel_hbm_mb']:.1f} MB vs dense "
          f"{t['kernel_dense_hbm_mb']:.1f} MB "
          f"({t['kernel_bytes_reduction']:.1f}x ~= alpha); mean PE util "
          f"{t['mean_pe_utilization']:.1%}; launches "
          f"{t['launches_fused']} vs {t['launches_staged']}")

    print("[4/4] parity on full VGG16 (batch 1): fused vs spatial "
          "(alpha=1) and fused-sparse+epilogue vs einsum oracle (alpha=4)")
    report["parity"] = fused_parity_vs_spatial(df.VGG16_LAYERS, 8, batch=1)
    print(f"      dense vs spatial: max abs err "
          f"{report['parity']['max_abs_err']:.2e} "
          f"(<= 1e-3: {report['parity']['passes_1e-3']})")
    report["parity_sparse"] = fused_sparse_parity_vs_oracle(
        df.VGG16_LAYERS, 8, alpha=4.0, batch=1)
    print(f"      sparse+epilogue vs oracle: max abs err "
          f"{report['parity_sparse']['max_abs_err']:.2e} "
          f"(<= 1e-4: {report['parity_sparse']['passes_1e-4']})")

    with open(args.json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
