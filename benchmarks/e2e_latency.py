"""End-to-end spectral-VGG16 inference latency + HBM-traffic benchmark.

Compares the three conv-stack backends of ``models.cnn.forward_spectral``
— pure-jnp einsum oracle, staged Pallas (3 pallas_calls/layer with
spectral intermediates round-tripping through HBM), and the fused single
pallas_call executing a compile-once ``core.plan.NetworkPlan`` whose
Hadamard stage runs per layer in the mode Alg 1 chose (dense / bin /
scheduled) — and emits ``BENCH_e2e.json``.

  PYTHONPATH=src python -m benchmarks.e2e_latency [--full] [--quick]
      [--json OUT] [--iters N]

``--quick`` is the CI smoke path: smoke-scale model everywhere, no
full-resolution plan build or parity sweeps (the scripts must not
crash; the committed BENCH_e2e.json comes from a full run).

BENCH_e2e.json schema
---------------------
  bench / backend / interpret_mode / model / fft_size / alpha / quick
      run metadata (``interpret_mode`` is true off-TPU: wall times are
      correctness-path trend signals, the analytic numbers are the
      hardware-portable ones).
  latency.{smoke,full}.batch{B}
      plan_build_ms, then {backend}_ms wall-clock per forward call.
      One plan per bucket, tuned AT that batch with the interpret-mode
      per-step overhead priced in (``dataflow.INTERPRET_STEP_S``).
  batch_sweep
      the gating per-bucket table: fused_ms vs einsum_ms at every
      serving bucket and the acceptance boolean
      ``fused_le_einsum_all_buckets`` (CI fails when the fused path
      loses to its own fallback at any bucket — the graduated form of
      the old ``known_gaps`` batch-8 entry).
  plan_build_s
      one-off full-VGG16 plan construction time (prune + Alg 2 +
      compaction + table compilation + autotune).
  layers[]  (one row per conv layer, analytic, at the TUNED config)
      layer / flow / hadamard / input_mode / block_n / block_m / block_p
          the plan's Alg-1 choice, incl. the Hadamard and input modes.
      alpha / nnz / active_bins / pe_utilization / schedule_cycles
          sparsity + Alg-2 stats (exact for scheduled layers).
      launches_fused / launches_staged
          kernel launches per layer (1 vs 3).
      fused_hbm_bytes / fused_hbm_bytes_dense
          total analytic HBM traffic of the fused kernel in the plan's
          modes vs the fully dense datapath (alpha = 1, windowed
          input) at the same config.
      input_hbm_bytes{,_windowed,_halo}
          the input-operand share of HBM traffic (stream * flow
          re-read factor + the one-off materialization / gather-
          selector bytes): the plan's input mode, then both modes at
          the same config.  halo counts raw-plus-halo words read
          straight from the NCHW activation; windowed counts the
          host-materialized [B, M, T, K, K] window tensor (one
          relayout pass + the ~(K/t)^2 duplicated stream).
      halo_lt_windowed
          acceptance flag: halo input bytes < windowed at this config.
      kernel_hbm_bytes{,_dense,_bin,_scheduled}
          the kernel-operand share of HBM traffic (re-read factors
          included): the plan's mode, then each mode at the same
          config.  The scheduled column counts the Alg-2 INDEX/VALUE
          table stream — the paper's O(nnz) kernel traffic — using the
          ACTUAL compiled table bytes when the plan carries tables
          (exact padding), else the nnz/mu analytic estimate.
      table_bytes
          actual bytes of the compiled tables (0 for plane modes).
      hadamard_flops{_dense,_bin,_scheduled}
          Hadamard-stage MACs per mode; the scheduled entry is the
          honest one-hot-matmul realization, not the paper's element
          count.
      scheduled_le_bin
          acceptance flag: scheduled kernel bytes <= bin-compacted.
      staged_os_hadamard_hbm_bytes / staged_fft_io_hbm_bytes /
      fused_le_staged_os / fused_predicted_us /
      staged_hadamard_predicted_us
          the staged-pipeline baseline at its own best blocks;
          ``fused_le_staged_os`` compares the fused kernel against the
          staged pipeline's TOTAL traffic (Hadamard + FFT/IFFT
          round-trips — the three launches it actually needs).
  totals
      aggregates of the above (MB), kernel_bytes vs dense/bin/
      scheduled, input_bytes vs windowed/halo, per-mode layer counts,
      mean Eq-14 PE utilization, launch counts, and the acceptance
      booleans ``all_layers_fused_le_staged_os``,
      ``all_sparse_scheduled_le_bin`` and
      ``all_layers_halo_input_lt_windowed`` (CI asserts the last one).
  parity / parity_sparse
      fused vs spatial (alpha = 1, <= 1e-3) and fused-sparse+epilogue
      vs einsum oracle (alpha = 4, <= 1e-4) on full-resolution VGG16.
  parity_scheduled
      acceptance: the SCHEDULED fused datapath vs the einsum oracle,
      <= 1e-5 — per-layer on the conv5 trio at full channel counts and
      end-to-end on the smoke network with every layer forced
      scheduled.
  parity_halo
      acceptance: the halo input path (in-kernel gather from the raw
      activation) vs the einsum oracle, <= 1e-5, across ALL THREE
      flows x ALL THREE Hadamard modes, plus the max deviation from
      the windowed path (one-hot gather => 0.0).
  sharded  (the multi-device column)
      cost_model: the two-level Alg-1 cost model at D shards (full
      VGG16 at D=8; smoke at D=4 under --quick) — per layer the chosen
      partitioning strategy (spatial / channel / replicate), per-chip
      HBM vs the single-chip autotuned footprint, ICI bytes and
      serialization, plus the gating booleans
      ``strategy_diversity_ge_2`` (Alg 1 run per layer must pick >= 2
      distinct strategies), ``ici_bytes_positive`` and
      ``per_chip_hbm_le_single_chip_all_layers``.
      parity: live end-to-end check (present when >= 2 devices are
      visible, e.g. under XLA_FLAGS=--xla_force_host_platform_device_
      count=8): channel- and spatial-forced ShardedNetworkPlans under
      shard_map vs the single-device einsum oracle, <= 1e-5.
  resnet  (the residual-DAG column, ISSUE 10)
      the ResNet-18-style smoke preset (stride-2 downsample, max/avg
      pool nodes, four residual-FUSED shortcut epilogues) at alpha = 1
      against the spatial DAG oracle (``cnn.forward_spatial``):
      graph composition (n_nodes / n_residual / n_stride2 / pools /
      shortcut_on_chip reuse verdicts), then the gating sections
      ``parity`` (all three backends <= 1e-5), ``shortcuts`` (per
      residual edge the analytic HBM bytes of the residual-fused
      epilogue <= the unfused kernel + 3-Y-pass XLA add;
      ``fused_le_unfused_all``), ``demotion`` (an injected 'lowering'
      fault matched on residual='fused' walks every residual node down
      the residual-fused -> residual-add ladder rung and the hardened
      plan still passes <= 1e-5), and — when >= 2 devices are visible —
      ``sharded`` (channel- and spatial-forced DAG execution under
      shard_map, <= 1e-5).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

STAGED_LAUNCHES_PER_LAYER = 3     # fft8 + spectral_hadamard + ifft8
FUSED_LAUNCHES_PER_LAYER = 1


def _time(fn, iters: int = 3) -> float:
    out = fn()
    jax.block_until_ready(out)            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def latency_table(cfg, batches=(1, 2, 4, 8),
                  backends=("einsum", "pallas_staged", "pallas_fused"),
                  iters: int = 3) -> dict:
    """Wall-clock per forward call, one plan PER BATCH BUCKET: each
    bucket's plan is tuned at its own batch
    (``dataflow.INTERPRET_STEP_S`` priced in — calibrated to zero, see
    its comment) — the fix for the old batch-8 ``known_gaps`` entry,
    which timed a batch-8 forward on batch-1 block choices."""
    from repro.core import dataflow as df
    from repro.core.plan import build_network_plan
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    step_s = (df.INTERPRET_STEP_S if jax.default_backend() != "tpu"
              else 0.0)
    out: dict = {}
    for batch in batches:
        t0 = time.perf_counter()
        plan = build_network_plan(params, cfg, batch=batch,
                                  step_overhead_s=step_s)
        plan_s = time.perf_counter() - t0
        x = jax.random.normal(key, (batch, 3, cfg.image_size,
                                    cfg.image_size), jnp.float32)
        row = {"plan_build_ms": 1e3 * plan_s}
        for backend in backends:
            row[f"{backend}_ms"] = 1e3 * _time(
                lambda b=backend: cnn.forward_spectral(
                    params, plan, x, backend=b),
                iters=iters)
        out[f"batch{batch}"] = row
    return out


def bucket_gate(latency_smoke: dict) -> dict:
    """The gating acceptance check that replaced the ``known_gaps``
    entry: at EVERY serving bucket the fused backend must beat (or
    match) the einsum oracle it would otherwise degrade to."""
    per_bucket = {}
    for name, row in sorted(latency_smoke.items()):
        if "pallas_fused_ms" not in row or "einsum_ms" not in row:
            continue
        per_bucket[name] = {
            "fused_ms": row["pallas_fused_ms"],
            "einsum_ms": row["einsum_ms"],
            "fused_le_einsum": bool(
                row["pallas_fused_ms"] <= row["einsum_ms"]),
        }
    return {
        "per_bucket": per_bucket,
        "fused_le_einsum_all_buckets": all(
            r["fused_le_einsum"] for r in per_bucket.values()),
    }


def per_layer_traffic(plan, fft_size: int, batch: int = 1) -> list[dict]:
    """Analytic per-layer HBM bytes from the plan's tuned fused config:
    the plan's Hadamard mode vs every mode at the SAME config (the
    dense/bin/scheduled trade Alg 1 ranked), vs the staged pipeline's
    output-stationary Hadamard prediction (the fusion saving), plus
    Alg-2 PE utilization."""
    from repro.core import autotune
    from repro.core import dataflow as df

    def best_staged_os(layer, alpha):
        """Give the staged baseline its own best block sizes under the
        SAME selection policy as the fused tuner (not a straw man)."""
        tn = autotune.autotune_layer(
            layer, fft_size, alpha, batch=batch, hw_safe=False,
            flows=("output_stationary",), cost_fn=df.tpu_flow_cost)
        return df.tpu_flow_cost(layer, fft_size, alpha, tn.block_n,
                                tn.block_p, tn.block_m, tn.flow,
                                batch=batch)

    rows = []
    for lp in plan.layers:
        layer, tn = lp.layer, lp.tuning
        fa = lp.n_active_bins
        cost = lambda a, bins, mode, imode: df.tpu_fused_flow_cost(
            layer, fft_size, a, tn.block_n, tn.block_p, tn.block_m,
            tn.flow, batch=batch, active_bins=bins, hadamard=mode,
            input_mode=imode)
        fused_plan = cost(lp.alpha, fa, lp.hadamard, lp.input_mode)
        fused_dense = cost(1.0, None, "dense", "windowed")
        mode_cost = {m: cost(lp.alpha, fa, m, lp.input_mode)
                     for m in df.HADAMARD_MODES}
        input_cost = {im: cost(lp.alpha, fa, lp.hadamard, im)
                      for im in df.INPUT_MODES}
        staged_os = best_staged_os(layer, lp.alpha)
        # Scheduled kernel bytes: prefer the ACTUAL compiled table
        # stream (exact t_max/channel padding) over the nnz/mu estimate
        # whenever the plan carries tables; same per-flow re-read
        # factor as the cost model.
        sched_bytes = mode_cost["scheduled"]["kernel_hbm_bytes"]
        if lp.tables is not None:
            t = layer.tiles(fft_size) * batch
            gp = max(1, -(-t // tn.block_p))
            reread = 1 if tn.flow == "weight_stationary" else gp
            sched_bytes = float(lp.tables.nbytes * reread)
        # staged pipeline additionally round-trips tiles through the
        # separate FFT/IFFT kernels (real in, 2 f32 planes out and
        # back), and consumes the same host-materialized window tensor
        # the windowed fused path does (raw read + windowed write) —
        # counted for symmetry with the fused input accounting.
        k2 = fft_size * fft_size
        t = layer.tiles(fft_size) * batch
        fft_io = (layer.c_in * t * (k2 + 2 * k2)
                  + layer.c_out * t * (2 * k2 + k2)
                  + layer.c_in * (layer.h_in * layer.w_in * batch
                                  + k2 * t)) * 4
        rows.append({
            "layer": layer.name,
            "launches_fused": FUSED_LAUNCHES_PER_LAYER,
            "launches_staged": STAGED_LAUNCHES_PER_LAYER,
            "flow": tn.flow,
            "hadamard": lp.hadamard,
            "input_mode": lp.input_mode,
            "block_n": tn.block_n, "block_m": tn.block_m,
            "block_p": tn.block_p,
            "alpha": lp.alpha,
            "nnz": lp.kernels.nnz,
            "active_bins": fa,
            "pe_utilization": lp.pe_utilization,
            "schedule_cycles": lp.schedule_cycles,
            "fused_hbm_bytes": fused_plan["hbm_bytes"],
            "fused_hbm_bytes_dense": fused_dense["hbm_bytes"],
            "input_hbm_bytes": fused_plan["input_hbm_bytes"],
            "input_hbm_bytes_windowed":
                input_cost["windowed"]["input_hbm_bytes"],
            "input_hbm_bytes_halo": input_cost["halo"]["input_hbm_bytes"],
            "halo_lt_windowed": bool(
                input_cost["halo"]["input_hbm_bytes"]
                < input_cost["windowed"]["input_hbm_bytes"]),
            "kernel_hbm_bytes": fused_plan["kernel_hbm_bytes"],
            "kernel_hbm_bytes_dense": fused_dense["kernel_hbm_bytes"],
            "kernel_hbm_bytes_bin": mode_cost["bin"]["kernel_hbm_bytes"],
            "kernel_hbm_bytes_scheduled": sched_bytes,
            "table_bytes": (lp.tables.nbytes
                            if lp.tables is not None else 0),
            "hadamard_flops_dense": mode_cost["dense"]["had_flops"],
            "hadamard_flops_bin": mode_cost["bin"]["had_flops"],
            "hadamard_flops_scheduled":
                mode_cost["scheduled"]["had_flops"],
            "scheduled_le_bin": bool(
                sched_bytes <= mode_cost["bin"]["kernel_hbm_bytes"]),
            "kernel_bytes_reduction": (
                fused_dense["kernel_hbm_bytes"]
                / fused_plan["kernel_hbm_bytes"]),
            "staged_os_hadamard_hbm_bytes": staged_os["hbm_bytes"],
            "staged_fft_io_hbm_bytes": float(fft_io),
            "fused_le_staged_os": bool(
                fused_plan["hbm_bytes"]
                <= staged_os["hbm_bytes"] + fft_io),
            "fused_predicted_us": 1e6 * (
                fused_plan["serial_s"] + max(fused_plan["hbm_s"],
                                             fused_plan["compute_s"])),
            "staged_hadamard_predicted_us": 1e6 * max(staged_os["hbm_s"],
                                                      staged_os["compute_s"]),
        })
    return rows


def fused_parity_vs_spatial(layers, fft_size: int, batch: int = 1,
                            seed: int = 0) -> dict:
    """Per-layer fused-vs-spatial max abs error at full resolution,
    unpruned (alpha = 1) so the spectral path is numerically equivalent."""
    from repro.core import autotune
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import fused_spectral_conv2d

    rng = np.random.default_rng(seed)
    tuning = autotune.autotune_network(layers, fft_size, 1.0, batch=batch)
    per_layer = {}
    worst = 0.0
    for layer in layers:
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
            * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft_size, layer.pad)
        tn = tuning[layer.name]
        y = fused_spectral_conv2d(x, spec.spectral_kernel(w, fft_size),
                                  geo, **tn.kwargs())
        y_ref = spec.spatial_conv2d(x, w)
        err = float(jnp.abs(y - y_ref).max())
        per_layer[layer.name] = err
        worst = max(worst, err)
    return {"batch": batch, "alpha": 1.0, "max_abs_err": worst,
            "per_layer": per_layer,
            "passes_1e-3": bool(worst <= 1e-3)}


def fused_sparse_parity_vs_oracle(layers, fft_size: int, alpha: float = 4.0,
                                  batch: int = 1, seed: int = 0) -> dict:
    """Acceptance check: the fused-sparse backend (active-bin compaction
    + in-kernel bias+ReLU epilogue) matches the sparse-aware einsum
    oracle to <= 1e-4 on every full-resolution VGG16 layer."""
    from repro.core import autotune, sparse as sp
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import fused_spectral_conv2d

    rng = np.random.default_rng(seed)
    per_layer = {}
    worst = 0.0
    for layer in layers:
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
            * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
        b = jnp.asarray(0.1 * rng.standard_normal(layer.c_out), jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft_size, layer.pad)
        sk = sp.prune_magnitude(spec.spectral_kernel(w, fft_size), alpha)
        tn = autotune.autotune_layer(layer, fft_size, alpha, batch=batch)
        y = fused_spectral_conv2d(x, sk, geo, bias=b, relu=True,
                                  **tn.kwargs())
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(y - y_ref).max())
        per_layer[layer.name] = err
        worst = max(worst, err)
    return {"batch": batch, "alpha": alpha, "epilogue": "bias+relu",
            "max_abs_err": worst, "per_layer": per_layer,
            "passes_1e-4": bool(worst <= 1e-4)}


def scheduled_parity_vs_oracle(layers, fft_size: int, alpha: float = 4.0,
                               batch: int = 1, seed: int = 0) -> dict:
    """Acceptance: the SCHEDULED fused datapath — Alg-2 INDEX/VALUE
    tables executed element-granularly inside the single pallas_call —
    matches the sparse-aware einsum oracle to <= 1e-5, bias+ReLU
    in-kernel, at the Alg-1 configuration tuned for the mode."""
    from repro.core import autotune, sparse as sp
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import (
        fused_spectral_conv2d_scheduled)

    rng = np.random.default_rng(seed)
    per_layer = {}
    worst = 0.0
    for layer in layers:
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
            * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
        b = jnp.asarray(0.1 * rng.standard_normal(layer.c_out), jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft_size, layer.pad)
        sk = sp.prune_magnitude(spec.spectral_kernel(w, fft_size), alpha)
        tn = autotune.autotune_layer(layer, fft_size, alpha, batch=batch,
                                     hadamard_modes=("scheduled",))
        y = fused_spectral_conv2d_scheduled(
            x, sk, geo, bias=b, relu=True, n_par=tn.block_n,
            flow=tn.flow, block_m=tn.block_m, block_p=tn.block_p)
        y_ref = jax.nn.relu(
            spec.spectral_conv2d_pretransformed(x, sk, geo)
            + b[None, :, None, None])
        err = float(jnp.abs(y - y_ref).max())
        per_layer[layer.name] = err
        worst = max(worst, err)
    return {"batch": batch, "alpha": alpha, "epilogue": "bias+relu",
            "max_abs_err": worst, "per_layer": per_layer,
            "passes_1e-5": bool(worst <= 1e-5)}


def scheduled_network_parity(cfg, batch: int = 1) -> dict:
    """End-to-end: the smoke network with EVERY layer forced to the
    scheduled datapath vs the einsum oracle on the same plan."""
    from repro.core.plan import build_network_plan
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    plan = build_network_plan(params, cfg, batch=batch,
                              hadamard="scheduled")
    x = jax.random.normal(key, (batch, 3, cfg.image_size, cfg.image_size),
                          jnp.float32)
    ref = cnn.forward_spectral(params, plan, x, backend="einsum")
    out = cnn.forward_spectral(params, plan, x, backend="pallas_fused")
    err = float(jnp.abs(out - ref).max())
    return {"model": cfg.name, "batch": batch,
            "modes": [lp.hadamard for lp in plan.layers],
            "max_abs_logit_err": err,
            "passes_1e-5": bool(err <= 1e-5)}


def halo_parity_matrix(fft_size: int = 8, alpha: float = 4.0,
                       batch: int = 1, seed: int = 0,
                       small: bool = False) -> dict:
    """Acceptance: the halo input path (in-kernel window gather from the
    raw activation) matches the einsum oracle <= 1e-5 across ALL THREE
    flows x ALL THREE Hadamard modes, bias+ReLU fused.  Also reports
    the max |halo - windowed| deviation, which the one-hot gather makes
    exactly 0.0."""
    from repro.core import dataflow as df
    from repro.core import sparse as sp
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import (
        fused_spectral_conv2d, fused_spectral_conv2d_scheduled)

    rng = np.random.default_rng(seed)
    layer = (df.ConvLayer("halo_matrix_smoke", 8, 8, 12, 12) if small
             else df.ConvLayer("halo_matrix", 48, 64, 28, 28))
    x = jnp.asarray(rng.standard_normal(
        (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
        * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
    b = jnp.asarray(0.1 * rng.standard_normal(layer.c_out), jnp.float32)
    geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                             fft_size, layer.pad)
    sk = sp.prune_magnitude(spec.spectral_kernel(w, fft_size), alpha)
    y_ref = jax.nn.relu(
        spec.spectral_conv2d_pretransformed(x, sk, geo)
        + b[None, :, None, None])

    cells = {}
    worst_oracle = worst_windowed = 0.0
    for flow in df.FLOWS:
        for mode in df.HADAMARD_MODES:
            out = {}
            for imode in df.INPUT_MODES:
                bn = min(16, layer.c_out)
                bm = min(16, layer.c_in)
                if mode == "scheduled":
                    out[imode] = fused_spectral_conv2d_scheduled(
                        x, sk, geo, n_par=bn, flow=flow, block_m=bm,
                        block_p=32, bias=b, relu=True, input_mode=imode)
                else:
                    w_f = sk.values if mode == "dense" else sk
                    out[imode] = fused_spectral_conv2d(
                        x, w_f, geo, flow=flow, block_n=bn, block_m=bm,
                        block_p=32, bias=b, relu=True, input_mode=imode)
            e_or = float(jnp.abs(out["halo"] - y_ref).max())
            e_win = float(jnp.abs(out["halo"] - out["windowed"]).max())
            cells[f"{flow}/{mode}"] = {"vs_oracle": e_or,
                                       "vs_windowed": e_win}
            worst_oracle = max(worst_oracle, e_or)
            worst_windowed = max(worst_windowed, e_win)
    return {"layer": layer.name, "alpha": alpha, "epilogue": "bias+relu",
            "cells": cells,
            "max_abs_err_vs_oracle": worst_oracle,
            "max_abs_err_vs_windowed": worst_windowed,
            "passes_1e-5": bool(worst_oracle <= 1e-5)}


def sharded_cost_model(layers, fft_size: int = 8, alpha: float = 4.0,
                       n_shards: int = 8, batch: int = 1) -> dict:
    """The multi-device column: the two-level Alg-1 cost model
    (``autotune.autotune_layer_sharded`` via
    ``distributed.planner.spectral_plan_cell``) over the conv stack at
    D shards.  Analytic — needs no devices — and gated:

      strategy_diversity_ge_2   the tuner must pick >= 2 DISTINCT
          partitionings across the stack (the whole point of running
          Alg 1 per layer instead of per network: early large-canvas
          convs shard spatially, late channel-heavy convs by channel).
      ici_bytes_positive        a sharded network that claims zero
          wire traffic is mis-modeling its collectives.
      per_chip_hbm_le_single_chip_all_layers
          every layer's per-chip HBM footprint under the chosen
          strategy is <= the single-chip autotuned footprint of the
          FULL layer — sharding must never inflate per-chip traffic.
    """
    from repro.core import autotune
    from repro.distributed.planner import spectral_plan_cell

    cell = spectral_plan_cell(layers, fft_size, alpha,
                              n_shards=n_shards, batch=batch)
    single = autotune.autotune_network(layers, fft_size, alpha,
                                       batch=batch)
    rows = []
    for layer in layers:
        t = cell["tunings"][layer.name]
        s = single[layer.name]
        rows.append({
            "layer": layer.name,
            "strategy": t.strategy,
            "flow": t.base.flow,
            "hadamard": t.base.hadamard,
            "input_mode": t.base.input_mode,
            "block_n": t.base.block_n,
            "block_m": t.base.block_m,
            "block_p": t.base.block_p,
            "per_chip_hbm_bytes": t.per_chip_hbm_bytes,
            "single_chip_hbm_bytes": s.hbm_bytes,
            "ici_bytes": t.ici_bytes,
            "ici_s": t.ici_s,
            "sharded_s": t.sharded_s,
            "single_chip_predicted_s": s.predicted_s,
            "per_chip_le_single_chip": bool(
                t.per_chip_hbm_bytes <= s.hbm_bytes),
        })
    distinct = sorted({r["strategy"] for r in rows})
    return {
        "n_shards": n_shards,
        "batch": batch,
        "alpha": alpha,
        "layers": rows,
        "strategy_counts": {
            "spatial": cell["n_spatial"],
            "channel": cell["n_channel"],
            "replicate": cell["n_replicate"],
        },
        "distinct_strategies": distinct,
        "per_chip_hbm_mb_worst": cell["per_chip_hbm_bytes"] / 1e6,
        "ici_mb_total": cell["ici_bytes_total"] / 1e6,
        "ici_s_total": cell["ici_s_total"],
        "sharded_s_total": cell["sharded_s_total"],
        "single_chip_s_total": sum(r["single_chip_predicted_s"]
                                   for r in rows),
        "strategy_diversity_ge_2": bool(len(distinct) >= 2),
        "ici_bytes_positive": bool(cell["ici_bytes_total"] > 0),
        "per_chip_hbm_le_single_chip_all_layers": all(
            r["per_chip_le_single_chip"] for r in rows),
    }


def sharded_parity(cfg, n_shards: int = 2, batch: int = 1) -> dict:
    """Live multi-device acceptance: channel- AND spatial-forced
    ``ShardedNetworkPlan`` forward passes under ``shard_map`` on a real
    ``n_shards``-device mesh match the single-device einsum oracle to
    <= 1e-5 end-to-end (conv stack + pools + FC head).  Layers where a
    forced strategy is infeasible (e.g. channel with D not dividing
    c_in) fall back to 'replicate' per the plan builder — the mixed
    plan still exercises the collectives on every feasible layer."""
    from repro.core.plan import (build_network_plan,
                                 build_sharded_network_plan)
    from repro.distributed.executor import forward_spectral_sharded
    from repro.launch.mesh import make_spectral_mesh
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    x = jax.random.normal(key, (batch, 3, cfg.image_size, cfg.image_size),
                          jnp.float32)
    base = build_network_plan(params, cfg, batch=batch)
    ref = cnn.forward_spectral(params, base, x, backend="einsum")
    mesh = make_spectral_mesh(n_shards)
    out: dict = {"model": cfg.name, "n_shards": n_shards, "batch": batch}
    worst = 0.0
    for strat in ("channel", "spatial"):
        splan = build_sharded_network_plan(
            params, cfg, n_shards=n_shards, strategies=(strat,),
            batch=batch)
        y = forward_spectral_sharded(params, splan, x, mesh=mesh)
        err = float(jnp.abs(y - ref).max())
        counts = {}
        for s in splan.strategies.values():
            counts[s] = counts.get(s, 0) + 1
        out[strat] = {"max_abs_logit_err": err,
                      "strategy_counts": counts}
        worst = max(worst, err)
    out["max_abs_err"] = worst
    out["passes_1e-5"] = bool(worst <= 1e-5)
    return out


def resnet_dag_column(batch: int = 1) -> dict:
    """The gated ``resnet`` column (ISSUE 10): the residual-DAG plan IR
    on the ResNet-18-style smoke preset — stride-2 downsample, max- and
    avg-pool nodes, and four residual-FUSED shortcut epilogues.

    Four acceptance surfaces, all against the SPATIAL DAG oracle
    (``cnn.forward_spatial`` walking the same graph) at alpha = 1
    (pruning off — the oracle does not prune, so parity is only defined
    dense):

      parity     all three backends <= 1e-5 end-to-end;
      shortcuts  per residual edge, the analytic HBM bytes of the
                 residual-FUSED epilogue (shortcut priced at the tuned
                 'vmem'/'hbm' placement) <= the unfused alternative
                 (same kernel without the shortcut operand + a separate
                 XLA add pass re-reading y and the shortcut and writing
                 y back: 3 extra Y-passes);
      demotion   an injected 'lowering' fault matched on
                 ``residual='fused'`` must walk every residual node down
                 the NEW ladder rung (residual-fused -> residual-add)
                 and the hardened plan must still match the oracle;
      sharded    when >= 2 devices are visible, a channel- and a
                 spatial-FORCED ShardedNetworkPlan of the same DAG must
                 match the oracle under shard_map.
    """
    import dataclasses

    from repro.configs import resnet18_spectral
    from repro.core import dataflow as df
    from repro.core import resilience as res
    from repro.core.plan import build_network_plan
    from repro.models import cnn
    from repro.testing import faults

    cfg = dataclasses.replace(resnet18_spectral.SMOKE, alpha=1.0)
    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    x = jax.random.normal(key, (batch, 3, cfg.image_size, cfg.image_size),
                          jnp.float32)
    plan = build_network_plan(params, cfg, batch=batch)
    ref = cnn.forward_spatial(params, cfg, x)

    graph = plan.execution_graph
    residual_nodes = [n for n in graph if n.residual_from is not None]
    out: dict = {
        "model": cfg.name,
        "alpha": cfg.alpha,
        "batch": batch,
        "n_nodes": len(graph),
        "n_residual": len(residual_nodes),
        "n_stride2": sum(
            n.kind == "conv"
            and plan.layers[n.layer_index].layer.stride == 2
            for n in graph),
        "pools": [n.pool for n in graph if n.kind == "pool"],
        "residual_fused_nodes": [
            n.id for n in residual_nodes
            if plan.layers[n.layer_index].epilogue.residual == "fused"],
        "shortcut_on_chip": {n.id: n.shortcut_on_chip
                             for n in residual_nodes},
    }

    per_backend = {}
    for backend in ("einsum", "pallas_staged", "pallas_fused"):
        y = cnn.forward_spectral(params, plan, x, backend=backend)
        per_backend[backend] = float(jnp.abs(y - ref).max())
    worst = max(per_backend.values())
    out["parity"] = {"per_backend": per_backend, "max_abs_err": worst,
                     "passes_1e-5": bool(worst <= 1e-5)}

    # Analytic shortcut gate: fusing the residual add into the epilogue
    # must never cost more HBM than the unfused alternative (kernel
    # without the shortcut operand + a 3-Y-pass XLA add: read y, read
    # shortcut, write y).
    rows = []
    for n in residual_nodes:
        lp = plan.layers[n.layer_index]
        tn = lp.tuning
        place = tn.residual or "hbm"

        def cost(residual):
            return df.tpu_fused_flow_cost(
                lp.layer, cfg.fft_size, lp.alpha, tn.block_n,
                tn.block_p, tn.block_m, tn.flow, batch=batch,
                active_bins=lp.n_active_bins, hadamard=lp.hadamard,
                input_mode=lp.input_mode, residual=residual)

        hw = lp.layer.out_hw
        y_bytes = 4 * batch * lp.layer.c_out * hw[0] * hw[1]
        fused = cost(place)["hbm_bytes"]
        unfused = cost(None)["hbm_bytes"] + 3 * y_bytes
        rows.append({
            "node": n.id,
            "placement": place,
            "shortcut_on_chip": n.shortcut_on_chip,
            "fused_hbm_bytes": fused,
            "unfused_hbm_bytes": unfused,
            "fused_le_unfused": bool(fused <= unfused),
        })
    out["shortcuts"] = {
        "per_edge": rows,
        "fused_le_unfused_all": all(r["fused_le_unfused"] for r in rows),
    }

    # Injected lowering fault on every residual-FUSED variant: the
    # hardening loop must take the NEW ladder rung (residual-fused ->
    # residual-add) and the demoted plan must still match the oracle.
    with faults.inject("lowering", residual="fused") as fault:
        hard = res.harden_network_plan(plan)
    demoted = {n.id: list(hard.layers[n.layer_index].provenance)
               for n in residual_nodes}
    rung_hit = all(
        any("residual-fused->residual-add" in p for p in prov)
        for prov in demoted.values())
    y = cnn.forward_spectral(params, hard, x, backend="pallas_fused")
    derr = float(jnp.abs(y - ref).max())
    out["demotion"] = {
        "fault_fires": fault.fires,
        "provenance": demoted,
        "all_residual_nodes_demoted_to_add": bool(rung_hit),
        "max_abs_err": derr,
        "passes_1e-5": bool(derr <= 1e-5),
    }

    if len(jax.devices()) >= 2:
        from repro.core.plan import build_sharded_network_plan
        from repro.distributed.executor import forward_spectral_sharded
        from repro.launch.mesh import make_spectral_mesh
        mesh = make_spectral_mesh(2)
        sh: dict = {"n_shards": 2}
        sworst = 0.0
        for strat in ("channel", "spatial"):
            splan = build_sharded_network_plan(
                params, cfg, n_shards=2, strategies=(strat,),
                batch=batch)
            y = forward_spectral_sharded(params, splan, x, mesh=mesh)
            err = float(jnp.abs(y - ref).max())
            sh[strat] = {"max_abs_err": err}
            sworst = max(sworst, err)
        sh["max_abs_err"] = sworst
        sh["passes_1e-5"] = bool(sworst <= 1e-5)
        out["sharded"] = sh
    return out


def main() -> None:
    from repro.configs import vgg16_spectral
    from repro.core import dataflow as df
    from repro.core.plan import build_network_plan
    from repro.models import cnn

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_e2e.json",
                    help="output path for the JSON report")
    ap.add_argument("--full", action="store_true",
                    help="also time the full 224x224 model (slow on CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke path: smoke-scale model everywhere, "
                    "skip full-resolution plan/parity sweeps")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    traffic_cfg = (vgg16_spectral.SMOKE if args.quick
                   else vgg16_spectral.CONFIG)
    report: dict = {
        "bench": "e2e_latency",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        # --quick swaps the traffic/parity model for the smoke config;
        # the metadata must say so (smoke layer NAMES shadow real VGG16
        # layers at much smaller channel counts).
        "model": traffic_cfg.name,
        "fft_size": traffic_cfg.fft_size,
        "alpha": traffic_cfg.alpha,
        "quick": bool(args.quick),
    }

    print("[1/8] latency: oracle vs staged Pallas vs fused Pallas "
          "(plan built per batch bucket, batch-tuned)")
    report["latency"] = {"smoke": latency_table(
        vgg16_spectral.SMOKE, iters=args.iters)}
    if args.full:
        report["latency"]["full"] = latency_table(
            vgg16_spectral.CONFIG, batches=(1,), iters=1)
    for scale, tbl in report["latency"].items():
        for b, row in tbl.items():
            pretty = ", ".join(f"{k}={v:.1f}" for k, v in row.items())
            print(f"      {scale}/{b}: {pretty}")
    report["batch_sweep"] = bucket_gate(report["latency"]["smoke"])
    print(f"      fused<=einsum at every bucket: "
          f"{report['batch_sweep']['fused_le_einsum_all_buckets']}")

    print(f"[2/8] {traffic_cfg.name} NetworkPlan (compile once: prune + "
          "Alg 2 tables + compaction + mode-aware autotune)")
    t0 = time.perf_counter()
    params_full = cnn.init(jax.random.PRNGKey(0), traffic_cfg)
    plan_full = build_network_plan(params_full, traffic_cfg, batch=1)
    report["plan_build_s"] = time.perf_counter() - t0
    n_sched = sum(lp.hadamard == "scheduled" for lp in plan_full.layers)
    print(f"      built in {report['plan_build_s']:.1f}s "
          f"({n_sched}/{len(plan_full.layers)} layers scheduled)")

    print("[3/8] per-layer launches + analytic HBM traffic "
          "(dense vs bin vs scheduled vs staged) + Alg-2 PE utilization")
    layer_rows = per_layer_traffic(plan_full, 8, batch=1)
    report["layers"] = layer_rows
    tot_fused = sum(r["fused_hbm_bytes"] for r in layer_rows)
    tot_fused_dense = sum(r["fused_hbm_bytes_dense"] for r in layer_rows)
    tot_staged = sum(r["staged_os_hadamard_hbm_bytes"]
                     + r["staged_fft_io_hbm_bytes"] for r in layer_rows)
    tot_k = sum(r["kernel_hbm_bytes"] for r in layer_rows)
    tot_k_dense = sum(r["kernel_hbm_bytes_dense"] for r in layer_rows)
    tot_k_bin = sum(r["kernel_hbm_bytes_bin"] for r in layer_rows)
    tot_k_sched = sum(r["kernel_hbm_bytes_scheduled"] for r in layer_rows)
    tot_in = sum(r["input_hbm_bytes"] for r in layer_rows)
    tot_in_win = sum(r["input_hbm_bytes_windowed"] for r in layer_rows)
    tot_in_halo = sum(r["input_hbm_bytes_halo"] for r in layer_rows)
    mus = [r["pe_utilization"] for r in layer_rows
           if r["pe_utilization"] is not None]
    sparse_rows = [r for r in layer_rows if r["alpha"] > 1.0]
    report["totals"] = {
        "fused_hbm_mb": tot_fused / 1e6,
        "fused_dense_hbm_mb": tot_fused_dense / 1e6,
        "staged_hbm_mb": tot_staged / 1e6,
        "hbm_reduction_vs_staged_pct": 100 * (1 - tot_fused / tot_staged),
        "kernel_hbm_mb": tot_k / 1e6,
        "kernel_dense_hbm_mb": tot_k_dense / 1e6,
        "kernel_bin_hbm_mb": tot_k_bin / 1e6,
        "kernel_scheduled_hbm_mb": tot_k_sched / 1e6,
        "kernel_bytes_reduction": tot_k_dense / tot_k,
        "input_hbm_mb": tot_in / 1e6,
        "input_windowed_hbm_mb": tot_in_win / 1e6,
        "input_halo_hbm_mb": tot_in_halo / 1e6,
        "input_bytes_reduction": tot_in_win / tot_in_halo,
        "mean_pe_utilization": float(np.mean(mus)) if mus else None,
        "launches_fused": FUSED_LAUNCHES_PER_LAYER * len(layer_rows),
        "launches_staged": STAGED_LAUNCHES_PER_LAYER * len(layer_rows),
        "hadamard_modes": {m: sum(r["hadamard"] == m for r in layer_rows)
                           for m in df.HADAMARD_MODES},
        "input_modes": {m: sum(r["input_mode"] == m for r in layer_rows)
                        for m in df.INPUT_MODES},
        "all_layers_fused_le_staged_os": all(
            r["fused_le_staged_os"] for r in layer_rows),
        "all_sparse_scheduled_le_bin": all(
            r["scheduled_le_bin"] for r in sparse_rows),
        "all_layers_halo_input_lt_windowed": all(
            r["halo_lt_windowed"] for r in layer_rows),
    }
    t = report["totals"]
    print(f"      fused {t['fused_hbm_mb']:.1f} MB (dense "
          f"{t['fused_dense_hbm_mb']:.1f} MB) vs staged "
          f"{t['staged_hbm_mb']:.1f} MB HBM "
          f"({t['hbm_reduction_vs_staged_pct']:.0f}% less than staged); "
          f"kernel bytes {t['kernel_hbm_mb']:.1f} MB (dense "
          f"{t['kernel_dense_hbm_mb']:.1f} / bin "
          f"{t['kernel_bin_hbm_mb']:.1f} / scheduled "
          f"{t['kernel_scheduled_hbm_mb']:.1f} MB; "
          f"{t['kernel_bytes_reduction']:.1f}x vs dense); "
          f"input bytes {t['input_hbm_mb']:.1f} MB (windowed "
          f"{t['input_windowed_hbm_mb']:.1f} / halo "
          f"{t['input_halo_hbm_mb']:.1f} MB; "
          f"{t['input_bytes_reduction']:.1f}x, halo<windowed on all "
          f"layers: {t['all_layers_halo_input_lt_windowed']}); "
          f"scheduled<=bin on all sparse layers: "
          f"{t['all_sparse_scheduled_le_bin']}; modes "
          f"{t['hadamard_modes']} / {t['input_modes']}; mean PE util "
          f"{t['mean_pe_utilization']:.1%}; launches "
          f"{t['launches_fused']} vs {t['launches_staged']}")

    if not args.quick:
        print("[4/8] parity on full VGG16 (batch 1): fused vs spatial "
              "(alpha=1) and fused-sparse+epilogue vs oracle (alpha=4)")
        report["parity"] = fused_parity_vs_spatial(df.VGG16_LAYERS, 8,
                                                   batch=1)
        print(f"      dense vs spatial: max abs err "
              f"{report['parity']['max_abs_err']:.2e} "
              f"(<= 1e-3: {report['parity']['passes_1e-3']})")
        report["parity_sparse"] = fused_sparse_parity_vs_oracle(
            df.VGG16_LAYERS, 8, alpha=4.0, batch=1)
        print(f"      sparse+epilogue vs oracle: max abs err "
              f"{report['parity_sparse']['max_abs_err']:.2e} "
              f"(<= 1e-4: {report['parity_sparse']['passes_1e-4']})")

    print("[5/8] SCHEDULED-fused parity vs einsum oracle (acceptance "
          "<= 1e-5)")
    sched = {"network_smoke": scheduled_network_parity(
        vgg16_spectral.SMOKE, batch=1)}
    if not args.quick:
        sched["per_layer_conv5"] = scheduled_parity_vs_oracle(
            df.VGG16_LAYERS[-3:], 8, alpha=4.0, batch=1)
        print(f"      conv5 trio (512ch, tables in-kernel): max abs err "
              f"{sched['per_layer_conv5']['max_abs_err']:.2e} "
              f"(<= 1e-5: {sched['per_layer_conv5']['passes_1e-5']})")
    report["parity_scheduled"] = sched
    print(f"      smoke net, all layers scheduled: max abs logit err "
          f"{sched['network_smoke']['max_abs_logit_err']:.2e} "
          f"(<= 1e-5: {sched['network_smoke']['passes_1e-5']})")

    print("[6/8] HALO input path parity vs einsum oracle, 3 flows x "
          "3 Hadamard modes (acceptance <= 1e-5)")
    report["parity_halo"] = halo_parity_matrix(8, alpha=4.0, batch=1,
                                               small=args.quick)
    ph = report["parity_halo"]
    print(f"      {ph['layer']}: max abs err vs oracle "
          f"{ph['max_abs_err_vs_oracle']:.2e} (<= 1e-5: "
          f"{ph['passes_1e-5']}); vs windowed path "
          f"{ph['max_abs_err_vs_windowed']:.2e}")

    print("[7/8] multi-device column: two-level Alg-1 cost model "
          "(strategy per layer) + live sharded parity when the mesh "
          "has devices")
    if args.quick:
        cost = sharded_cost_model(list(traffic_cfg.layers), 8,
                                  alpha=traffic_cfg.alpha, n_shards=4)
    else:
        cost = sharded_cost_model(list(df.VGG16_LAYERS), 8, alpha=4.0,
                                  n_shards=8)
    report["sharded"] = {"cost_model": cost}
    sc = cost["strategy_counts"]
    print(f"      D={cost['n_shards']}: strategies "
          f"spatial={sc['spatial']} channel={sc['channel']} "
          f"replicate={sc['replicate']} "
          f"(diversity>=2: {cost['strategy_diversity_ge_2']}); "
          f"ICI {cost['ici_mb_total']:.1f} MB on the wire; worst "
          f"per-chip HBM {cost['per_chip_hbm_mb_worst']:.1f} MB "
          f"(<= single-chip on all layers: "
          f"{cost['per_chip_hbm_le_single_chip_all_layers']}); "
          f"predicted {1e3 * cost['sharded_s_total']:.2f} ms sharded "
          f"vs {1e3 * cost['single_chip_s_total']:.2f} ms single-chip")
    n_dev = len(jax.devices())
    if n_dev >= 2:
        par = sharded_parity(vgg16_spectral.SMOKE, n_shards=2, batch=1)
        report["sharded"]["parity"] = par
        print(f"      live parity on {par['n_shards']}/{n_dev} devices "
              f"(channel + spatial forced): max abs logit err "
              f"{par['max_abs_err']:.2e} (<= 1e-5: {par['passes_1e-5']})")
    else:
        print(f"      live parity skipped: {n_dev} device(s) visible "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    print("[8/8] resnet column: residual-DAG plan IR on the "
          "ResNet-18-style smoke preset (alpha=1 vs the spatial DAG "
          "oracle)")
    rn = resnet_dag_column()
    report["resnet"] = rn
    print(f"      {rn['model']}: {rn['n_nodes']} nodes "
          f"({rn['n_residual']} residual, {rn['n_stride2']} stride-2, "
          f"pools {rn['pools']}); parity 3 backends max abs err "
          f"{rn['parity']['max_abs_err']:.2e} (<= 1e-5: "
          f"{rn['parity']['passes_1e-5']}); fused<=unfused shortcut "
          f"bytes on all edges: "
          f"{rn['shortcuts']['fused_le_unfused_all']}; fault-demoted "
          f"to residual-add rung on all residual nodes: "
          f"{rn['demotion']['all_residual_nodes_demoted_to_add']} "
          f"(parity {rn['demotion']['max_abs_err']:.2e})")
    if "sharded" in rn:
        print(f"      forced channel+spatial sharding on "
              f"{rn['sharded']['n_shards']} devices: max abs err "
              f"{rn['sharded']['max_abs_err']:.2e} (<= 1e-5: "
              f"{rn['sharded']['passes_1e-5']})")

    _write_report_atomic(report, args.json)
    print(f"wrote {args.json}")

    failed = _failed_gates(report)
    if failed:
        print("[gates] FAILED:", file=sys.stderr)
        for name, value in failed:
            print(f"  - {name} = {value!r}", file=sys.stderr)
        sys.exit(1)
    print("[gates] all acceptance gates pass")


def _write_report_atomic(report: dict, path: str) -> None:
    """Write the JSON report via a temp file in the same directory +
    ``os.replace`` so a crash (or a concurrent reader, e.g. CI tailing
    the file) never observes a truncated BENCH_e2e.json."""
    dirname = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=dirname, prefix=".bench_e2e_",
                               suffix=".json.tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _failed_gates(report: dict) -> list[tuple[str, object]]:
    """Collect acceptance-gate violations from a finished report.

    The report is written FIRST (atomically), then the gates fail the
    process with a nonzero exit so CI blocks on a parity or
    halo<windowed regression while the artifact stays inspectable."""
    gates: list[tuple[str, object]] = [
        ("batch_sweep.fused_le_einsum_all_buckets",
         report["batch_sweep"]["fused_le_einsum_all_buckets"]),
        ("totals.all_layers_halo_input_lt_windowed",
         report["totals"]["all_layers_halo_input_lt_windowed"]),
        ("totals.all_layers_fused_le_staged_os",
         report["totals"]["all_layers_fused_le_staged_os"]),
        ("totals.all_sparse_scheduled_le_bin",
         report["totals"]["all_sparse_scheduled_le_bin"]),
        ("parity_scheduled.network_smoke.passes_1e-5",
         report["parity_scheduled"]["network_smoke"]["passes_1e-5"]),
        ("parity_halo.passes_1e-5",
         report["parity_halo"]["passes_1e-5"]),
        ("sharded.cost_model.strategy_diversity_ge_2",
         report["sharded"]["cost_model"]["strategy_diversity_ge_2"]),
        ("sharded.cost_model.ici_bytes_positive",
         report["sharded"]["cost_model"]["ici_bytes_positive"]),
        ("sharded.cost_model.per_chip_hbm_le_single_chip_all_layers",
         report["sharded"]["cost_model"]
         ["per_chip_hbm_le_single_chip_all_layers"]),
    ]
    if "resnet" in report:
        rn = report["resnet"]
        gates += [
            ("resnet.parity.passes_1e-5", rn["parity"]["passes_1e-5"]),
            ("resnet.shortcuts.fused_le_unfused_all",
             rn["shortcuts"]["fused_le_unfused_all"]),
            ("resnet.demotion.all_residual_nodes_demoted_to_add",
             rn["demotion"]["all_residual_nodes_demoted_to_add"]),
            ("resnet.demotion.passes_1e-5",
             rn["demotion"]["passes_1e-5"]),
        ]
        if "sharded" in rn:
            gates.append(("resnet.sharded.passes_1e-5",
                          rn["sharded"]["passes_1e-5"]))
    # live multi-device parity (absent on single-device hosts)
    if "parity" in report.get("sharded", {}):
        gates.append(("sharded.parity.passes_1e-5",
                      report["sharded"]["parity"]["passes_1e-5"]))
    # full-run-only sweeps (absent under --quick)
    if "parity" in report:
        gates.append(("parity.passes_1e-3",
                      report["parity"]["passes_1e-3"]))
    if "parity_sparse" in report:
        gates.append(("parity_sparse.passes_1e-4",
                      report["parity_sparse"]["passes_1e-4"]))
    if "per_layer_conv5" in report.get("parity_scheduled", {}):
        gates.append(
            ("parity_scheduled.per_layer_conv5.passes_1e-5",
             report["parity_scheduled"]["per_layer_conv5"]["passes_1e-5"]))
    return [(name, value) for name, value in gates if not value]


if __name__ == "__main__":
    main()
