"""End-to-end spectral-VGG16 inference latency + HBM-traffic benchmark.

Compares the three conv-stack backends of ``models.cnn.forward_spectral``
— pure-jnp einsum oracle, staged Pallas (3 pallas_calls/layer with
spectral intermediates round-tripping through HBM), and the fused single
pallas_call — and emits ``BENCH_e2e.json`` with:

  * wall-clock latency at batch 1 and batch 8 (smoke VGG16 by default;
    the Pallas kernels run interpret-mode off-TPU, so off-TPU wall time
    is a correctness-path trend signal, not a perf claim — the analytic
    HBM/roofline numbers below are the hardware-portable signal),
  * per-layer kernel-launch counts (fused: 1, staged: 3) and analytic
    HBM bytes of the tuned fused kernel vs the ``output_stationary``
    prediction of ``dataflow.tpu_flow_cost`` for the staged Hadamard —
    fused must be <= (no spectral intermediates in HBM),
  * numerical parity of the fused kernel against the *spatial* oracle on
    every full-resolution VGG16 layer at batch 1 (alpha = 1, unpruned,
    so spectral == spatial up to fp error).

  PYTHONPATH=src python -m benchmarks.e2e_latency [--full] [--json OUT]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

STAGED_LAUNCHES_PER_LAYER = 3     # fft8 + spectral_hadamard + ifft8
FUSED_LAUNCHES_PER_LAYER = 1


def _time(fn, iters: int = 3) -> float:
    out = fn()
    jax.block_until_ready(out)            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def latency_table(cfg, batches=(1, 8), backends=("einsum", "pallas_staged",
                                                 "pallas_fused"),
                  iters: int = 3) -> dict:
    from repro.core import autotune
    from repro.models import cnn

    key = jax.random.PRNGKey(0)
    params = cnn.init(key, cfg)
    sks = cnn.transform_kernels(params, cfg)
    out: dict = {}
    for batch in batches:
        tuning = autotune.autotune_network(cfg.layers, cfg.fft_size,
                                           cfg.alpha, batch=batch)
        x = jax.random.normal(key, (batch, 3, cfg.image_size,
                                    cfg.image_size), jnp.float32)
        row = {}
        for backend in backends:
            row[f"{backend}_ms"] = 1e3 * _time(
                lambda b=backend: cnn.forward_spectral(
                    params, sks, cfg, x, backend=b, tuning=tuning),
                iters=iters)
        out[f"batch{batch}"] = row
    return out


def per_layer_traffic(layers, fft_size: int, alpha: float,
                      batch: int = 1) -> list[dict]:
    """Analytic per-layer HBM bytes: tuned fused kernel vs the staged
    pipeline's output-stationary Hadamard prediction (plus the staged
    FFT/IFFT stages' own HBM round-trips)."""
    from repro.core import autotune
    from repro.core import dataflow as df

    def best_staged_os(layer):
        """Give the staged baseline its own best block sizes under the
        SAME selection policy as the fused tuner (not a straw man)."""
        tn = autotune.autotune_layer(
            layer, fft_size, alpha, batch=batch, hw_safe=False,
            flows=("output_stationary",), cost_fn=df.tpu_flow_cost)
        return df.tpu_flow_cost(layer, fft_size, alpha, tn.block_n,
                                tn.block_p, tn.block_m, tn.flow,
                                batch=batch)

    tuning = autotune.autotune_network(layers, fft_size, alpha, batch=batch)
    rows = []
    for layer in layers:
        tn = tuning[layer.name]
        fused = df.tpu_fused_flow_cost(
            layer, fft_size, alpha, tn.block_n, tn.block_p, tn.block_m,
            tn.flow, batch=batch)
        staged_os = best_staged_os(layer)
        # staged pipeline additionally round-trips tiles through the
        # separate FFT/IFFT kernels (real in, 2 f32 planes out and back)
        k2 = fft_size * fft_size
        t = layer.tiles(fft_size) * batch
        tile2 = layer.tile_size(fft_size) ** 2
        fft_io = (layer.c_in * t * (tile2 + 2 * k2)
                  + layer.c_out * t * (2 * k2 + k2)) * 4
        rows.append({
            "layer": layer.name,
            "launches_fused": FUSED_LAUNCHES_PER_LAYER,
            "launches_staged": STAGED_LAUNCHES_PER_LAYER,
            "flow": tn.flow,
            "block_n": tn.block_n, "block_m": tn.block_m,
            "block_p": tn.block_p,
            "fused_hbm_bytes": fused["hbm_bytes"],
            "staged_os_hadamard_hbm_bytes": staged_os["hbm_bytes"],
            "staged_fft_io_hbm_bytes": float(fft_io),
            "fused_le_staged_os": bool(
                fused["hbm_bytes"] <= staged_os["hbm_bytes"]),
            "fused_predicted_us": 1e6 * max(fused["hbm_s"],
                                            fused["compute_s"]),
            "staged_hadamard_predicted_us": 1e6 * max(staged_os["hbm_s"],
                                                      staged_os["compute_s"]),
        })
    return rows


def fused_parity_vs_spatial(layers, fft_size: int, batch: int = 1,
                            seed: int = 0) -> dict:
    """Per-layer fused-vs-spatial max abs error at full resolution,
    unpruned (alpha = 1) so the spectral path is numerically equivalent."""
    from repro.core import autotune
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import fused_spectral_conv2d

    rng = np.random.default_rng(seed)
    tuning = autotune.autotune_network(layers, fft_size, 1.0, batch=batch)
    per_layer = {}
    worst = 0.0
    for layer in layers:
        x = jnp.asarray(rng.standard_normal(
            (batch, layer.c_in, layer.h_in, layer.w_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (layer.c_out, layer.c_in, layer.ksize, layer.ksize))
            * (2.0 / (layer.c_in * layer.ksize ** 2)) ** 0.5, jnp.float32)
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 fft_size, layer.pad)
        tn = tuning[layer.name]
        y = fused_spectral_conv2d(x, spec.spectral_kernel(w, fft_size),
                                  geo, **tn.kwargs())
        y_ref = spec.spatial_conv2d(x, w)
        err = float(jnp.abs(y - y_ref).max())
        per_layer[layer.name] = err
        worst = max(worst, err)
    return {"batch": batch, "alpha": 1.0, "max_abs_err": worst,
            "per_layer": per_layer,
            "passes_1e-3": bool(worst <= 1e-3)}


def main() -> None:
    from repro.configs import vgg16_spectral
    from repro.core import dataflow as df

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_e2e.json",
                    help="output path for the JSON report")
    ap.add_argument("--full", action="store_true",
                    help="also time the full 224x224 model (slow on CPU)")
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    report: dict = {
        "bench": "e2e_latency",
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "model": "vgg16-spectral",
        "fft_size": 8,
        "alpha": 4.0,
    }

    print("[1/3] latency: oracle vs staged Pallas vs fused Pallas")
    report["latency"] = {"smoke": latency_table(
        vgg16_spectral.SMOKE, iters=args.iters)}
    if args.full:
        report["latency"]["full"] = latency_table(
            vgg16_spectral.CONFIG, batches=(1,), iters=1)
    for scale, tbl in report["latency"].items():
        for b, row in tbl.items():
            pretty = ", ".join(f"{k}={v:.1f}" for k, v in row.items())
            print(f"      {scale}/{b}: {pretty}")

    print("[2/3] per-layer launches + analytic HBM traffic (full VGG16)")
    layer_rows = per_layer_traffic(df.VGG16_LAYERS, 8, 4.0, batch=1)
    report["layers"] = layer_rows
    tot_fused = sum(r["fused_hbm_bytes"] for r in layer_rows)
    tot_staged = sum(r["staged_os_hadamard_hbm_bytes"]
                     + r["staged_fft_io_hbm_bytes"] for r in layer_rows)
    report["totals"] = {
        "fused_hbm_mb": tot_fused / 1e6,
        "staged_hbm_mb": tot_staged / 1e6,
        "hbm_reduction_pct": 100 * (1 - tot_fused / tot_staged),
        "launches_fused": FUSED_LAUNCHES_PER_LAYER * len(layer_rows),
        "launches_staged": STAGED_LAUNCHES_PER_LAYER * len(layer_rows),
        "all_layers_fused_le_staged_os": all(
            r["fused_le_staged_os"] for r in layer_rows),
    }
    t = report["totals"]
    print(f"      fused {t['fused_hbm_mb']:.1f} MB vs staged "
          f"{t['staged_hbm_mb']:.1f} MB HBM "
          f"({t['hbm_reduction_pct']:.0f}% less), launches "
          f"{t['launches_fused']} vs {t['launches_staged']}")

    print("[3/3] fused vs spatial oracle parity (full VGG16, batch 1)")
    report["parity"] = fused_parity_vs_spatial(df.VGG16_LAYERS, 8, batch=1)
    print(f"      max abs err {report['parity']['max_abs_err']:.2e} "
          f"(<= 1e-3: {report['parity']['passes_1e-3']})")

    with open(args.json, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
