"""Benchmarks reproducing the paper's tables and figures.

Each function returns a list of (name, us_per_call, derived) rows and is
invoked by ``benchmarks.run``.  Paper anchors:

  Table 1  — optimal architecture + streaming parameters (Alg 1)
  Fig 2/7  — data transfers + BRAM usage, Flow #1/#2/#3 vs Flow opt
  Table 2  — per-layer bandwidth at tau = 20 ms
  Fig 8    — per-layer PE utilization, r=8, N'=64 (3 schedulers)
  Fig 9    — average PE utilization vs replicas (magnitude patterns)
  Fig 10   — average PE utilization vs replicas (random patterns)
  Table 3  — inference latency + bandwidth of the whole conv stack
             (9 ms / 12 GB/s @ 200 MHz on the paper's platform)
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import dataflow as df
from repro.core import optimizer as opt
from repro.core import scheduler as sch
from repro.core import sparse as sp
from repro.core import spectral as spec

K, ALPHA, R, P_PAR, N_PAR = 8, 4.0, 10, 9, 64
CLOCK_HZ = 200e6


def _timed(fn):
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


def _vgg_spectral_indices(alpha: float, seed: int = 0, random_pattern=False,
                          max_cout: int = 64, max_cin: int = 8):
    """Magnitude-pruned spectral kernels per VGG16 layer (subsampled
    channels for tractable scheduling; utilization is a per-kernel-group
    statistic so subsampling is unbiased)."""
    rng = np.random.default_rng(seed)
    out = {}
    for layer in df.VGG16_OPT_LAYERS:
        c_out = min(layer.c_out, max_cout)
        c_in = min(layer.c_in, max_cin)
        w = rng.standard_normal((c_out, c_in, 3, 3)).astype(np.float32)
        wf = spec.spectral_kernel(jax.numpy.asarray(w), K)
        sk = (sp.prune_random(wf, alpha, seed=seed) if random_pattern
              else sp.prune_magnitude(wf, alpha))
        out[layer.name] = np.asarray(sk.indices)
    return out


def table1_dataflow_opt() -> list[tuple]:
    rows = []
    for fft in (8, 16):
        arch = [(9, 64)] if fft == 8 else [(16, 32)]
        plan, us = _timed(lambda a=arch, f=fft: opt.optimize(
            fft_size=f, alpha=ALPHA, r=R, arch_candidates=a))
        for lp in plan.layers:
            rows.append((f"table1/K{fft}/{lp.layer}/Ps", us / 12,
                         lp.ps))
            rows.append((f"table1/K{fft}/{lp.layer}/Ns", us / 12,
                         lp.ns))
    return rows


def fig7_transfers() -> list[tuple]:
    plan = opt.optimize(arch_candidates=[(P_PAR, N_PAR)])
    pure = opt.pure_flow_transfers(df.VGG16_OPT_LAYERS, K, ALPHA,
                                   P_PAR, N_PAR)
    rows = []
    tot = {"flow1": 0, "flow2": 0, "flow3": 0, "opt": 0}
    for lp in plan.layers:
        p = pure[lp.layer]
        rows.append((f"fig7/{lp.layer}/flow1_Mwords", 0, p["flow1"] / 1e6))
        rows.append((f"fig7/{lp.layer}/flow2_Mwords", 0, p["flow2"] / 1e6))
        rows.append((f"fig7/{lp.layer}/opt_Mwords", 0,
                     lp.transfers_words / 1e6))
        for k_ in ("flow1", "flow2", "flow3"):
            tot[k_] += p[k_]
        tot["opt"] += lp.transfers_words
    reduction = 1 - tot["opt"] / tot["flow2"]
    rows.append(("fig7/total/reduction_vs_flow2_pct", 0, 100 * reduction))
    rows.append(("fig7/total/reduction_vs_best_pure_pct", 0,
                 100 * (1 - tot["opt"] / min(tot["flow1"], tot["flow2"],
                                             tot["flow3"]))))
    return rows


def table2_bandwidth() -> list[tuple]:
    plan = opt.optimize(arch_candidates=[(P_PAR, N_PAR)])
    paper = {"conv1_2": 8.2, "conv2_1": 7.3, "conv2_2": 4.7,
             "conv3_1": 4.8, "conv3_2": 3.5, "conv3_3": 3.5,
             "conv4_1": 5.0, "conv4_2": 4.3, "conv4_3": 4.3,
             "conv5_1": 9.9, "conv5_2": 9.9, "conv5_3": 9.9}
    rows = []
    for lp in plan.layers:
        rows.append((f"table2/{lp.layer}/bw_gbps", 0, lp.bandwidth_gbps))
        rows.append((f"table2/{lp.layer}/paper_gbps", 0, paper[lp.layer]))
    rows.append(("table2/max_bw_gbps", 0, plan.bw_max_gbps))
    return rows


def fig8_pe_utilization(r: int = 8) -> list[tuple]:
    idx = _vgg_spectral_indices(ALPHA)
    rows = []
    for layer in df.VGG16_OPT_LAYERS:
        for method in ("exact_cover", "lowest_index", "random"):
            mu, us = _timed(lambda l=layer, m=method: (
                sch.simulate_layer_utilization(
                    idx[l.name], K * K, r, N_PAR, method=m,
                    channel_sample=4)))
            rows.append((f"fig8/{layer.name}/{method}", us, mu))
    return rows


def fig9_replica_sweep(random_pattern: bool = False) -> list[tuple]:
    tag = "fig10" if random_pattern else "fig9"
    rows = []
    # weight layer utilizations by their compute share, as the paper does
    cmps = {l.name: l.spectral_macs(K, ALPHA) for l in df.VGG16_OPT_LAYERS}
    total_cmp = sum(cmps.values())
    for alpha in (4.0, 8.0):
        idx = _vgg_spectral_indices(alpha, random_pattern=random_pattern)
        for r in (4, 6, 8, 10, 12, 16, 20):
            for method in ("exact_cover", "lowest_index"):
                mu_avg, us = _timed(lambda a=alpha, rr=r, m=method: sum(
                    sch.simulate_layer_utilization(
                        idx[l.name], K * K, rr, N_PAR, method=m,
                        channel_sample=2) * cmps[l.name] / total_cmp
                    for l in df.VGG16_OPT_LAYERS))
                rows.append((f"{tag}/a{int(alpha)}/r{r}/{method}", us,
                             mu_avg))
    return rows


def table3_latency() -> list[tuple]:
    """Analytic latency of the full sparse spectral conv stack on the
    paper's platform model: cycles = ops / (N' P' mu), 200 MHz clock.
    Paper: 9 ms at 12 GB/s with r=10."""
    idx = _vgg_spectral_indices(ALPHA)
    plan = opt.optimize(arch_candidates=[(P_PAR, N_PAR)])
    total_cycles = 0.0
    total_words = plan.total_transfers_words
    rows = []
    for layer in df.VGG16_OPT_LAYERS:
        mu = sch.simulate_layer_utilization(
            idx[layer.name], K * K, R, N_PAR, channel_sample=4)
        t = layer.tiles(K)
        nnz = K * K / ALPHA
        groups = layer.c_out / N_PAR
        cycles = (np.ceil(t / P_PAR) * layer.c_in * groups
                  * nnz / mu)
        total_cycles += cycles
        rows.append((f"table3/{layer.name}/mu", 0, mu))
        rows.append((f"table3/{layer.name}/ms", 0,
                     1e3 * cycles / CLOCK_HZ))
    latency_s = total_cycles / CLOCK_HZ
    bw = total_words * df.WORD_BYTES / latency_s / 1e9
    rows.append(("table3/total_latency_ms", 0, latency_s * 1e3))
    rows.append(("table3/paper_latency_ms", 0, 9.0))
    rows.append(("table3/required_bw_gbps", 0, bw))
    rows.append(("table3/paper_bw_gbps", 0, 12.0))
    rows.append(("table3/throughput_fps", 0, 1.0 / latency_s))
    return rows


ALL = [table1_dataflow_opt, fig7_transfers, table2_bandwidth,
       fig8_pe_utilization, fig9_replica_sweep,
       lambda: fig9_replica_sweep(random_pattern=True), table3_latency]
