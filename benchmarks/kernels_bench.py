"""Kernel micro-benchmarks: wall time of the jitted reference paths on
CPU (the Pallas kernels themselves target TPU and run interpret-mode for
correctness only — interpret wall time is not a performance signal) plus
the analytic TPU-side roofline terms of each kernel configuration."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow as df
from repro.kernels import ref


def _bench(fn, *args, iters=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def kernel_benches() -> list[tuple]:
    rng = np.random.default_rng(0)
    rows = []

    # spectral Hadamard reference path (jit) at the paper's geometry
    f, n, m, p = 64, 64, 64, 128
    wr = jnp.asarray(rng.standard_normal((f, n, m)), jnp.float32)
    wi = jnp.asarray(rng.standard_normal((f, n, m)), jnp.float32)
    xr = jnp.asarray(rng.standard_normal((f, m, p)), jnp.float32)
    xi = jnp.asarray(rng.standard_normal((f, m, p)), jnp.float32)
    had = jax.jit(ref.spectral_hadamard_ref)
    rows.append(("kernels/hadamard_ref_f64n64m64p128",
                 _bench(had, wr, wi, xr, xi),
                 8 * f * n * m * p / 1e6))       # complex MFLOPs

    # fft tiles reference
    tiles = jnp.asarray(rng.standard_normal((1444, 6, 6)), jnp.float32)
    fft = jax.jit(lambda t: ref.fft2_tiles_ref(t, 8))
    rows.append(("kernels/fft8_ref_1444tiles", _bench(fft, tiles), 1444))

    # attention reference at a serving-ish shape
    q = jnp.asarray(rng.standard_normal((1, 8, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 1024, 64)), jnp.bfloat16)
    att = jax.jit(lambda q, k: ref.attention_ref(
        q, jnp.repeat(k, 4, 1), jnp.repeat(k, 4, 1)))
    rows.append(("kernels/attention_ref_s1024", _bench(att, q, k),
                 2 * 8 * 1024 * 1024 * 64 * 2 / 1e6))

    # TPU-side analytic terms of the Pallas spectral-Hadamard dataflows
    conv = df.VGG16_LAYERS[4]            # conv3_1
    for flow in ("output_stationary", "weight_stationary",
                 "input_stationary"):
        c = df.tpu_flow_cost(conv, 8, 4.0, 128, 128, 128, flow)
        rows.append((f"kernels/tpu_{flow}/hbm_ms", 0,
                     c["hbm_s"] * 1e3))
        rows.append((f"kernels/tpu_{flow}/fits_vmem", 0,
                     float(c["fits_vmem"])))
    return rows
