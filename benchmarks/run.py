"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV; ``--json OUT`` additionally
writes the same rows as machine-readable JSON (BENCH_*.json convention,
consumed by the perf-trajectory tooling alongside benchmarks.e2e_latency).

  python -m benchmarks.run [FILTER] [--json OUT]
"""

from __future__ import annotations

import argparse
import json


def main() -> None:
    from benchmarks import kernels_bench, paper

    ap = argparse.ArgumentParser()
    ap.add_argument("filter", nargs="?", default=None,
                    help="only run benches whose function name contains "
                    "this substring")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as JSON to OUT")
    args = ap.parse_args()

    fns = list(paper.ALL) + [kernels_bench.kernel_benches]
    rows: list[dict] = []
    print("name,us_per_call,derived")
    for fn in fns:
        name = getattr(fn, "__name__", "lambda")
        if args.filter and args.filter not in name:
            continue
        for row in fn():
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")
            rows.append({"name": n, "us_per_call": us, "derived": derived})

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "benchmarks.run", "filter": args.filter,
                       "rows": rows}, f, indent=2)


if __name__ == "__main__":
    main()
