"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``python -m benchmarks.run``.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import kernels_bench, paper

    fns = list(paper.ALL) + [kernels_bench.kernel_benches]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for fn in fns:
        name = getattr(fn, "__name__", "lambda")
        if only and only not in name:
            continue
        for row in fn():
            n, us, derived = row
            print(f"{n},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
