"""Fault-tolerant sharded checkpointing with elastic restore.

Design (1000+-node posture, exercised here on host devices):

  * each host writes only ITS shards of every array (``.npz`` per host),
    so checkpoint bandwidth scales with the fleet;
  * writes are atomic: temp directory + manifest fsync + ``rename`` —
    a killed writer never corrupts the latest checkpoint;
  * every array records a crc32 checksum; restore verifies integrity and
    fails loudly on corruption (bit-rot / partial-write detection);
  * restore is ELASTIC: arrays are re-sharded onto whatever mesh the
    restoring job brings up (different device count / topology), because
    the manifest stores the logical pytree + global shapes, not device
    placements;
  * async: ``save()`` returns immediately; a background thread serializes
    (device->host copies happen synchronously to respect donation, the
    file I/O overlaps the next step);
  * retention: ``keep`` newest checkpoints are retained, older deleted.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Any

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _treedef_paths(tree: PyTree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3,
                 host_id: int = 0, n_hosts: int = 1):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: PyTree, blocking: bool = False) -> None:
        """Checkpoint ``tree`` at ``step``.  Host copies happen now; file
        I/O runs on a background thread unless ``blocking``."""
        self.wait()
        arrays = _flatten(tree)

        def write() -> None:
            tmp = self.dir / f".tmp-{step}-{self.host_id}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "n_hosts": self.n_hosts,
                        "arrays": {}}
            shard_file = tmp / f"host{self.host_id}.npz"
            np.savez(shard_file, **arrays)
            for key, arr in arrays.items():
                manifest["arrays"][key] = {
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr)
                                        .tobytes()),
                    "host": self.host_id,
                }
            (tmp / _MANIFEST).write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)           # atomic publish
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if (p / _MANIFEST).exists())

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, like: PyTree,
                shardings: PyTree | None = None) -> tuple[int, PyTree]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (matching pytree of
        NamedSharding) re-shards elastically onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / _MANIFEST).read_text())
        data = np.load(cdir / f"host{self.host_id}.npz")

        paths = _treedef_paths(like)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(paths))
        out = []
        for key, leaf, sh in zip(paths, leaves_like, shard_leaves):
            if key not in manifest["arrays"]:
                raise KeyError(f"checkpoint missing array {key}")
            arr = data[key]
            meta = manifest["arrays"][key]
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checksum mismatch for {key} "
                              f"(corrupt checkpoint {cdir})")
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: shape {arr.shape} != "
                                 f"{leaf.shape}")
            arr = arr.astype(leaf.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, out)
