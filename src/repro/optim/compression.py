"""Int8 error-feedback gradient compression for DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce is the largest
single collective; int8 quantization cuts its wire bytes 4x (vs f32).
Error feedback (residual carried to the next step) keeps SGD/Adam
convergence unbiased in practice (1-bit Adam / EF-SGD literature).

Usage inside a train step:
    comp, new_residual = compress(grads, residual)
    comp = jax.lax.pmean(comp, 'data')        # or implicit via sharding
    grads = decompress(comp)

The compression is per-tensor symmetric: q = round(g / scale), scale =
max|g| / 127.  Tested for round-trip error bounds and error-feedback
convergence in tests/test_optim.py.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Compressed(NamedTuple):
    q: jax.Array          # int8 payload
    scale: jax.Array      # f32 scalar


def init_residual(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g: jax.Array, r: jax.Array
                   ) -> tuple[Compressed, jax.Array]:
    g = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    residual = g - q.astype(jnp.float32) * scale
    return Compressed(q, scale), residual


def compress(grads: PyTree, residual: PyTree
             ) -> tuple[PyTree, PyTree]:
    """Returns (tree of Compressed, new residual tree)."""
    pairs = jax.tree.map(_compress_leaf, grads, residual)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def decompress(comp: PyTree) -> PyTree:
    return jax.tree.map(
        lambda c: c.q.astype(jnp.float32) * c.scale, comp,
        is_leaf=lambda x: isinstance(x, Compressed))


def wire_bytes(grads: PyTree) -> tuple[int, int]:
    """(uncompressed f32 bytes, compressed int8 bytes) for reporting."""
    full = sum(g.size * 4 for g in jax.tree.leaves(grads))
    comp = sum(g.size * 1 + 4 for g in jax.tree.leaves(grads))
    return full, comp
