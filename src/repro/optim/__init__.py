"""Optimizers + schedules + gradient compression."""

from repro.optim.adamw import (OptimizerConfig, clip_by_global_norm,  # noqa
                               global_norm, init, update)
