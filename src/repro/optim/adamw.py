"""AdamW and Adafactor (factored second moment) optimizers.

Pure-pytree implementations (no optax dependency).  Adafactor is the
planner's answer for the 1 T-parameter arch: AdamW's 8 bytes/param of
f32 moments do not fit 512 chips, the factored second moment (row+col
statistics per matrix) does — the paper's reuse-vs-stream trade
replayed against optimizer state (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    decay: float = 0.8             # t^-decay second-moment schedule
    min_dim_size_to_factor: int = 128


def global_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float
                        ) -> tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params: PyTree) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: OptimizerConfig, grads: PyTree, state: dict,
                 params: PyTree) -> tuple[PyTree, dict]:
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * step
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_state = {
        "mu": tdef.unflatten([o[1] for o in out]),
        "nu": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_p, new_state


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ---------------------------------------------------------------------------

def _factored(shape: tuple[int, ...], threshold: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= threshold \
        and shape[-2] >= threshold


def adafactor_init(params: PyTree,
                   cfg: OptimizerConfig = OptimizerConfig()) -> dict:
    def per_leaf(p):
        if _factored(p.shape, cfg.min_dim_size_to_factor):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "v": jax.tree.map(per_leaf, params,
                          is_leaf=lambda x: isinstance(x, jax.Array)
                          or hasattr(x, "shape")),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(cfg: OptimizerConfig, grads: PyTree, state: dict,
                     params: PyTree) -> tuple[PyTree, dict]:
    count = state["count"] + 1
    beta2 = 1.0 - count.astype(jnp.float32) ** -cfg.decay

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = vr.mean(axis=-1, keepdims=True)
            rms = (vr / jnp.maximum(denom, 1e-30))[..., None] \
                * vc[..., None, :]
            new_v = {"vr": vr, "vc": vc}
        else:
            rms = beta2 * v["v"] + (1 - beta2) * g2
            new_v = {"v": rms}
        step = g * jax.lax.rsqrt(rms + 1e-30)
        # update clipping (Adafactor's d=1.0 RMS clip)
        step = step / jnp.maximum(
            1.0, jnp.sqrt(jnp.mean(step * step)))
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * step
        return new_p.astype(p.dtype), new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    return (tdef.unflatten([o[0] for o in out]),
            {"v": tdef.unflatten([o[1] for o in out]), "count": count})


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def init(cfg: OptimizerConfig, params: PyTree) -> dict:
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    raise ValueError(cfg.name)


def update(cfg: OptimizerConfig, grads: PyTree, state: dict, params: PyTree
           ) -> tuple[PyTree, dict, Array]:
    grads, norm = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.name == "adamw":
        p, s = adamw_update(cfg, grads, state, params)
    else:
        p, s = adafactor_update(cfg, grads, state, params)
    return p, s, norm
