"""Alg 1 on TPU: per-layer flow + block-size selection for the fused
spectral-conv kernel.

The paper's Alg 1 searches architecture parameters (P', N') and per-layer
streaming parameters (Ps, Ns) minimizing the worst-case DDR bandwidth
under a BRAM cap.  On TPU the analogous knobs of one fused pallas_call
(``kernels.fused_spectral_conv``) are

  flow      in {output_stationary, weight_stationary, input_stationary}
            — which operand block stays resident in VMEM between grid
            steps (the paper's reuse-kernels / reuse-activations /
            reuse-psums choice),
  block_n / block_m / block_p
            — the VMEM block sizes (the paper's N', M', P'),

and the BRAM cap becomes the VMEM budget.  The analytic model is
``dataflow.tpu_fused_flow_cost``; exactly as Alg 1, we enumerate the
candidate grid, drop configurations over budget, and keep the predicted-
latency argmin.  When a measurement callable is supplied (i.e. the fused
kernel can actually run — always true in interpret mode, but wall time is
only a *ranking* signal on real TPU), the top candidates by prediction
are re-ranked by measured time, mirroring the paper's practice of
validating Alg 1's pick against the implemented design.

The per-layer result is baked into ``core.plan.LayerPlan`` (the
compile-once IR ``models.cnn.forward_spectral`` executes) and feeds
``benchmarks/e2e_latency.py``.  The cost model is sparsity-aware — see
``autotune_layer(active_bins=...)`` — so Alg 1's choice reflects the
kernel Alg 2 compressed.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Iterable, Sequence

from repro.core import dataflow as df
from repro.core.dataflow import FLOWS

# Power-of-two VMEM block candidates; clamped to each layer's dims.
BLOCK_CANDIDATES = (32, 64, 128, 256)


def predict_seconds(c: dict) -> float:
    """Roofline latency of one cost-model row: pipelined kernel time
    plus any serial host-side pass (the windowed input path's window
    relayout — ``dataflow.tpu_fused_flow_cost`` 'serial_s'; staged
    ``tpu_flow_cost`` rows have none).  Public because the degradation
    ladder (``core.resilience.demote_layer``) re-prices demoted
    configurations through the same formula, keeping
    ``FusedTuning.predicted_s`` honest after a demotion.  'step_s' is
    the per-grid-step dispatch overhead term (zero unless the caller
    priced the model with ``step_overhead_s`` — the interpret-mode
    serving stack does, see ``dataflow.INTERPRET_STEP_S``)."""
    return (c.get("serial_s", 0.0) + c.get("step_s", 0.0)
            + max(c["hbm_s"], c["compute_s"]))


_predict = predict_seconds


@dataclasses.dataclass(frozen=True)
class FusedTuning:
    """Chosen fused-kernel configuration for one conv layer.

    ``hadamard`` is the Hadamard-stage mode (``df.HADAMARD_MODES``)
    when the tuner searched the mode axis, or None when it ran in
    legacy single-datapath mode (the cost model's compressed-stream
    default).  ``input_mode`` is the input path (``df.INPUT_MODES``)
    when the tuner searched that axis, or None (= 'windowed') in
    legacy mode.
    """

    layer: str
    flow: str
    block_n: int
    block_m: int
    block_p: int
    hbm_bytes: float
    vmem_bytes: float
    predicted_s: float           # serial_s + step_s + max(hbm_s, compute_s)
    measured_s: float | None = None
    hadamard: str | None = None
    input_mode: str | None = None
    grid_steps: float | None = None   # gn*gm*gp of the priced grid
    residual: str | None = None       # shortcut placement: 'hbm'|'vmem'

    def kwargs(self) -> dict:
        """Keyword arguments for ``fused_spectral_conv2d`` — includes
        the tuned ``input_mode`` so callers applying a halo-tuned
        config don't silently run the windowed path.  The Hadamard
        mode is NOT included (the scheduled datapath needs tables and
        a different entry point — dispatch on ``self.hadamard``)."""
        return {"flow": self.flow, "block_n": self.block_n,
                "block_m": self.block_m, "block_p": self.block_p,
                "input_mode": self.input_mode or "windowed"}


def _layer_candidates(layer: df.ConvLayer, fft_size: int, batch: int,
                      blocks: Sequence[int], hw_safe: bool,
                      flows: Sequence[str] = FLOWS
                      ) -> Iterable[tuple[str, int, int, int]]:
    # ``hw_safe`` is accepted for API compatibility but no longer prunes:
    # the RMW flows accumulate through manually DMA'd tiles (PR 8), so a
    # non-consecutive output revisit is legal on hardware for every
    # (flow, block) combination.
    del hw_safe
    t = layer.tiles(fft_size) * batch
    # Full-dimension blocks join the power-of-two candidates so that the
    # configuration space at batch B strictly contains the batch-1 space
    # (per-image tiles stay a candidate at every batch — this is what
    # makes the per-image predicted cost non-increasing in batch along
    # the doubling chain; see tests/test_batch_amortized.py).
    bns = sorted({min(b, layer.c_out) for b in blocks} | {layer.c_out})
    bms = sorted({min(b, layer.c_in) for b in blocks} | {layer.c_in})
    t_img = layer.tiles(fft_size)
    doubling = {t_img * (1 << i)
                for i in range(max(1, batch).bit_length())}
    bps = sorted({min(b, t) for b in blocks} | {t}
                 | {d for d in doubling if d <= t})
    for flow, bn, bm, bp in itertools.product(flows, bns, bms, bps):
        yield flow, bn, bm, bp


def autotune_layer(layer: df.ConvLayer, fft_size: int, alpha: float, *,
                   batch: int = 1,
                   vmem_budget: int = df.TPU_VMEM_BYTES,
                   blocks: Sequence[int] = BLOCK_CANDIDATES,
                   hw_safe: bool = True,
                   flows: Sequence[str] = FLOWS,
                   active_bins: int | None = None,
                   hadamard_modes: Sequence[str] | None = None,
                   input_modes: Sequence[str] | None = None,
                   schedule_r: int = df.SCHEDULE_R,
                   schedule_mu: float = df.SCHEDULE_MU,
                   step_overhead_s: float = 0.0,
                   residual: str | None = None,
                   cost_fn: Callable | None = None,
                   measure_fn: Callable[[FusedTuning], float] | None = None,
                   measure_top_k: int = 3) -> FusedTuning:
    """Pick (flow, block_n, block_m, block_p[, hadamard]) for one layer.

    Analytic pass: minimize the roofline latency max(hbm_s, compute_s)
    over all in-budget candidates (ties break toward fewer HBM bytes).
    The cost model is sparsity-aware: kernel traffic and Hadamard MACs
    scale with nnz = K^2/alpha and the spectral-transform dims with
    ``active_bins`` (pass the plan's compacted bin count so Alg 1 sees
    exactly the kernel Alg 2 compressed — this is where the two
    algorithms compose).

    ``hadamard_modes`` adds the third search axis: a subset of
    ``df.HADAMARD_MODES`` to rank per candidate (e.g. ('bin',
    'scheduled')), costed via ``cost_fn(..., hadamard=mode,
    r=schedule_r, mu=schedule_mu)``; the winning mode lands in
    ``FusedTuning.hadamard``.  None (default) keeps the legacy
    single-datapath behavior — the cost model's compressed-stream
    default and ``hadamard=None`` on the result.

    ``input_modes`` adds the fourth axis: a subset of
    ``df.INPUT_MODES`` ranking the host-materialized window stream
    against the in-kernel halo gather per candidate; the winner lands
    in ``FusedTuning.input_mode``.  None keeps the legacy windowed
    costing and ``input_mode=None`` on the result.

    ``step_overhead_s`` prices a fixed cost per grid step (gn*gm*gp),
    landing in the cost rows' 'step_s'.  The default 0.0 keeps the
    pure byte/flop roofline; the interpret-mode serving stack and the
    benchmarks pass ``dataflow.INTERPRET_STEP_S`` so per-bucket plans
    minimize the wall clock of the backend that actually runs.

    ``hw_safe`` is accepted for API compatibility but is a no-op since
    PR 8: the fused kernel accumulates through manually DMA'd tiles,
    so every (flow, block, input_mode, batch) combination is legal on
    hardware — including halo + weight-stationary at batch > 1.

    ``residual`` prices a fused shortcut add on the epilogue flush
    ('hbm' streams the shortcut back from HBM, 'vmem' holds it on-chip
    as retained bytes — the ShortcutFusion reuse decision, see
    ``dataflow.tpu_fused_flow_cost(residual=...)``); the placement is
    recorded in ``FusedTuning.residual``.

    Measured pass (optional): re-rank the ``measure_top_k`` best
    analytic candidates by ``measure_fn`` wall time.  ``cost_fn``
    defaults to the fused kernel's model; pass
    ``dataflow.tpu_flow_cost`` to tune the staged Hadamard under the
    same selection policy.
    """
    if cost_fn is None:
        cost_fn = df.tpu_fused_flow_cost
    modes: Sequence[str | None] = (
        [None] if hadamard_modes is None else list(hadamard_modes))
    imodes: Sequence[str | None] = (
        [None] if input_modes is None else list(input_modes))

    def cost(bn, bp, bm, flow, mode, imode):
        kw = {} if mode is None else {"hadamard": mode, "r": schedule_r,
                                      "mu": schedule_mu}
        if imode is not None:
            kw["input_mode"] = imode
        if step_overhead_s:
            kw["step_overhead_s"] = step_overhead_s
        if residual is not None:
            kw["residual"] = residual
        return cost_fn(layer, fft_size, alpha, bn, bp, bm, flow,
                       batch=batch, active_bins=active_bins, **kw)

    scored: list[FusedTuning] = []
    for flow, bn, bm, bp in _layer_candidates(layer, fft_size, batch,
                                              blocks, hw_safe, flows):
        for mode in modes:
            for imode in imodes:
                c = cost(bn, bp, bm, flow, mode, imode)
                if c["vmem_bytes"] > vmem_budget:
                    continue
                scored.append(FusedTuning(
                    layer.name, flow, bn, bm, bp, c["hbm_bytes"],
                    c["vmem_bytes"], _predict(c),
                    hadamard=mode, input_mode=imode,
                    grid_steps=c.get("grid_steps"),
                    residual=residual))
    if not scored:
        # Nothing fits the budget: return the smallest-footprint config
        # anyway.  Interpret mode runs it regardless; on real TPU an
        # over-budget working set fails at Mosaic compile time, so the
        # caller sees vmem_bytes > budget on the returned tuning and can
        # shrink blocks/batch before hitting that opaque error.
        flow = flows[0]
        bn = bm = bp = min(blocks)
        c = cost(bn, bp, bm, flow, modes[0], imodes[0])
        return FusedTuning(layer.name, flow, bn, bm, bp, c["hbm_bytes"],
                           c["vmem_bytes"], _predict(c),
                           hadamard=modes[0], input_mode=imodes[0],
                           grid_steps=c.get("grid_steps"),
                           residual=residual)
    scored.sort(key=lambda tn: (tn.predicted_s,
                                tn.grid_steps if tn.grid_steps is not None
                                else 0.0,
                                tn.hbm_bytes))
    if measure_fn is None:
        return scored[0]
    best, best_t = None, float("inf")
    for cand in scored[:measure_top_k]:
        t = measure_fn(cand)
        if t < best_t:
            best, best_t = cand, t
    return dataclasses.replace(best, measured_s=best_t)


def autotune_network(layers: Sequence[df.ConvLayer] = df.VGG16_LAYERS,
                     fft_size: int = 8,
                     alpha: "float | Sequence[float]" = 4.0, *,
                     batch: int = 1,
                     vmem_budget: int = df.TPU_VMEM_BYTES,
                     blocks: Sequence[int] = BLOCK_CANDIDATES,
                     hw_safe: bool = True,
                     active_bins: dict[str, int] | None = None,
                     hadamard_modes: Sequence[str] | None = None,
                     input_modes: Sequence[str] | None = None,
                     schedule_r: int = df.SCHEDULE_R,
                     schedule_mu: float = df.SCHEDULE_MU,
                     step_overhead_s: float = 0.0,
                     measure: bool = False,
                     interpret: bool | None = None
                     ) -> dict[str, FusedTuning]:
    """Alg-1-on-TPU over a conv stack -> {layer name: FusedTuning}.

    Args:
      layers: the conv stack to tune (default: the paper's VGG16).
      fft_size: spectral tile size K.
      alpha: kernel compression ratio — a scalar broadcasts, a sequence
        supplies one alpha per layer (the paper prunes non-uniformly).
      batch: images per forward call; scales the tile count the blocks
        are chosen against (plans are batch-specific, see
        ``models.cnn.forward_spectral``).
      vmem_budget: the BRAM-cap analogue — candidates whose working set
        exceeds it are dropped.
      blocks: candidate block sizes for each of block_n/block_m/block_p
        (clamped to the layer dims).
      hw_safe: accepted for API compatibility; a no-op since PR 8
        (manual-DMA accumulators make every configuration legal on
        hardware).
      active_bins: optional {layer name: Fa} — the compacted bin count
        realized by that layer's pruned kernels, so the cost model sees
        the kernel Alg 2 compressed.
      hadamard_modes: optional subset of ``df.HADAMARD_MODES`` to rank
        as a third search axis per layer (None = legacy single
        datapath); the winner lands in ``FusedTuning.hadamard``.
      input_modes: optional subset of ``df.INPUT_MODES`` to rank as a
        fourth axis — windowed stream vs in-kernel halo gather (None =
        legacy windowed costing); the winner lands in
        ``FusedTuning.input_mode``.
      schedule_r / schedule_mu: Alg-2 replica count and estimated Eq-14
        utilization used to cost 'scheduled' candidates — keep them in
        sync with what the tables will actually be compiled with.
      step_overhead_s: fixed cost per grid step added to predictions
        (``dataflow.INTERPRET_STEP_S`` for interpret-mode serving;
        default 0.0 keeps the pure roofline).
      measure: re-rank top analytic candidates by wall time on
        synthetic layer data (``interpret`` as in the kernels).

    Returns {layer name: ``FusedTuning``}.
    """
    from repro.core.sparse import per_layer_alphas

    layers = list(layers)
    alphas = per_layer_alphas(alpha, len(layers))
    plan: dict[str, FusedTuning] = {}
    for layer, a in zip(layers, alphas):
        measure_fn = None
        if measure:
            measure_fn = _make_measure_fn(layer, fft_size, a, batch,
                                          interpret)
        plan[layer.name] = autotune_layer(
            layer, fft_size, a, batch=batch, vmem_budget=vmem_budget,
            blocks=blocks, hw_safe=hw_safe,
            active_bins=(active_bins or {}).get(layer.name),
            hadamard_modes=hadamard_modes, input_modes=input_modes,
            schedule_r=schedule_r, schedule_mu=schedule_mu,
            step_overhead_s=step_overhead_s,
            measure_fn=measure_fn)
    return plan


def _make_measure_fn(layer: df.ConvLayer, fft_size: int, alpha: float,
                     batch: int, interpret: bool | None
                     ) -> Callable[[FusedTuning], float]:
    """Wall-clock one fused pallas_call on synthetic layer data, pruned
    to ``alpha`` so the measured workload (active-bin compaction
    included) is the one the plan will execute."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import sparse as sp
    from repro.core import spectral as spec
    from repro.kernels.fused_spectral_conv import (
        fused_spectral_conv2d, fused_spectral_conv2d_scheduled)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (batch, layer.c_in, layer.h_in, layer.w_in),
                          jnp.float32)
    w = jax.random.normal(key, (layer.c_out, layer.c_in, layer.ksize,
                                layer.ksize), jnp.float32)
    geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize, fft_size,
                             layer.pad)
    w_f = spec.spectral_kernel(w, fft_size)
    if alpha > 1.0:
        w_f = sp.prune_magnitude(w_f, alpha)

    def measure(tn: FusedTuning, iters: int = 3) -> float:
        imode = tn.input_mode or "windowed"
        if tn.hadamard == "scheduled" and hasattr(w_f, "values"):
            # Compile the Alg-2 tables ONCE per candidate, outside the
            # timing loop — the wall time ranked here must be the
            # kernel's, not the host scheduler's.
            from repro.core import scheduler as sch
            import numpy as np
            k2 = fft_size * fft_size
            tabs = sch.compile_layer_tables(
                np.asarray(w_f.indices),
                np.asarray(w_f.values).reshape(w_f.n_out, w_f.n_in, k2),
                k2, df.SCHEDULE_R, min(tn.block_n, w_f.n_out),
                active=sp.compacted_active_bins(w_f),
                m_pad_to=min(tn.block_m, w_f.n_in))
            fn = lambda: fused_spectral_conv2d_scheduled(
                x, w_f, geo, n_par=tn.block_n, flow=tn.flow,
                block_m=tn.block_m, block_p=tn.block_p, tables=tabs,
                input_mode=imode, interpret=interpret)
        else:
            fn = lambda: fused_spectral_conv2d(x, w_f, geo,
                                               interpret=interpret,
                                               **tn.kwargs())
        fn().block_until_ready()          # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    return measure


# ---------------------------------------------------------------------------
# Two-level Alg-1: strategy x (flow, blocks, modes) per layer (ISSUE 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardTuning:
    """Chosen (partitioning strategy, shard-local kernel config) for one
    conv layer on a D-shard mesh.

    ``base`` is the per-chip ``FusedTuning`` of the shard-local
    sub-problem (``dataflow.shard_local_layer``): its blocks are clamped
    to the LOCAL dims (channel sharding tunes against c_in/D), and its
    ``predicted_s`` is the per-chip roofline WITHOUT the collective —
    ``sharded_s = predicted_s + ici_s`` is the two-level objective this
    tuning minimizes.
    """

    base: FusedTuning
    strategy: str                # one of dataflow.SHARD_STRATEGIES
    n_shards: int
    ici_bytes: float
    ici_s: float
    per_chip_hbm_bytes: float
    sharded_s: float


def autotune_layer_sharded(layer: df.ConvLayer, fft_size: int,
                           alpha: float, *, n_shards: int,
                           strategies: Sequence[str] | None = None,
                           batch: int = 1,
                           vmem_budget: int = df.TPU_VMEM_BYTES,
                           blocks: Sequence[int] = BLOCK_CANDIDATES,
                           hw_safe: bool = True,
                           flows: Sequence[str] = FLOWS,
                           active_bins: int | None = None,
                           hadamard_modes: Sequence[str] | None = None,
                           input_modes: Sequence[str] | None = None,
                           schedule_r: int = df.SCHEDULE_R,
                           schedule_mu: float = df.SCHEDULE_MU,
                           step_overhead_s: float = 0.0,
                           residual: str | None = None) -> ShardTuning:
    """Pick (strategy, flow, blocks[, hadamard, input_mode]) for one
    layer on a ``n_shards``-device mesh — Alg 1 run one level up.

    The candidate grid is the per-strategy product of
    ``dataflow.SHARD_STRATEGIES`` (infeasible strategies drop out:
    channel needs D | c_in, spatial needs a tile row per shard;
    'replicate' is always feasible, so the search never comes back
    empty) with the usual (flow, block) grid enumerated against the
    SHARD-LOCAL layer dims.  Every candidate is priced by
    ``dataflow.tpu_sharded_flow_cost`` and ranked by ``sharded_s`` =
    per-chip roofline + ICI serialization, ties toward fewer grid steps
    then fewer total (HBM + ICI) bytes — the same policy as
    ``autotune_layer`` with the collective folded in.
    """
    strategies = (df.SHARD_STRATEGIES if strategies is None
                  else list(strategies))
    modes: Sequence[str | None] = (
        [None] if hadamard_modes is None else list(hadamard_modes))
    imodes: Sequence[str | None] = (
        [None] if input_modes is None else list(input_modes))
    scored: list[ShardTuning] = []
    for strategy in strategies:
        local = df.shard_local_layer(layer, fft_size, n_shards, strategy)
        if local is None:
            continue
        for flow, bn, bm, bp in _layer_candidates(local, fft_size, batch,
                                                  blocks, hw_safe, flows):
            for mode in modes:
                for imode in imodes:
                    kw = {} if mode is None else {
                        "hadamard": mode, "r": schedule_r,
                        "mu": schedule_mu}
                    if imode is not None:
                        kw["input_mode"] = imode
                    if step_overhead_s:
                        kw["step_overhead_s"] = step_overhead_s
                    if residual is not None:
                        kw["residual"] = residual
                    c = df.tpu_sharded_flow_cost(
                        layer, fft_size, alpha, bn, bp, bm, flow,
                        n_shards=n_shards, strategy=strategy,
                        batch=batch, active_bins=active_bins, **kw)
                    if c is None or c["vmem_bytes"] > vmem_budget:
                        continue
                    tn = FusedTuning(
                        layer.name, flow, bn, bm, bp, c["hbm_bytes"],
                        c["vmem_bytes"], _predict(c), hadamard=mode,
                        input_mode=imode,
                        grid_steps=c.get("grid_steps"),
                        residual=residual)
                    scored.append(ShardTuning(
                        base=tn, strategy=strategy, n_shards=n_shards,
                        ici_bytes=c["ici_bytes"], ici_s=c["ici_s"],
                        per_chip_hbm_bytes=c["per_chip_hbm_bytes"],
                        sharded_s=c["sharded_s"]))
    if not scored:
        # Nothing fit the budget: replicate with the single-chip
        # fallback tuning (autotune_layer's own over-budget escape
        # hatch) so the caller still gets an executable config.
        tn = autotune_layer(
            layer, fft_size, alpha, batch=batch,
            vmem_budget=vmem_budget, blocks=blocks, hw_safe=hw_safe,
            flows=flows, active_bins=active_bins,
            hadamard_modes=hadamard_modes, input_modes=input_modes,
            schedule_r=schedule_r, schedule_mu=schedule_mu,
            step_overhead_s=step_overhead_s, residual=residual)
        return ShardTuning(base=tn, strategy="replicate",
                           n_shards=n_shards, ici_bytes=0.0, ici_s=0.0,
                           per_chip_hbm_bytes=tn.hbm_bytes,
                           sharded_s=tn.predicted_s)
    scored.sort(key=lambda st: (st.sharded_s,
                                st.base.grid_steps
                                if st.base.grid_steps is not None else 0.0,
                                st.per_chip_hbm_bytes + st.ici_bytes))
    return scored[0]


def autotune_network_sharded(layers: Sequence[df.ConvLayer]
                             = df.VGG16_LAYERS,
                             fft_size: int = 8,
                             alpha: "float | Sequence[float]" = 4.0, *,
                             n_shards: int,
                             batch: int = 1,
                             vmem_budget: int = df.TPU_VMEM_BYTES,
                             blocks: Sequence[int] = BLOCK_CANDIDATES,
                             active_bins: dict[str, int] | None = None,
                             hadamard_modes: Sequence[str] | None = None,
                             input_modes: Sequence[str] | None = None,
                             schedule_r: int = df.SCHEDULE_R,
                             schedule_mu: float = df.SCHEDULE_MU,
                             step_overhead_s: float = 0.0
                             ) -> dict[str, ShardTuning]:
    """Two-level Alg-1 over a conv stack -> {layer name: ShardTuning}.
    Per-layer independent (activation layouts are reconciled at layer
    boundaries by the sharded executor, so strategies mix freely —
    channel-heavy late convs typically pick 'channel', large-image
    early convs 'spatial')."""
    from repro.core.sparse import per_layer_alphas

    layers = list(layers)
    alphas = per_layer_alphas(alpha, len(layers))
    return {
        layer.name: autotune_layer_sharded(
            layer, fft_size, a, n_shards=n_shards, batch=batch,
            vmem_budget=vmem_budget, blocks=blocks,
            active_bins=(active_bins or {}).get(layer.name),
            hadamard_modes=hadamard_modes, input_modes=input_modes,
            schedule_r=schedule_r, schedule_mu=schedule_mu,
            step_overhead_s=step_overhead_s)
        for layer, a in zip(layers, alphas)}
