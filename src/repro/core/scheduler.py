"""Alg 2 — exact-cover based memory-access scheduling (paper §5.3).

Problem: N' sparse kernels (rows of an index matrix, K^2/alpha non-zero
frequency indices each) read the same input tile held in BRAMs with r
replicas.  A *cycle* may serve at most one (value, index) per kernel (C1)
and touch at most r distinct indices (C2).  Rearranging each kernel's
value stream, find the minimum number of cycles covering every non-zero —
an exact-cover instance, approximated greedily:

  * if some candidate set covers ALL remaining kernels, choose the one
    built from low-degree index nodes (leave high-degree nodes free for
    future cycles);
  * otherwise choose the set covering the most kernels.

Implemented as greedy max-coverage with lexicographic tie-breaking
(coverage desc, then index-node degree asc), plus the two baselines the
paper compares against (random, lowest-index-first [16]) and a
cycle-accurate simulator that replays a schedule, checks C1/C2/exact-cover
and measures PE utilization (Eq 14).

The schedule compiles into the paper's Fig 6 storage layout: an INDEX
table [T, r] of replica read addresses and a VALUE table [T, N'] of
(weight, sel, valid) PE feeds — consumed by the Pallas kernel
``repro.kernels.sparse_hadamard``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import resilience as res


@dataclasses.dataclass
class Schedule:
    """A scheduling result for one group of N' kernels.

    cycles: list of (kernel_ids, index_ids) pairs per cycle, kernel_ids
            aligned with index_ids (the assigned read address per kernel).
    """

    n_kernels: int
    r: int
    cycles: list[tuple[np.ndarray, np.ndarray]]

    @property
    def n_cycles(self) -> int:
        return len(self.cycles)

    @property
    def total_ops(self) -> int:
        return sum(len(k) for k, _ in self.cycles)

    @property
    def pe_utilization(self) -> float:
        """Eq 14 with P' folded out (tiles share the schedule)."""
        if not self.cycles:
            return 1.0
        return self.total_ops / (self.n_cycles * self.n_kernels)


def _edges_from_matrix(index_matrix: np.ndarray, k2: int) -> np.ndarray:
    """[N', nnz] index matrix -> boolean incidence [N', K^2]."""
    n = index_matrix.shape[0]
    inc = np.zeros((n, k2), dtype=bool)
    rows = np.repeat(np.arange(n), index_matrix.shape[1])
    inc[rows, index_matrix.ravel()] = True
    return inc


def _assign_and_delete(inc: np.ndarray, active: np.ndarray,
                       chosen: list[int]) -> tuple[np.ndarray, np.ndarray]:
    """Each covered kernel consumes one edge to a chosen index; prefer the
    chosen index with the lowest remaining degree (burn scarce nodes)."""
    deg = inc.sum(axis=0)
    order = sorted(chosen, key=lambda f: deg[f])
    kernel_ids, index_ids = [], []
    taken = np.zeros(inc.shape[0], dtype=bool)
    for f in order:
        cand = inc[:, f] & active & ~taken
        ks = np.nonzero(cand)[0]
        for k in ks:
            kernel_ids.append(k)
            index_ids.append(f)
            taken[k] = True
            inc[k, f] = False
    return np.asarray(kernel_ids, np.int32), np.asarray(index_ids, np.int32)


def _merge_cycles(cycles: list[tuple[np.ndarray, np.ndarray]], r: int
                  ) -> list[tuple[np.ndarray, np.ndarray]]:
    """Repair pass (beyond-paper): greedily merge cycle pairs whose kernel
    sets are disjoint and whose union of indices still fits r replicas.
    Merging strictly reduces the cycle count, so PE utilization can only
    improve; C1/C2 are preserved by construction."""
    cycles = [(set(k.tolist()), list(zip(k.tolist(), f.tolist())))
              for k, f in cycles]
    merged = True
    while merged:
        merged = False
        cycles.sort(key=lambda c: len(c[1]))
        for i in range(len(cycles)):
            for j in range(len(cycles) - 1, i, -1):
                ki, pi = cycles[i]
                kj, pj = cycles[j]
                if ki & kj:
                    continue
                union_idx = {f for _, f in pi} | {f for _, f in pj}
                if len(union_idx) > r:
                    continue
                cycles[i] = (ki | kj, pi + pj)
                del cycles[j]
                merged = True
                break
            if merged:
                break
    out = []
    for _, pairs in cycles:
        ks = np.asarray([k for k, _ in pairs], np.int32)
        fs = np.asarray([f for _, f in pairs], np.int32)
        out.append((ks, fs))
    return out


def schedule_exact_cover(index_matrix: np.ndarray, k2: int, r: int,
                         merge: bool = True) -> Schedule:
    """Alg 2: greedy approximate exact cover (+ merge repair pass)."""
    inc = _edges_from_matrix(index_matrix, k2)
    n = inc.shape[0]
    cycles: list[tuple[np.ndarray, np.ndarray]] = []
    deg_tiebreak = n + 1
    while inc.any():
        active = inc.any(axis=1)
        uncovered = active.copy()
        chosen: list[int] = []
        deg = inc.sum(axis=0)
        while len(chosen) < r and uncovered.any():
            cover = inc[uncovered].sum(axis=0)
            for f in chosen:
                cover[f] = 0
            # maximize coverage; tie-break toward low-degree index nodes
            score = cover * deg_tiebreak - deg
            score[cover == 0] = -1
            f_star = int(np.argmax(score))
            if cover[f_star] == 0:
                break
            chosen.append(f_star)
            uncovered &= ~inc[:, f_star]
        ks, fs = _assign_and_delete(inc, active, chosen)
        cycles.append((ks, fs))
    if merge:
        cycles = _merge_cycles(cycles, r)
    return Schedule(n, r, cycles)


def schedule_lowest_index_first(index_matrix: np.ndarray, k2: int, r: int,
                                ) -> Schedule:
    """Baseline [16]: each kernel proposes its lowest remaining index; the
    cycle serves the r lowest distinct proposals."""
    inc = _edges_from_matrix(index_matrix, k2)
    cycles: list[tuple[np.ndarray, np.ndarray]] = []
    while inc.any():
        active = np.nonzero(inc.any(axis=1))[0]
        proposals = np.array([int(np.nonzero(inc[k])[0][0]) for k in active])
        served = np.unique(proposals)[:r]
        mask = np.isin(proposals, served)
        ks = active[mask].astype(np.int32)
        fs = proposals[mask].astype(np.int32)
        inc[ks, fs] = False
        cycles.append((ks, fs))
    return Schedule(inc.shape[0], r, cycles)


def schedule_random(index_matrix: np.ndarray, k2: int, r: int,
                    seed: int = 0) -> Schedule:
    """Baseline: random kernel order, random index pick per kernel; a pick
    is accepted if its index is already in the cycle or a replica is free."""
    rng = np.random.default_rng(seed)
    inc = _edges_from_matrix(index_matrix, k2)
    cycles: list[tuple[np.ndarray, np.ndarray]] = []
    while inc.any():
        active = np.nonzero(inc.any(axis=1))[0]
        rng.shuffle(active)
        in_cycle: set[int] = set()
        kernel_ids, index_ids = [], []
        for k in active:
            opts = np.nonzero(inc[k])[0]
            f = int(rng.choice(opts))
            if f in in_cycle or len(in_cycle) < r:
                in_cycle.add(f)
                kernel_ids.append(k)
                index_ids.append(f)
                inc[k, f] = False
        cycles.append((np.asarray(kernel_ids, np.int32),
                       np.asarray(index_ids, np.int32)))
    return Schedule(inc.shape[0], r, cycles)


SCHEDULERS = {
    "exact_cover": schedule_exact_cover,
    "lowest_index": schedule_lowest_index_first,
    "random": schedule_random,
}


# ---------------------------------------------------------------------------
# Verification / simulation
# ---------------------------------------------------------------------------

def verify_schedule(sched: Schedule, index_matrix: np.ndarray,
                    k2: int) -> None:
    """Check C1, C2 and exact cover (every non-zero served exactly once);
    raises ``resilience.PlanValidationError`` on violation."""
    seen = np.zeros((sched.n_kernels, k2), dtype=int)
    for ti, (ks, fs) in enumerate(sched.cycles):
        if len(np.unique(ks)) != len(ks):
            raise res.PlanValidationError(
                f"C1 violated: duplicate kernel in cycle {ti}",
                site="verify_schedule")
        if len(np.unique(fs)) > sched.r:
            raise res.PlanValidationError(
                f"C2 violated: cycle {ti} touches {len(np.unique(fs))} "
                f"distinct indices > r={sched.r} replicas",
                site="verify_schedule")
        seen[ks, fs] += 1
    want = _edges_from_matrix(index_matrix, k2).astype(int)
    if not np.array_equal(seen, want):
        raise res.PlanValidationError(
            "schedule is not an exact cover of the kernels "
            "(some non-zero served zero or multiple times)",
            site="verify_schedule")


def simulate_layer_utilization(indices: np.ndarray, k2: int, r: int,
                               n_par: int, method: str = "exact_cover",
                               channel_sample: int | None = None,
                               seed: int = 0) -> float:
    """Average PE utilization of a layer (Eq 14 numerator/denominator
    aggregated over kernel groups x input channels).

    indices: [c_out, c_in, nnz] per-kernel sorted freq indices.
    The schedule is shared by all P' parallel tiles, so utilization is
    independent of P'.  ``channel_sample`` caps the number of input
    channels simulated (deterministic subsample) — the paper's statistic
    is an average, and per-channel variance is tiny.
    """
    c_out, c_in, _ = indices.shape
    rng = np.random.default_rng(seed)
    chans = np.arange(c_in)
    if channel_sample is not None and channel_sample < c_in:
        chans = np.sort(rng.choice(c_in, channel_sample, replace=False))
    fn = SCHEDULERS[method]
    total_ops = 0
    total_slots = 0
    for m in chans:
        for g0 in range(0, c_out, n_par):
            mat = indices[g0:g0 + n_par, m, :]
            kwargs = {"seed": seed} if method == "random" else {}
            s = fn(mat, k2, r, **kwargs)
            total_ops += s.total_ops
            total_slots += s.n_cycles * mat.shape[0]
    return total_ops / total_slots


# ---------------------------------------------------------------------------
# Fig 6 storage layout: INDEX + VALUE tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScheduleTables:
    """Hardware tables for one (kernel-group, input-channel) schedule.

    index_table: int32 [T, r]    replica read addresses (padded with 0).
    sel:         int32 [T, N']   which replica column feeds PE n.
    valid:       bool  [T, N']   PE n active this cycle.
    values:      complex64 [T, N']  weight fed to PE n this cycle.
    out_index:   int32 [T, N']   frequency index PE n accumulates into
                                 (== index_table[t, sel[t, n]]).
    """

    index_table: np.ndarray
    sel: np.ndarray
    valid: np.ndarray
    values: np.ndarray
    out_index: np.ndarray

    @property
    def n_cycles(self) -> int:
        return self.index_table.shape[0]


def build_tables(sched: Schedule, kernel_values: np.ndarray,
                 index_matrix: np.ndarray) -> ScheduleTables:
    """Compile a schedule into INDEX/VALUE tables (Fig 6).

    kernel_values: complex [N', K^2] dense (zeros at pruned positions).
    """
    n = sched.n_kernels
    t = sched.n_cycles
    r = sched.r
    index_table = np.zeros((t, r), np.int32)
    sel = np.zeros((t, n), np.int32)
    valid = np.zeros((t, n), bool)
    values = np.zeros((t, n), np.complex64)
    out_index = np.zeros((t, n), np.int32)
    for ti, (ks, fs) in enumerate(sched.cycles):
        uniq = np.unique(fs)
        index_table[ti, :len(uniq)] = uniq
        pos = {int(f): i for i, f in enumerate(uniq)}
        for k, f in zip(ks, fs):
            sel[ti, k] = pos[int(f)]
            valid[ti, k] = True
            values[ti, k] = kernel_values[k, f]
            out_index[ti, k] = f
    return ScheduleTables(index_table, sel, valid, values, out_index)


def active_bins_from_tables(tables: "ScheduleTables | list[ScheduleTables]"
                            ) -> np.ndarray:
    """Frequency bins the schedule ever accumulates into.

    Because the schedule is an exact cover (every non-zero served exactly
    once, ``verify_schedule``), this union over valid ``out_index``
    entries equals the union of non-zero bins of the scheduled kernels —
    it is the bin set the fused kernel's active-bin compaction
    (``core.plan`` / ``kernels.fused_spectral_conv``) may restrict the
    spectral GEMM to.
    """
    if isinstance(tables, ScheduleTables):
        tables = [tables]
    bins: set[int] = set()
    for tb in tables:
        bins.update(np.unique(tb.out_index[tb.valid]).tolist())
    return np.asarray(sorted(bins), np.int64)


@dataclasses.dataclass(frozen=True)
class LayerTables:
    """Whole-layer Alg-2 tables, stacked and padded for the FUSED kernel.

    ``build_tables`` emits one ``ScheduleTables`` per (kernel-group,
    input-channel) pair; the fused scheduled datapath
    (``kernels.fused_spectral_conv``, hadamard mode 'scheduled') wants
    them as four rectangular operands it can block over the (n, m) grid
    axes.  Two FPGA planes are folded away relative to Fig 6:

      * ``valid`` — invalid PE lanes carry a zero weight, and a zero
        weight already kills the MAC *and* the scatter contribution;
      * ``out_index`` — by construction ``out_index == index_table[t,
        sel]``, so the scatter one-hot is recovered in-kernel as
        ``onehot(sel) @ onehot(index_table)`` (route the gather one-hot
        instead of the gathered value) and never needs streaming.

    Shapes (GN kernel groups of N' = n_par, Mp >= M channels, T cycles):

      idx  int32 [GN, Mp, T, r]   replica read addresses, in COMPACTED
                                  active-bin coordinates when ``active``
                                  was given (0-padded);
      sel  int32 [GN, Mp, T, N']  replica column feeding PE n;
      vr/vi f32  [GN, Mp, T, N']  complex weight per PE lane, zeroed on
                                  idle lanes and padded cycles/channels.

    ``total_cycles`` sums schedule length over every (group, channel)
    pair — the layer's serial Hadamard latency in PE cycles — and
    ``pe_utilization`` is the exact Eq-14 value over the whole layer
    (not sampled).
    """

    idx: np.ndarray
    sel: np.ndarray
    vr: np.ndarray
    vi: np.ndarray
    total_cycles: int
    pe_utilization: float

    @property
    def n_groups(self) -> int:
        return self.idx.shape[0]

    @property
    def m_pad(self) -> int:
        return self.idx.shape[1]

    @property
    def n_cycles(self) -> int:
        return self.idx.shape[2]

    @property
    def r(self) -> int:
        return self.idx.shape[3]

    @property
    def n_par(self) -> int:
        return self.sel.shape[3]

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in (self.idx, self.sel, self.vr, self.vi))


def compile_layer_tables(indices: np.ndarray, values: np.ndarray,
                         k2: int, r: int, n_par: int, *,
                         method: str = "exact_cover",
                         active: np.ndarray | None = None,
                         m_pad_to: int = 1) -> LayerTables:
    """Run Alg 2 over EVERY (kernel-group, input-channel) pair of a layer
    and stack the resulting INDEX/VALUE tables into ``LayerTables``.

    indices: int [N, M, nnz] per-kernel sorted frequency indices
             (``SparseSpectralKernels.indices``);
    values:  complex [N, M, K^2] dense kernel values (zeros at pruned
             positions);
    n_par:   N', the PE-group size == the fused kernel's block_n;
    active:  optional sorted active-bin set — table coordinates are
             remapped to positions within it so the kernel can gather/
             scatter directly against compacted spectral blocks;
    m_pad_to: pad the channel axis to this multiple (the fused kernel's
             block_m) with inert all-zero channels.

    This is the paper's offline schedule-compilation step and runs in
    host numpy exactly once per layer (``core.plan``); padded cycles,
    channels and group remainders all carry zero weights and are inert.
    """
    fn = SCHEDULERS[method]
    n, m_ch, _ = indices.shape
    groups = [(g0, min(g0 + n_par, n)) for g0 in range(0, n, n_par)]
    per: list[list[ScheduleTables]] = []
    t_max = 1
    total_ops = 0
    total_slots = 0
    total_cycles = 0
    for g0, g1 in groups:
        row = []
        for m in range(m_ch):
            mat = np.asarray(indices[g0:g1, m, :])
            s = fn(mat, k2, r)
            total_ops += s.total_ops
            total_slots += s.n_cycles * (g1 - g0)
            total_cycles += s.n_cycles
            tb = build_tables(s, np.asarray(values[g0:g1, m, :]), mat)
            t_max = max(t_max, tb.n_cycles)
            row.append(tb)
        per.append(row)

    pos = None
    if active is not None:
        pos = np.zeros(k2, np.int64)
        pos[np.asarray(active)] = np.arange(len(active))
    mp = m_ch + (-m_ch) % m_pad_to
    gn = len(groups)
    idx = np.zeros((gn, mp, t_max, r), np.int32)
    sel = np.zeros((gn, mp, t_max, n_par), np.int32)
    vr = np.zeros((gn, mp, t_max, n_par), np.float32)
    vi = np.zeros((gn, mp, t_max, n_par), np.float32)
    for g, (g0, g1) in enumerate(groups):
        ng = g1 - g0
        for m, tb in enumerate(per[g]):
            t = tb.n_cycles
            it = tb.index_table
            idx[g, m, :t] = pos[it] if pos is not None else it
            sel[g, m, :t, :ng] = tb.sel
            v = np.where(tb.valid, tb.values, 0)
            vr[g, m, :t, :ng] = v.real
            vi[g, m, :t, :ng] = v.imag
    mu = total_ops / max(1, total_slots)
    # Deterministic corruption sites for the fault-injection harness
    # (no-ops unless repro.testing.faults installed a matching fault).
    idx = res.fault_corrupt("oob_index", idx)
    vr = res.fault_corrupt("corrupt_value", vr)
    return LayerTables(idx, sel, vr, vi, total_cycles, mu)


def execute_tables(tables: ScheduleTables, x_tile: np.ndarray) -> np.ndarray:
    """Replay the INDEX/VALUE tables against one spectral input tile.

    x_tile: complex [K^2] (single channel).  Returns [N', K^2] partial
    products — must equal ``kernel_values * x_tile`` (masked dense).
    This mirrors the RTL datapath: read replicas at INDEX, route through
    sel, multiply VALUE, accumulate at out_index.
    """
    t, n = tables.sel.shape
    out = np.zeros((n, x_tile.shape[0]), np.complex64)
    for ti in range(t):
        replicas = x_tile[tables.index_table[ti]]          # r reads
        routed = replicas[tables.sel[ti]]                  # route to PEs
        prod = np.where(tables.valid[ti], tables.values[ti] * routed, 0)
        np.add.at(out, (np.arange(n), tables.out_index[ti]), prod)
    return out
