"""Bandwidth / on-chip-storage complexity models (paper §4, Eqs 6-13).

The paper analyses three pure dataflows for a sparse spectral conv layer —

  Flow #1  reuse kernels + partial sums, STREAM INPUT TILES
           (inputs re-loaded N/N' (pure) or N/Ns (flexible) times),
  Flow #2  reuse input tiles + partial sums, STREAM KERNELS
           (kernels re-loaded T/P' (pure) or T/Ps (flexible) times),
  Flow #3  reuse inputs + kernels, STREAM PARTIAL SUMS
           (psums written+read 2*M/M' times),

then interpolates between #1/#2 with the *streaming parameters* Ns (#kernels
resident before flushing input tiles) and Ps (#input tiles resident before
flushing kernels) — Eqs 12-13 — searched by Alg 1 (``repro.core.optimizer``).

Faithfulness notes
------------------
* Eqs 12/13 are implemented exactly as printed.  The pure-flow BRAM
  expressions (Eqs 6-8) are printed with garbled bank/depth placement in the
  source text; we implement the self-consistent reconstruction documented on
  each function (bank count x depth-overflow multiplier), which reproduces
  the paper's qualitative Fig 2: Flow #1 needs enormous BRAM counts on
  early (large-image) layers, Flow #2 few BRAMs but high traffic, Flow #3
  is never competitive.
* Data transfers are counted in 16-bit words as the paper does: spatial
  activations are real (1 word/value); spectral kernels and spectral psums
  are complex (2 words/value) — controlled by ``complex_words``.
* BRAM model: 36 Kb block = 1024 entries (paper's "memory depth 1024").

The same module also hosts the TPU re-cost of the flows used by the Pallas
kernel + mesh planner (HBM traffic / VMEM residency instead of DDR / BRAM):
see ``tpu_flow_cost``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.spectral import make_geometry

BRAM_DEPTH = 1024
WORD_BYTES = 2  # 16-bit fixed point


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """Static description of one spectral conv layer."""

    name: str
    c_in: int       # M
    c_out: int      # N
    h_in: int
    w_in: int
    ksize: int = 3
    pad: int = 1

    def tiles(self, fft_size: int) -> int:
        """T: number of input tiles per image (padded canvas)."""
        geo = make_geometry(self.h_in, self.w_in, self.ksize, fft_size,
                            self.pad)
        return geo.n_tiles

    def tile_size(self, fft_size: int) -> int:
        return fft_size - self.ksize + 1

    def spectral_macs(self, fft_size: int, alpha: float = 1.0) -> int:
        """Complex MACs of the (sparse) Hadamard stage (used to apportion
        the latency budget, Table 2 footnote)."""
        nnz = int(round(fft_size * fft_size / alpha))
        return self.tiles(fft_size) * nnz * self.c_in * self.c_out

    def spatial_macs(self) -> int:
        return (self.c_in * self.c_out * self.h_in * self.w_in
                * self.ksize * self.ksize)


# VGG16 conv stack (stride-1, pad-1, 3x3).  conv1_1 is omitted from dataflow
# optimization exactly as in the paper ("negligible computations").
VGG16_LAYERS: tuple[ConvLayer, ...] = (
    ConvLayer("conv1_1", 3, 64, 224, 224),
    ConvLayer("conv1_2", 64, 64, 224, 224),
    ConvLayer("conv2_1", 64, 128, 112, 112),
    ConvLayer("conv2_2", 128, 128, 112, 112),
    ConvLayer("conv3_1", 128, 256, 56, 56),
    ConvLayer("conv3_2", 256, 256, 56, 56),
    ConvLayer("conv3_3", 256, 256, 56, 56),
    ConvLayer("conv4_1", 256, 512, 28, 28),
    ConvLayer("conv4_2", 512, 512, 28, 28),
    ConvLayer("conv4_3", 512, 512, 28, 28),
    ConvLayer("conv5_1", 512, 512, 14, 14),
    ConvLayer("conv5_2", 512, 512, 14, 14),
    ConvLayer("conv5_3", 512, 512, 14, 14),
)

VGG16_OPT_LAYERS = VGG16_LAYERS[1:]


def _ceil(a: float, b: float) -> int:
    return int(math.ceil(a / b))


# ---------------------------------------------------------------------------
# On-chip storage (Eqs 6-8, 12) — #BRAMs
# ---------------------------------------------------------------------------

def bram_flow1(layer: ConvLayer, fft_size: int, alpha: float,
               p_par: int, n_par: int, r: int, m_par: int = 1) -> int:
    """Flow #1 (Eq 6): kernels + psums resident, input tiles stream.

    banks x depth-multiplier reconstruction:
      inputs : r*M'*P'  streaming double-buffers (1 tile deep)
      kernels: M'*N' banks, all N kernels resident
               -> depth multiplier ceil(N * K^2/alpha / (N' * 1024))
      psums  : N'*P' banks, psums of every tile of the image resident
               -> depth multiplier ceil(T * K^2 / (P' * 1024))
    """
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size)
    inp = r * m_par * p_par
    ker = m_par * n_par * max(1, _ceil(layer.c_out * k2 / alpha,
                                       n_par * BRAM_DEPTH))
    psum = n_par * p_par * max(1, _ceil(t * k2, p_par * BRAM_DEPTH))
    return inp + ker + psum


def bram_flow2(layer: ConvLayer, fft_size: int, alpha: float,
               p_par: int, n_par: int, r: int, m_par: int = 1) -> int:
    """Flow #2 (Eq 7): input tiles + psums resident, kernels stream.

      inputs : r*M'*P' banks, all T tiles resident
               -> depth multiplier ceil(T * K^2 / (P' * 1024))
      kernels: M'*N' streaming double-buffers
      psums  : N'*P' banks, N outputs for the P'-tile group resident
               -> depth multiplier ceil(N * K^2 / (N' * 1024))
    """
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size)
    inp = r * m_par * p_par * max(1, _ceil(t * k2, p_par * BRAM_DEPTH))
    ker = m_par * n_par
    psum = n_par * p_par * max(1, _ceil(layer.c_out * k2, n_par * BRAM_DEPTH))
    return inp + ker + psum


def bram_flow3(layer: ConvLayer, fft_size: int, alpha: float,
               p_par: int, n_par: int, r: int, m_par: int = 1) -> int:
    """Flow #3 (Eq 8): inputs + kernels resident, psums stream.

    Eq 8 is a min over which of (inputs, kernels) is held whole:
      (a) all T input tiles resident + kernel double-buffer
      (b) input double-buffer + all N kernels resident
    with a psum streaming buffer of N'*P' banks either way.
    """
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size)
    psum = n_par * p_par
    var_a = (r * m_par * p_par * max(1, _ceil(t * k2, p_par * BRAM_DEPTH))
             + m_par * n_par + psum)
    var_b = (r * m_par * p_par
             + m_par * n_par * max(1, _ceil(layer.c_out * k2 / alpha,
                                            n_par * BRAM_DEPTH))
             + psum)
    return min(var_a, var_b)


def bram_flexible(layer: ConvLayer, fft_size: int, alpha: float,
                  p_par: int, n_par: int, r: int,
                  ns: int, ps: int) -> int:
    """Eq 12: flexible flow with streaming parameters (Ns, Ps).

    As printed, plus the input-tile depth multiplier (Ps tiles resident
    across r replicas / P' parallel banks) which the printed equation
    folds into the bank count.
    """
    k2 = fft_size * fft_size
    inp = r * p_par * max(1, _ceil(ps * k2, p_par * BRAM_DEPTH))
    ker = n_par * max(1, _ceil(ns * k2 / alpha, n_par * BRAM_DEPTH))
    psum = n_par * p_par * max(1, _ceil(ns * ps * k2,
                                        n_par * p_par * BRAM_DEPTH))
    return inp + ker + psum


# ---------------------------------------------------------------------------
# Data transfers (Eqs 9-11, 13) — 16-bit words moved across DDR
# ---------------------------------------------------------------------------

def transfers_flow1(layer: ConvLayer, fft_size: int, alpha: float,
                    n_par: int, m_par: int = 1,
                    complex_words: int = 2) -> int:
    """Eq 9 numerator: inputs re-loaded once per N'-kernel group."""
    k2 = fft_size * fft_size
    reload_in = layer.c_out / n_par
    inp = layer.c_in * layer.h_in * layer.w_in * reload_in
    ker = layer.c_out * layer.c_in * k2 / alpha * complex_words
    out = layer.c_out * layer.h_in * layer.w_in
    return int(round(inp + ker + out))


def transfers_flow2(layer: ConvLayer, fft_size: int, alpha: float,
                    p_par: int, m_par: int = 1,
                    complex_words: int = 2) -> int:
    """Eq 10 numerator: kernels re-loaded once per P'-tile group."""
    k2 = fft_size * fft_size
    tile = layer.tile_size(fft_size)
    reload_k = (layer.h_in * layer.w_in) / (p_par * tile * tile)
    inp = layer.c_in * layer.h_in * layer.w_in
    ker = layer.c_out * layer.c_in * k2 / alpha * complex_words * reload_k
    out = layer.c_out * layer.h_in * layer.w_in
    return int(round(inp + ker + out))


def transfers_flow3(layer: ConvLayer, fft_size: int, alpha: float,
                    m_par: int = 1, complex_words: int = 2) -> int:
    """Eq 11 numerator: psums written + re-read once per input channel."""
    k2 = fft_size * fft_size
    inp = layer.c_in * layer.h_in * layer.w_in
    ker = layer.c_out * layer.c_in * k2 / alpha * complex_words
    out = (layer.c_out * layer.h_in * layer.w_in
           * 2 * (layer.c_in / m_par))
    return int(round(inp + ker + out))


def transfers_flexible(layer: ConvLayer, fft_size: int, alpha: float,
                       ns: int, ps: int, complex_words: int = 2) -> int:
    """Eq 13 numerator."""
    k2 = fft_size * fft_size
    tile = layer.tile_size(fft_size)
    inp = layer.c_in * layer.h_in * layer.w_in * (layer.c_out / ns)
    ker = (layer.c_out * layer.c_in * k2 / alpha * complex_words
           * (layer.h_in * layer.w_in) / (ps * tile * tile))
    out = layer.c_out * layer.h_in * layer.w_in
    return int(round(inp + ker + out))


def bandwidth_gbps(transfers_words: int, tau_s: float) -> float:
    """bw = #transfers / tau  (Eq at §4.2), in GB/s."""
    return transfers_words * WORD_BYTES / tau_s / 1e9


def layer_latency_budget(layers: Iterable[ConvLayer], fft_size: int,
                         alpha: float, total_tau_s: float) -> dict[str, float]:
    """tau_i = tau * CMP_i / CMP_total  (Table 2 footnote)."""
    layers = list(layers)
    cmps = {l.name: l.spectral_macs(fft_size, alpha) for l in layers}
    total = sum(cmps.values())
    return {n: total_tau_s * c / total for n, c in cmps.items()}


# ---------------------------------------------------------------------------
# TPU re-cost of the same three reuse choices (hardware adaptation)
# ---------------------------------------------------------------------------

# TPU v5e-class constants (also used by repro.roofline).
TPU_HBM_GBPS = 819e9
TPU_PEAK_FLOPS = 197e12
TPU_VMEM_BYTES = 16 * 2 ** 20   # ~16 MiB usable kernel working set
TPU_ICI_GBPS = 50e9

# The paper's three reuse choices as Pallas grid iteration orders —
# canonical name list shared by the kernels, the cost models below and
# the autotuner (core.autotune).
FLOWS = ("output_stationary", "weight_stationary", "input_stationary")


def tpu_flow_cost(layer: ConvLayer, fft_size: int, alpha: float,
                  block_n: int, block_p: int, block_m: int,
                  flow: str, batch: int = 1,
                  bytes_per_el: int = 4,
                  active_bins: int | None = None) -> dict[str, float]:
    """HBM traffic + VMEM residency of one spectral-Hadamard pallas_call.

    The Pallas kernel contracts input channels per frequency bin:
    ``Y[n,f,p] += W[n,m,f] X[m,f,p]`` with grid blocks (block_n x block_m x
    block_p).  The ``flow`` selects which operand stays resident across the
    grid's outermost iteration — the TPU translation of Flow #1/#2/#3:

      'weight_stationary' (Flow #1): W blocks stay in VMEM while all P
          blocks stream -> X re-read c_out/block_n times.
      'input_stationary'  (Flow #2): X blocks stay while kernel blocks
          stream -> W re-read T*batch/block_p times.
      'output_stationary' (Flow opt analogue): psums accumulate in VMEM
          across the m loop; X and W each read once per (n, p) block pair.

    Complex data: 2 real planes.  NOTE: the *staged* Pallas kernels
    stream and multiply DENSE spectral planes (pruned positions stored
    as zeros), so W traffic and FLOPs here are dense — ``alpha`` /
    ``active_bins`` are accepted for signature parity with
    ``tpu_fused_flow_cost`` (which IS sparsity-aware) and ignored.  The
    scheduled sparse kernel and the fused kernel's active-bin compaction
    are what turn compression into traffic/compute savings.
    """
    del alpha, active_bins  # dense-plane streaming: no compression here
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size) * batch
    cplx = 2
    x_bytes = layer.c_in * k2 * t * cplx * bytes_per_el
    w_bytes = layer.c_out * layer.c_in * k2 * cplx * bytes_per_el
    y_bytes = layer.c_out * k2 * t * cplx * bytes_per_el

    if flow == "weight_stationary":
        hbm = (x_bytes * math.ceil(layer.c_out / block_n)
               + w_bytes + y_bytes)
    elif flow == "input_stationary":
        hbm = (x_bytes + w_bytes * math.ceil(t / block_p) + y_bytes)
    elif flow == "output_stationary":
        hbm = (x_bytes * math.ceil(layer.c_out / block_n)
               + w_bytes * math.ceil(t / block_p) + y_bytes)
    else:
        raise ValueError(flow)

    # per-grid-step working set: ONE frequency bin's blocks (the Pallas
    # grid blocks F with size 1; see kernels/spectral_hadamard.py)
    vmem = (block_m * block_p * cplx * bytes_per_el             # X block
            + block_n * block_m * cplx * bytes_per_el           # W block
            + block_n * block_p * cplx * 4)                     # f32 acc
    flops = 8 * t * k2 * layer.c_in * layer.c_out
    return {
        "hbm_bytes": float(hbm),
        "vmem_bytes": float(vmem),
        "flops": float(flops),
        "hbm_s": float(hbm) / TPU_HBM_GBPS,
        "compute_s": float(flops) / TPU_PEAK_FLOPS,
        "fits_vmem": vmem <= TPU_VMEM_BYTES,
    }


def tpu_fused_flow_cost(layer: ConvLayer, fft_size: int, alpha: float,
                        block_n: int, block_p: int, block_m: int,
                        flow: str, batch: int = 1,
                        bytes_per_el: int = 4,
                        active_bins: int | None = None) -> dict[str, float]:
    """HBM traffic + VMEM working set of ONE fused pallas_call
    (``kernels.fused_spectral_conv``): FFT + Hadamard + IFFT (+ fused
    bias/ReLU epilogue) in a single kernel, so HBM only ever sees

      X  overlap-save windows [S, M, P]  real,  S = K^2, P = T * batch
      W  spectral kernel  [Fa, N, M]     complex, compacted/compressed
      Y  valid output tiles [S2, N, P]   real,  S2 = tile^2

    — the complex spectral intermediates X~/Y~ of the staged path
    (``tpu_flow_cost``'s x/y terms) never leave VMEM, and the post-conv
    elementwise epilogue adds no traffic at all.

    Sparsity (Alg 1 meets Alg 2): kernel bytes and Hadamard MACs scale
    with nnz = K^2/alpha — the paper streams kernels in compressed
    (value, index) form and the schedule executes only non-zeros.  The
    spectral-transform dims scale with ``active_bins`` (Fa <= K^2, the
    bin-granular compaction the TPU kernel actually realizes; pass the
    plan's padded count, default dense).  The nnz-granular Hadamard
    saving is fully realized by the scheduled sparse kernel and, on the
    fused path, down to active-bin granularity — the residual gap is the
    price of MXU-dense GEMMs and is visible here as
    ``kernel_hbm_bytes`` (nnz-scaled) vs FFT flops (Fa-scaled).

    Re-read factors follow the grid iteration order of each flow:

      'output_stationary': psums in VMEM scratch; X re-read per n block,
          W re-read per p block, Y written exactly once.
      'weight_stationary' (Flow #1, reuse kernels): W read once; X
          re-read per n block; real psum tiles RMW'd once per m block
          (2*gm - 1 passes).
      'input_stationary'  (Flow #2, reuse activations): X read once; W
          re-read per p block; same psum RMW traffic.
    """
    k2 = fft_size * fft_size
    tile = layer.tile_size(fft_size)
    t = layer.tiles(fft_size) * batch
    cplx = 2
    nnz = max(1, int(round(k2 / alpha)))
    fa = k2 if active_bins is None else max(1, min(int(active_bins), k2))
    gn = max(1, _ceil(layer.c_out, block_n))
    gm = max(1, _ceil(layer.c_in, block_m))
    gp = max(1, _ceil(t, block_p))
    s = k2                   # overlap-save: K x K input windows
    s2 = tile * tile         # only the valid rows are written back
    x_bytes = layer.c_in * s * t * bytes_per_el
    w_bytes = layer.c_out * layer.c_in * nnz * cplx * bytes_per_el
    y_bytes = layer.c_out * s2 * t * bytes_per_el

    if flow == "output_stationary":
        hbm = x_bytes * gn + w_bytes * gp + y_bytes
        w_hbm = w_bytes * gp
    elif flow == "weight_stationary":
        hbm = x_bytes * gn + w_bytes + y_bytes * (2 * gm - 1)
        w_hbm = w_bytes
    elif flow == "input_stationary":
        hbm = x_bytes + w_bytes * gp + y_bytes * (2 * gm - 1)
        w_hbm = w_bytes * gp
    else:
        raise ValueError(flow)

    bn = min(block_n, layer.c_out)
    bm = min(block_m, layer.c_in)
    bp = min(block_p, t)
    # Streamed blocks are double-buffered by the Pallas pipeline (x2);
    # the DFT operators, the in-flight spectral blocks and the psum
    # scratch are single-copy VMEM residents.  Spectral dims are Fa.
    vmem = (2 * (s * bm * bp                       # X window block
                 + cplx * fa * bn * bm             # W block (re+im)
                 + s2 * bn * bp)                   # Y output block
            + cplx * fa * bm * bp                  # X~ in flight
            + 2 * cplx * fa * bn * bp              # Y~ psum / Karatsuba
            + 2 * fa * s + 2 * s2 * fa             # DFT / IDFT operators
            ) * bytes_per_el

    had_flops = 8 * t * nnz * layer.c_in * layer.c_out
    fft_flops = (2 * 2 * fa * s * layer.c_in * t
                 * (gn if flow != "input_stationary" else 1))
    ifft_passes = 1 if flow == "output_stationary" else gm
    ifft_flops = 2 * 2 * s2 * fa * layer.c_out * t * ifft_passes
    flops = had_flops + fft_flops + ifft_flops
    return {
        "hbm_bytes": float(hbm),
        "kernel_hbm_bytes": float(w_hbm),
        "vmem_bytes": float(vmem),
        "flops": float(flops),
        "hbm_s": float(hbm) / TPU_HBM_GBPS,
        "compute_s": float(flops) / TPU_PEAK_FLOPS,
        "fits_vmem": vmem <= TPU_VMEM_BYTES,
    }
