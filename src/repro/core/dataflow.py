"""Bandwidth / on-chip-storage complexity models (paper §4, Eqs 6-13).

The paper analyses three pure dataflows for a sparse spectral conv layer —

  Flow #1  reuse kernels + partial sums, STREAM INPUT TILES
           (inputs re-loaded N/N' (pure) or N/Ns (flexible) times),
  Flow #2  reuse input tiles + partial sums, STREAM KERNELS
           (kernels re-loaded T/P' (pure) or T/Ps (flexible) times),
  Flow #3  reuse inputs + kernels, STREAM PARTIAL SUMS
           (psums written+read 2*M/M' times),

then interpolates between #1/#2 with the *streaming parameters* Ns (#kernels
resident before flushing input tiles) and Ps (#input tiles resident before
flushing kernels) — Eqs 12-13 — searched by Alg 1 (``repro.core.optimizer``).

Faithfulness notes
------------------
* Eqs 12/13 are implemented exactly as printed.  The pure-flow BRAM
  expressions (Eqs 6-8) are printed with garbled bank/depth placement in the
  source text; we implement the self-consistent reconstruction documented on
  each function (bank count x depth-overflow multiplier), which reproduces
  the paper's qualitative Fig 2: Flow #1 needs enormous BRAM counts on
  early (large-image) layers, Flow #2 few BRAMs but high traffic, Flow #3
  is never competitive.
* Data transfers are counted in 16-bit words as the paper does: spatial
  activations are real (1 word/value); spectral kernels and spectral psums
  are complex (2 words/value) — controlled by ``complex_words``.
* BRAM model: 36 Kb block = 1024 entries (paper's "memory depth 1024").

The same module also hosts the TPU re-cost of the flows used by the Pallas
kernel + mesh planner (HBM traffic / VMEM residency instead of DDR / BRAM):
see ``tpu_flow_cost``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.spectral import (halo_block_geometry, make_geometry,
                                 shard_band_rows)

BRAM_DEPTH = 1024
WORD_BYTES = 2  # 16-bit fixed point


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """Static description of one spectral conv layer.

    ``stride`` semantics (ISSUE 10): the spectral path always computes
    the stride-1 'same' output — overlap-save tiling has no native
    stride — and the executor subsamples ``y[..., ::stride, ::stride]``
    afterwards.  All tile/traffic/FLOP models therefore price the
    stride-1 problem, which is the work the kernel actually performs;
    only ``out_hw`` (and the DAG shape walker built on it) sees the
    stride.
    """

    name: str
    c_in: int       # M
    c_out: int      # N
    h_in: int
    w_in: int
    ksize: int = 3
    pad: int = 1
    stride: int = 1

    @property
    def out_hw(self) -> tuple[int, int]:
        """Post-stride output extent (the stride-1 'same' output is
        subsampled ``[::stride]`` -> ceil(h1/stride) rows survive)."""
        h1 = self.h_in + 2 * self.pad - self.ksize + 1
        w1 = self.w_in + 2 * self.pad - self.ksize + 1
        return (-(-h1 // self.stride), -(-w1 // self.stride))

    def tiles(self, fft_size: int) -> int:
        """T: number of input tiles per image (padded canvas)."""
        geo = make_geometry(self.h_in, self.w_in, self.ksize, fft_size,
                            self.pad)
        return geo.n_tiles

    def tile_size(self, fft_size: int) -> int:
        return fft_size - self.ksize + 1

    def spectral_macs(self, fft_size: int, alpha: float = 1.0) -> int:
        """Complex MACs of the (sparse) Hadamard stage (used to apportion
        the latency budget, Table 2 footnote)."""
        nnz = int(round(fft_size * fft_size / alpha))
        return self.tiles(fft_size) * nnz * self.c_in * self.c_out

    def spatial_macs(self) -> int:
        return (self.c_in * self.c_out * self.h_in * self.w_in
                * self.ksize * self.ksize)


# VGG16 conv stack (stride-1, pad-1, 3x3).  conv1_1 is omitted from dataflow
# optimization exactly as in the paper ("negligible computations").
VGG16_LAYERS: tuple[ConvLayer, ...] = (
    ConvLayer("conv1_1", 3, 64, 224, 224),
    ConvLayer("conv1_2", 64, 64, 224, 224),
    ConvLayer("conv2_1", 64, 128, 112, 112),
    ConvLayer("conv2_2", 128, 128, 112, 112),
    ConvLayer("conv3_1", 128, 256, 56, 56),
    ConvLayer("conv3_2", 256, 256, 56, 56),
    ConvLayer("conv3_3", 256, 256, 56, 56),
    ConvLayer("conv4_1", 256, 512, 28, 28),
    ConvLayer("conv4_2", 512, 512, 28, 28),
    ConvLayer("conv4_3", 512, 512, 28, 28),
    ConvLayer("conv5_1", 512, 512, 14, 14),
    ConvLayer("conv5_2", 512, 512, 14, 14),
    ConvLayer("conv5_3", 512, 512, 14, 14),
)

VGG16_OPT_LAYERS = VGG16_LAYERS[1:]


# ---------------------------------------------------------------------------
# DAG plan IR node description (ISSUE 10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """Config-level description of one node of a network DAG
    (``models.cnn.SpectralCNNConfig.graph``).

    The linear VGG16 stack is the degenerate case: a chain of 'conv'
    nodes with 'pool' nodes interleaved.  ResNet-class graphs add
    residual edges: a conv node with ``residual_from`` set adds that
    node's activation into its own output BEFORE the ReLU — fused into
    the kernel's bias+ReLU flush when the plan can (see
    ``plan.EpilogueSpec.residual``), an unfused XLA add otherwise.

    Fields:
      id:       stable node id.  For 'conv' nodes this IS the name of
                the ``ConvLayer`` in ``cfg.layers`` the node executes
                (each conv layer appears in exactly one node).
      kind:     'conv' | 'pool'.
      inputs:   ids of the main-input producer(s); always length 1
                (the DAG is a chain plus shortcut edges).  The network
                input is the reserved id 'input'.
      pool:     pooling kind for 'pool' nodes, 'max' | 'avg' (2x2,
                stride 2 — the only pooling the spatial stage does).
      residual_from: for 'conv' nodes, the id of the node whose output
                is the shortcut operand (or 'input'); None = no
                shortcut.  Shapes must match the conv's POST-stride
                output.
      relu:     apply ReLU after this conv node (default).  False for
                linear nodes such as ResNet projection shortcuts.
    """

    id: str
    kind: str = "conv"
    inputs: tuple[str, ...] = ("input",)
    pool: str = "max"
    residual_from: str | None = None
    relu: bool = True

    def __post_init__(self):
        if self.kind not in ("conv", "pool"):
            raise ValueError(f"node {self.id!r}: kind must be 'conv' or "
                             f"'pool', got {self.kind!r}")
        if self.kind == "pool" and self.pool not in ("max", "avg"):
            raise ValueError(f"node {self.id!r}: pool must be 'max' or "
                             f"'avg', got {self.pool!r}")
        if len(self.inputs) != 1:
            raise ValueError(f"node {self.id!r}: exactly one main input "
                             f"required, got {self.inputs!r}")


def _ceil(a: float, b: float) -> int:
    return int(math.ceil(a / b))


# ---------------------------------------------------------------------------
# On-chip storage (Eqs 6-8, 12) — #BRAMs
# ---------------------------------------------------------------------------

def bram_flow1(layer: ConvLayer, fft_size: int, alpha: float,
               p_par: int, n_par: int, r: int, m_par: int = 1) -> int:
    """Flow #1 (Eq 6): kernels + psums resident, input tiles stream.

    banks x depth-multiplier reconstruction:
      inputs : r*M'*P'  streaming double-buffers (1 tile deep)
      kernels: M'*N' banks, all N kernels resident
               -> depth multiplier ceil(N * K^2/alpha / (N' * 1024))
      psums  : N'*P' banks, psums of every tile of the image resident
               -> depth multiplier ceil(T * K^2 / (P' * 1024))
    """
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size)
    inp = r * m_par * p_par
    ker = m_par * n_par * max(1, _ceil(layer.c_out * k2 / alpha,
                                       n_par * BRAM_DEPTH))
    psum = n_par * p_par * max(1, _ceil(t * k2, p_par * BRAM_DEPTH))
    return inp + ker + psum


def bram_flow2(layer: ConvLayer, fft_size: int, alpha: float,
               p_par: int, n_par: int, r: int, m_par: int = 1) -> int:
    """Flow #2 (Eq 7): input tiles + psums resident, kernels stream.

      inputs : r*M'*P' banks, all T tiles resident
               -> depth multiplier ceil(T * K^2 / (P' * 1024))
      kernels: M'*N' streaming double-buffers
      psums  : N'*P' banks, N outputs for the P'-tile group resident
               -> depth multiplier ceil(N * K^2 / (N' * 1024))
    """
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size)
    inp = r * m_par * p_par * max(1, _ceil(t * k2, p_par * BRAM_DEPTH))
    ker = m_par * n_par
    psum = n_par * p_par * max(1, _ceil(layer.c_out * k2, n_par * BRAM_DEPTH))
    return inp + ker + psum


def bram_flow3(layer: ConvLayer, fft_size: int, alpha: float,
               p_par: int, n_par: int, r: int, m_par: int = 1) -> int:
    """Flow #3 (Eq 8): inputs + kernels resident, psums stream.

    Eq 8 is a min over which of (inputs, kernels) is held whole:
      (a) all T input tiles resident + kernel double-buffer
      (b) input double-buffer + all N kernels resident
    with a psum streaming buffer of N'*P' banks either way.
    """
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size)
    psum = n_par * p_par
    var_a = (r * m_par * p_par * max(1, _ceil(t * k2, p_par * BRAM_DEPTH))
             + m_par * n_par + psum)
    var_b = (r * m_par * p_par
             + m_par * n_par * max(1, _ceil(layer.c_out * k2 / alpha,
                                            n_par * BRAM_DEPTH))
             + psum)
    return min(var_a, var_b)


def bram_flexible(layer: ConvLayer, fft_size: int, alpha: float,
                  p_par: int, n_par: int, r: int,
                  ns: int, ps: int) -> int:
    """Eq 12: flexible flow with streaming parameters (Ns, Ps).

    As printed, plus the input-tile depth multiplier (Ps tiles resident
    across r replicas / P' parallel banks) which the printed equation
    folds into the bank count.
    """
    k2 = fft_size * fft_size
    inp = r * p_par * max(1, _ceil(ps * k2, p_par * BRAM_DEPTH))
    ker = n_par * max(1, _ceil(ns * k2 / alpha, n_par * BRAM_DEPTH))
    psum = n_par * p_par * max(1, _ceil(ns * ps * k2,
                                        n_par * p_par * BRAM_DEPTH))
    return inp + ker + psum


# ---------------------------------------------------------------------------
# Data transfers (Eqs 9-11, 13) — 16-bit words moved across DDR
# ---------------------------------------------------------------------------

def transfers_flow1(layer: ConvLayer, fft_size: int, alpha: float,
                    n_par: int, m_par: int = 1,
                    complex_words: int = 2) -> int:
    """Eq 9 numerator: inputs re-loaded once per N'-kernel group."""
    k2 = fft_size * fft_size
    reload_in = layer.c_out / n_par
    inp = layer.c_in * layer.h_in * layer.w_in * reload_in
    ker = layer.c_out * layer.c_in * k2 / alpha * complex_words
    out = layer.c_out * layer.h_in * layer.w_in
    return int(round(inp + ker + out))


def transfers_flow2(layer: ConvLayer, fft_size: int, alpha: float,
                    p_par: int, m_par: int = 1,
                    complex_words: int = 2) -> int:
    """Eq 10 numerator: kernels re-loaded once per P'-tile group."""
    k2 = fft_size * fft_size
    tile = layer.tile_size(fft_size)
    reload_k = (layer.h_in * layer.w_in) / (p_par * tile * tile)
    inp = layer.c_in * layer.h_in * layer.w_in
    ker = layer.c_out * layer.c_in * k2 / alpha * complex_words * reload_k
    out = layer.c_out * layer.h_in * layer.w_in
    return int(round(inp + ker + out))


def transfers_flow3(layer: ConvLayer, fft_size: int, alpha: float,
                    m_par: int = 1, complex_words: int = 2) -> int:
    """Eq 11 numerator: psums written + re-read once per input channel."""
    k2 = fft_size * fft_size
    inp = layer.c_in * layer.h_in * layer.w_in
    ker = layer.c_out * layer.c_in * k2 / alpha * complex_words
    out = (layer.c_out * layer.h_in * layer.w_in
           * 2 * (layer.c_in / m_par))
    return int(round(inp + ker + out))


def transfers_flexible(layer: ConvLayer, fft_size: int, alpha: float,
                       ns: int, ps: int, complex_words: int = 2) -> int:
    """Eq 13 numerator."""
    k2 = fft_size * fft_size
    tile = layer.tile_size(fft_size)
    inp = layer.c_in * layer.h_in * layer.w_in * (layer.c_out / ns)
    ker = (layer.c_out * layer.c_in * k2 / alpha * complex_words
           * (layer.h_in * layer.w_in) / (ps * tile * tile))
    out = layer.c_out * layer.h_in * layer.w_in
    return int(round(inp + ker + out))


def bandwidth_gbps(transfers_words: int, tau_s: float) -> float:
    """bw = #transfers / tau  (Eq at §4.2), in GB/s."""
    return transfers_words * WORD_BYTES / tau_s / 1e9


def layer_latency_budget(layers: Iterable[ConvLayer], fft_size: int,
                         alpha: float, total_tau_s: float) -> dict[str, float]:
    """tau_i = tau * CMP_i / CMP_total  (Table 2 footnote)."""
    layers = list(layers)
    cmps = {l.name: l.spectral_macs(fft_size, alpha) for l in layers}
    total = sum(cmps.values())
    return {n: total_tau_s * c / total for n, c in cmps.items()}


# ---------------------------------------------------------------------------
# TPU re-cost of the same three reuse choices (hardware adaptation)
# ---------------------------------------------------------------------------

# TPU v5e-class constants (also used by repro.roofline).
TPU_HBM_GBPS = 819e9
TPU_PEAK_FLOPS = 197e12
TPU_VMEM_BYTES = 16 * 2 ** 20   # ~16 MiB usable kernel working set
TPU_ICI_GBPS = 50e9

# Double-buffered VMEM accumulator slots the fused kernel's manual-DMA
# output path cycles through (PR 8): slot = linearized_grid_step %
# DMA_SLOTS, so an inbound psum prefetch for one step never collides
# with the previous step's write-back semaphore.  Shared by
# kernels.fused_spectral_conv (scratch allocation) and
# core.resilience.validate_plan (slot-budget invariant); lives here
# because both may not import each other.
DMA_SLOTS = 2

# Per-grid-step overhead priced into INTERPRET-mode plans, passed as
# ``tpu_fused_flow_cost(step_overhead_s=...)`` by the serving stack and
# the benchmarks.  Calibrated to ZERO: measured bucket sweeps
# (benchmarks/e2e_latency.py batch sweep on SMOKE) show interpret wall
# clock tracks the byte model's ranking — the serial windowed relayout
# dominates long before step count does — and because the predicted
# roofline times are microsecond-scale, ANY materially nonzero per-step
# price overturns byte preferences toward fewer-step windowed configs
# that are 2-3x slower on the wall clock.  Exact byte ties still
# resolve toward fewer dispatches structurally: autotune sorts on
# (predicted_s, grid_steps, hbm_bytes).  The step axis stays available
# through ``step_overhead_s`` for calibration on real hardware.
INTERPRET_STEP_S = 0.0

# The paper's three reuse choices as Pallas grid iteration orders —
# canonical name list shared by the kernels, the cost models below and
# the autotuner (core.autotune).
FLOWS = ("output_stationary", "weight_stationary", "input_stationary")

# Input-side modes of the fused kernel (kernels.fused_spectral_conv):
#   'windowed'  host materializes the [B, M, T, K, K] overlap-save
#               window tensor in HBM (one relayout pass + ~(K/t)^2
#               duplicated halo bytes), kernel streams windows;
#   'halo'      kernel reads the RAW NCHW activation via overlapping
#               (element-offset) input blocks sized bth*t + (K-t) per
#               spatial axis and gathers the windows in VMEM — no
#               windowed intermediate ever exists in HBM.
INPUT_MODES = ("windowed", "halo")

# Per-layer execution backends, in degradation-ladder order (see
# core.resilience.DEMOTION_LADDER): the fused single-pallas_call kernel,
# the 3-launch staged pipeline, and the pure-jnp einsum oracle — the
# terminal rung, which always executes.
EXEC_BACKENDS = ("fused", "staged", "einsum")


def tpu_flow_cost(layer: ConvLayer, fft_size: int, alpha: float,
                  block_n: int, block_p: int, block_m: int,
                  flow: str, batch: int = 1,
                  bytes_per_el: int = 4,
                  active_bins: int | None = None,
                  hadamard: str | None = None) -> dict[str, float]:
    """HBM traffic + VMEM residency of one spectral-Hadamard pallas_call.

    The Pallas kernel contracts input channels per frequency bin:
    ``Y[n,f,p] += W[n,m,f] X[m,f,p]`` with grid blocks (block_n x block_m x
    block_p).  The ``flow`` selects which operand stays resident across the
    grid's outermost iteration — the TPU translation of Flow #1/#2/#3:

      'weight_stationary' (Flow #1): W blocks stay in VMEM while all P
          blocks stream -> X re-read c_out/block_n times.
      'input_stationary'  (Flow #2): X blocks stay while kernel blocks
          stream -> W re-read T*batch/block_p times.
      'output_stationary' (Flow opt analogue): psums accumulate in VMEM
          across the m loop; X and W each read once per (n, p) block pair.

    Complex data: 2 real planes.  NOTE: the *staged* Pallas kernels
    stream and multiply DENSE spectral planes (pruned positions stored
    as zeros), so W traffic and FLOPs here are dense — ``alpha`` /
    ``active_bins`` are accepted for signature parity with
    ``tpu_fused_flow_cost`` (which IS sparsity-aware) and ignored.  The
    scheduled sparse kernel and the fused kernel's active-bin compaction
    are what turn compression into traffic/compute savings.
    ``hadamard`` is likewise accepted-and-ignored (the staged Hadamard
    has exactly one datapath).
    """
    del alpha, active_bins, hadamard  # dense-plane streaming only
    k2 = fft_size * fft_size
    t = layer.tiles(fft_size) * batch
    cplx = 2
    x_bytes = layer.c_in * k2 * t * cplx * bytes_per_el
    w_bytes = layer.c_out * layer.c_in * k2 * cplx * bytes_per_el
    y_bytes = layer.c_out * k2 * t * cplx * bytes_per_el

    if flow == "weight_stationary":
        hbm = (x_bytes * math.ceil(layer.c_out / block_n)
               + w_bytes + y_bytes)
    elif flow == "input_stationary":
        hbm = (x_bytes + w_bytes * math.ceil(t / block_p) + y_bytes)
    elif flow == "output_stationary":
        hbm = (x_bytes * math.ceil(layer.c_out / block_n)
               + w_bytes * math.ceil(t / block_p) + y_bytes)
    else:
        raise ValueError(flow)

    # per-grid-step working set: ONE frequency bin's blocks (the Pallas
    # grid blocks F with size 1; see kernels/spectral_hadamard.py)
    vmem = (block_m * block_p * cplx * bytes_per_el             # X block
            + block_n * block_m * cplx * bytes_per_el           # W block
            + block_n * block_p * cplx * 4)                     # f32 acc
    flops = 8 * t * k2 * layer.c_in * layer.c_out
    return {
        "hbm_bytes": float(hbm),
        "vmem_bytes": float(vmem),
        "flops": float(flops),
        "hbm_s": float(hbm) / TPU_HBM_GBPS,
        "compute_s": float(flops) / TPU_PEAK_FLOPS,
        "fits_vmem": vmem <= TPU_VMEM_BYTES,
    }


# Hadamard-stage modes of the fused kernel (kernels.fused_spectral_conv):
#   'dense'      full-K^2 kernel planes, Karatsuba GEMM;
#   'bin'        planes compacted to the Fa active bins, Karatsuba GEMM;
#   'scheduled'  Alg-2 INDEX/VALUE tables executed element-granularly.
HADAMARD_MODES = ("dense", "bin", "scheduled")

# Default Alg-2 knobs for analytic costing (paper S6.3: r = 10 replicas;
# mu ~= Eq-14 PE utilization, VGG16 measures ~0.85-0.9 — used to
# estimate schedule length T ~= nnz / mu before the schedule is built).
SCHEDULE_R = 10
SCHEDULE_MU = 0.85


def tpu_fused_flow_cost(layer: ConvLayer, fft_size: int, alpha: float,
                        block_n: int, block_p: int, block_m: int,
                        flow: str, batch: int = 1,
                        bytes_per_el: int = 4,
                        active_bins: int | None = None,
                        hadamard: str | None = None,
                        r: int = SCHEDULE_R,
                        mu: float = SCHEDULE_MU,
                        input_mode: str | None = None,
                        step_overhead_s: float = 0.0,
                        residual: str | None = None) -> dict[str, float]:
    """HBM traffic + VMEM working set of ONE fused pallas_call
    (``kernels.fused_spectral_conv``): FFT + Hadamard + IFFT (+ fused
    bias/ReLU epilogue) in a single kernel, so HBM only ever sees

      X  overlap-save windows [S, M, P]  real,  S = K^2, P = T * batch
      W  the Hadamard-stage kernel operand (planes or Alg-2 tables)
      Y  valid output tiles [S2, N, P]   real,  S2 = tile^2

    — the complex spectral intermediates X~/Y~ of the staged path
    (``tpu_flow_cost``'s x/y terms) never leave VMEM, and the post-conv
    elementwise epilogue adds no traffic at all.

    Args:
      layer, fft_size, alpha: the conv layer, tile size K and kernel
        compression ratio (nnz = K^2/alpha per kernel).
      block_n/block_p/block_m: VMEM block sizes (the paper's N'/P'/M');
        clamped to the layer dims.
      flow: grid iteration order, one of ``FLOWS``.
      batch: images per call (scales the tile count P).
      active_bins: Fa <= K^2, the compacted bin count realized by this
        layer's pruned kernels (``sparse.compacted_active_bins``); None
        means all K^2 bins.  Scales the spectral-transform dims
        (FFT/IFFT flops, spectral VMEM blocks, operator residency).
      hadamard: Hadamard-stage mode (``HADAMARD_MODES``), controlling
        the kernel-operand traffic and Hadamard MAC terms:
          None          legacy compressed-stream model: kernel bytes and
                        MACs ~ nnz (the paper's (value, index) stream),
                        kept for back-compat with pre-mode callers;
          'dense'       full K^2 planes — bytes and MACs ~ K^2;
          'bin'         compacted planes — bytes and MACs ~ Fa (what the
                        Karatsuba GEMM on active bins actually does);
          'scheduled'   Alg-2 tables — bytes ~ T*(r + 3N') words per
                        (group, channel) with T ~= nnz/mu cycles, i.e.
                        O(nnz); MACs are the HONEST one-hot-matmul
                        realization (gather/route/scatter GEMMs), which
                        exceeds the paper's element count — the mode
                        wins on bandwidth, not flops, and Alg 1 sees
                        both sides of that trade.
      r, mu: Alg-2 replica count and estimated Eq-14 utilization used
        to size the scheduled tables before the schedule exists.
      input_mode: input-side path (``INPUT_MODES``), controlling the
        X-operand traffic:
          None / 'windowed'  the host materializes the [B, M, T, K, K]
                       overlap-save window tensor: ONE relayout pass
                       (raw read + windowed write, counted once) plus
                       the kernel's window stream of T*K^2 words per
                       channel — ~(K/t)^2 more than the raw image —
                       re-read per the flow factor below;
          'halo'       the kernel reads the raw activation through
                       overlapping halo blocks (bth*t + k - 1 rows by
                       btw*t + k - 1 cols, ``halo_block_geometry``
                       split of block_p): raw-plus-halo words, re-read
                       per the same flow factor, plus the one-hot
                       gather selectors once; no materialization pass
                       exists at all.
      step_overhead_s: fixed cost per GRID STEP (dispatch + pipeline
        prologue + per-step DMA issue), added to the predicted latency
        as ``grid_steps * step_overhead_s`` (the ``step_s`` field).
        Default 0.0 keeps the pure byte/flop roofline; serving and the
        interpret-mode benchmarks pass ``INTERPRET_STEP_S`` (itself
        calibrated to zero — see its comment — but kept as the single
        knob for real-hardware calibration).  At larger batch the step
        count per image shrinks with bigger p blocks, which is exactly
        the kernel-amortization axis of the paper's reuse tradeoff.
      residual: shortcut-operand pricing for a residual-fused epilogue
        (ISSUE 10, the ShortcutFusion reuse question one operand over):
          None     no shortcut — the plain conv cost;
          'hbm'    the shortcut streams from HBM as one more kernel
                   operand in the OUTPUT layout: one Y-sized read per
                   output-block visit (once total under
                   output_stationary, once per m revisit under the RMW
                   flows, whose flush step re-sees each (n, p) block
                   gm times), plus its double-buffered VMEM block;
          'vmem'   the producer's activation is modeled as RETAINED
                   on-chip between the two kernels — zero extra HBM
                   traffic, but the full Y-sized shortcut is added to
                   the VMEM working set (the ShortcutFusion "hold the
                   shortcut" choice; it only wins while it fits).

    Batch amortization note: ``batch`` scales the tile count
    P = T * batch, so every per-whole-call byte term that does NOT
    scale with P — kernel planes / Alg-2 tables (ws streams them ONCE
    per call, i.e. once per batch, not once per image) and the one-off
    selector/materialization bytes — is amortized over the batch in the
    returned ``per_image_*`` fields.  That is SPEC2's kernel-reuse
    prediction: per-image fused cost is non-increasing in batch (along
    the doubling bucket chain; see ``tests/test_batch_amortized.py``).

    Returns a dict with ``hbm_bytes``, ``kernel_hbm_bytes`` (the
    W-operand share of hbm_bytes, re-read factors included),
    ``input_hbm_bytes`` (the X-operand share: stream * re-read factor
    + the one-off materialization / gather-selector bytes),
    ``had_flops`` (Hadamard stage only), ``flops``, ``vmem_bytes``,
    ``hbm_s``/``compute_s`` roofline times, ``serial_s``,
    ``fits_vmem``, plus (PR 8) ``batch``, ``grid_steps`` (= gn*gm*gp,
    the pallas grid size — the tuner's dispatch-overhead tie-break),
    ``step_s`` and the batch-normalized ``per_image_hbm_bytes`` /
    ``per_image_kernel_hbm_bytes`` / ``per_image_s``.  ``serial_s`` is the windowed path's materialization
    pass: an XLA relayout op that runs BEFORE the pallas_call and
    cannot overlap it, so its time adds to the roofline max instead of
    hiding under it (``serial_s + max(hbm_s, compute_s)`` is the
    honest per-layer latency; the halo path has serial_s = 0 — its
    gather selectors stream through the kernel's own pipeline).

    Re-read factors follow the grid iteration order of each flow:

      'output_stationary': psums in VMEM scratch; X re-read per n block,
          W re-read per p block, Y written exactly once.
      'weight_stationary' (Flow #1, reuse kernels): W read once; X
          re-read per n block; real psum tiles RMW'd once per m block
          (2*gm - 1 passes).
      'input_stationary'  (Flow #2, reuse activations): X read once; W
          re-read per p block; same psum RMW traffic.
    """
    if hadamard is not None and hadamard not in HADAMARD_MODES:
        raise ValueError(f"hadamard must be None or one of "
                         f"{HADAMARD_MODES}, got {hadamard!r}")
    if input_mode is not None and input_mode not in INPUT_MODES:
        raise ValueError(f"input_mode must be None or one of "
                         f"{INPUT_MODES}, got {input_mode!r}")
    if residual not in (None, "hbm", "vmem"):
        raise ValueError(f"residual must be None, 'hbm' or 'vmem', "
                         f"got {residual!r}")
    halo = input_mode == "halo"
    k2 = fft_size * fft_size
    tile = layer.tile_size(fft_size)
    t = layer.tiles(fft_size) * batch
    cplx = 2
    nnz = max(1, int(round(k2 / alpha)))
    fa = k2 if active_bins is None else max(1, min(int(active_bins), k2))
    gn = max(1, _ceil(layer.c_out, block_n))
    gm = max(1, _ceil(layer.c_in, block_m))
    gp = max(1, _ceil(t, block_p))
    bn = min(block_n, layer.c_out)
    bm = min(block_m, layer.c_in)
    bp = min(block_p, t)
    s = k2                   # overlap-save: K x K input windows
    s2 = tile * tile         # only the valid rows are written back
    raw_words = layer.c_in * layer.h_in * layer.w_in * batch
    if halo:
        geo = make_geometry(layer.h_in, layer.w_in, layer.ksize,
                            fft_size, layer.pad)
        hg = halo_block_geometry(geo, block_p)
        bp = hg.block_tiles          # effective tile block of the split
        # the kernel's actual p grid: one step per (image, block-row,
        # block-col) — NOT ceil(T / bt), which undercounts whenever the
        # halo split pads the tile grid.
        gp = max(1, batch * hg.n_blocks)
        # raw-plus-halo words: every block reads its bth*t+k-1 x
        # btw*t+k-1 clamped raw region; overlap between neighbours is
        # the k-1 halo only (vs the windowed tensor's ~(K/t)^2 full
        # duplication), and nothing is materialized first.
        x_stream = (layer.c_in * batch * hg.n_blocks * hg.rh * hg.rw
                    * bytes_per_el)
        # One-hot selector traffic is residency-aware: a selector block
        # is refetched only when its block index changes between
        # consecutive grid steps, so a single-block axis (nbh == 1 /
        # nbw == 1 — the btw-first split's common case) stays resident
        # for the whole kernel; otherwise it re-streams with the p
        # steps (upper bound: every p step, times the n revisits).
        sel_reread = {"output_stationary": gn * gp,
                      "weight_stationary": gn * gm * gp,
                      "input_stationary": gp}.get(flow, gp)
        gr_words = hg.bth * fft_size * hg.rh
        gc_words = hg.btw * fft_size * hg.rw
        x_once = ((gr_words * (1 if hg.nbh == 1 else sel_reread))
                  + (gc_words * (1 if hg.nbw == 1 else sel_reread))
                  ) * bytes_per_el
    else:
        # windowed: the kernel streams the host-materialized window
        # tensor (T * K^2 words/channel); the relayout pass that builds
        # it (raw read + windowed write) happens once, outside the
        # kernel, and is honest HBM traffic of this input path.
        x_stream = layer.c_in * s * t * bytes_per_el
        x_once = (raw_words + layer.c_in * s * t) * bytes_per_el
    y_bytes = layer.c_out * s2 * t * bytes_per_el

    t_cyc = max(nnz, _ceil(nnz, mu))     # schedule length estimate
    if hadamard is None:                 # legacy compressed stream
        w_bytes = layer.c_out * layer.c_in * nnz * cplx * bytes_per_el
        had_flops = 8 * t * nnz * layer.c_in * layer.c_out
    elif hadamard == "dense":
        w_bytes = layer.c_out * layer.c_in * k2 * cplx * bytes_per_el
        had_flops = 8 * t * k2 * layer.c_in * layer.c_out
    elif hadamard == "bin":
        w_bytes = layer.c_out * layer.c_in * fa * cplx * bytes_per_el
        had_flops = 8 * t * fa * layer.c_in * layer.c_out
    else:                                # scheduled: Alg-2 tables
        mp = gm * bm
        w_bytes = gn * mp * t_cyc * (r + 3 * bn) * bytes_per_el
        # One-hot-matmul realization, per (group, channel, cycle):
        #   p-dependent  gather 2*r*Fa + route 2*N'*r + cmul 6*N'
        #                + scatter 2*N'*Fa  (per tile element),
        #   p-independent  scatter one-hot o = sel @ gather,
        #                2*N'*r*Fa, recomputed per p block.
        per_cyc_p = 2 * r * fa + 2 * bn * r + 6 * bn + 2 * bn * fa
        per_cyc_fix = 2 * bn * r * fa
        had_flops = gn * mp * t_cyc * (per_cyc_p * t + per_cyc_fix * gp)

    if flow == "output_stationary":
        x_hbm = x_stream * gn + x_once
        hbm = x_hbm + w_bytes * gp + y_bytes
        w_hbm = w_bytes * gp
    elif flow == "weight_stationary":
        x_hbm = x_stream * gn + x_once
        hbm = x_hbm + w_bytes + y_bytes * (2 * gm - 1)
        w_hbm = w_bytes
    elif flow == "input_stationary":
        x_hbm = x_stream + x_once
        hbm = x_hbm + w_bytes * gp + y_bytes * (2 * gm - 1)
        w_hbm = w_bytes * gp
    else:
        raise ValueError(flow)

    # Shortcut operand of a residual-fused epilogue: Y-layout blocks,
    # consumed at the flush step of each output block.  Under the RMW
    # flows the flush dimension is innermost, so the block is refetched
    # on every m revisit; output_stationary sees each (n, p) exactly
    # once.  'vmem' instead retains the producer's full activation
    # on-chip (zero HBM, Y-sized VMEM residency).
    sc_hbm = 0.0
    sc_vmem = 0.0
    if residual == "hbm":
        sc_reread = 1 if flow == "output_stationary" else gm
        sc_hbm = float(y_bytes * sc_reread)
        sc_vmem = float(2 * s2 * bn * bp * bytes_per_el)
        x_hbm += sc_hbm
        hbm += sc_hbm
    elif residual == "vmem":
        sc_vmem = float(y_bytes)

    # Streamed blocks are double-buffered by the Pallas pipeline (x2);
    # the DFT operators, the in-flight spectral blocks and the psum
    # scratch are single-copy VMEM residents.  Spectral dims are Fa.
    if hadamard == "scheduled":
        w_block = bm * t_cyc * (r + 3 * bn)       # table block
        flight = bm * (r * fa + bn * r + bn * fa  # one-hot g/s/o
                       + 2 * r * bp + 2 * bn * bp)  # replicas + PE in
    else:
        w_block = cplx * fa * bn * bm             # W plane block
        flight = 0
    if halo:
        # raw halo block instead of a window block; the gathered
        # windows [S, bm, bt] live in VMEM registers in flight, as do
        # this block's one-hot selectors.
        x_block = bm * hg.rh * hg.rw
        flight += (s * bm * bp
                   + hg.bth * fft_size * hg.rh
                   + hg.btw * fft_size * hg.rw)
    else:
        x_block = s * bm * bp
    vmem = (2 * (x_block                          # X block (windows/raw)
                 + w_block)
            + DMA_SLOTS * s2 * bn * bp            # manual-DMA Y staging
            + cplx * fa * bm * bp                 # X~ in flight
            + 2 * cplx * fa * bn * bp             # Y~ psum / Karatsuba
            + flight
            + 2 * fa * s + 2 * s2 * fa            # DFT / IDFT operators
            ) * bytes_per_el + sc_vmem            # retained / staged shortcut

    refft = gn if flow != "input_stationary" else 1
    fft_flops = 2 * 2 * fa * s * layer.c_in * t * refft
    if halo:
        # the in-kernel gather's two one-hot matmuls, recomputed
        # whenever the block's FFT is
        gather_macs = (hg.n_blocks
                       * (hg.bth * fft_size * hg.rh * hg.rw
                          + hg.bth * fft_size * hg.btw * fft_size
                          * hg.rw))
        fft_flops += 2 * gather_macs * layer.c_in * batch * refft
    ifft_passes = 1 if flow == "output_stationary" else gm
    ifft_flops = 2 * 2 * s2 * fa * layer.c_out * t * ifft_passes
    flops = had_flops + fft_flops + ifft_flops
    serial = 0 if halo else x_once      # windowed relayout pass: serial
    grid_steps = gn * gm * gp
    step_s = float(grid_steps) * float(step_overhead_s)
    hbm_s = float(hbm - serial) / TPU_HBM_GBPS
    serial_s = float(serial) / TPU_HBM_GBPS
    compute_s = float(flops) / TPU_PEAK_FLOPS
    total_s = serial_s + step_s + max(hbm_s, compute_s)
    return {
        "hbm_bytes": float(hbm),
        "kernel_hbm_bytes": float(w_hbm),
        "input_hbm_bytes": float(x_hbm),
        "input_mode": "halo" if halo else "windowed",
        "had_flops": float(had_flops),
        "vmem_bytes": float(vmem),
        "flops": float(flops),
        "hbm_s": hbm_s,
        "serial_s": serial_s,
        "compute_s": compute_s,
        "fits_vmem": vmem <= TPU_VMEM_BYTES,
        # --- residual-shortcut pricing (ISSUE 10) ---------------------
        "residual": residual,
        "shortcut_hbm_bytes": sc_hbm,
        "shortcut_vmem_bytes": sc_vmem,
        # --- batch-as-an-Alg-1-axis fields (PR 8) ---------------------
        "batch": int(batch),
        "grid_steps": float(grid_steps),
        "step_s": step_s,
        "per_image_hbm_bytes": float(hbm) / batch,
        "per_image_kernel_hbm_bytes": float(w_hbm) / batch,
        "per_image_s": total_s / batch,
    }


# ---------------------------------------------------------------------------
# Two-level Alg-1: per-chip HBM + ICI bytes for a sharded layer (ISSUE 9)
# ---------------------------------------------------------------------------

# Per-layer partitioning strategies over a 1-D device mesh of D shards:
#   'replicate'  every chip runs the whole layer (terminal rung of the
#                sharded degradation ladder; also the only legal choice
#                when neither split is feasible) — no ICI traffic, no
#                per-chip savings;
#   'channel'    split the input channels M: shard d owns c_in/D
#                channels and the matching kernel slice, computes a
#                PARTIAL conv (epilogue deferred) and ring-all-reduces
#                the psum — the TPU translation of the paper's Flow-#3
#                psum streaming, with the stream crossing ICI instead
#                of DDR.  Feasible iff D divides c_in;
#   'spatial'    split the tile rows: shard d owns a band of
#                ceil(n_tiles_h/D) tile rows and receives the k-1 raw
#                halo rows of its top neighbour over ICI before the
#                conv (the PR-5 in-kernel halo gather's geometry, one
#                level up).  Feasible iff the tile grid has at least
#                one tile row per shard.
SHARD_STRATEGIES = ("replicate", "channel", "spatial")


def shard_local_layer(layer: ConvLayer, fft_size: int, n_shards: int,
                      strategy: str) -> "ConvLayer | None":
    """The shard-local sub-problem of ``layer`` as a ConvLayer, or None
    when ``strategy`` is infeasible at ``n_shards``.

    The returned layer is what ONE chip computes — feed it to
    ``tpu_fused_flow_cost`` for the per-chip level of the two-level
    model.  'channel' shrinks c_in; 'spatial' shrinks h_in to
    ``tr*t - pad`` (the unique height whose ``make_geometry`` tile grid
    is exactly the shard's tr = ceil(n_tiles_h/D) tile rows — the band's
    k-1 in-buffer halo rows are ICI-accounted, not HBM-re-modeled).
    """
    if strategy not in SHARD_STRATEGIES:
        raise ValueError(f"strategy must be one of {SHARD_STRATEGIES}, "
                         f"got {strategy!r}")
    if strategy == "replicate" or n_shards <= 1:
        return layer
    if strategy == "channel":
        if layer.c_in % n_shards:
            return None
        return dataclasses.replace(layer, c_in=layer.c_in // n_shards)
    geo = make_geometry(layer.h_in, layer.w_in, layer.ksize, fft_size,
                        layer.pad)
    if n_shards > geo.n_tiles_h:
        return None
    tr = shard_band_rows(geo, n_shards)
    return dataclasses.replace(layer, h_in=tr * geo.tile - layer.pad)


def shard_ici_bytes(layer: ConvLayer, n_shards: int, strategy: str,
                    batch: int = 1, bytes_per_el: int = 4,
                    residual: bool = False) -> float:
    """Modeled inter-chip bytes of one sharded layer forward.

      'replicate'  0 — nothing crosses ICI.
      'channel'    ring all-reduce of the [B, N, H_out, W_out] psum:
                   each chip sends (and receives) 2*(D-1)/D of the
                   output bytes (reduce-scatter + all-gather).
      'spatial'    each interior boundary moves exactly the k-1 raw
                   halo rows one hop down: (D-1) * (k-1) * W * M * B
                   words (outputs stay resident — bands concatenate
                   only at the consumer, which is itself band-sharded).

    ``residual`` (ISSUE 10): a residual add on a non-replicated layer
    moves the Y-sized shortcut into the shards' layout — one more
    (D-1)/D all-gather-shaped term on top of the strategy's own
    collective (replicate pays nothing: the shortcut is already whole
    on every chip).
    """
    if strategy == "replicate" or n_shards <= 1:
        return 0.0
    h_out = layer.h_in + 2 * layer.pad - layer.ksize + 1
    w_out = layer.w_in + 2 * layer.pad - layer.ksize + 1
    out_bytes = layer.c_out * h_out * w_out * batch * bytes_per_el
    sc = ((n_shards - 1) / n_shards * out_bytes) if residual else 0.0
    if strategy == "channel":
        return 2.0 * (n_shards - 1) / n_shards * out_bytes + sc
    if strategy == "spatial":
        return float((n_shards - 1) * (layer.ksize - 1) * layer.w_in
                     * layer.c_in * batch * bytes_per_el) + sc
    raise ValueError(f"strategy must be one of {SHARD_STRATEGIES}, "
                     f"got {strategy!r}")


def tpu_sharded_flow_cost(layer: ConvLayer, fft_size: int, alpha: float,
                          block_n: int, block_p: int, block_m: int,
                          flow: str, *, n_shards: int, strategy: str,
                          batch: int = 1, bytes_per_el: int = 4,
                          active_bins: int | None = None,
                          hadamard: str | None = None,
                          r: int = SCHEDULE_R, mu: float = SCHEDULE_MU,
                          input_mode: str | None = None,
                          step_overhead_s: float = 0.0,
                          residual: str | None = None
                          ) -> "dict[str, float] | None":
    """Two-level Alg-1 cost: ONE CHIP's ``tpu_fused_flow_cost`` of the
    shard-local sub-problem, plus the ICI collective priced at
    ``TPU_ICI_GBPS``.  Returns None when ``strategy`` is infeasible at
    ``n_shards`` (channel: D must divide c_in; spatial: at least one
    tile row per shard).

    Adds to the per-chip cost dict:
      'strategy' / 'n_shards'   the partitioning priced,
      'per_chip_hbm_bytes'      alias of the local 'hbm_bytes',
      'ici_bytes' / 'ici_s'     the collective's bytes and serialized
                                time (ICI does not overlap the fused
                                kernel today: channel's all-reduce
                                waits on the full psum, spatial's halo
                                exchange precedes the conv),
      'sharded_s'               the two-level objective
                                per-chip predicted + ici_s.
    """
    local = shard_local_layer(layer, fft_size, n_shards, strategy)
    if local is None:
        return None
    c = tpu_fused_flow_cost(local, fft_size, alpha, block_n, block_p,
                            block_m, flow, batch=batch,
                            bytes_per_el=bytes_per_el,
                            active_bins=active_bins, hadamard=hadamard,
                            r=r, mu=mu, input_mode=input_mode,
                            step_overhead_s=step_overhead_s,
                            residual=residual)
    ici = shard_ici_bytes(layer, n_shards, strategy, batch, bytes_per_el,
                          residual=residual is not None)
    chip_s = c["serial_s"] + c["step_s"] + max(c["hbm_s"], c["compute_s"])
    c.update({
        "strategy": strategy,
        "n_shards": int(n_shards),
        "per_chip_hbm_bytes": c["hbm_bytes"],
        "ici_bytes": float(ici),
        "ici_s": float(ici) / TPU_ICI_GBPS,
        "sharded_s": chip_s + float(ici) / TPU_ICI_GBPS,
    })
    return c
