"""Sparse spectral kernels: uniform per-kernel pruning + representations.

The paper consumes kernels pruned by SPEC2's ADMM method [16]: every
K x K spectral kernel keeps exactly K^2 / alpha non-zeros (uniform
compression ratio alpha across all kernels, which removes load imbalance).
We emulate that property two ways:

* ``prune_magnitude`` — keep the K^2/alpha largest-|.|  entries per (n, m)
  kernel (an ADMM run converges to (approximately) this projection, so the
  resulting *index distribution* is magnitude-shaped, concentrated at low
  frequencies — matching the paper's observation that lowest-index-first
  scheduling works well on conv5_x layers);
* ``prune_random`` — K^2/alpha uniformly random positions per kernel
  (the robustness study of Fig 10).

A pruned kernel set is stored both dense-masked (for the jnp/Pallas compute
paths) and in the (val, index) stream format of §5.3 that the scheduler
consumes.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def per_layer_alphas(alpha: float | Sequence[float], n_layers: int
                     ) -> tuple[float, ...]:
    """Resolve a compression spec to one alpha per layer.

    The paper prunes layers non-uniformly (early layers tolerate less
    compression than conv5_x); a scalar broadcasts, a sequence must match
    the layer count exactly.
    """
    if isinstance(alpha, (int, float)):
        alphas = (float(alpha),) * n_layers
    else:
        alphas = tuple(float(a) for a in alpha)
        if len(alphas) != n_layers:
            raise ValueError(
                f"per-layer alpha needs {n_layers} entries, "
                f"got {len(alphas)}")
    if any(a < 1.0 for a in alphas):
        raise ValueError(f"alpha must be >= 1, got {alphas}")
    return alphas


class SparseSpectralKernels(NamedTuple):
    """Pruned spectral kernels for one layer.

    values:  complex64 [N, M, K, K] — dense with zeros at pruned positions.
    mask:    bool      [N, M, K, K]
    indices: int32     [N, M, nnz]  — flattened freq indices (row-major u*K+v),
                                      sorted ascending per kernel.
    alpha:   compression ratio (K^2 / nnz).
    active_bins: host numpy int array of freq bins non-zero in ANY kernel
                 (precomputed at prune time so forward passes never pull
                 the mask back from device), or None.
    """

    values: Array
    mask: Array
    indices: Array
    alpha: float
    active_bins: np.ndarray | None = None

    @property
    def n_out(self) -> int:
        return self.values.shape[0]

    @property
    def n_in(self) -> int:
        return self.values.shape[1]

    @property
    def fft_size(self) -> int:
        return self.values.shape[2]

    @property
    def nnz(self) -> int:
        return self.indices.shape[2]


def _finalize(w_f: Array, mask: np.ndarray, alpha: float
              ) -> SparseSpectralKernels:
    n, m, K, _ = w_f.shape
    nnz = int(mask[0, 0].sum())
    flat = mask.reshape(n, m, K * K)
    idx = np.argsort(~flat, axis=-1, kind="stable")[..., :nnz]
    idx = np.sort(idx, axis=-1)
    return SparseSpectralKernels(
        values=jnp.asarray(w_f) * jnp.asarray(mask),
        mask=jnp.asarray(mask),
        indices=jnp.asarray(idx, jnp.int32),
        alpha=alpha,
        active_bins=np.flatnonzero(mask.any(axis=(0, 1)).reshape(-1)))


def prune_magnitude(w_f: Array, alpha: float) -> SparseSpectralKernels:
    """Keep the K^2/alpha largest-magnitude entries of each (n, m) kernel."""
    n, m, K, _ = w_f.shape
    nnz = max(1, int(round(K * K / alpha)))
    mag = np.abs(np.asarray(w_f)).reshape(n, m, K * K)
    order = np.argsort(-mag, axis=-1, kind="stable")
    mask = np.zeros((n, m, K * K), bool)
    np.put_along_axis(mask, order[..., :nnz], True, axis=-1)
    return _finalize(w_f, mask.reshape(n, m, K, K), K * K / nnz)


def prune_random(w_f: Array, alpha: float, seed: int = 0
                 ) -> SparseSpectralKernels:
    """Keep K^2/alpha uniformly-random positions per kernel (Fig 10)."""
    n, m, K, _ = w_f.shape
    nnz = max(1, int(round(K * K / alpha)))
    rng = np.random.default_rng(seed)
    scores = rng.random((n, m, K * K))
    order = np.argsort(scores, axis=-1)
    mask = np.zeros((n, m, K * K), bool)
    np.put_along_axis(mask, order[..., :nnz], True, axis=-1)
    return _finalize(w_f, mask.reshape(n, m, K, K), K * K / nnz)


def compacted_active_bins(sk: SparseSpectralKernels, *,
                          pad_to: int = 8,
                          dense_threshold: float = 1.0
                          ) -> np.ndarray | None:
    """Frequency bins the fused Hadamard GEMM must touch, or None.

    Returns the union of bins non-zero in ANY kernel, padded to a
    multiple of ``pad_to`` rows (hardware sublane granularity; pad bins
    carry all-zero operator rows / kernel planes so they contribute
    nothing).  Returns None — dense fallback — when the padded count is
    >= ``dense_threshold`` * K^2, i.e. when nnz ~= K^2 and compaction
    buys nothing.
    """
    f = sk.fft_size * sk.fft_size
    active = sk.active_bins
    if active is None:
        active = np.flatnonzero(
            np.asarray(sk.mask).any(axis=(0, 1)).reshape(f))
    active = np.asarray(active, np.int64)
    n_pad = -len(active) % pad_to
    if len(active) + n_pad >= dense_threshold * f:
        return None
    if n_pad:
        spare = np.setdiff1d(np.arange(f), active)[:n_pad]
        active = np.sort(np.concatenate([active, spare]))
    return active.astype(np.int64)


def compact_planes(sk: SparseSpectralKernels,
                   active: np.ndarray | None) -> tuple[Array, Array]:
    """Kernel planes for the fused kernel: complex [N, M, K, K] ->
    (re, im) f32 [Fa, N, M], rows restricted to ``active`` bins (all K^2
    bins when active is None)."""
    n, m, K, _ = sk.values.shape
    f = K * K
    flat = sk.values.reshape(n, m, f)
    if active is not None:
        flat = flat[..., np.asarray(active)]
    wr = jnp.transpose(flat.real, (2, 0, 1)).astype(jnp.float32)
    wi = jnp.transpose(flat.imag, (2, 0, 1)).astype(jnp.float32)
    return wr, wi


def sparse_hadamard_reference(x_f: Array, sk: SparseSpectralKernels) -> Array:
    """Oracle for the sparse Hadamard stage: masked dense einsum (Eq 3)."""
    return jnp.einsum("bmtuv,nmuv->bntuv", x_f, sk.values)


def kernel_index_matrix(sk: SparseSpectralKernels, m: int,
                        group: slice) -> np.ndarray:
    """The scheduler's input: matrix M of shape [N', nnz] (§5.3) whose row
    n holds the sorted non-zero freq indices of kernel (n, m)."""
    return np.asarray(sk.indices[group, m, :])
