"""Sparse spectral kernels: uniform per-kernel pruning + representations.

The paper consumes kernels pruned by SPEC2's ADMM method [16]: every
K x K spectral kernel keeps exactly K^2 / alpha non-zeros (uniform
compression ratio alpha across all kernels, which removes load imbalance).
We emulate that property two ways:

* ``prune_magnitude`` — keep the K^2/alpha largest-|.|  entries per (n, m)
  kernel (an ADMM run converges to (approximately) this projection, so the
  resulting *index distribution* is magnitude-shaped, concentrated at low
  frequencies — matching the paper's observation that lowest-index-first
  scheduling works well on conv5_x layers);
* ``prune_random`` — K^2/alpha uniformly random positions per kernel
  (the robustness study of Fig 10).

A pruned kernel set is stored both dense-masked (for the jnp/Pallas compute
paths) and in the (val, index) stream format of §5.3 that the scheduler
consumes.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class SparseSpectralKernels(NamedTuple):
    """Pruned spectral kernels for one layer.

    values:  complex64 [N, M, K, K] — dense with zeros at pruned positions.
    mask:    bool      [N, M, K, K]
    indices: int32     [N, M, nnz]  — flattened freq indices (row-major u*K+v),
                                      sorted ascending per kernel.
    alpha:   compression ratio (K^2 / nnz).
    active_bins: host numpy int array of freq bins non-zero in ANY kernel
                 (precomputed at prune time so forward passes never pull
                 the mask back from device), or None.
    """

    values: Array
    mask: Array
    indices: Array
    alpha: float
    active_bins: np.ndarray | None = None

    @property
    def n_out(self) -> int:
        return self.values.shape[0]

    @property
    def n_in(self) -> int:
        return self.values.shape[1]

    @property
    def fft_size(self) -> int:
        return self.values.shape[2]

    @property
    def nnz(self) -> int:
        return self.indices.shape[2]


def _finalize(w_f: Array, mask: np.ndarray, alpha: float
              ) -> SparseSpectralKernels:
    n, m, K, _ = w_f.shape
    nnz = int(mask[0, 0].sum())
    flat = mask.reshape(n, m, K * K)
    idx = np.argsort(~flat, axis=-1, kind="stable")[..., :nnz]
    idx = np.sort(idx, axis=-1)
    return SparseSpectralKernels(
        values=jnp.asarray(w_f) * jnp.asarray(mask),
        mask=jnp.asarray(mask),
        indices=jnp.asarray(idx, jnp.int32),
        alpha=alpha,
        active_bins=np.flatnonzero(mask.any(axis=(0, 1)).reshape(-1)))


def prune_magnitude(w_f: Array, alpha: float) -> SparseSpectralKernels:
    """Keep the K^2/alpha largest-magnitude entries of each (n, m) kernel."""
    n, m, K, _ = w_f.shape
    nnz = max(1, int(round(K * K / alpha)))
    mag = np.abs(np.asarray(w_f)).reshape(n, m, K * K)
    order = np.argsort(-mag, axis=-1, kind="stable")
    mask = np.zeros((n, m, K * K), bool)
    np.put_along_axis(mask, order[..., :nnz], True, axis=-1)
    return _finalize(w_f, mask.reshape(n, m, K, K), K * K / nnz)


def prune_random(w_f: Array, alpha: float, seed: int = 0
                 ) -> SparseSpectralKernels:
    """Keep K^2/alpha uniformly-random positions per kernel (Fig 10)."""
    n, m, K, _ = w_f.shape
    nnz = max(1, int(round(K * K / alpha)))
    rng = np.random.default_rng(seed)
    scores = rng.random((n, m, K * K))
    order = np.argsort(scores, axis=-1)
    mask = np.zeros((n, m, K * K), bool)
    np.put_along_axis(mask, order[..., :nnz], True, axis=-1)
    return _finalize(w_f, mask.reshape(n, m, K, K), K * K / nnz)


def sparse_hadamard_reference(x_f: Array, sk: SparseSpectralKernels) -> Array:
    """Oracle for the sparse Hadamard stage: masked dense einsum (Eq 3)."""
    return jnp.einsum("bmtuv,nmuv->bntuv", x_f, sk.values)


def kernel_index_matrix(sk: SparseSpectralKernels, m: int,
                        group: slice) -> np.ndarray:
    """The scheduler's input: matrix M of shape [N', nnz] (§5.3) whose row
    n holds the sorted non-zero freq indices of kernel (n, m)."""
    return np.asarray(sk.indices[group, m, :])
