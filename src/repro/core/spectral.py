"""Spectral (FFT-domain) convolution with tiling and Overlap-and-Add.

This is the mathematical substrate of the paper (§3, Eqs 3-4): spatial
convolution is replaced by

    1. tile the input into h' x w' tiles,
    2. zero-pad each tile to K x K  (K = h' + k - 1)  and 2-D FFT it,
    3. Hadamard-multiply with the K x K spectral kernels and accumulate
       over input channels  (Eq 3),
    4. inverse FFT each output tile,
    5. Overlap-and-Add (OaA) the output tiles (adjacent tiles overlap by
       k - 1 pixels)  (Eq 4).

Everything here is pure JAX and serves both as the production forward path
on CPU/TPU and as the oracle for the Pallas kernels in ``repro.kernels``.

Two tilings are provided.  The paper's OaA decomposition
(``extract_tiles`` / ``overlap_add``) sums overlapping K x K output
tiles, so per-tile outputs are *partial* until OaA completes.  The
production forward path instead uses the dual overlap-save decomposition
(``extract_tiles_overlapping`` / ``assemble_valid_tiles``): overlapping
K x K *input* windows whose t x t valid outputs are complete — which is
what lets the fused Pallas kernel apply bias + ReLU inside its flush
step (DESIGN.md adaptation note 5).  For un-pruned kernels the two are
numerically identical (both equal ``spatial_conv2d``).

Conventions
-----------
* CNN "convolution" is cross-correlation; we FLIP the spatial kernel before
  the FFT so that the spectral Hadamard product implements correlation.
* Activations are NCHW: ``x[b, c, h, w]``; kernels ``w[n, m, k, k]``
  (out-channels, in-channels, kh, kw) — the paper's notation.
* Only stride-1 convolutions are tiled spectrally (VGG16 uses stride 1
  everywhere in its conv stack); pooling happens in the spatial domain.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class SpectralGeometry(NamedTuple):
    """Static geometry of a tiled spectral convolution."""

    fft_size: int        # K
    tile: int            # h' = w' = K - k + 1
    ksize: int           # spatial kernel size k
    pad: int             # spatial 'same' padding p (VGG16: 1)
    h_in: int            # input spatial height (pre-padding)
    w_in: int
    n_tiles_h: int       # tiles along H after padding to a multiple of h'
    n_tiles_w: int
    h_pad: int           # padded input size = n_tiles_h * tile
    w_pad: int
    # Rows of TOP halo already PRESENT in the input (sharded bands):
    # the first pre_halo_h input rows are a neighbour shard's bottom
    # rows (or explicit zeros on shard 0), so overlap-save extraction
    # zero-pads only the remaining k-1-pre_halo_h halo rows and every
    # H-axis window/gather coordinate shifts down by pre_halo_h.
    # 0 (the default, and the only value `make_geometry` emits) is the
    # single-device geometry — all formulas reduce to their PR-5 form.
    pre_halo_h: int = 0

    @property
    def n_tiles(self) -> int:
        return self.n_tiles_h * self.n_tiles_w


def make_geometry(h_in: int, w_in: int, ksize: int, fft_size: int,
                  pad: int | None = None) -> SpectralGeometry:
    tile = fft_size - ksize + 1
    if tile <= 0:
        raise ValueError(f"fft_size {fft_size} too small for kernel {ksize}")
    if ksize - 1 > tile:
        raise ValueError("OaA decomposition requires k - 1 <= tile size")
    if pad is None:
        pad = (ksize - 1) // 2  # 'same' for odd kernels
    # Tile the input padded by at least `pad` on the bottom/right so the
    # cropped 'same' output never reads past the tiled canvas.
    n_th = -(-(h_in + pad) // tile)
    n_tw = -(-(w_in + pad) // tile)
    return SpectralGeometry(fft_size, tile, ksize, pad, h_in, w_in,
                            n_th, n_tw, n_th * tile, n_tw * tile)


# ---------------------------------------------------------------------------
# Kernel transform
# ---------------------------------------------------------------------------

def spectral_kernel(w: Array, fft_size: int) -> Array:
    """Spatial kernel [N, M, k, k] -> spectral kernel [N, M, K, K] complex.

    The kernel is flipped (correlation -> convolution) and zero-padded to
    K x K before the FFT.  This is done once, offline, exactly as the paper
    stores pre-transformed spectral kernels in DDR.
    """
    k = w.shape[-1]
    w = w[..., ::-1, ::-1]
    w = jnp.pad(w, [(0, 0)] * (w.ndim - 2) + [(0, fft_size - k)] * 2)
    return jnp.fft.fft2(w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Input tiling / output OaA
# ---------------------------------------------------------------------------

def extract_tiles(x: Array, geo: SpectralGeometry) -> Array:
    """[B, M, H, W] -> [B, M, T, h', w']  (T = n_tiles, row-major)."""
    b, m = x.shape[:2]
    x = jnp.pad(x, ((0, 0), (0, 0),
                    (0, geo.h_pad - geo.h_in), (0, geo.w_pad - geo.w_in)))
    x = x.reshape(b, m, geo.n_tiles_h, geo.tile, geo.n_tiles_w, geo.tile)
    x = x.transpose(0, 1, 2, 4, 3, 5)
    return x.reshape(b, m, geo.n_tiles, geo.tile, geo.tile)


def extract_tiles_overlapping(x: Array, geo: SpectralGeometry) -> Array:
    """[B, M, H, W] -> [B, M, T, K, K] overlap-save input tiles.

    Overlap-save (a.k.a. overlap-discard) is the dual of OaA: instead of
    disjoint h' x h' tiles whose K x K full-conv outputs are summed, take
    *overlapping* K x K input windows with stride h' starting at offset
    -(k-1).  The K-point circular convolution of such a window with the
    (flipped, K-padded) kernel is wraparound-free at output rows k-1..K-1,
    and those t x t = h' x h' valid outputs are exactly the full-conv
    canvas block at (i*h', j*h') — **complete**, with no cross-tile
    additions pending.  That is what lets the fused kernel apply a
    non-linear epilogue (bias + ReLU) inside its flush step: every value
    it writes is a finished pre-activation.  The price is re-reading the
    k-1-pixel halo between neighbouring windows ((K/h')^2 input traffic
    instead of 1x) — the same duplicated-halo DMA the paper's FPGA input
    loader performs.
    """
    b, m = x.shape[:2]
    ov = geo.ksize - 1
    x = jnp.pad(x, ((0, 0), (0, 0),
                    (ov - geo.pre_halo_h,
                     geo.h_pad + geo.pre_halo_h - geo.h_in),
                    (ov, geo.w_pad - geo.w_in)))
    ih = (np.arange(geo.n_tiles_h)[:, None] * geo.tile
          + np.arange(geo.fft_size)[None, :])           # [n_th, K]
    iw = (np.arange(geo.n_tiles_w)[:, None] * geo.tile
          + np.arange(geo.fft_size)[None, :])           # [n_tw, K]
    xt = x[:, :, ih][:, :, :, :, iw]                    # [B,M,n_th,K,n_tw,K]
    xt = xt.transpose(0, 1, 2, 4, 3, 5)
    return xt.reshape(b, m, geo.n_tiles, geo.fft_size, geo.fft_size)


class HaloGeometry(NamedTuple):
    """Static geometry of the in-kernel halo gather (PR 5 tentpole).

    The fused kernel's halo input mode reads the RAW NCHW activation
    directly: each grid step gets an input block covering ``bth x btw``
    tiles *plus* the k-1-pixel halo the overlap-save windows share —
    ``rh = bth*t + (K - t)`` rows by ``rw = btw*t + (K - t)`` cols,
    clamped to the image (small images fit in one block) — and gathers
    its stride-t, size-K windows in VMEM with one-hot row/col matmuls
    (``halo_gather_matrices``).  Consecutive blocks overlap by the halo,
    which Pallas expresses with element-offset (``pl.Unblocked``) index
    maps; no ``[B, M, T, K, K]`` windowed tensor is ever materialized
    in HBM.
    """

    bth: int             # tiles per block along H
    btw: int             # tiles per block along W
    nbh: int             # blocks along H  (ceil(n_tiles_h / bth))
    nbw: int             # blocks along W
    rh: int              # raw rows per block: min(bth*t + k - 1, h_in)
    rw: int              # raw cols per block

    @property
    def block_tiles(self) -> int:
        """Tiles per grid step — the halo path's effective block_p."""
        return self.bth * self.btw

    @property
    def n_blocks(self) -> int:
        return self.nbh * self.nbw


def halo_block_geometry(geo: SpectralGeometry, block_p: int) -> HaloGeometry:
    """Split a tile-count budget ``block_p`` into a 2-D halo block.

    Favors full tile rows (btw first) so the per-axis halo fraction
    (K - t)/(b*t) is paid on as few axes as possible; the resulting
    ``block_tiles = bth*btw <= block_p`` is what the VMEM/psum blocks
    are sized by.  Deterministic: the kernel, the cost model and the
    autotuner all derive the same blocks from (geo, block_p).
    """
    block_p = max(1, block_p)
    btw = max(1, min(geo.n_tiles_w, block_p))
    bth = max(1, min(geo.n_tiles_h, block_p // btw))
    ov = geo.ksize - 1
    return HaloGeometry(
        bth=bth, btw=btw,
        nbh=-(-geo.n_tiles_h // bth), nbw=-(-geo.n_tiles_w // btw),
        rh=min(bth * geo.tile + ov, geo.h_in),
        rw=min(btw * geo.tile + ov, geo.w_in))


def halo_block_starts(geo: SpectralGeometry, hg: HaloGeometry
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Clamped raw-image start offsets of every halo block, per axis.

    Block ib's windows span raw rows ``[ib*bth*t - (k-1), ...+rh)``;
    the start is clamped to ``[0, h_in - rh]`` so the block never reads
    out of bounds — the gather matrices re-align the windows against
    the clamped block and encode the 'same'-padding (and bottom/right
    tile padding) as all-zero one-hot rows.  The kernel's element-offset
    index map computes exactly this formula on traced indices.
    """
    ov = geo.ksize - 1
    sh = np.arange(hg.nbh) * hg.bth * geo.tile - ov + geo.pre_halo_h
    sw = np.arange(hg.nbw) * hg.btw * geo.tile - ov
    return (np.clip(sh, 0, geo.h_in - hg.rh),
            np.clip(sw, 0, geo.w_in - hg.rw))


def halo_gather_matrices(geo: SpectralGeometry, hg: HaloGeometry
                         ) -> tuple[np.ndarray, np.ndarray]:
    """One-hot window selectors for the in-kernel halo gather.

    gr [nbh, bth*K, rh] / gc [nbw, btw*K, rw] f32: row ``ii*K + kh`` of
    block ib selects raw image row ``(ib*bth + ii)*t - (k-1) + kh``
    relative to the block's clamped start.  Rows whose raw coordinate
    falls outside the image ('same' zero-padding, bottom/right tile
    padding past n_tiles) or whose tile index exceeds the tile grid are
    left all-zero, so the gathered window values are exact zeros — the
    one-hot matmul IS the zero-padding.  Being 0/1 operands, the gather
    is numerically exact: halo windows equal
    ``extract_tiles_overlapping`` bit for bit.
    """
    k = geo.fft_size
    ov = geo.ksize - 1
    sh, sw = halo_block_starts(geo, hg)

    def axis(nb, bt, n_tiles, start, size, extent, pre=0):
        g = np.zeros((nb, bt * k, size), np.float32)
        for ib in range(nb):
            for ii in range(bt):
                tile_idx = ib * bt + ii
                if tile_idx >= n_tiles:
                    continue                      # block padding tile
                for kh in range(k):
                    raw = tile_idx * geo.tile - ov + kh + pre
                    if 0 <= raw < extent:
                        g[ib, ii * k + kh, raw - start[ib]] = 1.0
        return g

    return (axis(hg.nbh, hg.bth, geo.n_tiles_h, sh, hg.rh, geo.h_in,
                 geo.pre_halo_h),
            axis(hg.nbw, hg.btw, geo.n_tiles_w, sw, hg.rw, geo.w_in))


def halo_window_reference(x: Array, geo: SpectralGeometry,
                          hg: HaloGeometry) -> Array:
    """Host-side emulation of the kernel's halo gather (tests/docs).

    Replays exactly what the fused kernel does per grid step — clamped
    raw block read, one-hot row/col gather — then reorders the
    block-major tiles back to row-major and crops the block padding.
    Must equal ``extract_tiles_overlapping(x, geo)`` for every
    (H, W, k, t, block_p) the plan can emit (property-tested).
    """
    b, m = x.shape[:2]
    k = geo.fft_size
    gr, gc = halo_gather_matrices(geo, hg)
    sh, sw = halo_block_starts(geo, hg)
    xn = np.asarray(x)
    out = np.zeros((b, m, hg.nbh * hg.bth, hg.nbw * hg.btw, k, k),
                   xn.dtype)
    for ib in range(hg.nbh):
        for jb in range(hg.nbw):
            blk = xn[:, :, sh[ib]:sh[ib] + hg.rh, sw[jb]:sw[jb] + hg.rw]
            win = np.einsum("rh,bmhw,cw->bmrc", gr[ib], blk, gc[jb])
            win = win.reshape(b, m, hg.bth, k, hg.btw, k)
            out[:, :, ib * hg.bth:(ib + 1) * hg.bth,
                jb * hg.btw:(jb + 1) * hg.btw] = win.transpose(
                    0, 1, 2, 4, 3, 5)
    out = out[:, :, :geo.n_tiles_h, :geo.n_tiles_w]
    return jnp.asarray(out.reshape(b, m, geo.n_tiles, k, k))


def assemble_tile_canvas(y_tiles: Array, geo: SpectralGeometry) -> Array:
    """[B, N, T, h', h'] valid tiles -> UNCROPPED [B, N, h_pad, w_pad]
    full-conv canvas (pure relayout, no overlap additions).

    The sharded executor assembles each shard's band canvas with this
    and crops only after concatenating the bands — the 'same' crop is a
    global operation (its start offset is relative to the whole image),
    so per-shard outputs must stay uncropped.
    """
    b, n, t, tl, _ = y_tiles.shape
    assert t == geo.n_tiles and tl == geo.tile
    yt = y_tiles.reshape(b, n, geo.n_tiles_h, geo.n_tiles_w, tl, tl)
    return (yt.transpose(0, 1, 2, 4, 3, 5)
            .reshape(b, n, geo.h_pad, geo.w_pad))


def crop_canvas_same(canvas: Array, geo: SpectralGeometry) -> Array:
    """'same' crop of a full-conv canvas: [B, N, h_pad*, w_pad] ->
    [B, N, H_out, W_out].  ``geo`` must be the GLOBAL geometry (the
    canvas may be taller than h_pad when bands were concatenated past
    the image; only the cropped range is read)."""
    start = geo.ksize - 1 - geo.pad
    h_out = geo.h_in + 2 * geo.pad - geo.ksize + 1
    w_out = geo.w_in + 2 * geo.pad - geo.ksize + 1
    return canvas[:, :, start:start + h_out, start:start + w_out]


def assemble_valid_tiles(y_tiles: Array, geo: SpectralGeometry) -> Array:
    """Overlap-save output assembly: [B, N, T, h', h'] valid tiles ->
    [B, N, H_out, W_out].

    Each tile's t x t block is the finished full-conv canvas block at
    (i*h', j*h') (see ``extract_tiles_overlapping``), so assembly is a
    pure relayout — no overlap additions — followed by the same 'same'
    crop as ``overlap_add``.
    """
    return crop_canvas_same(assemble_tile_canvas(y_tiles, geo), geo)


# ---------------------------------------------------------------------------
# Spatial sharding: tile-row bands + cross-shard halo (ISSUE 9)
# ---------------------------------------------------------------------------
#
# Spatial sharding splits the image into horizontal BANDS of whole tile
# rows (pruned-kernel overlap-save semantics are tile-alignment
# dependent, so shard boundaries must fall on tile boundaries).  Shard d
# owns tile rows [d*tr, (d+1)*tr) = raw rows [d*tr*t, (d+1)*tr*t), and
# needs exactly ov = k-1 rows of TOP halo from shard d-1 (shard 0's
# halo is zeros — the global 'same' padding); no bottom halo, because a
# window starting at the band's last tile row spans (tr-1)*t + K =
# tr*t + ov rows, i.e. ends inside the band + its own top halo.  The
# extended band [B, C, ov + tr*t, W] is described by
# ``make_band_geometry`` — a SpectralGeometry with pre_halo_h = ov whose
# extraction needs ZERO H padding and whose gather coordinates are all
# in bounds by construction (property-tested).

def shard_band_rows(geo: SpectralGeometry, n_shards: int) -> int:
    """Tile rows per shard band: ceil(n_tiles_h / n_shards)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    return -(-geo.n_tiles_h // n_shards)


def make_band_geometry(geo: SpectralGeometry,
                       tile_rows: int) -> SpectralGeometry:
    """Per-shard geometry of a ``tile_rows``-tall band of ``geo``.

    The band input is the EXTENDED band [B, C, (k-1) + tile_rows*t, W]
    (top halo included), so h_in counts the halo rows and pre_halo_h
    marks them; h_pad is the band's canvas height tile_rows*t.  W-axis
    geometry is inherited unchanged (bands span the full width).
    """
    ov = geo.ksize - 1
    return SpectralGeometry(
        geo.fft_size, geo.tile, geo.ksize, geo.pad,
        h_in=ov + tile_rows * geo.tile, w_in=geo.w_in,
        n_tiles_h=tile_rows, n_tiles_w=geo.n_tiles_w,
        h_pad=tile_rows * geo.tile, w_pad=geo.w_pad,
        pre_halo_h=ov)


def halo_exchange_reference(x: Array, geo: SpectralGeometry,
                            n_shards: int) -> list[Array]:
    """Host emulation of the cross-shard halo exchange (tests/docs).

    Returns the ``n_shards`` extended bands [B, C, (k-1) + tr*t, W] the
    ppermute exchange produces on-device: shard d's band of the
    (bottom-zero-padded) image prefixed by the last k-1 rows of shard
    d-1's band (zeros for shard 0).  Exactly k-1 rows cross each
    boundary — the property the geometry test pins down.
    """
    ov = geo.ksize - 1
    tr = shard_band_rows(geo, n_shards)
    hb = tr * geo.tile
    xn = np.asarray(x)
    b, c, h, w = xn.shape
    xp = np.zeros((b, c, n_shards * hb, w), xn.dtype)
    xp[:, :, :h] = xn
    bands = []
    for d in range(n_shards):
        halo = (np.zeros((b, c, ov, w), xn.dtype) if d == 0
                else xp[:, :, d * hb - ov:d * hb])
        bands.append(jnp.asarray(
            np.concatenate([halo, xp[:, :, d * hb:(d + 1) * hb]], axis=2)))
    return bands


def spectral_band_conv2d_pretransformed(x_ext: Array, w_f,
                                        geo: SpectralGeometry) -> Array:
    """Band einsum oracle: one shard's extended band -> its UNCROPPED
    band canvas [B, N, tile_rows*t, w_pad].

    ``geo`` is a ``make_band_geometry`` result; ``x_ext`` is the
    extended band (top halo included).  Concatenating the shard
    canvases along H reconstructs ``assemble_tile_canvas`` of the
    unsharded image: the band windows are BIT-identical to the
    full-image overlap-save windows (property-tested), and the canvas
    matches to float-accumulation tolerance — XLA may schedule the
    Hadamard contraction differently at band vs full tile extents.
    ``crop_canvas_same`` with the GLOBAL geometry then yields the
    'same' output.
    """
    windows = extract_tiles_overlapping(x_ext, geo)  # [B,M,T,K,K]
    x_f = jnp.fft.fft2(windows.astype(jnp.float32))
    y_f = _hadamard_maybe_sparse(x_f, w_f, geo)
    y_sp = jnp.fft.ifft2(y_f).real
    ov = geo.ksize - 1
    y_valid = y_sp[..., ov:, ov:]
    return assemble_tile_canvas(y_valid.astype(x_ext.dtype), geo)


def fft_tiles(tiles: Array, geo: SpectralGeometry) -> Array:
    """[..., h', w'] -> [..., K, K] complex spectral tiles."""
    pad = geo.fft_size - geo.tile
    tiles = jnp.pad(tiles, [(0, 0)] * (tiles.ndim - 2) + [(0, pad)] * 2)
    return jnp.fft.fft2(tiles.astype(jnp.float32))


def overlap_add(y_tiles: Array, geo: SpectralGeometry) -> Array:
    """OaA merge: [B, N, T, K, K] spatial-domain output tiles -> [B, N, H, W].

    Tile (i, j)'s K x K full-convolution output sits at canvas offset
    (i*tile, j*tile); adjacent tiles overlap by ov = k - 1 pixels which are
    summed.  With ov <= tile (checked in ``make_geometry``) the canvas block
    (i, j) of size tile x tile receives exactly four contributions:

      block(i,j)[:, :]        += tile(i,   j  )[:tile, :tile]   (body)
      block(i,j)[:, :ov]      += tile(i,   j-1)[:tile, tile:]   (left nbr)
      block(i,j)[:ov, :]      += tile(i-1, j  )[tile:, :tile]   (upper nbr)
      block(i,j)[:ov, :ov]    += tile(i-1, j-1)[tile:, tile:]   (diag nbr)

    The bottom/right canvas spill (rows/cols >= h_pad) is only dropped
    because ``make_geometry`` padded the canvas past every row the 'same'
    crop can read.
    """
    b, n, t, kk, _ = y_tiles.shape
    assert t == geo.n_tiles and kk == geo.fft_size
    ov = geo.ksize - 1
    tl = geo.tile
    th, tw = geo.n_tiles_h, geo.n_tiles_w
    yt = y_tiles.reshape(b, n, th, tw, kk, kk)

    def shift(a: Array, axis: int) -> Array:
        """a'[..., i, ...] = a[..., i-1, ...] with a'[..., 0, ...] = 0."""
        pad = [(0, 0)] * a.ndim
        pad[axis] = (1, 0)
        sl = [slice(None)] * a.ndim
        sl[axis] = slice(0, a.shape[axis])
        return jnp.pad(a, pad)[tuple(sl)]

    blk = yt[..., :tl, :tl]
    blk = blk.at[..., :, :ov].add(shift(yt[..., :tl, tl:], 3))
    blk = blk.at[..., :ov, :].add(shift(yt[..., tl:, :tl], 2))
    blk = blk.at[..., :ov, :ov].add(shift(shift(yt[..., tl:, tl:], 2), 3))

    out = blk.transpose(0, 1, 2, 4, 3, 5).reshape(b, n, geo.h_pad, geo.w_pad)

    # 'same' crop: same-output row i' reads full-conv row i' + (k-1-pad).
    start = geo.ksize - 1 - geo.pad
    h_out = geo.h_in + 2 * geo.pad - geo.ksize + 1
    w_out = geo.w_in + 2 * geo.pad - geo.ksize + 1
    return out[:, :, start:start + h_out, start:start + w_out]


# ---------------------------------------------------------------------------
# Hadamard stage (Eq 3) — reference path
# ---------------------------------------------------------------------------

def hadamard_accumulate(x_f: Array, w_f: Array) -> Array:
    """Eq 3:  Y~[b,n,t,u,v] = sum_m X~[b,m,t,u,v] * W~[n,m,u,v].

    Per frequency bin (u, v) this is a complex GEMM contracting input
    channels m — the formulation the TPU kernel exploits (MXU batched over
    frequency bins).  Here: plain einsum oracle.
    """
    return jnp.einsum("bmtuv,nmuv->bntuv", x_f, w_f)


# ---------------------------------------------------------------------------
# End-to-end spectral convolution
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("fft_size", "pad"))
def spectral_conv2d(x: Array, w: Array, *, fft_size: int = 8,
                    pad: int | None = None) -> Array:
    """Spectral convolution of NCHW ``x`` with spatial kernel ``w``.

    Equivalent (up to fp error) to 'same' cross-correlation, computed via
    FFT tiling + Hadamard + IFFT + OaA.
    """
    geo = make_geometry(x.shape[2], x.shape[3], w.shape[-1], fft_size, pad)
    w_f = spectral_kernel(w, fft_size)
    return spectral_conv2d_pretransformed(x, w_f, geo)


def spectral_conv2d_pretransformed(x: Array, w_f,
                                   geo: SpectralGeometry) -> Array:
    """Spectral conv with an already-transformed (possibly pruned) kernel.

    ``w_f`` is either a dense complex [N, M, K, K] array or a
    ``repro.core.sparse.SparseSpectralKernels`` (duck-typed on
    ``.values``/``.mask`` to avoid an import cycle).  For pruned kernels
    the Hadamard einsum is restricted to the frequency bins that are
    non-zero in *some* kernel — the whole-bin zero work (which the
    magnitude patterns of high-alpha layers concentrate at high
    frequencies) is skipped, so oracle benchmarks reflect sparsity.

    Uses overlap-save tiling (``extract_tiles_overlapping``), the
    repo-wide formulation since the fused-epilogue refactor: every output
    tile is complete after the IFFT, so a bias/ReLU epilogue can follow
    immediately.  For un-pruned kernels this equals the paper's OaA
    formulation (and ``spatial_conv2d``) exactly; for pruned kernels the
    two differ in where the circular wraparound of the full-support
    spectral kernel lands (DESIGN.md adaptation note 5) — this oracle
    defines the repo's pruned-conv semantics and the Pallas backends
    match it bit-for-bit in structure.
    """
    windows = extract_tiles_overlapping(x, geo)      # [B,M,T,K,K]
    x_f = jnp.fft.fft2(windows.astype(jnp.float32))  # [B,M,T,K,K]
    y_f = _hadamard_maybe_sparse(x_f, w_f, geo)      # [B,N,T,K,K]
    y_sp = jnp.fft.ifft2(y_f).real
    ov = geo.ksize - 1
    y_valid = y_sp[..., ov:, ov:]                    # [B,N,T,h',h']
    return assemble_valid_tiles(y_valid.astype(x.dtype), geo)


def _hadamard_maybe_sparse(x_f: Array, w_f, geo: SpectralGeometry) -> Array:
    if not hasattr(w_f, "values"):                   # dense kernel
        return hadamard_accumulate(x_f, w_f)
    values = w_f.values
    kk = geo.fft_size
    f = kk * kk
    # precomputed at prune time; deriving it here would pull the mask
    # back from device on every forward call
    active = getattr(w_f, "active_bins", None)
    if active is None:
        mask = w_f.mask
        if isinstance(mask, jax.core.Tracer):        # traced: stay dense
            return hadamard_accumulate(x_f, values)
        active = np.flatnonzero(np.asarray(mask).any(axis=(0, 1))
                                .reshape(f))
    if len(active) >= f:                             # nothing prunable
        return hadamard_accumulate(x_f, values)
    b, m, t = x_f.shape[:3]
    n = values.shape[0]
    xa = x_f.reshape(b, m, t, f)[..., active]
    wa = values.reshape(n, m, f)[..., active]
    ya = jnp.einsum("bmtf,nmf->bntf", xa, wa)
    y = jnp.zeros((b, n, t, f), ya.dtype)
    return y.at[..., active].set(ya).reshape(b, n, t, kk, kk)


@functools.partial(jax.jit, static_argnames=("pad", "stride"))
def spatial_conv2d(x: Array, w: Array, *, pad: int | None = None,
                   stride: int = 1) -> Array:
    """Spatial-domain oracle: 'same' cross-correlation.

    ``stride > 1`` is numerically identical to computing the stride-1
    'same' output and subsampling ``[..., ::stride, ::stride]`` — the
    exact contract of the spectral path's stride handling (the
    overlap-save kernel always produces the stride-1 output; see
    ``dataflow.ConvLayer.stride``).
    """
    k = w.shape[-1]
    if pad is None:
        pad = (k - 1) // 2
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW")).astype(x.dtype)
