"""Compile-once network-plan IR: everything the forward pass needs,
precomputed.

The paper's headline result comes from *composing* its three
contributions — per-layer flexible dataflow (Alg 1), kernel compression
(SPEC2-style pruning), and conflict-free scheduling of the sparse
kernels (Alg 2 / Fig 6).  On the FPGA that composition happens at
synthesis time: the host compiles per-layer configurations once and the
accelerator just executes them.  This module is the TPU analogue — a
small IR built once, offline, and executed by every backend of
``models.cnn.forward_spectral`` without re-deriving anything per call:

  LayerPlan   one conv layer's precompiled state:
    * tile geometry (``SpectralGeometry``, overlap-save),
    * pruned ``SparseSpectralKernels`` (per-layer alpha),
    * the active-frequency-bin set the exact-cover schedule touches
      (== the union of non-zero kernel bins, see
      ``scheduler.active_bins_from_tables``) with the compacted kernel
      planes and restricted DFT operators derived from it,
    * the autotuned (flow, block_n, block_m, block_p, hadamard mode)
      from Alg-1-on-TPU (``core.autotune``), costed sparsity-aware so
      Alg 1 sees the kernel Alg 2 compressed AND ranks the scheduled
      element-granular datapath against bin compaction per layer,
    * for layers whose mode is 'scheduled': the full Alg-2 INDEX/VALUE
      tables (one exact-cover schedule per kernel-group x channel,
      ``scheduler.compile_layer_tables``), remapped to compacted-bin
      coordinates and padded to the tuned blocks — the fused kernel
      executes them directly,
    * a fused epilogue spec (bias + ReLU inside the kernel flush,
      2x2-max-pool flag for the spatial stage that follows),
    * Alg-2 schedule statistics (cycles, Eq-14 PE utilization) —
      sampled for plane modes, exact for scheduled layers.

  NetworkPlan  the per-layer plans plus the FC-head bookkeeping.

Plan construction is host-side numpy/python and happens exactly once;
the jitted forward path (``kernels.fused_spectral_conv.execute_layer_plan``)
only consumes device arrays and static metadata, so repeated calls hit
the jit cache directly — no schedule, pruning, compaction, autotune or
geometry work ever runs inside (or between) jitted steps.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune as at
from repro.core import dataflow as df
from repro.core import resilience as res
from repro.core import scheduler as sch
from repro.core import sparse as sp
from repro.core import spectral as spec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """Post-conv elementwise work fused into (bias, relu) or scheduled
    right after (pool) the conv kernel.

    ``residual`` is the shortcut-add mode of a DAG node with a
    ``residual_from`` edge (ISSUE 10):

      None     no shortcut (every linear-stack layer);
      'fused'  the shortcut activation is one more VMEM operand on the
               kernel's epilogue flush — added after bias, before ReLU,
               inside the same pallas_call (requires the fused backend
               and stride 1);
      'add'    the dense fallback: the conv runs with ReLU deferred and
               the executor applies ``relu(y + shortcut)`` as an
               unfused XLA add — the degradation-ladder rung
               ``epilogue residual-fused->residual-add``.
    """

    bias: bool = True
    relu: bool = True
    pool: bool = False       # 2x2 max-pool follows this layer (spatial)
    residual: str | None = None   # None | 'fused' | 'add'


class PlanTables(NamedTuple):
    """Device-resident Alg-2 INDEX/VALUE tables for one scheduled layer
    (stacked layout of ``scheduler.LayerTables``; consumed verbatim by
    ``kernels.fused_spectral_conv.fused_spectral_pipeline_scheduled``).
    """

    idx: Array                        # [GN, Mp, T, r]  int32
    sel: Array                        # [GN, Mp, T, N'] int32
    vr: Array                         # [GN, Mp, T, N'] f32
    vi: Array

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self)


@dataclasses.dataclass(frozen=True)
class PlanNode:
    """One node of the compiled DAG plan (ISSUE 10).

    The plan-level twin of the config-level ``dataflow.NodeSpec``:
    topo-ordered by ``build_network_plan``, resolved against the
    compiled ``LayerPlan`` tuple, and carrying the plan-time decisions
    a NodeSpec cannot (the ShortcutFusion on-chip verdict).

      id            stable node id; for 'conv' nodes this IS the
                    ``ConvLayer`` name (== ``layers[layer_index]``).
      kind          'conv' | 'pool'.
      inputs        producer ids (length 1; 'input' = network input).
      layer_index   index into ``NetworkPlan.layers`` (-1 for pools).
      pool          'max' | 'avg' (2x2, stride 2) for pool nodes.
      residual_from shortcut producer id, or None.
      relu          apply ReLU at this node's output.  For residual
                    nodes this is the POST-add ReLU (the in-kernel
                    epilogue relu is suppressed on the 'add' rung and
                    the executor applies ``relu(y + shortcut)``).
      shortcut_on_chip  the reuse decision for a fused shortcut: True
                    when the autotuner priced the shortcut as retained
                    VMEM bytes ('vmem' placement) and it fit the
                    budget; False when it re-reads from HBM.
    """

    id: str
    kind: str = "conv"
    inputs: tuple[str, ...] = ("input",)
    layer_index: int = -1
    pool: str = "max"
    residual_from: str | None = None
    relu: bool = True
    shortcut_on_chip: bool = False


def _linear_node_specs(layers, pool_after) -> tuple:
    """Synthesize the degenerate chain graph for a linear conv stack:
    one 'conv' node per layer, a 'max' pool node (id '<name>:pool')
    after every layer named in ``pool_after``."""
    nodes = []
    prev = "input"
    for layer in layers:
        nodes.append(df.NodeSpec(id=layer.name, inputs=(prev,)))
        prev = layer.name
        if layer.name in pool_after:
            pid = f"{layer.name}:pool"
            nodes.append(df.NodeSpec(id=pid, kind="pool", inputs=(prev,)))
            prev = pid
    return tuple(nodes)


def _topo_order_specs(specs) -> list:
    """Kahn topo-order of config NodeSpecs (shortcut edges included).

    Raises ``PlanValidationError`` (site='graph') on duplicate ids,
    references to unknown ids, or a cycle — at plan build, not at
    execution.
    """
    by_id: dict[str, object] = {}
    for s in specs:
        if s.id == "input" or s.id in by_id:
            raise res.PlanValidationError(
                f"graph node id {s.id!r} is duplicated or reserved",
                layer=s.id, site="graph")
        by_id[s.id] = s
    deps: dict[str, set] = {}
    for s in specs:
        edges = set(s.inputs)
        if getattr(s, "residual_from", None) is not None:
            edges.add(s.residual_from)
        edges.discard("input")
        unknown = edges - by_id.keys()
        if unknown:
            raise res.PlanValidationError(
                f"graph node {s.id!r} references unknown node(s) "
                f"{sorted(unknown)}", layer=s.id, site="graph")
        deps[s.id] = edges
    order, ready = [], [s for s in specs if not deps[s.id]]
    done: set[str] = set()
    while ready:
        s = ready.pop(0)
        order.append(s)
        done.add(s.id)
        for t in specs:
            if t.id not in done and t not in ready \
                    and deps[t.id] <= done:
                ready.append(t)
    if len(order) != len(list(specs)):
        stuck = sorted(set(by_id) - done)
        raise res.PlanValidationError(
            f"graph has a cycle through node(s) {stuck}",
            layer=stuck[0], site="graph")
    return order


def graph_sink(nodes) -> str:
    """Id of the network output node of a topo-ordered node sequence:
    the last node whose output no other node consumes (main or shortcut
    edge).  Falls back to the final topo node for degenerate graphs."""
    consumed: set[str] = set()
    for n in nodes:
        consumed.update(n.inputs)
        rf = getattr(n, "residual_from", None)
        if rf is not None:
            consumed.add(rf)
    sinks = [n.id for n in nodes if n.id not in consumed]
    return sinks[-1] if sinks else nodes[-1].id


def node_output_shapes(layers, specs) -> dict[str, tuple[int, int, int]]:
    """Walk a topo-ordered node sequence and return every node's output
    shape as ``{id: (C, H, W)}`` (batch elided).

    Works on both config-level ``dataflow.NodeSpec`` and plan-level
    ``PlanNode`` sequences (both carry id/kind/inputs/residual_from).
    Conv nodes produce their layer's post-stride 'same' extent
    (``ConvLayer.out_hw``); pool nodes halve H and W (2x2, stride 2,
    floor — odd edge rows/cols are dropped, matching the executor).

    Raises ``PlanValidationError`` when a conv node's declared layer
    geometry disagrees with what its producer actually emits
    (site='graph/input-shape') or a shortcut edge carries a shape
    other than the node's own output (site='graph/residual-shape') —
    the DAG checks of ISSUE 10, enforced at plan build.
    """
    by_name = {l.name: l for l in layers}
    first = next((by_name[s.id] for s in specs
                  if s.kind == "conv" and s.id in by_name), None)
    shapes: dict[str, tuple[int, int, int]] = {}
    if first is not None:
        shapes["input"] = (first.c_in, first.h_in, first.w_in)
    for s in specs:
        src = shapes.get(s.inputs[0])
        if s.kind == "pool":
            if src is None:
                raise res.PlanValidationError(
                    f"pool node {s.id!r} has no resolvable input shape",
                    layer=s.id, site="graph/input-shape")
            c, h, w = src
            out = (c, h // 2, w // 2)
        else:
            layer = by_name.get(s.id)
            if layer is None:
                raise res.PlanValidationError(
                    f"conv node {s.id!r} has no matching ConvLayer",
                    layer=s.id, site="graph/input-shape")
            want = (layer.c_in, layer.h_in, layer.w_in)
            if src is not None and src != want:
                raise res.PlanValidationError(
                    f"conv node {s.id!r} declares input {want} but its "
                    f"producer {s.inputs[0]!r} emits {src}",
                    layer=s.id, site="graph/input-shape")
            hw = getattr(layer, "out_hw", (layer.h_in, layer.w_in))
            out = (layer.c_out, hw[0], hw[1])
        rf = getattr(s, "residual_from", None)
        if rf is not None:
            sc = shapes.get(rf)
            if sc != out:
                raise res.PlanValidationError(
                    f"residual edge {rf!r} -> {s.id!r} adds shape "
                    f"{sc} to output shape {out}",
                    layer=s.id, site="graph/residual-shape")
        shapes[s.id] = out
    return shapes


@dataclasses.dataclass(frozen=True, eq=False)
class LayerPlan:
    """Precompiled state for one spectral conv layer (see module doc).

    Fields (N = c_out, M = c_in, S = K^2, S2 = tile^2, Fa = active
    bins):

      layer / geo / kernels / alpha   static layer description, tile
          geometry and the pruned spectral kernels (per-layer alpha).
      tuning      Alg-1-on-TPU result: flow, block sizes, the chosen
          Hadamard mode and its analytic cost.
      epilogue / bias                 fused bias+ReLU spec (+ pool-after
          flag); bias is [1, N] f32, zeros when disabled.
      active      compacted active-bin set (host numpy) or None (all
          K^2 bins); coordinate system of the spectral operands below.
      wr / wi     [Fa, N, M] f32 kernel planes (dense/bin modes).
      dfr / dfi   [Fa, S] forward DFT rows; dvr/dvi [S2, Fa] inverse
          DFT on valid rows — shared by every Hadamard mode.
      hadamard    'dense' | 'bin' | 'scheduled' — which datapath
          ``execute_layer_plan`` dispatches to.
      input_mode  'windowed' | 'halo' — which input path the fused
          kernel uses: host-materialized overlap-save windows, or the
          in-kernel halo gather reading the raw activation (the
          windowed path is the fallback/oracle; both are numerically
          identical).
      tables      ``PlanTables`` for scheduled layers, else None.
      schedule_cycles / pe_utilization   Alg-2 stats: exact totals when
          the full tables were compiled (scheduled mode), otherwise
          sampled (None when scheduling was skipped).
      backend     'fused' | 'staged' | 'einsum' — which execution path
          runs this layer under the pallas_fused network backend
          (``df.EXEC_BACKENDS``).  Always 'fused' at build time; the
          degradation ladder (``core.resilience``) demotes it when the
          fused variant cannot compile/execute.
      provenance  audit trail of demotions applied to this layer by
          ``resilience.harden_network_plan`` (empty = as built).
    """

    layer: df.ConvLayer
    geo: spec.SpectralGeometry
    kernels: sp.SparseSpectralKernels
    alpha: float
    tuning: at.FusedTuning
    epilogue: EpilogueSpec
    bias: Array                       # [1, N] f32 (zeros when no bias)
    active: np.ndarray | None         # compacted bin set; None = dense
    wr: Array                         # [Fa, N, M] f32 kernel planes
    wi: Array
    dfr: Array                        # [Fa, S]  forward DFT rows
    dfi: Array
    dvr: Array                        # [S2, Fa] inverse DFT (valid rows)
    dvi: Array
    schedule_cycles: int | None       # Alg-2 stats (None: skipped)
    pe_utilization: float | None      # Eq 14
    hadamard: str = "bin"             # Hadamard-stage mode
    input_mode: str = "windowed"      # fused-kernel input path
    tables: PlanTables | None = None  # Alg-2 tables (scheduled mode)
    backend: str = "fused"            # per-layer execution path
    provenance: tuple[str, ...] = ()  # demotion audit trail

    @property
    def n_active_bins(self) -> int:
        k2 = self.geo.fft_size ** 2
        return k2 if self.active is None else len(self.active)

    def stats(self) -> dict:
        """Per-layer summary row (example / benchmark reporting)."""
        return {
            "layer": self.layer.name,
            "alpha": self.alpha,
            "nnz": self.kernels.nnz,
            "active_bins": self.n_active_bins,
            "flow": self.tuning.flow,
            "hadamard": self.hadamard,
            "input_mode": self.input_mode,
            "backend": self.backend,
            "demotions": len(self.provenance),
            "block_n": self.tuning.block_n,
            "block_m": self.tuning.block_m,
            "block_p": self.tuning.block_p,
            "hbm_bytes": self.tuning.hbm_bytes,
            "table_bytes": (self.tables.nbytes
                            if self.tables is not None else 0),
            "schedule_cycles": self.schedule_cycles,
            "pe_utilization": self.pe_utilization,
            "pool": self.epilogue.pool,
        }


@dataclasses.dataclass(frozen=True, eq=False)
class NetworkPlan:
    """The compile-once artifact ``models.cnn.forward_spectral`` executes.

    ``graph`` is the topo-ordered DAG the executors walk (ISSUE 10);
    ``build_network_plan`` always populates it (linear configs get the
    synthesized chain).  Plans constructed by hand with ``graph=()``
    fall back to the chain derived from ``layers`` + the epilogue pool
    flags via ``execution_graph``.
    """

    name: str
    fft_size: int
    batch: int                        # batch the autotune assumed
    layers: tuple[LayerPlan, ...]
    graph: tuple[PlanNode, ...] = ()

    @property
    def tuning(self) -> dict[str, at.FusedTuning]:
        return {lp.layer.name: lp.tuning for lp in self.layers}

    @property
    def execution_graph(self) -> tuple[PlanNode, ...]:
        """The DAG to execute — ``graph``, or the linear chain implied
        by ``layers`` (+ epilogue pool flags) for legacy plans."""
        if self.graph:
            return self.graph
        nodes, prev = [], "input"
        for i, lp in enumerate(self.layers):
            name = lp.layer.name
            nodes.append(PlanNode(id=name, kind="conv", inputs=(prev,),
                                  layer_index=i,
                                  relu=lp.epilogue.relu))
            prev = name
            if lp.epilogue.pool:
                pid = f"{name}:pool"
                nodes.append(PlanNode(id=pid, kind="pool",
                                      inputs=(prev,)))
                prev = pid
        return tuple(nodes)

    def node_plan(self, node: PlanNode) -> LayerPlan:
        """The LayerPlan a 'conv' node executes."""
        if node.kind != "conv":
            raise ValueError(f"node {node.id!r} is {node.kind!r}, "
                             f"not 'conv'")
        return self.layers[node.layer_index]

    def summary(self) -> list[dict]:
        return [lp.stats() for lp in self.layers]

    def health_report(self) -> dict:
        """Resilience status of the plan: validation diagnostics plus
        the demotion audit trail (``core.resilience``).

        Returns a dict with ``healthy`` (no error-severity diagnostics
        and no demoted layers), ``demoted_layers``, ``issues`` (count
        by severity) and one row per layer carrying its current modes,
        provenance and any outstanding diagnostics.
        """
        diags = res.validate_plan(self, raise_on_error=False)
        rows = []
        # Rows key by STABLE NODE ID, not layer index: on a DAG plan
        # positional indices are meaningless (pool nodes interleave,
        # topo order need not match cfg.layers order), and provenance
        # must survive plan rebuilds that reorder layers.
        for node in self.execution_graph:
            if node.kind != "conv":
                rows.append({"node": node.id, "kind": node.kind,
                             "pool": node.pool,
                             "demotions": [], "issues": []})
                continue
            lp = self.layers[node.layer_index]
            mine = [d for d in diags if d.layer == node.id]
            rows.append({
                "node": node.id,
                "kind": "conv",
                "layer": node.id,
                "backend": lp.backend,
                "flow": lp.tuning.flow,
                "hadamard": lp.hadamard,
                "input_mode": lp.input_mode,
                "residual": getattr(lp.epilogue, "residual", None),
                "demotions": list(lp.provenance),
                "issues": [str(d) for d in mine],
            })
        n_err = sum(d.severity == "error" for d in diags)
        n_warn = sum(d.severity == "warn" for d in diags)
        demoted = {lp.layer.name: list(lp.provenance)
                   for lp in self.layers if lp.provenance}
        return {
            "name": self.name,
            "batch": self.batch,
            "healthy": n_err == 0 and not demoted,
            "demoted_layers": list(demoted),
            "demotions_by_node": demoted,
            "issues": {"error": n_err, "warn": n_warn},
            "layers": rows,
        }


def _sampled_schedule_stats(sk: sp.SparseSpectralKernels, k2: int, *,
                            r: int, n_par: int, channel_sample: int,
                            ) -> tuple[int, float, np.ndarray]:
    """Run Alg 2 on a bounded sample of (group, channel) pairs; return
    (total cycles, Eq-14 utilization, bins the sampled schedules touch).
    The full-layer active set is the union over ALL kernels — equal to
    the union of schedule-served bins by the exact-cover property (every
    non-zero served exactly once; ``scheduler.active_bins_from_tables``
    is the table-level statement of the same fact, unit-tested) — so the
    sample's bins are always a subset of ``sk.active_bins``."""
    idx = np.asarray(sk.indices)
    n_out, c_in, _ = idx.shape
    chans = np.linspace(0, c_in - 1, min(channel_sample, c_in)).astype(int)
    group = slice(0, min(n_par, n_out))
    total_ops = 0
    total_cycles = 0
    n_pe = group.stop
    bins: set[int] = set()
    for m in np.unique(chans):
        s = sch.schedule_exact_cover(idx[group, m, :], k2, r)
        total_ops += s.total_ops
        total_cycles += s.n_cycles
        for _, fs in s.cycles:
            bins.update(fs.tolist())
    mu = total_ops / max(1, total_cycles * n_pe)
    return total_cycles, mu, np.asarray(sorted(bins), np.int64)


def _resolve_hadamard_modes(hadamard: str, alpha: float, schedule: bool,
                            active: np.ndarray | None) -> list[str]:
    """Hadamard-mode candidates for one layer, honoring availability.

    'bin' needs a compacted active set (otherwise it IS dense);
    'scheduled' needs a non-degenerate schedule (alpha > 1 and
    scheduling enabled) — when it degenerates, the request falls back
    to the plane datapath, the ISSUE's dense/bin fallback.
    """
    plane = "bin" if active is not None else "dense"
    sched_ok = schedule and alpha > 1.0
    if hadamard == "auto":
        return [plane] + (["scheduled"] if sched_ok else [])
    if hadamard == "scheduled":
        return ["scheduled"] if sched_ok else [plane]
    if hadamard == "bin":
        return [plane]
    if hadamard == "dense":
        return ["dense"]
    raise ValueError(
        f"hadamard must be 'auto' or one of {df.HADAMARD_MODES}, "
        f"got {hadamard!r}")


def _resolve_input_modes(input_mode: str) -> list[str]:
    """Input-path candidates for the autotuner ('auto' ranks both; the
    windowed path is always a valid forced fallback/oracle)."""
    if input_mode == "auto":
        return list(df.INPUT_MODES)
    if input_mode in df.INPUT_MODES:
        return [input_mode]
    raise ValueError(
        f"input_mode must be 'auto' or one of {df.INPUT_MODES}, "
        f"got {input_mode!r}")


def build_network_plan(params: dict, cfg, *,
                       batch: int = 1,
                       prune: str = "magnitude",
                       vmem_budget: int = df.TPU_VMEM_BYTES,
                       blocks: Sequence[int] = at.BLOCK_CANDIDATES,
                       hw_safe: bool = True,
                       schedule: bool = True,
                       schedule_r: int = 10,
                       schedule_n_par: int = 64,
                       schedule_channel_sample: int = 2,
                       hadamard: str = "auto",
                       input_mode: str = "auto",
                       schedule_mu: float = df.SCHEDULE_MU,
                       step_overhead_s: float = 0.0,
                       measure: bool = False,
                       interpret: bool | None = None,
                       validate: bool = True) -> NetworkPlan:
    """Compile the whole conv stack once (see module docstring).

    Args:
      params: spatial conv weights + biases (``models.cnn.init``);
        kernels are spectrally transformed and pruned here — the
        paper's offline path — and each layer's bias is baked into the
        plan for the fused epilogue.
      cfg: duck-typed on ``layers`` / ``fft_size`` / ``alpha`` /
        ``pool_after`` / ``name`` (``models.cnn.SpectralCNNConfig``);
        ``cfg.alpha`` may be a scalar or a per-layer sequence.
      batch: images per forward call the autotuner assumes; the plan
        records it and the fused backend enforces it for RMW flows.
      prune: 'magnitude' (SPEC2-like) or 'random' (Fig-10 robustness).
      vmem_budget / blocks: Alg-1 search space, see
        ``autotune.autotune_layer``.  ``hw_safe`` is accepted for API
        compatibility and is a no-op since PR 8 (manual-DMA
        accumulators make every configuration hardware-legal).
      schedule: run Alg 2 at all (False skips schedule stats AND
        disables the scheduled datapath).
      schedule_r: r, the BRAM-replica analogue (paper S6.3: 10).
      schedule_n_par: PE-group size for the SAMPLED stats of plane-mode
        layers (scheduled layers group by the tuned block_n instead).
      schedule_channel_sample: channels sampled for those stats.
      hadamard: 'auto' (default — Alg 1 ranks the available modes per
        layer), or force 'dense' / 'bin' / 'scheduled'.  A forced
        'scheduled' falls back to the plane datapath when the schedule
        degenerates (alpha ~= 1); forced 'bin' degrades to 'dense' when
        no bin is empty.
      input_mode: 'auto' (default — Alg 1 ranks the windowed stream
        against the in-kernel halo gather per layer; the halo path's
        raw-plus-halo input bytes win essentially always), or force
        'windowed' / 'halo' (windowed is the fallback/oracle path).
      schedule_mu: estimated Eq-14 utilization used by the cost model
        to size scheduled tables before the schedules exist.
      step_overhead_s: fixed per-grid-step cost added to Alg 1's
        predictions (``dataflow.INTERPRET_STEP_S`` when the plan will
        execute in interpret mode — the serving stack's default — so
        per-bucket tunings minimize the wall clock of the backend that
        actually runs; 0.0 keeps the pure hardware roofline).
      measure: re-rank top analytic candidates by wall time
        (``autotune``); ``interpret`` selects the kernel execution mode
        for that measurement.
      validate: run ``resilience.validate_plan`` on the finished plan
        (default) so invariant violations — corrupted Alg-2 tables,
        inconsistent operators, out-of-range halo starts — are rejected
        at plan build, not at kernel launch.  VMEM/hw-safety findings
        are advisory (warn severity) here because the autotuner's
        documented fallback may legitimately exceed the budget; use
        ``resilience.harden_network_plan`` to demote such layers.

    For every layer whose chosen mode is 'scheduled', the full Alg-2
    tables are compiled here (one exact-cover schedule per kernel-group
    x input-channel — the expensive offline step the FPGA does at
    synthesis time) and stored device-resident in the plan; the fused
    kernel then executes them without any host-side work per call.
    """
    prune_fn = {"magnitude": sp.prune_magnitude,
                "random": sp.prune_random}[prune]
    layers = list(cfg.layers)
    alphas = sp.per_layer_alphas(cfg.alpha, len(layers))
    pool_after = getattr(cfg, "pool_after", frozenset())
    k2 = cfg.fft_size * cfg.fft_size

    # --- DAG plan IR (ISSUE 10): resolve + topo-order the node graph.
    # Linear configs get the synthesized chain, so every plan carries a
    # graph and the executors have exactly one walk to implement.
    graph_specs = getattr(cfg, "graph", None)
    explicit_graph = graph_specs is not None
    if not explicit_graph:
        graph_specs = _linear_node_specs(layers, pool_after)
    order = _topo_order_specs(graph_specs)
    conv_specs = {s.id: s for s in order if s.kind == "conv"}
    names = [l.name for l in layers]
    if sorted(conv_specs) != sorted(names):
        raise res.PlanValidationError(
            f"graph conv nodes {sorted(conv_specs)} do not match "
            f"cfg.layers {sorted(names)} (each conv layer must appear "
            f"in exactly one node)", site="graph")
    node_output_shapes(layers, order)   # DAG shape checks (raises)

    shortcut_on_chip: dict[str, bool] = {}
    plans: list[LayerPlan] = []
    for layer, conv, alpha in zip(layers, params["convs"], alphas):
        geo = spec.make_geometry(layer.h_in, layer.w_in, layer.ksize,
                                 cfg.fft_size, layer.pad)
        w_f = spec.spectral_kernel(conv["w"], cfg.fft_size)
        sk = prune_fn(w_f, alpha)

        cycles = mu = None
        if schedule and alpha > 1.0:
            cycles, mu, sampled_bins = _sampled_schedule_stats(
                sk, k2, r=schedule_r, n_par=schedule_n_par,
                channel_sample=schedule_channel_sample)
            full = np.asarray(sk.active_bins)
            if not np.isin(sampled_bins, full).all():
                raise res.PlanValidationError(
                    f"Alg-2 schedule for {layer.name} touched a "
                    f"frequency bin outside the pruned kernel support",
                    layer=layer.name, site="schedule-stats")

        active = sp.compacted_active_bins(sk)
        wr, wi = sp.compact_planes(sk, active)
        ops = jnp.asarray  # device placement of the numpy operators
        dfr, dfi, dvr, dvi = (ops(a) for a in _operators(geo, active))

        measure_fn = None
        if measure:
            measure_fn = at._make_measure_fn(layer, cfg.fft_size, alpha,
                                             batch, interpret)
        modes = _resolve_hadamard_modes(hadamard, alpha, schedule, active)
        imodes = _resolve_input_modes(input_mode)
        node_spec = conv_specs[layer.name]
        stride = getattr(layer, "stride", 1)
        # Residual mode: the fused epilogue add needs the stride-1
        # output the kernel actually flushes (stride subsampling
        # happens after the kernel), so strided nodes take the dense
        # 'add' fallback from the start.
        residual_mode = None
        if node_spec.residual_from is not None:
            residual_mode = "fused" if stride == 1 else "add"

        def _tune(residual=None):
            return at.autotune_layer(
                layer, cfg.fft_size, alpha, batch=batch,
                vmem_budget=vmem_budget, blocks=blocks, hw_safe=hw_safe,
                active_bins=len(active) if active is not None else None,
                hadamard_modes=modes, input_modes=imodes,
                schedule_r=schedule_r,
                schedule_mu=schedule_mu,
                step_overhead_s=step_overhead_s,
                residual=residual, measure_fn=measure_fn)

        if residual_mode == "fused":
            # ShortcutFusion reuse decision: hold the shortcut on-chip
            # (retained VMEM bytes) when the working set still fits the
            # budget, else re-read it from HBM on the flush path.
            tuning = _tune(residual="vmem")
            if tuning.vmem_bytes > vmem_budget:
                tuning = _tune(residual="hbm")
            shortcut_on_chip[layer.name] = tuning.residual == "vmem"
        else:
            tuning = _tune()

        tables = None
        if tuning.hadamard == "scheduled":
            # The paper's offline schedule compilation: one exact-cover
            # schedule per (kernel-group, channel), stacked and
            # remapped to the compacted coordinates of the operators
            # above.  Group size == the tuned block_n; channel padding
            # == the tuned block_m.
            lt = sch.compile_layer_tables(
                np.asarray(sk.indices),
                np.asarray(sk.values).reshape(layer.c_out, layer.c_in,
                                              k2),
                k2, schedule_r, min(tuning.block_n, layer.c_out),
                active=active, m_pad_to=min(tuning.block_m, layer.c_in))
            tables = PlanTables(jnp.asarray(lt.idx), jnp.asarray(lt.sel),
                                jnp.asarray(lt.vr), jnp.asarray(lt.vi))
            cycles, mu = lt.total_cycles, lt.pe_utilization  # exact

        # On the 'add' rung the kernel flushes bias-only output and the
        # executor applies relu(y + shortcut) — in-kernel relu would
        # clamp the pre-add activation, which is wrong.
        epi = EpilogueSpec(bias=True,
                           relu=(node_spec.relu
                                 and residual_mode != "add"),
                           pool=(not explicit_graph
                                 and layer.name in pool_after),
                           residual=residual_mode)
        bias = jnp.asarray(conv["b"], jnp.float32).reshape(1, -1)
        plans.append(LayerPlan(
            layer=layer, geo=geo, kernels=sk, alpha=alpha, tuning=tuning,
            epilogue=epi, bias=bias, active=active, wr=wr, wi=wi,
            dfr=dfr, dfi=dfi, dvr=dvr, dvi=dvi,
            schedule_cycles=cycles, pe_utilization=mu,
            hadamard=tuning.hadamard or
            ("bin" if active is not None else "dense"),
            input_mode=tuning.input_mode or "windowed",
            tables=tables))
    layer_index = {name: i for i, name in enumerate(names)}
    pnodes = tuple(
        PlanNode(id=s.id, kind="conv", inputs=tuple(s.inputs),
                 layer_index=layer_index[s.id],
                 residual_from=s.residual_from, relu=s.relu,
                 shortcut_on_chip=shortcut_on_chip.get(s.id, False))
        if s.kind == "conv" else
        PlanNode(id=s.id, kind="pool", inputs=tuple(s.inputs),
                 pool=s.pool)
        for s in order)
    net = NetworkPlan(name=getattr(cfg, "name", "spectral-cnn"),
                      fft_size=cfg.fft_size, batch=batch,
                      layers=tuple(plans), graph=pnodes)
    if validate:
        res.validate_plan(net, vmem_budget=vmem_budget, hw_safe=hw_safe)
    return net


def _operators(geo: spec.SpectralGeometry, active: np.ndarray | None):
    from repro.kernels.fused_spectral_conv import overlap_save_operators
    key = tuple(int(a) for a in active) if active is not None else None
    return overlap_save_operators(geo.fft_size, geo.ksize, key)


# ---------------------------------------------------------------------------
# Keyed plan cache (serving front end)
# ---------------------------------------------------------------------------

def plan_cache_key(cfg, batch: int, *,
                   mesh_shape: Sequence[int] | None = None,
                   **build_kwargs) -> tuple:
    """Cache key for one compiled ``NetworkPlan``: (config name,
    fft_size, per-layer alpha, batch bucket, mesh shape, build options).

    Everything else a plan depends on (layer geometry, pool placement)
    is a function of the named config; alpha is normalized so a scalar
    and the equivalent per-layer sequence key identically.  Build
    kwargs (forced hadamard/input_mode, vmem budget, ...) are folded in
    by repr so plans built with different options never collide.

    ``mesh_shape`` is the device topology the plan targets and is part
    of the key — a sharded plan's shard geometry, collective shapes and
    Alg-2 table slices are all functions of the mesh, so a plan built
    for one mesh must never be served to another (serving it would be
    silent cross-mesh cache poisoning: wrong shard math, not an error).
    ``None`` (single-device / unsharded) keys distinctly from every
    concrete mesh, including ``(1,)``.

    DAG configs additionally fold a graph signature — node ids, kinds,
    edges (main + shortcut), pool kinds and per-node relu flags — so
    two configs sharing a name but wired differently (or a config that
    gained a residual edge) never collide.  ``None`` (linear config)
    keys distinctly from an explicit chain-shaped graph.
    """
    alphas = sp.per_layer_alphas(cfg.alpha, len(list(cfg.layers)))
    mesh = (tuple(int(d) for d in mesh_shape)
            if mesh_shape is not None else None)
    graph = getattr(cfg, "graph", None)
    gsig = (None if graph is None else tuple(
        (n.id, n.kind, tuple(n.inputs), n.pool, n.residual_from,
         bool(getattr(n, "relu", True)))
        for n in graph))
    return (getattr(cfg, "name", "spectral-cnn"), int(cfg.fft_size),
            tuple(float(a) for a in alphas), int(batch),
            ("mesh", mesh), ("graph", gsig),
            tuple(sorted((k, repr(v)) for k, v in build_kwargs.items())))


@dataclasses.dataclass
class PlanCache:
    """Keyed, warmable cache of compile-once NetworkPlans.

    ``build_network_plan`` is the expensive offline step (~2 minutes on
    full VGG16: prune + Alg-2 tables + compaction + autotune — see
    ``plan_build_s`` in BENCH_e2e.json); a serving front end cannot
    afford it on the request path.  The cache keys plans by
    ``plan_cache_key(cfg, batch)`` and is *warmed* at server startup
    for every batch bucket, so no request ever pays a plan build.

    ``invalidate(key)`` drops one entry (e.g. after the serving layer
    detected a corrupted plan) so the next ``get`` rebuilds it; the
    hit/miss/build/invalidation counters and cumulative build seconds
    are surfaced via ``stats()`` for the serve-level health report.

    ``builder`` is injectable for tests (defaults to
    ``build_network_plan``); extra ``get`` kwargs are forwarded to it.
    """

    builder: Callable | None = None
    _plans: dict = dataclasses.field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    builds: int = 0
    invalidations: int = 0
    build_s: float = 0.0

    def warm(self, params: dict, cfg, batches: Sequence[int],
             mesh_shape: Sequence[int] | None = None,
             **build_kwargs) -> dict:
        """Build (or confirm) one plan per batch bucket; returns
        {bucket: key} for the entries warmed."""
        return {int(b): self.key_of(params, cfg, int(b),
                                    mesh_shape=mesh_shape,
                                    **build_kwargs)
                for b in batches}

    def key_of(self, params: dict, cfg, batch: int,
               mesh_shape: Sequence[int] | None = None,
               **build_kwargs) -> tuple:
        """``get`` that returns the cache key instead of the plan."""
        self.get(params, cfg, batch, mesh_shape=mesh_shape,
                 **build_kwargs)
        return plan_cache_key(cfg, batch, mesh_shape=mesh_shape,
                              **build_kwargs)

    def get(self, params: dict, cfg, batch: int,
            mesh_shape: Sequence[int] | None = None,
            **build_kwargs) -> NetworkPlan:
        # mesh_shape participates in the KEY only: builders that target
        # a mesh (e.g. a closure over build_sharded_network_plan) carry
        # the topology themselves, and build_network_plan has no mesh
        # concept — but both must key by it (cross-mesh poisoning).
        key = plan_cache_key(cfg, batch, mesh_shape=mesh_shape,
                             **build_kwargs)
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            return plan
        self.misses += 1
        import time as _time
        t0 = _time.perf_counter()
        builder = self.builder or build_network_plan
        plan = builder(params, cfg, batch=batch, **build_kwargs)
        self.build_s += _time.perf_counter() - t0
        self.builds += 1
        self._plans[key] = plan
        return plan

    def invalidate(self, key: tuple) -> bool:
        """Drop one entry; the next ``get`` for its key rebuilds."""
        if key in self._plans:
            del self._plans[key]
            self.invalidations += 1
            return True
        return False

    def __len__(self) -> int:
        return len(self._plans)

    def stats(self) -> dict:
        return {"entries": len(self._plans), "hits": self.hits,
                "misses": self.misses, "builds": self.builds,
                "invalidations": self.invalidations,
                "build_s": self.build_s}


# ---------------------------------------------------------------------------
# Sharded plans (multi-device execution under shard_map)
# ---------------------------------------------------------------------------

def _pad_layer_tables(tabs: Sequence[sch.LayerTables]) -> list[PlanTables]:
    """Pad per-shard Alg-2 tables to a common cycle count T.

    Channel shards schedule DIFFERENT kernel slices, so their exact-cover
    schedules can differ in length; ``shard_map`` stacks the per-shard
    operands into one array and needs uniform shapes.  Padded cycles
    carry idx=0, sel=0 and vr=vi=0.0 — the zero weight kills both the
    MAC and the scatter contribution, so they are inert (the same
    convention ``scheduler.compile_layer_tables`` uses for its own
    padding).
    """
    t_max = max(t.idx.shape[2] for t in tabs)
    out = []
    for t in tabs:
        pad_t = t_max - t.idx.shape[2]
        pads4 = ((0, 0), (0, 0), (0, pad_t), (0, 0))
        out.append(PlanTables(
            jnp.asarray(np.pad(t.idx, pads4)),
            jnp.asarray(np.pad(t.sel, pads4)),
            jnp.asarray(np.pad(t.vr, pads4)),
            jnp.asarray(np.pad(t.vi, pads4))))
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedLayerPlan:
    """One conv layer's multi-device execution plan.

    ``base`` is the unsharded ``LayerPlan`` (the single-device truth:
    full geometry, full kernels, the fused epilogue and the pool-after
    flag — always executable as-is, and the terminal fallback of the
    sharded degradation ladder).  ``shards`` holds the shard-LOCAL
    plans the executor runs under ``shard_map``:

      'replicate'  () — every device executes ``base`` identically;
      'spatial'    (band_plan,) — ONE plan shared by all shards: the
          shard-local layer (``dataflow.shard_local_layer``) over the
          band geometry (``spectral.make_band_geometry``), whose
          ``pre_halo_h`` rows arrive from the left mesh neighbor via
          ``ppermute`` before the kernel runs;
      'channel'    D plans — shard d owns input channels
          [d*M/D, (d+1)*M/D): kernels/planes/tables sliced on the
          channel axis, bias+ReLU DEFERRED (``EpilogueSpec(False,
          False)``) because shard outputs are partial sums — the
          executor applies ``base.epilogue`` after the psum.

    ``tuning`` is the two-level Alg-1 verdict (``autotune.ShardTuning``)
    that chose the strategy; ``provenance`` audits shard-level
    demotions (``resilience.harden_sharded_plan``).
    """

    base: LayerPlan
    strategy: str                     # dataflow.SHARD_STRATEGIES
    n_shards: int
    tuning: at.ShardTuning
    shards: tuple[LayerPlan, ...]
    provenance: tuple[str, ...] = ()

    def stats(self) -> dict:
        row = self.base.stats()
        row.update({
            "strategy": self.strategy,
            "n_shards": self.n_shards,
            "ici_bytes": self.tuning.ici_bytes,
            "per_chip_hbm_bytes": self.tuning.per_chip_hbm_bytes,
            "sharded_s": self.tuning.sharded_s,
            "shard_demotions": len(self.provenance),
        })
        return row


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedNetworkPlan:
    """A ``NetworkPlan`` plus its per-layer partitioning for one mesh.

    ``base`` remains fully executable on a single device (it IS the
    parity oracle the sharded tests compare against); ``layers`` align
    1:1 with ``base.layers``.  ``mesh_shape`` records the device
    topology the plan was built for — a plan built for one mesh must
    never serve another (see ``plan_cache_key(mesh_shape=...)``).
    """

    base: NetworkPlan
    n_shards: int
    mesh_shape: tuple[int, ...]
    layers: tuple[ShardedLayerPlan, ...]
    axis: str = "shard"

    @property
    def name(self) -> str:
        return self.base.name

    @property
    def fft_size(self) -> int:
        return self.base.fft_size

    @property
    def batch(self) -> int:
        return self.base.batch

    @property
    def strategies(self) -> dict[str, str]:
        return {slp.base.layer.name: slp.strategy for slp in self.layers}

    def summary(self) -> list[dict]:
        return [slp.stats() for slp in self.layers]


def _band_tables(lp: LayerPlan, tn: at.FusedTuning,
                 schedule_r: int) -> PlanTables | None:
    """Tables for the spatial band plan (full channels; reuses the base
    tables when the tuned blocks agree, recompiles otherwise)."""
    if tn.hadamard != "scheduled":
        return None
    n, m = lp.layer.c_out, lp.layer.c_in
    bt = lp.tuning
    if (lp.tables is not None
            and min(tn.block_n, n) == min(bt.block_n, n)
            and min(tn.block_m, m) == min(bt.block_m, m)):
        return lp.tables
    k2 = lp.geo.fft_size ** 2
    lt = sch.compile_layer_tables(
        np.asarray(lp.kernels.indices),
        np.asarray(lp.kernels.values).reshape(n, m, k2),
        k2, schedule_r, min(tn.block_n, n),
        active=lp.active, m_pad_to=min(tn.block_m, m))
    return PlanTables(jnp.asarray(lt.idx), jnp.asarray(lt.sel),
                      jnp.asarray(lt.vr), jnp.asarray(lt.vi))


def make_sharded_layer_plan(lp: LayerPlan, st: at.ShardTuning,
                            n_shards: int, *,
                            schedule_r: int = df.SCHEDULE_R
                            ) -> ShardedLayerPlan:
    """Construct the shard-local plans for one layer (see
    ``ShardedLayerPlan``).  Also the REBUILD step of the sharded
    degradation ladder: after ``resilience`` demotes the base plan one
    rung, calling this again re-derives consistent shard plans.

    A base plan demoted off the fused backend executes replicated —
    sharded execution is a fused-kernel path; 'staged'/'einsum' rungs
    run the base plan outside ``shard_map`` (a plan-level, uniform
    decision, so no device can be left waiting on a collective).
    """
    strategy = st.strategy
    if (n_shards <= 1 or strategy == "replicate"
            or lp.backend != "fused"):
        return ShardedLayerPlan(
            base=lp, strategy="replicate", n_shards=n_shards,
            tuning=st, shards=())
    local = df.shard_local_layer(lp.layer, lp.geo.fft_size, n_shards,
                                 strategy)
    if local is None:                 # infeasible at this D: replicate
        return ShardedLayerPlan(
            base=lp, strategy="replicate", n_shards=n_shards,
            tuning=st, shards=())
    tn = st.base
    hadamard = tn.hadamard or lp.hadamard
    input_mode = tn.input_mode or lp.input_mode
    if strategy == "spatial":
        tr = spec.shard_band_rows(lp.geo, n_shards)
        band_geo = spec.make_band_geometry(lp.geo, tr)
        band = dataclasses.replace(
            lp, layer=local, geo=band_geo, tuning=tn,
            epilogue=dataclasses.replace(lp.epilogue, pool=False),
            hadamard=hadamard, input_mode=input_mode,
            tables=_band_tables(lp, tn, schedule_r))
        return ShardedLayerPlan(base=lp, strategy="spatial",
                                n_shards=n_shards, tuning=st,
                                shards=(band,))
    # channel: slice kernels/planes/tables on the input-channel axis;
    # shard outputs are PARTIAL sums, so bias+ReLU defer to post-psum.
    mloc = local.c_in
    k2 = lp.geo.fft_size ** 2
    no_epi = EpilogueSpec(bias=False, relu=False, pool=False)
    zero_bias = jnp.zeros_like(lp.bias)
    sliced = []
    raw_tables: list[sch.LayerTables] = []
    for d in range(n_shards):
        sl = slice(d * mloc, (d + 1) * mloc)
        sk = lp.kernels
        skd = sp.SparseSpectralKernels(
            values=sk.values[:, sl], mask=sk.mask[:, sl],
            indices=sk.indices[:, sl], alpha=sk.alpha,
            active_bins=sk.active_bins)
        sliced.append(skd)
        if hadamard == "scheduled":
            raw_tables.append(sch.compile_layer_tables(
                np.asarray(skd.indices),
                np.asarray(skd.values).reshape(lp.layer.c_out, mloc, k2),
                k2, schedule_r, min(tn.block_n, lp.layer.c_out),
                active=lp.active, m_pad_to=min(tn.block_m, mloc)))
    tables = (_pad_layer_tables(raw_tables) if raw_tables
              else [None] * n_shards)
    shards = tuple(
        dataclasses.replace(
            lp, layer=local, kernels=sliced[d], tuning=tn,
            epilogue=no_epi, bias=zero_bias,
            wr=lp.wr[:, :, d * mloc:(d + 1) * mloc],
            wi=lp.wi[:, :, d * mloc:(d + 1) * mloc],
            hadamard=hadamard, input_mode=input_mode,
            schedule_cycles=(raw_tables[d].total_cycles
                             if raw_tables else lp.schedule_cycles),
            pe_utilization=(raw_tables[d].pe_utilization
                            if raw_tables else lp.pe_utilization),
            tables=tables[d])
        for d in range(n_shards))
    return ShardedLayerPlan(base=lp, strategy="channel",
                            n_shards=n_shards, tuning=st, shards=shards)


def resharded_layer_plan(slp: ShardedLayerPlan, new_base: LayerPlan, *,
                         schedule_r: int = df.SCHEDULE_R,
                         note: str | None = None) -> ShardedLayerPlan:
    """Rebuild a ``ShardedLayerPlan`` around a demoted base plan.

    The shard-local tuning inherits the demoted base's hadamard /
    input-mode so shard plans track the base down the ladder; once the
    base leaves the fused backend, ``make_sharded_layer_plan`` collapses
    the strategy to 'replicate' (terminal rung — structurally immune to
    collective hangs because no shard_map runs at all).
    """
    tn = dataclasses.replace(slp.tuning.base,
                             hadamard=new_base.hadamard,
                             input_mode=new_base.input_mode)
    st = dataclasses.replace(slp.tuning, base=tn)
    rebuilt = make_sharded_layer_plan(new_base, st, slp.n_shards,
                                      schedule_r=schedule_r)
    prov = slp.provenance + ((note,) if note else ())
    return dataclasses.replace(rebuilt, provenance=prov)


def build_sharded_network_plan(params: dict, cfg, *,
                               n_shards: int,
                               mesh_shape: Sequence[int] | None = None,
                               batch: int = 1,
                               strategies: Sequence[str] | None = None,
                               validate: bool = True,
                               **build_kwargs) -> ShardedNetworkPlan:
    """Compile a ``NetworkPlan`` AND its per-layer partitioning.

    Builds the single-device base plan first (``build_network_plan``,
    which also serves as the parity oracle), then runs the two-level
    Alg-1 (``autotune.autotune_layer_sharded``) per layer over the
    surviving hadamard/input-mode candidates and materializes the
    shard-local plans (``make_sharded_layer_plan``).

    ``mesh_shape`` defaults to ``(n_shards,)``; ``strategies`` restricts
    the partitioning search (e.g. ``("channel",)`` for a forced-mode
    test).  Remaining kwargs flow to ``build_network_plan`` and the
    relevant ones (vmem budget, blocks, schedule knobs) are re-read for
    the sharded tuner so both levels cost the same machine.
    """
    base = build_network_plan(params, cfg, batch=batch,
                              validate=validate, **build_kwargs)
    vmem_budget = build_kwargs.get("vmem_budget", df.TPU_VMEM_BYTES)
    blocks = build_kwargs.get("blocks", at.BLOCK_CANDIDATES)
    hw_safe = build_kwargs.get("hw_safe", True)
    schedule = build_kwargs.get("schedule", True)
    schedule_r = build_kwargs.get("schedule_r", 10)
    schedule_mu = build_kwargs.get("schedule_mu", df.SCHEDULE_MU)
    step_overhead_s = build_kwargs.get("step_overhead_s", 0.0)
    hadamard = build_kwargs.get("hadamard", "auto")
    input_mode = build_kwargs.get("input_mode", "auto")

    slayers = []
    for lp in base.layers:
        modes = _resolve_hadamard_modes(hadamard, lp.alpha, schedule,
                                        lp.active)
        imodes = _resolve_input_modes(input_mode)
        # Residual layers charge the shortcut at BOTH levels: the
        # per-chip fused pricing (placement from the base tuning) and
        # the extra (D-1)/D ICI term ``shard_ici_bytes`` adds for
        # moving the Y-sized shortcut into the shards' layout.
        residual = None
        if getattr(lp.epilogue, "residual", None) is not None:
            residual = (lp.tuning.residual or "hbm"
                        if lp.epilogue.residual == "fused" else "hbm")
        st = at.autotune_layer_sharded(
            lp.layer, base.fft_size, lp.alpha, n_shards=n_shards,
            strategies=strategies, batch=batch,
            vmem_budget=vmem_budget, blocks=blocks, hw_safe=hw_safe,
            active_bins=(len(lp.active) if lp.active is not None
                         else None),
            hadamard_modes=modes, input_modes=imodes,
            schedule_r=schedule_r, schedule_mu=schedule_mu,
            step_overhead_s=step_overhead_s, residual=residual)
        slayers.append(make_sharded_layer_plan(lp, st, n_shards,
                                               schedule_r=schedule_r))
    splan = ShardedNetworkPlan(
        base=base, n_shards=n_shards,
        mesh_shape=(tuple(int(d) for d in mesh_shape)
                    if mesh_shape is not None else (n_shards,)),
        layers=tuple(slayers))
    if validate:
        res.validate_sharded_plan(splan, vmem_budget=vmem_budget,
                                  hw_safe=hw_safe)
    return splan
