"""Core: the paper's contributions.

- spectral:   FFT-tiled spectral convolution with Overlap-and-Add (Eqs 3-4)
- sparse:     uniform per-kernel spectral pruning (SPEC2-style)
- dataflow:   bandwidth/storage complexity models (Eqs 6-13)
- optimizer:  Alg 1 flexible-dataflow optimization
- scheduler:  Alg 2 exact-cover memory-access scheduling + Fig 6 tables
"""

from repro.core import dataflow, optimizer, scheduler, sparse, spectral  # noqa: F401
