"""Alg 1 — heuristic dataflow optimization (paper §5.2).

Searches architecture parameters (P' parallel tiles, N' parallel kernels)
and per-layer streaming parameters (Ps, Ns) that minimize the maximum
per-layer off-chip bandwidth subject to the BRAM capacity constraint.

Search structure follows Alg 1 literally:

  for (P', N') in candidate architecture parameters:
      for layer in conv layers:
          for (Ps, Ns) in candidate streaming parameters:
              n_bram <- min over Flow #1/#2/#3 *and* the flexible flow
              if n_bram < N_BRAM and bw(Ps, Ns) < bw_min: keep (Ps, Ns)
      bw_max <- max over layers
      keep (P', N') minimizing bw_max
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Sequence

from repro.core import dataflow as df


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    layer: str
    ps: int            # streaming parameter Ps (input tiles resident)
    ns: int            # streaming parameter Ns (kernels resident)
    n_bram: int
    transfers_words: int
    bandwidth_gbps: float
    tau_s: float


@dataclasses.dataclass(frozen=True)
class DataflowPlan:
    p_par: int         # P'
    n_par: int         # N'
    r: int
    fft_size: int
    alpha: float
    layers: tuple[LayerPlan, ...]
    bw_max_gbps: float

    @property
    def total_transfers_words(self) -> int:
        return sum(l.transfers_words for l in self.layers)


def _streaming_candidates(layer: df.ConvLayer, fft_size: int,
                          p_par: int, n_par: int) -> Iterable[tuple[int, int]]:
    """(Ps, Ns) grid: multiples of (P', N') up to (T, N)."""
    t = layer.tiles(fft_size)
    ps_opts = sorted({min(p_par * k, t)
                      for k in (1, 2, 3, 4, 6, 8, 12, 14, 16, 24, 27, 32,
                                48, 64, 96, 128, 192, 256, 512, 1 << 20)})
    ns_opts = sorted({min(n_par * k, layer.c_out)
                      for k in (1, 2, 4, 8, 16, 32, 64, 1 << 20)})
    return itertools.product(ps_opts, ns_opts)


def optimize_layer(layer: df.ConvLayer, fft_size: int, alpha: float,
                   p_par: int, n_par: int, r: int, tau_s: float,
                   n_bram_cap: int) -> LayerPlan | None:
    """Inner loop of Alg 1 for one layer: best (Ps, Ns) under the cap."""
    best: LayerPlan | None = None
    for ps, ns in _streaming_candidates(layer, fft_size, p_par, n_par):
        n_bram = min(
            df.bram_flexible(layer, fft_size, alpha, p_par, n_par, r, ns, ps),
            df.bram_flow1(layer, fft_size, alpha, p_par, n_par, r),
            df.bram_flow2(layer, fft_size, alpha, p_par, n_par, r),
            df.bram_flow3(layer, fft_size, alpha, p_par, n_par, r),
        )
        if n_bram >= n_bram_cap:
            continue
        words = df.transfers_flexible(layer, fft_size, alpha, ns, ps)
        bw = df.bandwidth_gbps(words, tau_s)
        if best is None or bw < best.bandwidth_gbps:
            best = LayerPlan(layer.name, ps, ns, n_bram, words, bw, tau_s)
    return best


def optimize(layers: Sequence[df.ConvLayer] = df.VGG16_OPT_LAYERS,
             fft_size: int = 8, alpha: float = 4.0, r: int = 10,
             total_tau_s: float = 20e-3, n_bram_cap: int = 2160,
             arch_candidates: Sequence[tuple[int, int]] | None = None,
             ) -> DataflowPlan:
    """Alg 1: best (P', N') + per-layer (Ps, Ns)."""
    if arch_candidates is None:
        arch_candidates = [(p, n) for p in (1, 4, 9, 16, 25)
                           for n in (16, 32, 64, 128)
                           if p * n <= 1024]
    taus = df.layer_latency_budget(layers, fft_size, alpha, total_tau_s)

    best_plan: DataflowPlan | None = None
    for p_par, n_par in arch_candidates:
        lps = []
        feasible = True
        for layer in layers:
            lp = optimize_layer(layer, fft_size, alpha, p_par, n_par, r,
                                taus[layer.name], n_bram_cap)
            if lp is None:
                feasible = False
                break
            lps.append(lp)
        if not feasible:
            continue
        bw_max = max(lp.bandwidth_gbps for lp in lps)
        if best_plan is None or bw_max < best_plan.bw_max_gbps:
            best_plan = DataflowPlan(p_par, n_par, r, fft_size, alpha,
                                     tuple(lps), bw_max)
    if best_plan is None:
        raise ValueError("no feasible architecture parameters under the "
                         f"BRAM cap {n_bram_cap}")
    return best_plan


def pure_flow_transfers(layers: Sequence[df.ConvLayer], fft_size: int,
                        alpha: float, p_par: int, n_par: int
                        ) -> dict[str, dict[str, int]]:
    """Per-layer transfer words for Flow #1/#2/#3 (Fig 7 comparison)."""
    out: dict[str, dict[str, int]] = {}
    for layer in layers:
        out[layer.name] = {
            "flow1": df.transfers_flow1(layer, fft_size, alpha, n_par),
            "flow2": df.transfers_flow2(layer, fft_size, alpha, p_par),
            "flow3": df.transfers_flow3(layer, fft_size, alpha),
        }
    return out
