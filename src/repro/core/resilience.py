"""Resilient execution layer: validated plans, tiered degradation, guards.

Everything aggressive in the fused path — element-offset halo blocks,
scheduled one-hot Hadamard, the in-kernel epilogue — is validated in
interpret mode but flagged as unverified on real Mosaic (ROADMAP item 6).
Until this module existed, any lowering failure, VMEM overflow or
corrupted Alg-2 table surfaced as a raw Pallas traceback or, worse, a
silently wrong output.  The paper's own framing (and SPEC2's fixed-point
parity gates) is that a compressed/scheduled datapath earns its speedups
only if it is *provably equivalent* to the reference — so the execution
layer needs a principled failure model:

  1. **Plan validation** (build time).  ``validate_plan`` runs structured
     invariant checks over a ``core.plan.NetworkPlan`` — VMEM budget vs
     the chosen blocks, Alg-2 INDEX/VALUE table bounds and dtypes, halo
     block starts within the raw image, manual-DMA accumulator
     geometry (tile bounds / revisit order / slot budget) —
     and raises ``PlanValidationError`` with per-layer diagnostics
     instead of a bare ``ValueError`` or a kernel-launch-time assert.

  2. **Tiered graceful degradation** (plan hardening).
     ``harden_network_plan`` probes each layer's chosen kernel variant
     (compile + one forward on zeros) and, on failure, demotes the layer
     one rung at a time along the explicit ladder

         input_mode   halo      -> windowed
         hadamard     scheduled -> dense (plane datapath)
         backend      fused     -> staged -> einsum

     re-pricing the tuning via ``dataflow.tpu_fused_flow_cost`` /
     ``tpu_flow_cost`` so the recorded cost stays honest.  Every
     demotion is recorded in ``LayerPlan.provenance`` and surfaced via
     ``NetworkPlan.health_report()``.  Every rung lands on a datapath
     that is numerically equivalent to the one it replaces (windowed ==
     halo bit-for-bit; plane == scheduled to float tolerance; staged /
     einsum are the standing oracles), so a demoted plan stays inside
     the existing parity gates.

     The backend axis is also exposed in isolation
     (``BACKEND_RUNGS`` / ``demote_layer_backend`` /
     ``plan_at_backend_rung``) together with a per-backend
     ``CircuitBreaker`` — the rungs the serving front end
     (``launch.spectral_serve``) trades under load rather than faults.

  3. **Runtime numeric guards** (opt-in).  ``NumericGuards`` adds a
     per-layer NaN/Inf scan and a sampled-channel parity self-check
     against the einsum oracle to ``models.cnn.forward_spectral``, with
     a configurable policy: ``raise`` (``NumericGuardError``),
     ``demote`` (recompute the offending layer through the oracle and
     continue) or ``warn``.

  4. **Deterministic fault injection** (testing).  The module hosts the
     low-level fault registry (``install_fault`` / ``fault_check`` /
     ``fault_corrupt``) that ``repro.testing.faults`` drives, so tests
     exercise *every* edge of the degradation ladder without real
     hardware.  The hooks are no-ops (one truthiness check) when no
     fault is installed.

Import discipline: this module imports only leaf ``core`` modules
(``dataflow`` / ``sparse`` / ``spectral``); kernels, models and
``core.plan`` import *it*, and the probe/execute helpers import them
lazily.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import dataflow as df
from repro.core import sparse as sp
from repro.core import spectral as spec


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class ResilienceError(Exception):
    """Base of the structured failure taxonomy.

    Carries the failing ``layer`` (or None for network-level failures),
    the ``site`` that detected the problem, and a list of per-layer
    ``Diagnostic`` records — so callers never have to parse a raw
    traceback to find out *which* layer, *which* invariant.
    """

    def __init__(self, message: str, *, layer: str | None = None,
                 site: str | None = None,
                 diagnostics: Sequence["Diagnostic"] = ()):
        self.layer = layer
        self.site = site
        self.diagnostics = tuple(diagnostics)
        if self.diagnostics:
            lines = [message] + [f"  - {d}" for d in self.diagnostics]
            message = "\n".join(lines)
        super().__init__(message)


class PlanValidationError(ResilienceError, ValueError):
    """A NetworkPlan/LayerPlan invariant is violated (build/validate
    time).  Subclasses ``ValueError`` so pre-taxonomy callers that
    caught the bare error keep working."""


class KernelLoweringError(ResilienceError, NotImplementedError):
    """The chosen kernel variant cannot compile/lower/execute (VMEM
    overflow, Mosaic lowering failure, unsupported grid shape...).
    Subclasses ``NotImplementedError`` for back-compat with the
    pre-PR-8 hardware-safety guard, which raised that type."""


class NumericGuardError(ResilienceError, ValueError):
    """A runtime numeric guard tripped: non-finite activations or a
    sampled parity check against the einsum oracle out of tolerance."""


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One failed (or advisory) invariant check for one layer."""

    layer: str
    check: str               # e.g. 'tables/idx-bounds', 'vmem-budget'
    message: str
    severity: str = "error"  # 'error' | 'warn'

    def __str__(self) -> str:
        return f"[{self.layer}] {self.check} ({self.severity}): " \
               f"{self.message}"


# ---------------------------------------------------------------------------
# Fault-injection registry (driven by repro.testing.faults)
# ---------------------------------------------------------------------------

# Named sites production code consults.  Keep in sync with
# ``repro.testing.faults.FAULT_SITES``.  The ``serve_*`` sites live in
# the serving front end (``launch.spectral_serve``): a kernel fault
# mid-request, a corrupted plan fetched from the keyed plan cache, and
# injected per-batch slowness (deadline pressure).
# ``shard_tables`` is shard-scoped: its context carries the shard index
# (plus layer/strategy), so a fault can corrupt or fail ONE shard of a
# sharded plan.  It is consulted host-side — at shard-plan preparation
# and probing — never inside a shard_map body, where per-device python
# control flow does not exist (the body traces once for all devices).
FAULT_SITES = ("lowering", "vmem_overflow", "oob_index", "corrupt_value",
               "nan_activations", "shard_tables", "serve_kernel",
               "serve_plan_cache", "serve_slow")


@dataclasses.dataclass
class InjectedFault:
    """A deterministic fault installed at a named site.

    ``match`` restricts the fault to call sites whose context carries
    every listed key with an equal value (e.g. ``{"input_mode":
    "halo"}`` fails only halo-variant attempts, so a probe demoting to
    'windowed' succeeds — exactly one rung of the ladder).  ``exc`` is
    an exception *factory* for raise-sites; ``corrupt`` a value
    transform for corruption-sites.  ``fires`` counts activations so
    tests can assert the fault actually triggered.
    """

    site: str
    match: dict = dataclasses.field(default_factory=dict)
    exc: Callable[[], Exception] | None = None
    corrupt: Callable[[Any], Any] | None = None
    fires: int = 0

    def matches(self, ctx: dict) -> bool:
        return all(ctx.get(k) == v for k, v in self.match.items())


_FAULTS: list[InjectedFault] = []


def install_fault(fault: InjectedFault) -> None:
    if fault.site not in FAULT_SITES:
        raise ValueError(f"unknown fault site {fault.site!r}; "
                         f"must be one of {FAULT_SITES}")
    _FAULTS.append(fault)


def remove_fault(fault: InjectedFault) -> None:
    if fault in _FAULTS:
        _FAULTS.remove(fault)


def fault_check(site: str, **ctx) -> None:
    """Raise the injected exception if a matching fault is installed.

    Called by production code at named failure sites (kernel entry,
    staged dispatch...).  A no-op — one truthiness check — when no
    fault is active.
    """
    if not _FAULTS:
        return
    for f in _FAULTS:
        if f.site == site and f.exc is not None and f.matches(ctx):
            f.fires += 1
            raise f.exc()


def fault_corrupt(site: str, value, **ctx):
    """Return ``value`` passed through any matching corruption faults."""
    if not _FAULTS:
        return value
    for f in _FAULTS:
        if f.site == site and f.corrupt is not None and f.matches(ctx):
            f.fires += 1
            value = f.corrupt(value)
    return value


# ---------------------------------------------------------------------------
# (1) Plan validation
# ---------------------------------------------------------------------------

def validate_tables(tables, *, n_bins: int, r: int, c_out: int,
                    c_in: int, block_m: int, layer: str = "?"
                    ) -> list[Diagnostic]:
    """Bounds/dtype/shape invariants of one layer's Alg-2 tables.

    ``tables`` duck-types ``core.plan.PlanTables`` /
    ``scheduler.LayerTables`` (``idx``/``sel``/``vr``/``vi``).  Checks:

      * dtypes: idx/sel int32, vr/vi float32;
      * idx entries within [0, n_bins) — the compacted-coordinate
        gather addresses the kernel one-hots against;
      * sel entries within [0, r) — the crossbar replica columns;
      * sel/vr/vi share one shape, idx agrees on [GN, Mp, T];
      * GN * N' covers c_out and Mp equals c_in padded to block_m;
      * vr/vi finite (a NaN weight poisons every psum it touches).
    """
    out: list[Diagnostic] = []
    d = lambda check, msg: out.append(Diagnostic(layer, check, msg))

    idx = np.asarray(tables.idx)
    sel = np.asarray(tables.sel)
    vr = np.asarray(tables.vr)
    vi = np.asarray(tables.vi)
    for name, arr, want in (("idx", idx, np.int32), ("sel", sel, np.int32),
                            ("vr", vr, np.float32),
                            ("vi", vi, np.float32)):
        if arr.dtype != want:
            d(f"tables/{name}-dtype",
              f"{name} dtype {arr.dtype} != {np.dtype(want)}")
    if sel.shape != vr.shape or sel.shape != vi.shape:
        d("tables/shape", f"sel {sel.shape} / vr {vr.shape} / "
                          f"vi {vi.shape} shapes disagree")
    if idx.ndim != 4 or sel.ndim != 4 or idx.shape[:3] != sel.shape[:3]:
        d("tables/shape",
          f"idx {idx.shape} does not align with sel {sel.shape} "
          f"on [GN, Mp, T]")
        return out                       # downstream checks meaningless
    gn, mp, _, r_tab = idx.shape
    n_pe = sel.shape[3]
    if r_tab > r:
        d("tables/replicas", f"idx carries {r_tab} replica slots, "
                             f"schedule allows r={r}")
    if gn * n_pe < c_out:
        d("tables/groups", f"GN*N' = {gn}*{n_pe} covers only "
                           f"{gn * n_pe} kernels, layer has {c_out}")
    bm = min(block_m, c_in)
    mp_want = c_in + (-c_in) % max(1, bm)
    if mp != mp_want:
        d("tables/m-pad", f"channel padding Mp={mp} != {mp_want} "
                          f"(c_in={c_in} padded to block_m={bm}); the "
                          f"kernel blocks over mismatched channels")
    if idx.size and (idx.min() < 0 or idx.max() >= n_bins):
        d("tables/idx-bounds",
          f"INDEX entries outside [0, {n_bins}): min={idx.min()} "
          f"max={idx.max()} — an in-kernel gather against these "
          f"addresses reads unrelated spectra")
    if sel.size and (sel.min() < 0 or sel.max() >= max(1, r_tab)):
        d("tables/sel-bounds",
          f"sel entries outside [0, {r_tab}): min={sel.min()} "
          f"max={sel.max()}")
    if not (np.isfinite(vr).all() and np.isfinite(vi).all()):
        d("tables/value-finite", "non-finite entries in VALUE planes")
    return out


def _layer_cost(lp, batch: int) -> dict:
    """Re-price one layer's tuned config through the fused cost model."""
    tn = lp.tuning
    fa = lp.n_active_bins if lp.active is not None else None
    residual = None
    if getattr(lp.epilogue, "residual", None) == "fused":
        # keep pricing the fused shortcut operand the way the autotuner
        # placed it ('vmem' retained on-chip / 'hbm' re-read)
        residual = tn.residual or "hbm"
    return df.tpu_fused_flow_cost(
        lp.layer, lp.geo.fft_size, lp.alpha, tn.block_n, tn.block_p,
        tn.block_m, tn.flow, batch=batch, active_bins=fa,
        hadamard=lp.hadamard, input_mode=lp.input_mode,
        residual=residual)


def validate_layer_plan(lp, *, batch: int = 1,
                        vmem_budget: int = df.TPU_VMEM_BYTES,
                        hw_safe: bool = True) -> list[Diagnostic]:
    """Structured invariant checks for one ``core.plan.LayerPlan``.

    Returns a list of ``Diagnostic`` records (empty = healthy).
    Severity 'error' marks invariants whose violation makes the kernel
    wrong or un-launchable; 'warn' marks advisory findings (an
    over-budget VMEM working set still runs in interpret mode — the
    autotuner's documented smallest-footprint fallback — but will fail
    Mosaic compilation on hardware).
    """
    out: list[Diagnostic] = []
    name = lp.layer.name
    d = lambda check, msg, sev="error": out.append(
        Diagnostic(name, check, msg, sev))

    backend = getattr(lp, "backend", "fused")
    if backend not in df.EXEC_BACKENDS:
        d("modes/backend", f"backend {backend!r} not in "
                           f"{df.EXEC_BACKENDS}")
        return out
    if backend != "fused":
        return out          # staged/einsum consume only kernels+geo
    tn = lp.tuning
    if tn.flow not in df.FLOWS:
        d("modes/flow", f"flow {tn.flow!r} not in {df.FLOWS}")
        return out
    if lp.hadamard not in df.HADAMARD_MODES:
        d("modes/hadamard",
          f"hadamard {lp.hadamard!r} not in {df.HADAMARD_MODES}")
        return out
    if lp.input_mode not in df.INPUT_MODES:
        d("modes/input",
          f"input_mode {lp.input_mode!r} not in {df.INPUT_MODES}")
        return out

    k2 = lp.geo.fft_size ** 2
    s2 = lp.geo.tile ** 2
    fa = lp.n_active_bins
    if lp.dfr.shape != (fa, k2) or lp.dvr.shape != (s2, fa):
        d("operators/shape",
          f"DFT operators dfr {lp.dfr.shape} / dvr {lp.dvr.shape} "
          f"do not match (Fa={fa}, S={k2}, S2={s2})")
    if lp.hadamard != "scheduled" and lp.wr.shape != (
            fa, lp.layer.c_out, lp.layer.c_in):
        d("operators/planes",
          f"kernel planes {lp.wr.shape} != "
          f"({fa}, {lp.layer.c_out}, {lp.layer.c_in})")
    bias = np.asarray(lp.bias)
    if bias.shape != (1, lp.layer.c_out):
        d("epilogue/bias-shape",
          f"bias {bias.shape} != (1, {lp.layer.c_out})")
    elif not np.isfinite(bias).all():
        d("epilogue/bias-finite", "non-finite bias entries")

    # --- VMEM budget vs the chosen blocks -----------------------------
    try:
        cost = _layer_cost(lp, batch)
        if cost["vmem_bytes"] > vmem_budget:
            d("vmem-budget",
              f"working set {cost['vmem_bytes'] / 2**20:.1f} MiB exceeds "
              f"budget {vmem_budget / 2**20:.1f} MiB at blocks "
              f"(n={tn.block_n}, m={tn.block_m}, p={tn.block_p}); "
              f"Mosaic compilation will fail on hardware", "warn")
    except Exception as e:          # cost model itself rejected the config
        d("vmem-budget", f"cost model rejected the tuned config: {e}")

    # --- Alg-2 tables -------------------------------------------------
    if lp.hadamard == "scheduled":
        if lp.tables is None:
            d("tables/missing", "hadamard='scheduled' but no tables "
                                "compiled into the plan")
        else:
            out.extend(validate_tables(
                lp.tables, n_bins=fa, r=df.SCHEDULE_R,
                c_out=lp.layer.c_out, c_in=lp.layer.c_in,
                block_m=tn.block_m, layer=name))

    # --- halo geometry: block starts within the raw image -------------
    t_total = lp.layer.tiles(lp.geo.fft_size) * batch
    if lp.input_mode == "halo":
        try:
            hg = spec.halo_block_geometry(lp.geo, tn.block_p)
            sh, sw = spec.halo_block_starts(lp.geo, hg)
            if (sh.size and (sh.min() < 0
                             or sh.max() + hg.rh > lp.geo.h_in)) or \
               (sw.size and (sw.min() < 0
                             or sw.max() + hg.rw > lp.geo.w_in)):
                d("halo/starts",
                  f"halo block starts leave the raw image: rows "
                  f"{sh.min()}..{sh.max()}+{hg.rh} vs H={lp.geo.h_in}, "
                  f"cols {sw.min()}..{sw.max()}+{hg.rw} vs "
                  f"W={lp.geo.w_in}")
            gr, gc = spec.halo_gather_matrices(lp.geo, hg)
            if (gr.sum(axis=2) > 1).any() or (gc.sum(axis=2) > 1).any():
                d("halo/gather-onehot",
                  "gather selector has a row with >1 non-zero — the "
                  "window 'gather' would sum raw pixels")
        except Exception as e:
            d("halo/geometry", f"halo geometry rejected block_p="
                               f"{tn.block_p}: {e}")

    # --- manual-DMA accumulator geometry (PR 8) -----------------------
    # The fused kernel streams psums through manually DMA'd VMEM tiles
    # (``kernels.fused_spectral_conv``), so any (flow, blocks, batch)
    # is legal on hardware; what a malformed batch-tuned plan can still
    # break is the accumulator geometry itself: destination tiles must
    # cover (and stay inside) the padded output, every destination must
    # see >= 1 m-revisit ending in the epilogue flush, and the staging
    # buffer must hold ``df.DMA_SLOTS`` slots.  ``hw_safe`` is accepted
    # for API compatibility; the checks below always run.
    del hw_safe
    if tn.block_n < 1 or tn.block_m < 1 or tn.block_p < 1:
        d("dma/tile-bounds",
          f"non-positive block sizes (n={tn.block_n}, m={tn.block_m}, "
          f"p={tn.block_p}) cannot address accumulator tiles")
    else:
        gn = -(-lp.layer.c_out // tn.block_n)
        gm = -(-lp.layer.c_in // tn.block_m)
        s2 = lp.geo.tile ** 2
        if lp.input_mode == "halo":
            try:
                hg = spec.halo_block_geometry(lp.geo, tn.block_p)
            except Exception:
                hg = None       # already diagnosed under halo/geometry
            if hg is not None:
                if (hg.nbh * hg.bth < lp.geo.n_tiles_h
                        or hg.nbw * hg.btw < lp.geo.n_tiles_w):
                    d("dma/tile-bounds",
                      f"halo block grid {hg.nbh}x{hg.nbw} of "
                      f"{hg.bth}x{hg.btw} tiles does not cover the "
                      f"{lp.geo.n_tiles_h}x{lp.geo.n_tiles_w} tile "
                      f"canvas — accumulator tiles would miss output")
                stage_elems = tn.block_n * (hg.bth * lp.geo.tile) \
                    * (hg.btw * lp.geo.tile)
            else:
                stage_elems = 0
        else:
            gp = -(-t_total // tn.block_p)
            if gp * tn.block_p < t_total:
                d("dma/tile-bounds",
                  f"{gp} p blocks of {tn.block_p} cover only "
                  f"{gp * tn.block_p} of {t_total} tile columns")
            stage_elems = s2 * tn.block_n * tn.block_p
        if gn * tn.block_n < lp.layer.c_out:
            d("dma/tile-bounds",
              f"{gn} n blocks of {tn.block_n} cover only "
              f"{gn * tn.block_n} of {lp.layer.c_out} output channels")
        if gm < 1:
            d("dma/revisit-order",
              f"m grid is empty ({gm} blocks of {tn.block_m} over "
              f"c_in={lp.layer.c_in}): no revisit ever flushes the "
              f"accumulator epilogue")
        if df.DMA_SLOTS < 2:
            d("dma/slot-count",
              f"DMA_SLOTS={df.DMA_SLOTS}: double-buffered accumulator "
              f"staging needs >= 2 slots")
        stage_bytes = df.DMA_SLOTS * stage_elems * 4
        if stage_bytes > vmem_budget:
            d("dma/slot-count",
              f"{df.DMA_SLOTS} accumulator slots stage "
              f"{stage_bytes / 2**20:.1f} MiB > VMEM budget "
              f"{vmem_budget / 2**20:.1f} MiB", "warn")

    if lp.pe_utilization is not None and not (
            0.0 < lp.pe_utilization <= 1.0):
        d("schedule/utilization",
          f"Eq-14 utilization {lp.pe_utilization} outside (0, 1]")
    return out


def validate_graph(plan) -> list[Diagnostic]:
    """DAG invariants of a ``core.plan.NetworkPlan`` (ISSUE 10).

    The stored graph must be topo-ordered with unique non-reserved ids,
    every edge (main + shortcut) resolving to an already-emitted node,
    conv nodes pointing at the layer that carries their name, shortcut
    shapes matching the node's post-stride output, and residual-FUSED
    epilogues only on stride-1 fused-backend layers (anything else must
    sit on the 'add' rung).
    """
    out: list[Diagnostic] = []
    graph = plan.execution_graph
    d = lambda layer, check, msg, sev="error": out.append(
        Diagnostic(layer, check, msg, sev))
    seen: set[str] = set()
    for node in graph:
        if node.id == "input" or node.id in seen:
            d(node.id, "graph/node-id",
              f"node id {node.id!r} is duplicated or reserved")
        refs = list(node.inputs)
        if node.residual_from is not None:
            refs.append(node.residual_from)
        for ref in refs:
            if ref != "input" and ref not in seen:
                d(node.id, "graph/order",
                  f"node {node.id!r} consumes {ref!r} before it is "
                  f"produced (unknown id, cycle, or bad topo order)")
        if node.kind == "conv":
            if not 0 <= node.layer_index < len(plan.layers):
                d(node.id, "graph/layer-index",
                  f"layer_index {node.layer_index} outside "
                  f"[0, {len(plan.layers)})")
            else:
                lp = plan.layers[node.layer_index]
                if lp.layer.name != node.id:
                    d(node.id, "graph/layer-index",
                      f"node {node.id!r} resolves to layer "
                      f"{lp.layer.name!r}")
                residual = getattr(lp.epilogue, "residual", None)
                stride = getattr(lp.layer, "stride", 1)
                if residual == "fused" and (
                        getattr(lp, "backend", "fused") != "fused"
                        or stride != 1):
                    d(node.id, "graph/residual-fused",
                      f"residual-fused epilogue on backend="
                      f"{getattr(lp, 'backend', 'fused')!r} stride="
                      f"{stride}: the in-kernel add needs the fused "
                      f"backend at stride 1 (demote to the "
                      f"residual-add rung)")
                if residual is not None and node.residual_from is None:
                    d(node.id, "graph/residual-fused",
                      f"epilogue residual={residual!r} but the node "
                      f"has no residual_from edge")
        seen.add(node.id)
    if not any(x.check == "graph/order" or x.check == "graph/layer-index"
               for x in out):
        from repro.core.plan import node_output_shapes
        try:
            node_output_shapes([lp.layer for lp in plan.layers], graph)
        except PlanValidationError as e:
            d(e.layer, e.site or "graph", str(e).splitlines()[0])
    return out


def validate_plan(plan, *, vmem_budget: int = df.TPU_VMEM_BYTES,
                  hw_safe: bool = True, raise_on_error: bool = True
                  ) -> list[Diagnostic]:
    """Validate every layer of a ``core.plan.NetworkPlan``, plus the
    DAG invariants of its execution graph (``validate_graph``).

    Returns all diagnostics (errors and warnings).  When
    ``raise_on_error`` (default), raises ``PlanValidationError``
    aggregating every *error*-severity diagnostic — at build time, not
    at kernel launch.
    """
    diags: list[Diagnostic] = []
    for lp in plan.layers:
        diags.extend(validate_layer_plan(
            lp, batch=plan.batch, vmem_budget=vmem_budget,
            hw_safe=hw_safe))
    diags.extend(validate_graph(plan))
    errors = [d for d in diags if d.severity == "error"]
    if errors and raise_on_error:
        raise PlanValidationError(
            f"plan {plan.name!r} failed validation "
            f"({len(errors)} error(s))",
            layer=errors[0].layer, site="validate_plan",
            diagnostics=errors)
    return diags


# ---------------------------------------------------------------------------
# Per-layer execution with a per-layer backend (the bottom ladder axis)
# ---------------------------------------------------------------------------

def _spatial_epilogue(y, lp, shortcut=None):
    """Bias -> (+shortcut) -> ReLU, the same ordering the fused
    kernel's epilogue flush uses."""
    if lp.epilogue.bias:
        y = y + lp.bias[0][None, :, None, None]
    if shortcut is not None:
        y = y + shortcut
    if lp.epilogue.relu:
        y = jnp.maximum(y, 0.0)
    return y


def execute_planned_layer(x, lp, *, interpret: bool | None = None,
                          shortcut=None):
    """Run one conv layer honoring ``LayerPlan.backend``.

    'fused' dispatches to ``kernels.fused_spectral_conv.
    execute_layer_plan`` (the plan's tuned variant); 'staged' runs the
    three-launch Pallas pipeline; 'einsum' the pure-jnp oracle — the
    ladder's terminal rung, which always executes.  Pooling stays with
    the caller.

    ``shortcut`` is the residual operand of a residual-fused DAG node
    (``EpilogueSpec.residual == 'fused'``): added after bias, before
    ReLU — inside the kernel flush on the fused backend, in the spatial
    epilogue otherwise.  On the 'add' rung the caller performs the add
    itself and must NOT pass a shortcut here.
    """
    backend = getattr(lp, "backend", "fused")
    if backend == "einsum":
        y = spec.spectral_conv2d_pretransformed(x, lp.kernels, lp.geo)
        return _spatial_epilogue(y, lp, shortcut)
    if backend == "staged":
        fault_check("lowering", layer=lp.layer.name, backend="staged")
        from repro.kernels import ops
        y = ops.spectral_conv2d_pallas(x, lp.kernels.values, lp.geo,
                                       interpret=interpret)
        return _spatial_epilogue(y, lp, shortcut)
    from repro.kernels.fused_spectral_conv import execute_layer_plan
    return execute_layer_plan(x, lp, interpret=interpret,
                              shortcut=shortcut)


# ---------------------------------------------------------------------------
# (2) Tiered graceful degradation
# ---------------------------------------------------------------------------

# The explicit demotion ladder, cheapest rung first.  Each entry is
# (axis, from, to); 'backend' rungs change which execution path runs
# the layer, the others stay on the fused kernel with a safer variant.
DEMOTION_LADDER = (
    ("input_mode", "halo", "windowed"),
    ("hadamard", "scheduled", "dense"),
    ("epilogue", "residual-fused", "residual-add"),
    ("backend", "fused", "staged"),
    ("backend", "staged", "einsum"),
)


def _summarize(err: BaseException) -> str:
    first = str(err).strip().splitlines()
    return f"{type(err).__name__}: {first[0] if first else ''}"


def _residual_add_fallback(lp):
    """Flip a residual-FUSED layer plan to the unfused-add rung: the
    kernel flushes bias-only output (in-kernel ReLU suppressed — it
    would clamp the pre-add activation) and the executor applies
    ``relu(y + shortcut)`` as a plain XLA add.  The tuning's shortcut
    placement is cleared so repricing stops charging fused-shortcut
    bytes."""
    import dataclasses as dc

    return dc.replace(
        lp,
        epilogue=dc.replace(lp.epilogue, residual="add", relu=False),
        tuning=dc.replace(lp.tuning, residual=None))


def _reprice_tuning(lp, batch: int):
    """Re-price one (possibly demoted) layer's tuning through the cost
    model so the recorded bytes/seconds stay honest for its current
    backend/modes."""
    import dataclasses as dc

    from repro.core.autotune import predict_seconds

    tn = lp.tuning
    if getattr(lp, "backend", "fused") == "fused":
        cost = _layer_cost(lp, batch)
        return dc.replace(tn, hbm_bytes=cost["hbm_bytes"],
                          vmem_bytes=cost["vmem_bytes"],
                          predicted_s=predict_seconds(cost),
                          hadamard=lp.hadamard,
                          input_mode=lp.input_mode)
    cost = df.tpu_flow_cost(lp.layer, lp.geo.fft_size, lp.alpha,
                            tn.block_n, tn.block_p, tn.block_m,
                            "output_stationary", batch=batch)
    return dc.replace(tn, hbm_bytes=cost["hbm_bytes"],
                      vmem_bytes=cost["vmem_bytes"],
                      predicted_s=predict_seconds(cost))


def demote_layer(lp, *, batch: int = 1, reason: BaseException | str = ""):
    """Demote one layer ONE rung down ``DEMOTION_LADDER``.

    Returns the demoted ``LayerPlan`` (tuning re-priced through the
    cost model so autotune's recorded numbers stay honest, demotion
    recorded in provenance), or None when the layer already sits on the
    terminal rung (einsum).
    """
    import dataclasses as dc

    note = _summarize(reason) if isinstance(reason, BaseException) \
        else str(reason)
    backend = getattr(lp, "backend", "fused")

    if backend == "fused" and lp.input_mode == "halo":
        new = dc.replace(lp, input_mode="windowed")
        rung = "input_mode halo->windowed"
    elif backend == "fused" and lp.hadamard == "scheduled":
        plane = "bin" if lp.active is not None else "dense"
        new = dc.replace(lp, hadamard=plane, tables=None)
        rung = f"hadamard scheduled->{plane}"
    elif backend == "fused" and \
            getattr(lp.epilogue, "residual", None) == "fused":
        new = _residual_add_fallback(lp)
        rung = "epilogue residual-fused->residual-add"
    elif backend == "fused":
        new = dc.replace(lp, backend="staged")
        rung = "backend fused->staged"
    elif backend == "staged":
        new = dc.replace(lp, backend="einsum")
        rung = "backend staged->einsum"
    else:
        return None

    tn = _reprice_tuning(new, batch)
    prov = getattr(lp, "provenance", ()) + (
        f"{rung} ({note})" if note else rung,)
    return dc.replace(new, tuning=tn, provenance=prov)


# The backend axis of the ladder in isolation — the rungs the serving
# front end (``launch.spectral_serve``) trades under load: each step
# swaps the whole execution path for a cheaper-to-trust one instead of
# a kernel variant (the input_mode/hadamard rungs stay with fault-driven
# hardening, where the *variant* is what failed).
BACKEND_RUNGS = ("fused", "staged", "einsum")


def demote_layer_backend(lp, *, batch: int = 1,
                         reason: BaseException | str = ""):
    """Demote one layer ONE rung along the backend axis only
    (fused -> staged -> einsum), skipping the input_mode/hadamard rungs.

    Used by the load-triggered ladder of ``launch.spectral_serve``:
    under queue/deadline pressure the server trades the whole execution
    path one rung at a time rather than individual kernel variants.
    Returns the demoted ``LayerPlan`` (re-priced, provenance-stamped
    like ``demote_layer``), or None on the terminal einsum rung.
    """
    import dataclasses as dc

    note = _summarize(reason) if isinstance(reason, BaseException) \
        else str(reason)
    backend = getattr(lp, "backend", "fused")
    nxt = {"fused": "staged", "staged": "einsum"}.get(backend)
    if nxt is None:
        return None
    new = lp
    extra = ()
    if backend == "fused" and \
            getattr(lp.epilogue, "residual", None) == "fused":
        # off the fused backend the epilogue add can't stay in-kernel;
        # drop to the unfused-add rung in the same step (the spatial
        # epilogue would otherwise ReLU before the add)
        new = _residual_add_fallback(new)
        extra = ("epilogue residual-fused->residual-add "
                 "(backend demotion)",)
    new = dc.replace(new, backend=nxt)
    tn = _reprice_tuning(new, batch)
    rung = f"backend {backend}->{nxt}"
    prov = getattr(lp, "provenance", ()) + extra + (
        f"{rung} ({note})" if note else rung,)
    return dc.replace(new, tuning=tn, provenance=prov)


def plan_at_backend_rung(plan, backend: str, *, reason: str = ""):
    """Return a copy of ``plan`` with every layer demoted to AT LEAST
    the given backend rung ('fused' | 'staged' | 'einsum').

    Layers already at (or below) the rung are untouched; the others are
    walked down ``demote_layer_backend`` one rung at a time so each
    transition is re-priced and recorded in provenance —
    ``health_report()`` on the result shows exactly what the load
    ladder traded.  ``backend='fused'`` returns the plan unchanged.
    """
    import dataclasses as dc

    if backend not in BACKEND_RUNGS:
        raise ValueError(f"backend must be one of {BACKEND_RUNGS}, "
                         f"got {backend!r}")
    target = BACKEND_RUNGS.index(backend)
    new_layers = []
    changed = False
    for lp in plan.layers:
        while BACKEND_RUNGS.index(getattr(lp, "backend", "fused")) < target:
            lp = demote_layer_backend(lp, batch=plan.batch, reason=reason)
            changed = True
        new_layers.append(lp)
    if not changed:
        return plan
    return dc.replace(plan, layers=tuple(new_layers))


def probe_layer_plan(lp, *, batch: int = 1,
                     interpret: bool | None = None
                     ) -> BaseException | None:
    """Capability probe: compile + run one layer forward on zeros.

    Returns None when the layer's chosen variant executes, else the
    exception it died with (for the hardening loop to attach to the
    demotion provenance).  In interpret mode this exercises the full
    trace/lower/execute path of the variant; on real TPU it is where a
    Mosaic lowering failure or VMEM overflow surfaces — once, at plan
    time, instead of mid-inference.
    """
    x = jnp.zeros((batch, lp.layer.c_in, lp.layer.h_in, lp.layer.w_in),
                  jnp.float32)
    shortcut = None
    if getattr(lp.epilogue, "residual", None) == "fused":
        # probe the variant that will actually run: a residual-fused
        # epilogue takes one more VMEM operand on the flush path
        hw = getattr(lp.layer, "out_hw", (lp.layer.h_in, lp.layer.w_in))
        shortcut = jnp.zeros((batch, lp.layer.c_out, hw[0], hw[1]),
                             jnp.float32)
    try:
        y = execute_planned_layer(x, lp, interpret=interpret,
                                  shortcut=shortcut)
        jnp.asarray(y).block_until_ready()
        return None
    except BaseException as e:           # noqa: BLE001 — probe boundary
        if isinstance(e, (KeyboardInterrupt, SystemExit)):
            raise
        return e


def harden_network_plan(plan, *, vmem_budget: int = df.TPU_VMEM_BYTES,
                        hw_safe: bool = True,
                        interpret: bool | None = None,
                        probe: bool = True):
    """Walk every layer down the demotion ladder until it validates AND
    its capability probe passes.

    A healthy plan comes back unchanged (same layer objects).  A layer
    whose chosen variant fails validation (error severity or VMEM
    over-budget) or fails to compile/execute is demoted one rung at a
    time — ``halo -> windowed``, ``scheduled -> dense``, ``fused ->
    staged -> einsum`` — re-probing after each rung.  The terminal
    einsum rung always executes; if even it fails, the original
    exception is re-raised wrapped in ``KernelLoweringError``.

    Returns a new ``NetworkPlan``; inspect ``health_report()`` (or each
    layer's ``provenance``) for what was demoted and why.
    """
    import dataclasses as dc

    new_layers = []
    for lp in plan.layers:
        for _ in range(len(DEMOTION_LADDER) + 1):
            issue: BaseException | None = None
            if getattr(lp, "backend", "fused") == "fused":
                diags = validate_layer_plan(
                    lp, batch=plan.batch, vmem_budget=vmem_budget,
                    hw_safe=hw_safe)
                bad = [d for d in diags
                       if d.severity == "error" or d.check == "vmem-budget"]
                if bad:
                    issue = PlanValidationError(
                        f"layer {lp.layer.name} failed validation",
                        layer=lp.layer.name, site="harden",
                        diagnostics=bad)
            if issue is None and probe:
                issue = probe_layer_plan(lp, batch=plan.batch,
                                         interpret=interpret)
            if issue is None:
                break
            demoted = demote_layer(lp, batch=plan.batch, reason=issue)
            if demoted is None:
                raise KernelLoweringError(
                    f"layer {lp.layer.name} failed on the terminal "
                    f"einsum rung: {_summarize(issue)}",
                    layer=lp.layer.name, site="harden") from issue
            lp = demoted
        new_layers.append(lp)
    return dc.replace(plan, layers=tuple(new_layers))


# ---------------------------------------------------------------------------
# (2b) Per-backend circuit breaker (serving front end)
# ---------------------------------------------------------------------------

BREAKER_STATES = ("closed", "open", "half_open")


@dataclasses.dataclass
class CircuitBreaker:
    """Consecutive-failure circuit breaker for one execution backend.

    The serving front end (``launch.spectral_serve``) keeps one breaker
    per ladder rung ('fused' / 'staged'; einsum is terminal and never
    gated).  State machine:

      closed     healthy: every request allowed.  ``failure_threshold``
                 CONSECUTIVE failures open the breaker (one success
                 resets the count).
      open       the rung is skipped entirely — requests start one rung
                 down — until ``cooldown_s`` elapses, when the breaker
                 moves to half_open.
      half_open  recovery probing: traffic is allowed through again;
                 ``recovery_successes`` consecutive successes close the
                 breaker, a single failure re-opens it (cooldown
                 restarts).

    ``clock`` is injectable for deterministic tests (any zero-arg
    callable returning seconds).  Every state change is appended to
    ``transitions`` and surfaced by ``snapshot()`` — the serve-level
    ``health_report()`` includes one snapshot per rung.
    """

    name: str = ""
    failure_threshold: int = 3
    cooldown_s: float = 1.0
    recovery_successes: int = 1
    clock: Callable[[], float] = time.monotonic
    state: str = "closed"
    failures: int = 0                 # consecutive failures
    successes: int = 0                # consecutive successes (half_open)
    opened_at: float | None = None
    n_opens: int = 0
    transitions: list = dataclasses.field(default_factory=list)

    def _to(self, state: str, why: str) -> None:
        self.transitions.append({"t": self.clock(), "from": self.state,
                                 "to": state, "why": why})
        self.state = state

    def allow(self) -> bool:
        """May a request be attempted on this backend right now?"""
        if self.state == "open":
            if self.clock() - self.opened_at >= self.cooldown_s:
                self.successes = 0
                self._to("half_open",
                         f"cooldown {self.cooldown_s}s elapsed")
                return True
            return False
        return True                   # closed or half_open (probing)

    def record_success(self) -> None:
        if self.state == "half_open":
            self.successes += 1
            if self.successes >= self.recovery_successes:
                self._to("closed", f"{self.successes} recovery "
                                   f"probe(s) succeeded")
                self.failures = 0
        else:
            self.failures = 0

    def record_failure(self, reason: str = "") -> None:
        self.successes = 0
        if self.state == "half_open":
            self.opened_at = self.clock()
            self.n_opens += 1
            self._to("open", f"recovery probe failed ({reason})"
                     if reason else "recovery probe failed")
        elif self.state == "closed":
            self.failures += 1
            if self.failures >= self.failure_threshold:
                self.opened_at = self.clock()
                self.n_opens += 1
                self._to("open",
                         f"{self.failures} consecutive failure(s)"
                         + (f" ({reason})" if reason else ""))

    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "state": self.state,
            "consecutive_failures": self.failures,
            "n_opens": self.n_opens,
            "transitions": list(self.transitions),
        }


# ---------------------------------------------------------------------------
# (3) Runtime numeric guards
# ---------------------------------------------------------------------------

GUARD_POLICIES = ("raise", "demote", "warn")


@dataclasses.dataclass
class NumericGuards:
    """Opt-in per-layer runtime checks for ``forward_spectral``.

    nan_scan:  scan every layer output for NaN/Inf.
    parity:    sampled self-check against the einsum oracle — recompute
               ``parity_channels`` evenly-spaced output channels on the
               first ``parity_batch`` images through
               ``spectral_conv2d_pretransformed`` and compare to
               ``parity_tol``.  Catches corrupted kernel operands /
               tables that are numerically valid but *wrong*.
    policy:    what a tripped guard does —
               'raise'  raise ``NumericGuardError`` (default);
               'demote' recompute the offending layer through the
                        einsum oracle and continue (the run's answer
                        stays parity-bounded);
               'warn'   emit a warning and keep the suspect output.
    events:    every trip is appended here as a dict, whatever the
               policy — the run's numeric-health audit trail.
    """

    nan_scan: bool = True
    parity: bool = False
    parity_tol: float = 1e-4
    parity_channels: int = 4
    parity_batch: int = 1
    policy: str = "raise"
    events: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.policy not in GUARD_POLICIES:
            raise ValueError(f"policy must be one of {GUARD_POLICIES}, "
                             f"got {self.policy!r}")


def _oracle_layer(x, lp, shortcut=None):
    y = spec.spectral_conv2d_pretransformed(x, lp.kernels, lp.geo)
    return _spatial_epilogue(y, lp, shortcut)


def _sampled_parity_err(x, y, lp, guards: NumericGuards,
                        shortcut=None) -> float:
    sk = lp.kernels
    n = sk.n_out
    sel = np.unique(np.linspace(
        0, n - 1, max(1, min(guards.parity_channels, n))).astype(int))
    sub = sp.SparseSpectralKernels(
        values=sk.values[sel], mask=sk.mask[sel],
        indices=sk.indices[sel], alpha=sk.alpha,
        active_bins=sk.active_bins)
    nb = max(1, min(guards.parity_batch, x.shape[0]))
    ref = spec.spectral_conv2d_pretransformed(x[:nb], sub, lp.geo)
    if lp.epilogue.bias:
        ref = ref + lp.bias[0][sel][None, :, None, None]
    if shortcut is not None:
        ref = ref + shortcut[:nb, np.asarray(sel)]
    if lp.epilogue.relu:
        ref = jnp.maximum(ref, 0.0)
    got = y[:nb, np.asarray(sel)]
    return float(jnp.abs(got - ref).max())


def apply_guards(x, y, lp, guards: NumericGuards, shortcut=None):
    """Run the enabled guards on one layer's output.

    ``x`` is the layer input (needed for the parity oracle and the
    demote fallback), ``y`` its computed output; ``shortcut`` is the
    residual operand already fused into ``y`` (residual-fused layers),
    so the parity oracle reproduces the same bias -> +shortcut -> ReLU
    epilogue.  Returns the output to carry forward — ``y`` itself, or
    the oracle recompute under the 'demote' policy.
    """
    name = lp.layer.name

    def trip(check: str, message: str):
        guards.events.append({"layer": name, "check": check,
                              "message": message,
                              "policy": guards.policy})
        if guards.policy == "raise":
            raise NumericGuardError(message, layer=name, site=check)
        if guards.policy == "warn":
            warnings.warn(f"[numeric-guard] {message}", RuntimeWarning,
                          stacklevel=3)
            return y
        return _oracle_layer(x, lp, shortcut)  # demote: oracle recompute

    if guards.nan_scan and not bool(jnp.isfinite(y).all()):
        return trip("nan_scan",
                    f"non-finite values in {name} output "
                    f"(backend={getattr(lp, 'backend', 'fused')}, "
                    f"hadamard={lp.hadamard}, "
                    f"input_mode={lp.input_mode})")
    if guards.parity and getattr(lp, "backend", "fused") != "einsum":
        err = _sampled_parity_err(x, y, lp, guards, shortcut)
        if not err <= guards.parity_tol:
            return trip(
                "parity",
                f"sampled parity vs einsum oracle failed on {name}: "
                f"max abs err {err:.3e} > tol {guards.parity_tol:.1e} "
                f"({guards.parity_channels} channels, "
                f"{guards.parity_batch} image(s))")
    return y


# ---------------------------------------------------------------------------
# (5) Sharded plans: partition validation + the sharded degradation ladder
# ---------------------------------------------------------------------------

def validate_layer_partition(slp, *, batch: int = 1) -> list[Diagnostic]:
    """Partition invariants of one ``core.plan.ShardedLayerPlan``:
    per-shard geometry AND the shapes every ICI collective assumes.

    The collective checks matter because a mismatch there does not
    raise — it HANGS (a psum over differently-shaped partials, or a
    ppermute whose halo width disagrees with the receiver's
    ``pre_halo_h``, deadlocks the mesh).  Checked per strategy:

      spatial   one shared band plan; ``pre_halo_h`` == k-1 (the rows
          ppermute ships), band rows == ``shard_band_rows``, band
          covers the full canvas, W-axis untouched;
      channel   D shard plans; D | c_in; every shard the SAME local
          dims, geometry and output channels (psum operands must agree
          elementwise), epilogue deferred (bias/relu post-psum, else
          the bias is summed D times), Alg-2 tables padded to one T
          (they stack into a single shard-mapped operand);
      replicate no shard plans at all.
    """
    out: list[Diagnostic] = []
    name = slp.base.layer.name
    d = lambda check, msg, sev="error": out.append(
        Diagnostic(name, check, msg, sev))

    if slp.strategy not in df.SHARD_STRATEGIES:
        d("shard/strategy", f"unknown strategy {slp.strategy!r}; must "
                            f"be one of {df.SHARD_STRATEGIES}")
        return out
    if slp.strategy == "replicate":
        if slp.shards:
            d("shard/replicate", f"replicate carries {len(slp.shards)} "
                                 f"shard plans; expected none")
        return out
    if slp.base.backend != "fused":
        d("shard/backend", f"sharded execution requires the fused "
                           f"backend; base is {slp.base.backend!r} "
                           f"(demote to 'replicate' instead)")
    geo = slp.base.geo
    ov = geo.ksize - 1
    D = slp.n_shards

    if slp.strategy == "spatial":
        if len(slp.shards) != 1:
            d("shard/spatial", f"spatial wants ONE shared band plan, "
                               f"got {len(slp.shards)}")
            return out
        band = slp.shards[0]
        bg = band.geo
        tr = spec.shard_band_rows(geo, D)
        if bg.pre_halo_h != ov:
            d("shard/halo-rows",
              f"band pre_halo_h={bg.pre_halo_h} != k-1={ov}; the "
              f"ppermute ships exactly k-1 rows per boundary — the "
              f"receiver would mis-index every tile")
        if bg.n_tiles_h != tr:
            d("shard/band-rows",
              f"band has {bg.n_tiles_h} tile rows, shard_band_rows "
              f"says {tr}")
        if bg.h_in != ov + tr * geo.tile or bg.h_pad != tr * geo.tile:
            d("shard/band-height",
              f"band h_in={bg.h_in}/h_pad={bg.h_pad} inconsistent with "
              f"{tr} tile rows of stride {geo.tile} plus {ov} halo rows")
        if D * tr < geo.n_tiles_h:
            d("shard/coverage",
              f"{D} bands x {tr} tile rows cover {D * tr} < "
              f"{geo.n_tiles_h} canvas tile rows")
        if (bg.w_in, bg.w_pad, bg.n_tiles_w) != (geo.w_in, geo.w_pad,
                                                 geo.n_tiles_w):
            d("shard/band-width",
              f"band W-axis {(bg.w_in, bg.w_pad, bg.n_tiles_w)} != "
              f"base {(geo.w_in, geo.w_pad, geo.n_tiles_w)}; spatial "
              f"sharding splits rows only")
        if band.layer.c_in != slp.base.layer.c_in:
            d("shard/band-channels",
              f"band c_in={band.layer.c_in} != {slp.base.layer.c_in}; "
              f"spatial shards keep full channels")
        return out

    # channel
    if len(slp.shards) != D:
        d("shard/channel", f"channel wants {D} shard plans, got "
                           f"{len(slp.shards)}")
        return out
    M = slp.base.layer.c_in
    if M % D:
        d("shard/divisibility", f"c_in={M} not divisible by D={D}")
        return out
    mloc = M // D
    t_lens = set()
    for i, sh in enumerate(slp.shards):
        if sh.layer.c_in != mloc:
            d("shard/local-dims",
              f"shard {i} c_in={sh.layer.c_in} != c_in/D={mloc}")
        if sh.layer.c_out != slp.base.layer.c_out or sh.geo != geo:
            d("shard/psum-shape",
              f"shard {i} output shape disagrees with the others "
              f"(c_out={sh.layer.c_out}, geo mismatch={sh.geo != geo}) "
              f"— psum operands must agree elementwise or the "
              f"collective deadlocks")
        if sh.epilogue.bias or sh.epilogue.relu:
            d("shard/epilogue",
              f"shard {i} fuses bias/relu into a PARTIAL sum; channel "
              f"shards must defer the epilogue to post-psum")
        if sh.tables is not None:
            t_lens.add(int(np.asarray(sh.tables.idx).shape[2]))
    if len(t_lens) > 1:
        d("shard/table-pad",
          f"shard Alg-2 tables disagree on cycle count T {sorted(t_lens)}"
          f"; they stack into one shard-mapped operand — pad to max T")
    return out


def validate_sharded_plan(splan, *,
                          vmem_budget: int = df.TPU_VMEM_BYTES,
                          hw_safe: bool = True,
                          raise_on_error: bool = True
                          ) -> list[Diagnostic]:
    """Validate a ``core.plan.ShardedNetworkPlan``: the base plan, every
    shard-local ``LayerPlan`` (full ``validate_layer_plan`` — shard
    plans carry LOCAL dims, so table/operand/halo checks see the shapes
    the kernel will), and the partition/collective invariants
    (``validate_layer_partition``)."""
    diags: list[Diagnostic] = []
    batch = splan.base.batch
    if len(splan.layers) != len(splan.base.layers):
        diags.append(Diagnostic(
            "<plan>", "shard/alignment",
            f"{len(splan.layers)} sharded layers vs "
            f"{len(splan.base.layers)} base layers"))
    if int(np.prod(splan.mesh_shape)) != splan.n_shards:
        diags.append(Diagnostic(
            "<plan>", "shard/mesh",
            f"mesh_shape {splan.mesh_shape} has "
            f"{int(np.prod(splan.mesh_shape))} devices, plan says "
            f"n_shards={splan.n_shards}"))
    for slp in splan.layers:
        for sh in slp.shards:
            diags.extend(validate_layer_plan(
                sh, batch=batch, vmem_budget=vmem_budget,
                hw_safe=hw_safe))
        diags.extend(validate_layer_partition(slp, batch=batch))
    errors = [d for d in diags if d.severity == "error"]
    if errors and raise_on_error:
        raise PlanValidationError(
            f"sharded plan {splan.name!r} failed validation "
            f"({len(errors)} error(s))",
            layer=errors[0].layer, site="validate_sharded_plan",
            diagnostics=errors)
    return diags


def probe_sharded_layer(slp, *, batch: int = 1,
                        interpret: bool | None = None
                        ) -> BaseException | None:
    """Capability probe for one sharded layer: consult the shard-scoped
    fault site, then compile + run every shard-local plan on zeros.

    Host-side and mesh-free by design: each shard plan executes as an
    ordinary single-device program (the collective wrappers add only
    ppermute/psum around these exact kernels), so a shard whose tables
    were corrupted or whose variant cannot lower is caught HERE — at
    plan time, before any device enters a collective it can never leave.
    """
    for i, sh in enumerate(slp.shards):
        try:
            fault_check("shard_tables", layer=slp.base.layer.name,
                        shard=i, strategy=slp.strategy)
        except BaseException as e:      # noqa: BLE001 — probe boundary
            if isinstance(e, (KeyboardInterrupt, SystemExit)):
                raise
            return e
        err = probe_layer_plan(sh, batch=batch, interpret=interpret)
        if err is not None:
            return err
    if not slp.shards:                  # replicate: probe the base
        return probe_layer_plan(slp.base, batch=batch,
                                interpret=interpret)
    return None


def harden_sharded_plan(splan, *,
                        vmem_budget: int = df.TPU_VMEM_BYTES,
                        hw_safe: bool = True,
                        interpret: bool | None = None,
                        probe: bool = True):
    """Per-layer degradation ladder for a sharded plan.

    Structured demotion instead of a collective hang: every check and
    probe runs host-side per shard (``probe_sharded_layer``), and every
    demotion is a PLAN-level decision applied before any shard_map is
    entered — all devices always trace the same program.  A failing
    layer walks the same ladder as ``harden_network_plan`` applied to
    its BASE plan (halo->windowed, scheduled->dense, fused->staged ->
    einsum), and the shard plans are REBUILT from the demoted base at
    each rung (``plan.resharded_layer_plan``); once the base leaves the
    fused backend the strategy collapses to 'replicate', whose terminal
    einsum rung always executes.

    Returns a new ``ShardedNetworkPlan`` (same objects where healthy);
    per-layer shard demotions append to ``ShardedLayerPlan.provenance``.
    """
    import dataclasses as dc

    from repro.core import plan as pl

    batch = splan.base.batch
    new_layers = []
    for slp in splan.layers:
        for _ in range(len(DEMOTION_LADDER) + 1):
            issue: BaseException | None = None
            diags = [dg for sh in slp.shards
                     for dg in validate_layer_plan(
                         sh, batch=batch, vmem_budget=vmem_budget,
                         hw_safe=hw_safe)]
            diags += validate_layer_partition(slp, batch=batch)
            bad = [dg for dg in diags
                   if dg.severity == "error" or dg.check == "vmem-budget"]
            if bad:
                issue = PlanValidationError(
                    f"sharded layer {slp.base.layer.name} failed "
                    f"validation", layer=slp.base.layer.name,
                    site="harden_sharded", diagnostics=bad)
            if issue is None and probe:
                issue = probe_sharded_layer(slp, batch=batch,
                                            interpret=interpret)
            if issue is None:
                break
            demoted = demote_layer(slp.base, batch=batch, reason=issue)
            if demoted is None:
                raise KernelLoweringError(
                    f"sharded layer {slp.base.layer.name} failed on "
                    f"the terminal replicated-einsum rung: "
                    f"{_summarize(issue)}",
                    layer=slp.base.layer.name,
                    site="harden_sharded") from issue
            slp = pl.resharded_layer_plan(
                slp, demoted, note=f"shard ladder: {_summarize(issue)}")
        new_layers.append(slp)
    base = dc.replace(splan.base,
                      layers=tuple(slp.base for slp in new_layers))
    return dc.replace(splan, base=base, layers=tuple(new_layers))
