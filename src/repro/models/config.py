"""Unified model configuration for every assigned architecture."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | xlstm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    rope_theta: float = 1e4
    qk_norm: bool = False
    window: int | None = None    # sliding-window attention
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # hybrid (zamba2): shared attention block every `attn_every` ssm blocks
    attn_every: int = 6
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # xlstm: every `slstm_every`-th block is sLSTM (0 = none)
    slstm_every: int = 8
    # encoder-decoder
    n_enc_layers: int = 0
    dec_train_len: int = 512     # decoder length used in train/prefill cells
    # frontend stub: 'tokens' consumes ids, 'frames' consumes embeddings
    frontend: str = "tokens"
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    remat_policy: str = "full"   # full | dots (save matmul outputs)
    kv_quant: bool = False       # int8 KV cache (decode memory lever)
    max_position: int = 1 << 20

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdt(self):
        return DTYPES[self.param_dtype]

    @property
    def cdt(self):
        return DTYPES[self.compute_dtype]

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.family == "moe":
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        elif self.mlp == "swiglu":
            ffn = 3 * d * f
        else:
            ffn = 2 * d * f
        if self.family == "xlstm":
            di = int(d * 2)
            per = 2 * d * di + 3 * di * di + di * d   # mLSTM block approx
            body = self.n_layers * per
        elif self.family == "hybrid":
            di = self.d_inner
            per = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) \
                + di * d
            n_attn = -(-self.n_layers // self.attn_every)
            body = self.n_layers * per + n_attn * 0 + (attn + 3 * d * f)
        elif self.family == "encdec":
            body = self.n_enc_layers * (attn + ffn) \
                + self.n_layers * (2 * attn + ffn)
        else:
            body = self.n_layers * (attn + ffn)
        embed = v * d * (1 if self.tie_embeddings else 2)
        return body + embed

    def active_param_count(self) -> int:
        """N_active for MoE (routed experts actually used per token)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        expert_ffn = self.n_layers * self.n_experts * 3 * d * f
        active_ffn = self.n_layers * self.top_k * 3 * d * f
        return self.param_count() - expert_ffn + active_ffn
