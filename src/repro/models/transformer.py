"""Decoder-only transformer stack (dense and MoE families).

Layers are stacked pytrees scanned with ``lax.scan`` so the lowered HLO is
depth-independent (critical for the 80-cell dry-run compile matrix).
``cfg.remat`` wraps the block body in ``jax.checkpoint`` for training.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain, current as current_ctx
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.config import ModelConfig

Array = jax.Array


def attn_config(cfg: ModelConfig) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
        window=cfg.window, use_rope=True)


def moe_config(cfg: ModelConfig) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(cfg.d_model, cfg.d_ff, cfg.n_experts,
                             cfg.top_k, cfg.capacity_factor)


def _norm_init(cfg: ModelConfig) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), cfg.pdt),
                "bias": jnp.zeros((cfg.d_model,), cfg.pdt)}
    return {"scale": jnp.ones((cfg.d_model,), cfg.pdt)}


def apply_norm(cfg: ModelConfig, p: dict, x: Array) -> Array:
    if cfg.norm == "layernorm":
        return L.layer_norm(x, p["scale"], p["bias"])
    return L.rms_norm(x, p["scale"])


def init_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": _norm_init(cfg),
        "attn": attn.init(k1, attn_config(cfg), cfg.pdt),
        "mlp_norm": _norm_init(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_lib.init(k2, moe_config(cfg), cfg.pdt)
    elif cfg.mlp == "gelu":
        p["mlp"] = L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.pdt)
    else:
        p["mlp"] = L.swiglu_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.pdt)
    return p


def block_forward(p: dict, cfg: ModelConfig, x: Array,
                  positions: Array) -> Array:
    h = apply_norm(cfg, p["attn_norm"], x)
    x = x + attn.forward(p["attn"], attn_config(cfg), h, positions)
    h = apply_norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        ctx = current_ctx()
        if ctx is not None and ctx.moe_ep and ctx.mesh is not None:
            y, _aux = moe_lib.forward_ep(
                p["moe"], moe_config(cfg), h, mesh=ctx.mesh,
                data_axes=ctx.batch_axes, model_axis=ctx.model_axis,
                fsdp_axes=ctx.fsdp_axes)
        else:
            y, _aux = moe_lib.forward(p["moe"], moe_config(cfg), h)
    elif cfg.mlp == "gelu":
        y = L.gelu_mlp(p["mlp"], h)
    else:
        y = L.swiglu_mlp(p["mlp"], h)
    return x + y


def block_decode(p: dict, cfg: ModelConfig, x: Array,
                 cache: attn.KVCache, pos: Array
                 ) -> tuple[Array, attn.KVCache]:
    h = apply_norm(cfg, p["attn_norm"], x)
    y, cache = attn.decode_step(p["attn"], attn_config(cfg), h, cache, pos)
    x = x + y
    h = apply_norm(cfg, p["mlp_norm"], x)
    if cfg.family == "moe":
        y, _ = moe_lib.forward(p["moe"], moe_config(cfg), h)
    elif cfg.mlp == "gelu":
        y = L.gelu_mlp(p["mlp"], h)
    else:
        y = L.swiglu_mlp(p["mlp"], h)
    return x + y, cache


# ---------------------------------------------------------------------------
# Stack
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> dict:
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(
        jax.random.split(k_blocks, cfg.n_layers))
    p = {
        "embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, cfg.pdt),
        "blocks": blocks,
        "final_norm": _norm_init(cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.dense_init(k_head, cfg.d_model, cfg.vocab, cfg.pdt)
    return p


def logits_head(params: dict, cfg: ModelConfig, x: Array) -> Array:
    x = apply_norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        return x @ params["embed"].astype(x.dtype).T
    return x @ params["unembed"].astype(x.dtype)


def embed_tokens(params: dict, cfg: ModelConfig, tokens: Array) -> Array:
    return params["embed"].astype(cfg.cdt)[tokens]


def forward(params: dict, cfg: ModelConfig, tokens: Array,
            positions: Array | None = None,
            last_only: bool = False) -> Array:
    """tokens: [B, S] int32 (or [B, S, d] frames for stub frontends).
    ``last_only`` heads only the final position (prefill serving)."""
    if tokens.ndim == 2:
        x = embed_tokens(params, cfg, tokens)
    else:
        x = tokens.astype(cfg.cdt)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)

    body = functools.partial(block_forward, cfg=cfg)

    def scan_body(carry, blk):
        if cfg.remat:
            policy = (jax.checkpoint_policies
                      .dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            fn = jax.checkpoint(body, policy=policy)
        else:
            fn = body
        return constrain(fn(blk, x=carry, positions=positions),
                         "residual"), None

    x = constrain(x, "residual")
    x, _ = jax.lax.scan(scan_body, x, params["blocks"])
    if last_only:
        x = x[:, -1:]
    return logits_head(params, cfg, x)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> attn.KVCache:
    one = lambda: attn.init_cache(attn_config(cfg), batch, max_len,
                                  cfg.cdt, quant=cfg.kv_quant)
    caches = [one() for _ in range(cfg.n_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def decode(params: dict, cfg: ModelConfig, token: Array,
           cache: attn.KVCache, pos: Array
           ) -> tuple[Array, attn.KVCache]:
    """token: [B, 1] int32; pos: scalar absolute position."""
    x = embed_tokens(params, cfg, token)

    def scan_body(carry, inp):
        blk, layer_cache = inp
        y, new_cache = block_decode(blk, cfg, carry, layer_cache, pos)
        return y, new_cache

    x, new_caches = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    return logits_head(params, cfg, x), new_caches
