"""Unified model API: init / forward / decode / caches for every family.

Families:
  dense   — decoder-only transformer (qwen3, yi, smollm, h2o-danube,
            chameleon backbone)
  moe     — decoder-only with MoE FFN (moonshot, kimi-k2)
  hybrid  — Mamba2 backbone + shared attention (zamba2)
  xlstm   — mLSTM/sLSTM stack (xlstm-350m)
  encdec  — whisper backbone (stub frame frontend)

All functions are pure; parameters and caches are pytrees, so the same
API lowers for the dry-run via ``jax.eval_shape`` without allocating.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer, xlstm_model
from repro.models import layers as L
from repro.models.config import ModelConfig

Array = jax.Array

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "hybrid": hybrid,
    "xlstm": xlstm_model,
    "encdec": encdec,
}


def module(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def init(key, cfg: ModelConfig) -> dict:
    return module(cfg).init(key, cfg)


def init_abstract(cfg: ModelConfig, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(seed), cfg))


def forward(params: dict, cfg: ModelConfig, batch: dict,
            last_only: bool = False) -> Array:
    """batch: {'tokens': [B,S]} or {'frames':..., 'tokens':...} (encdec)."""
    if cfg.family == "encdec":
        return encdec.forward(params, cfg, batch, last_only=last_only)
    inputs = batch["frames"] if cfg.frontend == "frames" else batch["tokens"]
    return module(cfg).forward(params, cfg, inputs, last_only=last_only)


def prefill(params: dict, cfg: ModelConfig, batch: dict) -> Array:
    """Prefill serving step: logits for the final position only."""
    return forward(params, cfg, batch, last_only=True)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict) -> Array:
    logits = forward(params, cfg, batch)
    return L.cross_entropy_loss(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, max_len, enc_len)
    if cfg.family == "xlstm":
        return xlstm_model.init_state(cfg, batch)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch, max_len)
    return transformer.init_cache(cfg, batch, max_len)


def decode(params: dict, cfg: ModelConfig, token: Array, cache,
           pos: Array):
    """One decode step: token [B, 1] -> (logits [B, 1, V], new cache)."""
    return module(cfg).decode(params, cfg, token, cache, pos)
