"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Recurrent, attention-free token mixing — the 'ssm' family arch of the
assignment (xlstm-350m).  Both cells use exponential input gating with
the max-stabilizer from the xLSTM paper; sequences run under
``lax.scan`` (compile size is depth-independent), decode carries the
explicit recurrent state, so long_500k decoding is O(1) per token.

mLSTM (per head, head dim P):
    m_t = max(f~_t + m_{t-1}, i~_t)
    f'  = exp(f~_t + m_{t-1} - m_t),  i' = exp(i~_t - m_t)
    C_t = f' C_{t-1} + i' v_t k_t^T          C in R^{P x P}
    n_t = f' n_{t-1} + i' k_t
    h_t = o_t * (C_t q_t) / max(|n_t . q_t|, exp(-m_t))

sLSTM (per unit, block-diagonal recurrence over heads):
    z = tanh(Wz x + Rz h_{t-1}),  gates i~, f~, o from x and h_{t-1}
    c_t = f' c_{t-1} + i' z,  n_t = f' n_{t-1} + i',  h_t = o * c_t / n_t
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    d_conv: int = 4

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "w_up": L.dense_init(ks[0], d, di, dtype),
        "w_gate": L.dense_init(ks[1], d, di, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.d_conv, di), jnp.float32)
                   * 0.2).astype(dtype),
        "wq": L.dense_init(ks[3], di, di, dtype),
        "wk": L.dense_init(ks[4], di, di, dtype),
        "wv": L.dense_init(ks[5], di, di, dtype),
        "w_if": L.dense_init(ks[6], di, 2 * h, dtype),
        "if_bias": jnp.concatenate([jnp.zeros((h,), jnp.float32),
                                    jnp.full((h,), 3.0, jnp.float32)]),
        "out_norm": jnp.ones((di,), dtype),
        "w_down": L.dense_init(ks[7], di, d, dtype),
    }


def mlstm_state(cfg: XLSTMConfig, batch: int) -> dict:
    h, p = cfg.n_heads, cfg.head_dim
    return {
        "c": jnp.zeros((batch, h, p, p), jnp.float32),
        "n": jnp.zeros((batch, h, p), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner),
                          jnp.float32),
    }


def _mlstm_gates(params, cfg, conv_out, u):
    bsz, t, _ = conv_out.shape
    h, p = cfg.n_heads, cfg.head_dim
    q = (conv_out @ params["wq"].astype(conv_out.dtype)
         ).reshape(bsz, t, h, p) * p ** -0.5
    k = (conv_out @ params["wk"].astype(conv_out.dtype)
         ).reshape(bsz, t, h, p) * p ** -0.5
    v = (u @ params["wv"].astype(u.dtype)).reshape(bsz, t, h, p)
    if_raw = (conv_out @ params["w_if"].astype(conv_out.dtype)
              ).astype(jnp.float32) + params["if_bias"]
    i_t, f_raw = jnp.split(if_raw, 2, axis=-1)              # [B,T,H]
    f_t = jax.nn.log_sigmoid(f_raw)
    return (q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), i_t, f_t)


def _mlstm_step(state, qkvif):
    q, k, v, i_t, f_t = qkvif
    m_new = jnp.maximum(f_t + state["m"], i_t)
    fp = jnp.exp(f_t + state["m"] - m_new)[..., None]
    ip = jnp.exp(i_t - m_new)[..., None]
    c = fp[..., None] * state["c"] + ip[..., None] * (
        v[..., :, None] * k[..., None, :])
    n = fp * state["n"] + ip * k
    denom = jnp.maximum(jnp.abs(jnp.sum(n * q, -1)), jnp.exp(-m_new))
    h_t = jnp.einsum("bhpn,bhn->bhp", c, q) / denom[..., None]
    new = dict(state, c=c, n=n, m=m_new)
    return new, h_t


def mlstm_forward(params: dict, cfg: XLSTMConfig, x: Array,
                  state: dict | None = None) -> tuple[Array, dict]:
    """x: [B, T, d_model]."""
    bsz, t, _ = x.shape
    if state is None:
        state = mlstm_state(cfg, bsz)
    u = x @ params["w_up"].astype(x.dtype)
    z = x @ params["w_gate"].astype(x.dtype)
    conv_out, new_conv = L.causal_conv1d(u, params["conv_w"],
                                         state["conv"].astype(u.dtype))
    conv_out = jax.nn.silu(conv_out)
    q, k, v, i_t, f_t = _mlstm_gates(params, cfg, conv_out, u)

    cell = {k2: state[k2] for k2 in ("c", "n", "m")}
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_t, f_t))
    cell, hs = jax.lax.scan(_mlstm_step, cell, xs)
    hs = jnp.moveaxis(hs, 0, 1).reshape(bsz, t, cfg.d_inner)

    y = L.rms_norm(hs.astype(x.dtype), params["out_norm"])
    y = y * jax.nn.silu(z)
    out = y @ params["w_down"].astype(x.dtype)
    return out, dict(cell, conv=new_conv.astype(jnp.float32))


def mlstm_decode(params: dict, cfg: XLSTMConfig, x: Array, state: dict
                 ) -> tuple[Array, dict]:
    return mlstm_forward(params, cfg, x, state)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    w = jax.random.normal(ks[0], (d, 4 * d), jnp.float32) * d ** -0.5
    r = jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32) * hd ** -0.5
    return {
        "w": w.astype(dtype),
        "r": r.astype(dtype),                     # block-diag recurrence
        "bias": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                                 jnp.full((d,), 3.0, jnp.float32),
                                 jnp.zeros((d,), jnp.float32)]),
        "ffn": L.swiglu_mlp_init(ks[2], d, int(d * 4 / 3), dtype),
        "ffn_norm": jnp.ones((d,), dtype),
    }


def slstm_state(cfg: XLSTMConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(params, cfg, state, x_t):
    """x_t: [B, 4d] pre-projected input; recurrent term added here."""
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    b = x_t.shape[0]
    h_prev = state["h"].reshape(b, h, hd)
    rec = jnp.einsum("bhi,hij->bhj", h_prev,
                     params["r"].astype(jnp.float32)).reshape(b, 4 * d)
    pre = x_t.astype(jnp.float32) + rec + params["bias"]
    zr, ir, fr, orr = jnp.split(pre.reshape(b, 4, d), 4, axis=1)
    z = jnp.tanh(zr[:, 0])
    i_t = ir[:, 0]
    f_t = jax.nn.log_sigmoid(fr[:, 0])
    o = jax.nn.sigmoid(orr[:, 0])
    m_new = jnp.maximum(f_t + state["m"], i_t)
    fp = jnp.exp(f_t + state["m"] - m_new)
    ip = jnp.exp(i_t - m_new)
    c = fp * state["c"] + ip * z
    n = fp * state["n"] + ip
    h_new = o * c / jnp.maximum(n, 1e-6)
    return dict(c=c, n=n, m=m_new, h=h_new), h_new


def slstm_forward(params: dict, cfg: XLSTMConfig, x: Array,
                  state: dict | None = None) -> tuple[Array, dict]:
    bsz, t, d = x.shape
    if state is None:
        state = slstm_state(cfg, bsz)
    pre = x @ params["w"].astype(x.dtype)                   # [B,T,4d]

    def step(st, x_t):
        return _slstm_step(params, cfg, st, x_t)

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    out = hs + L.swiglu_mlp(params["ffn"],
                            L.rms_norm(hs, params["ffn_norm"]))
    return out, state


def slstm_decode(params: dict, cfg: XLSTMConfig, x: Array, state: dict
                 ) -> tuple[Array, dict]:
    return slstm_forward(params, cfg, x, state)
