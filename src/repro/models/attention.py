"""Grouped-query attention with qk-norm / sliding-window / KV-cache decode.

Two execution paths:
  * ``impl='reference'`` — fused-by-XLA jnp attention (default; used for
    dry-run lowering and CPU smoke tests),
  * ``impl='flash'``    — the Pallas flash-attention kernel
    (repro.kernels.flash_attention), interpret-mode on CPU.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 1e4
    qk_norm: bool = False
    window: int | None = None        # sliding-window size (None = full)
    causal: bool = True
    use_rope: bool = True


def init(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": L.dense_init(ks[0], d, h * hd, dtype),
        "wk": L.dense_init(ks[1], d, g * hd, dtype),
        "wv": L.dense_init(ks[2], d, g * hd, dtype),
        "wo": L.dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params: dict, cfg: AttnConfig, x: Array,
                 positions: Array) -> tuple[Array, Array, Array]:
    b, s, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, s, g, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, s, g, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"])
        k = L.rms_norm(k, params["k_norm"])
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if cfg.use_rope:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, cfg: AttnConfig,
          q_positions: Array, k_positions: Array,
          kv_valid: Array | None = None) -> Array:
    """Reference attention. q: [B,H,S,D], k/v: [B,G,Skv,D]."""
    b, h, s, hd = q.shape
    g = k.shape[1]
    rep = h // g
    qg = q.reshape(b, g, rep, s, hd)
    logits = jnp.einsum("bgrqd,bgkd->bgrqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    qi = q_positions.reshape(b, 1, 1, s, 1)
    ki = k_positions.reshape(b, 1, 1, 1, -1)
    mask = jnp.ones(logits.shape[-2:], bool)
    if cfg.causal:
        mask = ki <= qi
    if cfg.window is not None:
        mask = mask & (ki > qi - cfg.window)
    if kv_valid is not None:
        mask = mask & kv_valid.reshape(b, 1, 1, 1, -1)
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, hd).astype(q.dtype)


def _chunked_sdpa(q: Array, k: Array, v: Array, cfg: AttnConfig,
                  q_positions: Array, k_positions: Array,
                  chunk: int = 1024) -> Array:
    """Flash-style online-softmax attention in pure XLA: lax.scan over KV
    chunks with running (max, denom, acc) — O(Sq * chunk) live memory so
    32k-500k cells pass memory analysis.  Matches ``_sdpa`` exactly."""
    b, h, sq, hd = q.shape
    g = k.shape[1]
    rep = h // g
    skv = k.shape[2]
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=jnp.iinfo(jnp.int32).max)
    n_chunks = k.shape[2] // chunk
    qg = q.reshape(b, g, rep, sq, hd).astype(jnp.float32)
    kc = k.reshape(b, g, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, g, n_chunks, chunk, hd).transpose(2, 0, 1, 3, 4)
    kp = k_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    qi = q_positions.reshape(b, 1, 1, sq, 1)
    scale = hd ** -0.5
    neg = jnp.float32(-1e30)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, kpb = inp
        logits = jnp.einsum("bgrqd,bgkd->bgrqk", qg,
                            kb.astype(jnp.float32)) * scale
        ki = kpb.reshape(b, 1, 1, 1, chunk)
        mask = jnp.ones(logits.shape[-2:], bool)
        if cfg.causal:
            mask = ki <= qi
        if cfg.window is not None:
            mask = mask & (ki > qi - cfg.window)
        mask = mask & (ki < jnp.iinfo(jnp.int32).max)
        logits = jnp.where(mask, logits, neg)
        m_new = jnp.maximum(m, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        p = jnp.where(m_new > neg / 2, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bgrqk,bgkd->bgrqd", p,
                                       vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, g, rep, sq, 1), neg, jnp.float32)
    l0 = jnp.zeros((b, g, rep, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, g, rep, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, kp))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, h, sq, hd).astype(q.dtype)


# sequences at or above this length use the chunked online-softmax path
CHUNKED_THRESHOLD = 4096


def forward(params: dict, cfg: AttnConfig, x: Array,
            positions: Array | None = None,
            impl: str = "auto") -> Array:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
    q, k, v = _project_qkv(params, cfg, x, positions)
    if impl == "flash":
        from repro.kernels import ops
        out = ops.attention(q, k, v, causal=cfg.causal, window=cfg.window)
    elif impl == "chunked" or (impl == "auto" and s >= CHUNKED_THRESHOLD):
        out = _chunked_sdpa(q, k, v, cfg, positions, positions)
    else:
        out = _sdpa(q, k, v, cfg, positions, positions)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"].astype(x.dtype)


def cross_forward(params: dict, cfg: AttnConfig, x: Array,
                  kv: tuple[Array, Array]) -> Array:
    """Cross-attention against precomputed encoder K/V [B,G,Senc,D]."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    q = q.transpose(0, 2, 1, 3)
    k, v = kv
    senc = k.shape[2]
    pos_q = jnp.zeros((b, s), jnp.int32)
    pos_k = jnp.zeros((b, senc), jnp.int32)
    nc_cfg = cfg._replace(causal=False, window=None)
    out = _sdpa(q, k, v, nc_cfg, pos_q, pos_k)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return out @ params["wo"].astype(x.dtype)


def encode_kv(params: dict, cfg: AttnConfig, enc: Array
              ) -> tuple[Array, Array]:
    """Project encoder states once into cross-attention K/V."""
    b, s, _ = enc.shape
    g, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc @ params["wk"].astype(enc.dtype)).reshape(b, s, g, hd)
    v = (enc @ params["wv"].astype(enc.dtype)).reshape(b, s, g, hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Ring-buffered KV cache.  For full attention the buffer length is the
    max context; for sliding-window layers it is the window size — the
    O(window) memory that makes long_500k runnable on SWA archs."""

    k: Array           # [B, G, L, D]
    v: Array           # [B, G, L, D]


class QuantKVCache(NamedTuple):
    """Int8 KV cache with per-(token, head) symmetric scales — halves the
    HBM traffic of the memory-bound decode cells (§Roofline 'next
    lever'); dequantized on the fly inside attention."""

    k_q: Array         # int8 [B, G, L, D]
    v_q: Array         # int8 [B, G, L, D]
    k_s: Array         # f32  [B, G, L, 1]
    v_s: Array         # f32  [B, G, L, 1]


def _quantize_rows(x: Array) -> tuple[Array, Array]:
    """x: [..., D] -> (int8 values, f32 scale over the last dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale


def init_cache(cfg: AttnConfig, batch: int, max_len: int,
               dtype=jnp.float32, quant: bool = False
               ) -> KVCache | QuantKVCache:
    length = min(max_len, cfg.window) if cfg.window else max_len
    shape = (batch, cfg.n_kv_heads, length, cfg.head_dim)
    if quant:
        sshape = shape[:-1] + (1,)
        return QuantKVCache(jnp.zeros(shape, jnp.int8),
                            jnp.zeros(shape, jnp.int8),
                            jnp.ones(sshape, jnp.float32),
                            jnp.ones(sshape, jnp.float32))
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_step(params: dict, cfg: AttnConfig, x: Array,
                cache: KVCache | QuantKVCache, pos: Array
                ) -> tuple[Array, KVCache | QuantKVCache]:
    """One-token attention.  x: [B, 1, d], pos: [] or [B] current index."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k_new, v_new = _project_qkv(params, cfg, x, pos[:, None])
    quant = isinstance(cache, QuantKVCache)
    length = (cache.k_q if quant else cache.k).shape[2]
    slot = pos % length
    bidx = jnp.arange(b)
    if quant:
        kq, ks = _quantize_rows(k_new[:, :, 0])
        vq, vs = _quantize_rows(v_new[:, :, 0])
        new_cache = QuantKVCache(
            cache.k_q.at[bidx, :, slot].set(kq),
            cache.v_q.at[bidx, :, slot].set(vq),
            cache.k_s.at[bidx, :, slot].set(ks),
            cache.v_s.at[bidx, :, slot].set(vs))
        k = (new_cache.k_q.astype(x.dtype)
             * new_cache.k_s.astype(x.dtype))
        v = (new_cache.v_q.astype(x.dtype)
             * new_cache.v_s.astype(x.dtype))
    else:
        k = cache.k.at[bidx, :, slot].set(k_new[:, :, 0])
        v = cache.v.at[bidx, :, slot].set(v_new[:, :, 0])

    # absolute positions of cache slots (ring arithmetic)
    slots = jnp.arange(length)[None, :]                      # [1, L]
    wrap = jnp.where(slots <= slot[:, None], 0, length)      # [B, L]
    k_pos = slots - wrap + (pos[:, None] // length) * length
    k_valid = (k_pos >= 0) & (k_pos <= pos[:, None])

    out = _sdpa(q, k, v, cfg, pos[:, None], k_pos, kv_valid=k_valid)
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    final = new_cache if quant else KVCache(k, v)
    return out @ params["wo"].astype(x.dtype), final
