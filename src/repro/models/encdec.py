"""Whisper-style encoder-decoder backbone.

Per the assignment the conv/audio frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings [B, S_enc, d_model].  The encoder is
a bidirectional transformer over frames with sinusoidal absolute positions
(computed on the fly, so any S_enc lowers); the decoder is causal
self-attention + cross-attention + GELU MLP over text tokens.

Deviation noted in DESIGN.md: original Whisper uses learned decoder
positions capped at 448; the assignment's decode_32k/prefill_32k cells
need arbitrary positions, so both sides use sinusoidal encodings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import transformer as tf
from repro.models.config import ModelConfig

Array = jax.Array


def _sinusoid(positions: Array, d: int) -> Array:
    """[.., S] int positions -> [.., S, d] sinusoidal embeddings."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_attn_config(cfg: ModelConfig) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        causal=False, use_rope=False, qk_norm=False)


def _dec_attn_config(cfg: ModelConfig) -> attn.AttnConfig:
    return attn.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
        causal=True, use_rope=False, qk_norm=False)


def _init_enc_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": tf._norm_init(cfg),
        "attn": attn.init(k1, _enc_attn_config(cfg), cfg.pdt),
        "mlp_norm": tf._norm_init(cfg),
        "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.pdt),
    }


def _init_dec_block(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": tf._norm_init(cfg),
        "self_attn": attn.init(k1, _dec_attn_config(cfg), cfg.pdt),
        "cross_norm": tf._norm_init(cfg),
        "cross_attn": attn.init(k2, _dec_attn_config(cfg), cfg.pdt),
        "mlp_norm": tf._norm_init(cfg),
        "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.pdt),
    }


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    return {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdt),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(
            jax.random.split(ks[1], cfg.n_enc_layers)),
        "enc_norm": tf._norm_init(cfg),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(
            jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": tf._norm_init(cfg),
        "unembed": L.dense_init(ks[3], cfg.d_model, cfg.vocab, cfg.pdt),
    }


def encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """frames: [B, S_enc, d_model] (stub frontend output)."""
    b, s, _ = frames.shape
    pos = jnp.arange(s, dtype=jnp.int32)
    x = frames.astype(cfg.cdt) + _sinusoid(pos, cfg.d_model
                                           )[None].astype(cfg.cdt)

    def body(carry, blk):
        x = carry
        h = tf.apply_norm(cfg, blk["attn_norm"], x)
        x = x + attn.forward(blk["attn"], _enc_attn_config(cfg), h)
        h = tf.apply_norm(cfg, blk["mlp_norm"], x)
        x = x + L.gelu_mlp(blk["mlp"], h)
        return x, None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return tf.apply_norm(cfg, params["enc_norm"], x)


def _dec_block_fwd(blk, cfg: ModelConfig, x: Array, enc: Array,
                   positions: Array) -> Array:
    h = tf.apply_norm(cfg, blk["self_norm"], x)
    x = x + attn.forward(blk["self_attn"], _dec_attn_config(cfg), h,
                         positions)
    h = tf.apply_norm(cfg, blk["cross_norm"], x)
    kv = attn.encode_kv(blk["cross_attn"], _dec_attn_config(cfg), enc)
    x = x + attn.cross_forward(blk["cross_attn"], _dec_attn_config(cfg),
                               h, kv)
    h = tf.apply_norm(cfg, blk["mlp_norm"], x)
    return x + L.gelu_mlp(blk["mlp"], h)


def forward(params: dict, cfg: ModelConfig, batch: dict,
            last_only: bool = False) -> Array:
    """batch: {'frames': [B, S_enc, d], 'tokens': [B, S_dec]}."""
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"].astype(cfg.cdt)[tokens] \
        + _sinusoid(jnp.arange(s, dtype=jnp.int32),
                    cfg.d_model)[None].astype(cfg.cdt)
    positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)

    def body(carry, blk):
        return _dec_block_fwd(blk, cfg, carry, enc, positions), None

    fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
    if last_only:
        x = x[:, -1:]
    return tf.apply_norm(cfg, params["final_norm"], x) \
        @ params["unembed"].astype(cfg.cdt)


# ---------------------------------------------------------------------------
# Decode: self KV cache + precomputed cross KV
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int) -> dict:
    acfg = _dec_attn_config(cfg)
    stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
    return {
        "self": stack([attn.init_cache(acfg, batch, max_len, cfg.cdt)
                       for _ in range(cfg.n_layers)]),
        "cross_k": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads,
                              enc_len, cfg.hd), cfg.cdt),
        "cross_v": jnp.zeros((cfg.n_layers, batch, cfg.n_kv_heads,
                              enc_len, cfg.hd), cfg.cdt),
    }


def precompute_cross(params: dict, cfg: ModelConfig, frames: Array,
                     cache: dict) -> dict:
    enc = encode(params, cfg, frames)

    def per_layer(blk):
        return attn.encode_kv(blk["cross_attn"], _dec_attn_config(cfg), enc)

    k, v = jax.vmap(per_layer)(params["dec_blocks"])
    return dict(cache, cross_k=k, cross_v=v)


def decode(params: dict, cfg: ModelConfig, token: Array, cache: dict,
           pos: Array) -> tuple[Array, dict]:
    b = token.shape[0]
    x = params["embed"].astype(cfg.cdt)[token] \
        + _sinusoid(jnp.asarray(pos, jnp.int32)[None, None],
                    cfg.d_model).astype(cfg.cdt)

    def body(carry, inp):
        x = carry
        blk, self_cache, ck, cv = inp
        h = tf.apply_norm(cfg, blk["self_norm"], x)
        y, new_self = attn.decode_step(blk["self_attn"],
                                       _dec_attn_config(cfg), h,
                                       self_cache, pos)
        x = x + y
        h = tf.apply_norm(cfg, blk["cross_norm"], x)
        x = x + attn.cross_forward(blk["cross_attn"],
                                   _dec_attn_config(cfg), h, (ck, cv))
        h = tf.apply_norm(cfg, blk["mlp_norm"], x)
        x = x + L.gelu_mlp(blk["mlp"], h)
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"],
                  cache["cross_k"], cache["cross_v"]))
    logits = tf.apply_norm(cfg, params["final_norm"], x) \
        @ params["unembed"].astype(cfg.cdt)
    return logits, dict(cache, self=new_self)
