"""Model substrate: layers, attention, MoE, SSM, xLSTM, assemblies.

Public entry point: ``repro.models.api`` (init / forward / decode) driven
by ``repro.models.config.ModelConfig``; architecture configs live in
``repro.configs``.
"""
