"""Zamba2-style hybrid stack: Mamba2 backbone + a SHARED attention block.

The backbone is ``n_layers`` Mamba2 blocks; before every ``attn_every``-th
block the single shared transformer block (attention + MLP, one parameter
set reused at every application — Zamba2's signature trick) refines the
residual stream.  Layers are scanned in groups of ``attn_every`` so the
HLO stays depth-independent:

    [shared attn] -> ssm x attn_every   ... repeated, remainder unrolled.

Each shared-block application has its own KV cache (it sees the stream at
a different depth), so decode carries ``n_groups (+1)`` caches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm as ssm_lib
from repro.models import transformer as tf
from repro.models.config import ModelConfig

Array = jax.Array


def ssm_config(cfg: ModelConfig) -> ssm_lib.SSMConfig:
    return ssm_lib.SSMConfig(
        d_model=cfg.d_model, d_inner=cfg.d_inner,
        n_heads=cfg.ssm_heads, d_state=cfg.ssm_state,
        chunk=cfg.ssm_chunk)


def _group_sizes(cfg: ModelConfig) -> tuple[int, int]:
    n_groups = cfg.n_layers // cfg.attn_every
    remainder = cfg.n_layers - n_groups * cfg.attn_every
    return n_groups, remainder


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    scfg = ssm_config(cfg)
    n_groups, remainder = _group_sizes(cfg)

    def init_ssm_block(k):
        return {"norm": tf._norm_init(cfg),
                "ssm": ssm_lib.init(k, scfg, cfg.pdt)}

    grouped = jax.vmap(jax.vmap(lambda k: init_ssm_block(k)))(
        jax.random.split(ks[0], n_groups * cfg.attn_every
                         ).reshape(n_groups, cfg.attn_every, 2))
    p = {
        "embed": L.embed_init(ks[1], cfg.vocab, cfg.d_model, cfg.pdt),
        "groups": grouped,
        "shared": tf.init_block(ks[2], cfg),
        "final_norm": tf._norm_init(cfg),
        "unembed": L.dense_init(ks[3], cfg.d_model, cfg.vocab, cfg.pdt),
    }
    if remainder:
        p["rem"] = jax.vmap(lambda k: init_ssm_block(k))(
            jax.random.split(ks[4], remainder))
    return p


def _ssm_block_fwd(blk, cfg: ModelConfig, x: Array,
                   state: dict | None) -> tuple[Array, dict]:
    h = tf.apply_norm(cfg, blk["norm"], x)
    y, new_state = ssm_lib.forward(blk["ssm"], ssm_config(cfg), h, state)
    return x + y, new_state


def _ssm_block_step(blk, cfg: ModelConfig, x: Array,
                    state: dict) -> tuple[Array, dict]:
    h = tf.apply_norm(cfg, blk["norm"], x)
    y, new_state = ssm_lib.decode_step(blk["ssm"], ssm_config(cfg), h,
                                       state)
    return x + y, new_state


def forward(params: dict, cfg: ModelConfig, tokens: Array,
            positions: Array | None = None,
            last_only: bool = False) -> Array:
    x = tf.embed_tokens(params, cfg, tokens)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
    n_groups, remainder = _group_sizes(cfg)
    shared = params["shared"]

    def group_body(carry, group_params):
        x = carry
        x = tf.block_forward(shared, cfg, x, positions)     # shared attn

        def inner(c, blk):
            y, _ = _ssm_block_fwd(blk, cfg, c, None)
            return y, None

        inner_fn = jax.checkpoint(inner) if cfg.remat else inner
        x, _ = jax.lax.scan(inner_fn, x, group_params)
        return x, None

    x, _ = jax.lax.scan(group_body, x, params["groups"])
    if remainder:
        x = tf.block_forward(shared, cfg, x, positions)

        def inner(c, blk):
            y, _ = _ssm_block_fwd(blk, cfg, c, None)
            return y, None

        x, _ = jax.lax.scan(inner, x, params["rem"])
    if last_only:
        x = x[:, -1:]
    return tf.logits_head(params, cfg, x)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_groups, remainder = _group_sizes(cfg)
    scfg = ssm_config(cfg)
    acfg = tf.attn_config(cfg)
    n_attn = n_groups + (1 if remainder else 0)
    attn_caches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[attn.init_cache(acfg, batch, max_len, cfg.cdt,
                          quant=cfg.kv_quant)
          for _ in range(n_attn)])
    ssm_states = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[ssm_lib.init_state(scfg, batch, cfg.cdt)
          for _ in range(n_groups * cfg.attn_every)])
    ssm_states = jax.tree.map(
        lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]),
        ssm_states)
    cache = {"attn": attn_caches, "ssm": ssm_states}
    if remainder:
        cache["ssm_rem"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[ssm_lib.init_state(scfg, batch, cfg.cdt)
              for _ in range(remainder)])
    return cache


def decode(params: dict, cfg: ModelConfig, token: Array, cache: dict,
           pos: Array) -> tuple[Array, dict]:
    x = tf.embed_tokens(params, cfg, token)
    n_groups, remainder = _group_sizes(cfg)
    shared = params["shared"]
    attn_caches = cache["attn"]
    group_attn = jax.tree.map(lambda a: a[:n_groups], attn_caches)

    def group_body(carry, inp):
        x = carry
        gp, a_cache, s_states = inp
        x, new_a = tf.block_decode(shared, cfg, x, a_cache, pos)

        def inner(c, blk_state):
            blk, st = blk_state
            y, new_st = _ssm_block_step(blk, cfg, c, st)
            return y, new_st

        x, new_s = jax.lax.scan(inner, x, (gp, s_states))
        return x, (new_a, new_s)

    x, (new_attn, new_ssm) = jax.lax.scan(
        group_body, x, (params["groups"], group_attn, cache["ssm"]))
    new_cache = {"attn": new_attn, "ssm": new_ssm}
    if remainder:
        last_attn = jax.tree.map(lambda a: a[n_groups], attn_caches)
        x, new_last = tf.block_decode(shared, cfg, x, last_attn, pos)
        new_cache["attn"] = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b[None]], 0),
            new_attn, new_last)

        def inner(c, blk_state):
            blk, st = blk_state
            y, new_st = _ssm_block_step(blk, cfg, c, st)
            return y, new_st

        x, new_rem = jax.lax.scan(inner, x, (params["rem"],
                                             cache["ssm_rem"]))
        new_cache["ssm_rem"] = new_rem
    return tf.logits_head(params, cfg, x), new_cache
