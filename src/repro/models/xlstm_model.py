"""xLSTM stack assembly: groups of (slstm_every - 1) mLSTM + 1 sLSTM.

xlstm-350m: 24 blocks with an sLSTM every 8th block (7:1 ratio as in the
xLSTM paper); the remainder (if depth % slstm_every != 0) is mLSTM-only.
Scanned in groups so compile size is depth-independent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as tf
from repro.models import xlstm as xl
from repro.models.config import ModelConfig

Array = jax.Array


def xl_config(cfg: ModelConfig) -> xl.XLSTMConfig:
    return xl.XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads)


def _group_sizes(cfg: ModelConfig) -> tuple[int, int, int]:
    per = cfg.slstm_every
    n_groups = cfg.n_layers // per
    remainder = cfg.n_layers - n_groups * per
    return n_groups, per - 1, remainder


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    xcfg = xl_config(cfg)
    n_groups, m_per, remainder = _group_sizes(cfg)

    def init_m(k):
        return {"norm": tf._norm_init(cfg),
                "cell": xl.mlstm_init(k, xcfg, cfg.pdt)}

    def init_s(k):
        return {"norm": tf._norm_init(cfg),
                "cell": xl.slstm_init(k, xcfg, cfg.pdt)}

    p = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdt),
        "m_groups": jax.vmap(jax.vmap(init_m))(
            jax.random.split(ks[1], n_groups * m_per
                             ).reshape(n_groups, m_per, 2)),
        "s_blocks": jax.vmap(init_s)(jax.random.split(ks[2], n_groups)),
        "final_norm": tf._norm_init(cfg),
        "unembed": L.dense_init(ks[3], cfg.d_model, cfg.vocab, cfg.pdt),
    }
    if remainder:
        p["rem"] = jax.vmap(init_m)(jax.random.split(ks[4], remainder))
    return p


def _run(params: dict, cfg: ModelConfig, tokens: Array,
         states: dict | None, last_only: bool = False
         ) -> tuple[Array, dict]:
    xcfg = xl_config(cfg)
    n_groups, m_per, remainder = _group_sizes(cfg)
    x = params["embed"].astype(cfg.cdt)[tokens]
    bsz = x.shape[0]

    if states is None:
        states = init_state(cfg, bsz)

    def m_block(c, blk_st):
        blk, st = blk_st
        h = tf.apply_norm(cfg, blk["norm"], c)
        y, new_st = xl.mlstm_forward(blk["cell"], xcfg, h, st)
        return c + y, new_st

    def group_body(carry, inp):
        x = carry
        gp, sp, m_states, s_state = inp
        fn = jax.checkpoint(m_block) if cfg.remat else m_block
        x, new_m = jax.lax.scan(fn, x, (gp, m_states))
        h = tf.apply_norm(cfg, sp["norm"], x)
        y, new_s = xl.slstm_forward(sp["cell"], xcfg, h, s_state)
        return x + y, (new_m, new_s)

    x, (new_m, new_s) = jax.lax.scan(
        group_body, x,
        (params["m_groups"], params["s_blocks"],
         states["m"], states["s"]))
    new_states = {"m": new_m, "s": new_s}
    if remainder:
        x, new_rem = jax.lax.scan(m_block, x,
                                  (params["rem"], states["rem"]))
        new_states["rem"] = new_rem
    if last_only:
        x = x[:, -1:]
    logits = tf.apply_norm(cfg, params["final_norm"], x) \
        @ params["unembed"].astype(cfg.cdt)
    return logits, new_states


def forward(params: dict, cfg: ModelConfig, tokens: Array,
            positions: Array | None = None,
            last_only: bool = False) -> Array:
    return _run(params, cfg, tokens, None, last_only)[0]


def init_state(cfg: ModelConfig, batch: int) -> dict:
    xcfg = xl_config(cfg)
    n_groups, m_per, remainder = _group_sizes(cfg)
    stack = lambda xs: jax.tree.map(lambda *a: jnp.stack(a), *xs)
    m_states = stack([xl.mlstm_state(xcfg, batch)
                      for _ in range(n_groups * m_per)])
    m_states = jax.tree.map(
        lambda a: a.reshape((n_groups, m_per) + a.shape[1:]), m_states)
    st = {
        "m": m_states,
        "s": stack([xl.slstm_state(xcfg, batch) for _ in range(n_groups)]),
    }
    if remainder:
        st["rem"] = stack([xl.mlstm_state(xcfg, batch)
                           for _ in range(remainder)])
    return st


def decode(params: dict, cfg: ModelConfig, token: Array, states: dict,
           pos: Array) -> tuple[Array, dict]:
    """Recurrent one-token step — pos is unused (stateful model)."""
    return _run(params, cfg, token, states)
