"""Spectral VGG16 — the paper's own target model, end to end.

Conv stack runs in the spectral domain (overlap-save FFT tiling + sparse
Hadamard, repro.core.spectral) with per-layer dataflow chosen by Alg 1;
max-pool / FC head run in the spatial domain.  On the paper's CPU+FPGA
platform those stages were offloaded to the CPU; here everything is one
jitted JAX program (DESIGN.md, adaptation note 3).

Since the LayerPlan refactor the forward pass *executes a plan*
(``core.plan.build_network_plan``): geometry, pruned kernels, Alg-2
active-bin compaction, autotuned flow/blocks and the fused bias+ReLU
epilogue are all precompiled once, offline — exactly as the paper
compiles per-layer configurations before inference — and every backend
of ``forward_spectral`` just walks the plan.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import dataflow as df
from repro.core import resilience as res
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.models import layers as L

Array = jax.Array

# after which conv layers a 2x2 max-pool follows
_POOL_AFTER = frozenset(
    {"conv1_2", "conv2_2", "conv3_3", "conv4_3", "conv5_3"})


@dataclasses.dataclass(frozen=True)
class SpectralCNNConfig:
    name: str = "vgg16-spectral"
    layers: Sequence[df.ConvLayer] = df.VGG16_LAYERS
    fft_size: int = 8
    # Spectral kernel compression: scalar, or one alpha per conv layer
    # (the paper prunes layers non-uniformly).
    alpha: float | Sequence[float] = 4.0
    n_classes: int = 1000
    image_size: int = 224
    fc_dim: int = 4096
    pool_after: frozenset = _POOL_AFTER


def init(key, cfg: SpectralCNNConfig) -> dict:
    """Spatial-domain weights; spectral transform + pruning are separate
    (mirrors the paper: kernels pruned offline, stored pre-transformed)."""
    ks = jax.random.split(key, len(cfg.layers) + 3)
    convs = []
    for k, layer in zip(ks, cfg.layers):
        fan_in = layer.c_in * layer.ksize ** 2
        w = jax.random.normal(
            k, (layer.c_out, layer.c_in, layer.ksize, layer.ksize),
            jnp.float32) * (2.0 / fan_in) ** 0.5
        convs.append({"w": w, "b": jnp.zeros((layer.c_out,))})
    feat = cfg.layers[-1].c_out * (cfg.image_size // 32) ** 2
    return {
        "convs": convs,
        "fc1": L.dense_init(ks[-3], feat, cfg.fc_dim),
        "fc2": L.dense_init(ks[-2], cfg.fc_dim, cfg.fc_dim),
        "fc3": L.dense_init(ks[-1], cfg.fc_dim, cfg.n_classes),
    }


def transform_kernels(params: dict, cfg: SpectralCNNConfig
                      ) -> list[sp.SparseSpectralKernels]:
    """Offline: spatial -> spectral -> pruned, per-layer alpha."""
    alphas = sp.per_layer_alphas(cfg.alpha, len(cfg.layers))
    out = []
    for conv, alpha in zip(params["convs"], alphas):
        wf = spec.spectral_kernel(conv["w"], cfg.fft_size)
        out.append(sp.prune_magnitude(wf, alpha))
    return out


def build_plan(params: dict, cfg: SpectralCNNConfig, **kwargs):
    """Convenience re-export: ``core.plan.build_network_plan``."""
    from repro.core.plan import build_network_plan
    return build_network_plan(params, cfg, **kwargs)


def _pool(x: Array) -> Array:
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


BACKENDS = ("einsum", "pallas_staged", "pallas_fused")


def _epilogue_spatial(x: Array, lp) -> Array:
    """Bias + ReLU for the backends that don't fuse it into the kernel."""
    if lp.epilogue.bias:
        x = x + lp.bias[0][None, :, None, None]
    if lp.epilogue.relu:
        x = jax.nn.relu(x)
    return x


def forward_spectral(params: dict, plan, x: Array, *,
                     backend: str = "einsum",
                     interpret: bool | None = None,
                     guards: res.NumericGuards | None = None) -> Array:
    """Inference by executing a precompiled ``core.plan.NetworkPlan``.

    Args:
      params: the weights ``init`` produced (the conv stack reads only
        the plan's baked operands, but the FC head reads ``params``).
      plan: a ``core.plan.NetworkPlan`` built ONCE by
        ``build_network_plan`` for this config and batch size.
      x: [B, C, H, W] f32 input batch; must match the plan's layer
        geometry, and for the fused backend on hardware the plan's
        tuned batch (RMW-flow safety — see the error message).
      backend: conv-stack implementation, one of ``BACKENDS``:
        'einsum'        pure-jnp oracle (sparse-aware masked einsum);
        'pallas_staged' 3 pallas_calls/layer: fft8 -> hadamard ->
                        ifft8, spectral intermediates round-tripping
                        through HBM;
        'pallas_fused'  ONE pallas_call/layer executing the plan's
                        precompiled operands with bias+ReLU fused into
                        the kernel flush.  Each layer runs the Hadamard
                        datapath its plan chose (``LayerPlan.hadamard``):
                        'dense'/'bin' stream (compacted) kernel planes
                        through the Karatsuba GEMM, 'scheduled' executes
                        the layer's Alg-2 INDEX/VALUE tables element-
                        granularly (``execute_layer_plan`` dispatches).
      interpret: force Pallas interpret mode (None = auto: interpret
        everywhere except real TPU).
      guards: optional ``core.resilience.NumericGuards`` enabling the
        opt-in per-layer runtime checks (NaN/Inf scan, sampled parity
        vs the einsum oracle) on the Pallas backends, with policy
        'raise' | 'demote' | 'warn'.  Every trip is appended to
        ``guards.events``.

    Under the 'pallas_fused' backend each layer runs the execution path
    its plan records (``LayerPlan.backend`` — 'fused' as built, or
    'staged'/'einsum' after ``resilience.harden_network_plan`` demoted
    it), and any unexpected per-layer failure is re-raised as a
    structured ``resilience.KernelLoweringError`` naming the layer and
    its modes — never a raw Pallas traceback.

    Returns [B, n_classes] logits.  Everything layer-specific was
    derived at plan-build time; nothing (geometry, schedules, pruning,
    table compilation, autotune) is rebuilt here, so repeated calls go
    straight to the jit cache.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if backend == "pallas_fused" and x.shape[0] != plan.batch:
        on_hw = interpret is False or (interpret is None
                                       and jax.default_backend() == "tpu")
        rmw = [lp.layer.name for lp in plan.layers
               if lp.tuning.flow != "output_stationary"]
        if on_hw and rmw:
            # the RMW flows' hardware-safety (single p/n block) was
            # established for plan.batch; a different batch changes P
            # and would fail deep inside the kernel with a less useful
            # error
            raise ValueError(
                f"plan was autotuned for batch {plan.batch} but got "
                f"batch {x.shape[0]}; RMW-flow layers {rmw} are only "
                f"hardware-safe at the tuned batch — rebuild with "
                f"build_network_plan(..., batch={x.shape[0]})")
    for lp in plan.layers:
        if (x.shape[1] != lp.layer.c_in or x.shape[2] != lp.layer.h_in
                or x.shape[3] != lp.layer.w_in):
            raise ValueError(
                f"plan/input mismatch at {lp.layer.name}: plan expects "
                f"[B, {lp.layer.c_in}, {lp.layer.h_in}, {lp.layer.w_in}], "
                f"got {x.shape}")
        if backend == "einsum":
            x = spec.spectral_conv2d_pretransformed(x, lp.kernels, lp.geo)
            x = _epilogue_spatial(x, lp)
        elif backend == "pallas_staged":
            from repro.kernels import ops
            y = ops.spectral_conv2d_pallas(x, lp.kernels.values, lp.geo,
                                           interpret=interpret)
            y = _epilogue_spatial(y, lp)
            if guards is not None:
                y = res.apply_guards(x, y, lp, guards)
            x = y
        else:
            try:
                y = res.execute_planned_layer(x, lp, interpret=interpret)
            except res.ResilienceError:
                raise
            except Exception as e:
                raise res.KernelLoweringError(
                    f"layer {lp.layer.name} failed under backend="
                    f"{getattr(lp, 'backend', 'fused')!r} (flow="
                    f"{lp.tuning.flow}, hadamard={lp.hadamard}, "
                    f"input_mode={lp.input_mode}): {e}",
                    layer=lp.layer.name, site="forward") from e
            if guards is not None:
                y = res.apply_guards(x, y, lp, guards)
            x = y
        if lp.epilogue.pool:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]


def forward_spatial(params: dict, cfg: SpectralCNNConfig, x: Array) -> Array:
    """Dense spatial-domain oracle of the same network."""
    for layer, conv in zip(cfg.layers, params["convs"]):
        x = spec.spatial_conv2d(x, conv["w"], pad=layer.pad)
        x = jax.nn.relu(x + conv["b"][None, :, None, None])
        if layer.name in cfg.pool_after:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]
