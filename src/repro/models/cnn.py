"""Spectral VGG16 — the paper's own target model, end to end.

Conv stack runs in the spectral domain (FFT tiling + sparse Hadamard +
OaA, repro.core.spectral) with per-layer dataflow chosen by Alg 1;
ReLU / max-pool / FC head run in the spatial domain.  On the paper's
CPU+FPGA platform those stages were offloaded to the CPU; here everything
is one jitted JAX program (DESIGN.md, adaptation note 3).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import dataflow as df
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.models import layers as L

Array = jax.Array

# after which conv layers a 2x2 max-pool follows
_POOL_AFTER = {"conv1_2", "conv2_2", "conv3_3", "conv4_3", "conv5_3"}


@dataclasses.dataclass(frozen=True)
class SpectralCNNConfig:
    name: str = "vgg16-spectral"
    layers: Sequence[df.ConvLayer] = df.VGG16_LAYERS
    fft_size: int = 8
    alpha: float = 4.0           # spectral kernel compression
    n_classes: int = 1000
    image_size: int = 224
    fc_dim: int = 4096


def init(key, cfg: SpectralCNNConfig) -> dict:
    """Spatial-domain weights; spectral transform + pruning are separate
    (mirrors the paper: kernels pruned offline, stored pre-transformed)."""
    ks = jax.random.split(key, len(cfg.layers) + 3)
    convs = []
    for k, layer in zip(ks, cfg.layers):
        fan_in = layer.c_in * layer.ksize ** 2
        w = jax.random.normal(
            k, (layer.c_out, layer.c_in, layer.ksize, layer.ksize),
            jnp.float32) * (2.0 / fan_in) ** 0.5
        convs.append({"w": w, "b": jnp.zeros((layer.c_out,))})
    feat = cfg.layers[-1].c_out * (cfg.image_size // 32) ** 2
    return {
        "convs": convs,
        "fc1": L.dense_init(ks[-3], feat, cfg.fc_dim),
        "fc2": L.dense_init(ks[-2], cfg.fc_dim, cfg.fc_dim),
        "fc3": L.dense_init(ks[-1], cfg.fc_dim, cfg.n_classes),
    }


def transform_kernels(params: dict, cfg: SpectralCNNConfig
                      ) -> list[sp.SparseSpectralKernels]:
    """Offline: spatial -> spectral -> pruned (uniform alpha)."""
    out = []
    for conv in params["convs"]:
        wf = spec.spectral_kernel(conv["w"], cfg.fft_size)
        out.append(sp.prune_magnitude(wf, cfg.alpha))
    return out


def _pool(x: Array) -> Array:
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


BACKENDS = ("einsum", "pallas_staged", "pallas_fused")


def forward_spectral(params: dict, spectral_kernels, cfg: SpectralCNNConfig,
                     x: Array, *, backend: str = "einsum",
                     tuning: dict | None = None,
                     interpret: bool | None = None) -> Array:
    """Inference with pre-transformed (pruned) spectral kernels.

    backend selects the conv-stack implementation:
      'einsum'        pure-jnp oracle (sparse-aware masked einsum)
      'pallas_staged' 3 pallas_calls/layer: fft8 -> hadamard -> ifft8,
                      spectral intermediates round-tripping through HBM
      'pallas_fused'  ONE pallas_call/layer (kernels.fused_spectral_conv);
                      ``tuning`` maps layer name -> core.autotune
                      FusedTuning for per-layer flow/block choice.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    for layer, conv, sk in zip(cfg.layers, params["convs"],
                               spectral_kernels):
        geo = spec.make_geometry(x.shape[2], x.shape[3], layer.ksize,
                                 cfg.fft_size, layer.pad)
        if backend == "einsum":
            x = spec.spectral_conv2d_pretransformed(x, sk, geo)
        elif backend == "pallas_staged":
            from repro.kernels import ops
            x = ops.spectral_conv2d_pallas(x, sk.values, geo,
                                           interpret=interpret)
        else:
            from repro.kernels.fused_spectral_conv import fused_spectral_conv2d
            tn = (tuning or {}).get(layer.name)
            kw = tn.kwargs() if tn is not None else {}
            x = fused_spectral_conv2d(x, sk, geo, interpret=interpret, **kw)
        x = jax.nn.relu(x + conv["b"][None, :, None, None])
        if layer.name in _POOL_AFTER:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]


def forward_spatial(params: dict, cfg: SpectralCNNConfig, x: Array) -> Array:
    """Dense spatial-domain oracle of the same network."""
    for layer, conv in zip(cfg.layers, params["convs"]):
        x = spec.spatial_conv2d(x, conv["w"], pad=layer.pad)
        x = jax.nn.relu(x + conv["b"][None, :, None, None])
        if layer.name in _POOL_AFTER:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]
