"""Spectral VGG16 — the paper's own target model, end to end.

Conv stack runs in the spectral domain (overlap-save FFT tiling + sparse
Hadamard, repro.core.spectral) with per-layer dataflow chosen by Alg 1;
max-pool / FC head run in the spatial domain.  On the paper's CPU+FPGA
platform those stages were offloaded to the CPU; here everything is one
jitted JAX program (DESIGN.md, adaptation note 3).

Since the LayerPlan refactor the forward pass *executes a plan*
(``core.plan.build_network_plan``): geometry, pruned kernels, Alg-2
active-bin compaction, autotuned flow/blocks and the fused bias+ReLU
epilogue are all precompiled once, offline — exactly as the paper
compiles per-layer configurations before inference — and every backend
of ``forward_spectral`` just walks the plan.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import dataflow as df
from repro.core import resilience as res
from repro.core import sparse as sp
from repro.core import spectral as spec
from repro.models import layers as L

Array = jax.Array

# after which conv layers a 2x2 max-pool follows
_POOL_AFTER = frozenset(
    {"conv1_2", "conv2_2", "conv3_3", "conv4_3", "conv5_3"})


@dataclasses.dataclass(frozen=True)
class SpectralCNNConfig:
    """``graph`` (ISSUE 10) is an optional tuple of
    ``dataflow.NodeSpec`` describing a DAG over the conv layers —
    stride-2 convs, 2x2 max/avg pool nodes and residual shortcut edges
    (ResNet-class).  ``None`` keeps the linear VGG semantics: a chain
    of the layers with max-pools after ``pool_after``."""

    name: str = "vgg16-spectral"
    layers: Sequence[df.ConvLayer] = df.VGG16_LAYERS
    fft_size: int = 8
    # Spectral kernel compression: scalar, or one alpha per conv layer
    # (the paper prunes layers non-uniformly).
    alpha: float | Sequence[float] = 4.0
    n_classes: int = 1000
    image_size: int = 224
    fc_dim: int = 4096
    pool_after: frozenset = _POOL_AFTER
    graph: Sequence[df.NodeSpec] | None = None


def _config_graph(cfg: SpectralCNNConfig):
    """The topo-ordered NodeSpec sequence a config describes (explicit
    ``cfg.graph``, or the synthesized linear chain)."""
    from repro.core import plan as pl
    specs = cfg.graph
    if specs is None:
        specs = pl._linear_node_specs(
            list(cfg.layers), getattr(cfg, "pool_after", frozenset()))
    return pl._topo_order_specs(specs)


def feature_dim(cfg: SpectralCNNConfig) -> int:
    """Flattened feature size entering the FC head: the output shape of
    the graph's sink node (shape-walked, so stride/pool/DAG configs all
    agree with what the conv stack actually emits)."""
    from repro.core import plan as pl
    order = _config_graph(cfg)
    shapes = pl.node_output_shapes(list(cfg.layers), order)
    c, h, w = shapes[pl.graph_sink(order)]
    return c * h * w


def init(key, cfg: SpectralCNNConfig) -> dict:
    """Spatial-domain weights; spectral transform + pruning are separate
    (mirrors the paper: kernels pruned offline, stored pre-transformed)."""
    ks = jax.random.split(key, len(cfg.layers) + 3)
    convs = []
    for k, layer in zip(ks, cfg.layers):
        fan_in = layer.c_in * layer.ksize ** 2
        w = jax.random.normal(
            k, (layer.c_out, layer.c_in, layer.ksize, layer.ksize),
            jnp.float32) * (2.0 / fan_in) ** 0.5
        convs.append({"w": w, "b": jnp.zeros((layer.c_out,))})
    return {
        "convs": convs,
        "fc1": L.dense_init(ks[-3], feature_dim(cfg), cfg.fc_dim),
        "fc2": L.dense_init(ks[-2], cfg.fc_dim, cfg.fc_dim),
        "fc3": L.dense_init(ks[-1], cfg.fc_dim, cfg.n_classes),
    }


def transform_kernels(params: dict, cfg: SpectralCNNConfig
                      ) -> list[sp.SparseSpectralKernels]:
    """Offline: spatial -> spectral -> pruned, per-layer alpha."""
    alphas = sp.per_layer_alphas(cfg.alpha, len(cfg.layers))
    out = []
    for conv, alpha in zip(params["convs"], alphas):
        wf = spec.spectral_kernel(conv["w"], cfg.fft_size)
        out.append(sp.prune_magnitude(wf, alpha))
    return out


def build_plan(params: dict, cfg: SpectralCNNConfig, **kwargs):
    """Convenience re-export: ``core.plan.build_network_plan``."""
    from repro.core.plan import build_network_plan
    return build_network_plan(params, cfg, **kwargs)


def _pool(x: Array, kind: str = "max") -> Array:
    """2x2 stride-2 max/avg pool; odd edge rows/cols are dropped
    (floor semantics, mirrored by ``plan.node_output_shapes``)."""
    b, c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :, :h2 * 2, :w2 * 2].reshape(b, c, h2, 2, w2, 2)
    return x.max(axis=(3, 5)) if kind == "max" else x.mean(axis=(3, 5))


BACKENDS = ("einsum", "pallas_staged", "pallas_fused")


def _epilogue_spatial(x: Array, lp) -> Array:
    """Bias + ReLU for the backends that don't fuse it into the kernel."""
    if lp.epilogue.bias:
        x = x + lp.bias[0][None, :, None, None]
    if lp.epilogue.relu:
        x = jax.nn.relu(x)
    return x


def forward_spectral(params: dict, plan, x: Array, *,
                     backend: str = "einsum",
                     interpret: bool | None = None,
                     guards: res.NumericGuards | None = None) -> Array:
    """Inference by executing a precompiled ``core.plan.NetworkPlan``.

    Args:
      params: the weights ``init`` produced (the conv stack reads only
        the plan's baked operands, but the FC head reads ``params``).
      plan: a ``core.plan.NetworkPlan`` built ONCE by
        ``build_network_plan`` for this config and batch size.
      x: [B, C, H, W] f32 input batch; must match the plan's layer
        geometry, and for the fused backend on hardware the plan's
        tuned batch (RMW-flow safety — see the error message).
      backend: conv-stack implementation, one of ``BACKENDS``:
        'einsum'        pure-jnp oracle (sparse-aware masked einsum);
        'pallas_staged' 3 pallas_calls/layer: fft8 -> hadamard ->
                        ifft8, spectral intermediates round-tripping
                        through HBM;
        'pallas_fused'  ONE pallas_call/layer executing the plan's
                        precompiled operands with bias+ReLU fused into
                        the kernel flush.  Each layer runs the Hadamard
                        datapath its plan chose (``LayerPlan.hadamard``):
                        'dense'/'bin' stream (compacted) kernel planes
                        through the Karatsuba GEMM, 'scheduled' executes
                        the layer's Alg-2 INDEX/VALUE tables element-
                        granularly (``execute_layer_plan`` dispatches).
      interpret: force Pallas interpret mode (None = auto: interpret
        everywhere except real TPU).
      guards: optional ``core.resilience.NumericGuards`` enabling the
        opt-in per-layer runtime checks (NaN/Inf scan, sampled parity
        vs the einsum oracle) on the Pallas backends, with policy
        'raise' | 'demote' | 'warn'.  Every trip is appended to
        ``guards.events``.

    Under the 'pallas_fused' backend each layer runs the execution path
    its plan records (``LayerPlan.backend`` — 'fused' as built, or
    'staged'/'einsum' after ``resilience.harden_network_plan`` demoted
    it), and any unexpected per-layer failure is re-raised as a
    structured ``resilience.KernelLoweringError`` naming the layer and
    its modes — never a raw Pallas traceback.

    Returns [B, n_classes] logits.  Everything layer-specific was
    derived at plan-build time; nothing (geometry, schedules, pruning,
    table compilation, autotune) is rebuilt here, so repeated calls go
    straight to the jit cache.
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}")
    if backend == "pallas_fused" and x.shape[0] != plan.batch:
        on_hw = interpret is False or (interpret is None
                                       and jax.default_backend() == "tpu")
        rmw = [lp.layer.name for lp in plan.layers
               if lp.tuning.flow != "output_stationary"]
        if on_hw and rmw:
            # the RMW flows' hardware-safety (single p/n block) was
            # established for plan.batch; a different batch changes P
            # and would fail deep inside the kernel with a less useful
            # error
            raise ValueError(
                f"plan was autotuned for batch {plan.batch} but got "
                f"batch {x.shape[0]}; RMW-flow layers {rmw} are only "
                f"hardware-safe at the tuned batch — rebuild with "
                f"build_network_plan(..., batch={x.shape[0]})")
    from repro.core.plan import graph_sink
    graph = plan.execution_graph
    out_id = graph_sink(graph)
    # Reference counts so large intermediate activations are freed as
    # soon as their last consumer (main or shortcut edge) has run.
    refs: dict[str, int] = {out_id: 1}
    for node in graph:
        for src in (node.inputs[0], node.residual_from):
            if src is not None:
                refs[src] = refs.get(src, 0) + 1
    acts: dict[str, Array] = {"input": x}
    for node in graph:
        src = acts[node.inputs[0]]
        if node.kind == "pool":
            y = _pool(src, node.pool)
        else:
            lp = plan.layers[node.layer_index]
            if src.shape[1:] != (lp.layer.c_in, lp.layer.h_in,
                                 lp.layer.w_in):
                raise ValueError(
                    f"plan/input mismatch at {node.id}: plan expects "
                    f"[B, {lp.layer.c_in}, {lp.layer.h_in}, "
                    f"{lp.layer.w_in}], got {src.shape}")
            sc = (acts[node.residual_from]
                  if node.residual_from is not None else None)
            y = _conv_node(src, lp, node, sc, backend, interpret, guards)
        acts[node.id] = y
        for s in (node.inputs[0], node.residual_from):
            if s is not None:
                refs[s] -= 1
                if refs[s] == 0:
                    acts.pop(s, None)
    x = acts[out_id]
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]


def _conv_node(x: Array, lp, node, sc: Array | None, backend: str,
               interpret: bool | None,
               guards: res.NumericGuards | None) -> Array:
    """Execute one conv DAG node under the chosen network backend.

    Epilogue ordering is uniform across every path: bias -> stride
    subsample -> (+shortcut) -> ReLU.  (Bias and ReLU are elementwise,
    so applying them before or after the ``[::stride]`` subsample is
    numerically identical; the shortcut always matches the POST-stride
    output shape.)  Residual-FUSED nodes on the fused backend do bias +
    shortcut + ReLU inside the kernel flush; every other combination —
    the 'add' rung, strided nodes, staged/einsum paths — applies the
    add as a plain XLA op with the ReLU deferred until after it.
    """
    stride = getattr(lp.layer, "stride", 1)
    residual = getattr(lp.epilogue, "residual", None)
    if backend == "einsum":
        y = spec.spectral_conv2d_pretransformed(x, lp.kernels, lp.geo)
        if lp.epilogue.bias:
            y = y + lp.bias[0][None, :, None, None]
        y = y[:, :, ::stride, ::stride]
        if sc is not None:
            y = y + sc
        if node.relu:
            y = jax.nn.relu(y)
        return y
    if backend == "pallas_staged":
        from repro.kernels import ops
        y = ops.spectral_conv2d_pallas(x, lp.kernels.values, lp.geo,
                                       interpret=interpret)
        if sc is None:
            y = _epilogue_spatial(y, lp)
            if guards is not None:
                y = res.apply_guards(x, y, lp, guards)
            return y[:, :, ::stride, ::stride]
        # Residual node: ReLU defers until after the add, so guard the
        # bias-only output (parity oracle with relu disabled), then
        # subsample -> add -> ReLU.
        if lp.epilogue.bias:
            y = y + lp.bias[0][None, :, None, None]
        if guards is not None:
            lp_nr = dataclasses.replace(
                lp, epilogue=dataclasses.replace(lp.epilogue,
                                                 relu=False))
            y = res.apply_guards(x, y, lp_nr, guards)
        y = y[:, :, ::stride, ::stride] + sc
        return jax.nn.relu(y) if node.relu else y
    # pallas_fused: the plan's per-layer backend decides the path.
    fuse_in_kernel = (residual == "fused" and sc is not None
                      and getattr(lp, "backend", "fused") == "fused")
    try:
        y = res.execute_planned_layer(
            x, lp, interpret=interpret,
            shortcut=sc if fuse_in_kernel else None)
    except res.ResilienceError:
        raise
    except Exception as e:
        raise res.KernelLoweringError(
            f"layer {lp.layer.name} failed under backend="
            f"{getattr(lp, 'backend', 'fused')!r} (flow="
            f"{lp.tuning.flow}, hadamard={lp.hadamard}, "
            f"input_mode={lp.input_mode}): {e}",
            layer=lp.layer.name, site="forward") from e
    if guards is not None:
        y = res.apply_guards(x, y, lp, guards,
                             shortcut=sc if fuse_in_kernel else None)
    if not fuse_in_kernel:
        y = y[:, :, ::stride, ::stride]
        if sc is not None:
            y = y + sc
            if node.relu:
                y = jax.nn.relu(y)
    return y


def forward_spatial(params: dict, cfg: SpectralCNNConfig, x: Array) -> Array:
    """Dense spatial-domain oracle of the same network.

    Walks the SAME DAG the spectral executors walk (explicit
    ``cfg.graph`` or the synthesized linear chain) entirely in the
    spatial domain — stride-2 convs, max/avg pool nodes and residual
    adds included, with the canonical epilogue ordering bias -> stride
    -> (+shortcut) -> ReLU.  This is the reference every backend,
    degradation rung and shard strategy is diffed against (ISSUE 10's
    oracle-diff harness).
    """
    from repro.core.plan import graph_sink
    order = _config_graph(cfg)
    convs = {layer.name: (layer, conv)
             for layer, conv in zip(cfg.layers, params["convs"])}
    acts: dict[str, Array] = {"input": x}
    for s in order:
        src = acts[s.inputs[0]]
        if s.kind == "pool":
            y = _pool(src, s.pool)
        else:
            layer, conv = convs[s.id]
            stride = getattr(layer, "stride", 1)
            y = spec.spatial_conv2d(src, conv["w"], pad=layer.pad,
                                    stride=stride)
            y = y + conv["b"][None, :, None, None]
            if s.residual_from is not None:
                y = y + acts[s.residual_from]
            if s.relu:
                y = jax.nn.relu(y)
        acts[s.id] = y
    x = acts[graph_sink(order)]
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]
