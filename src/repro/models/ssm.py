"""Mamba2-style selective state-space block (chunked SSD algorithm).

Recurrence per head h with scalar decay (Mamba2's A is scalar/head):

    S_t = exp(A dt_t) S_{t-1} + dt_t * x_t B_t^T        S in R^{P x N}
    y_t = S_t C_t + D x_t

Training/prefill uses the chunked state-space-dual form: within a chunk
of length Lc an attention-like (masked, decay-weighted) product; across
chunks a scan over compressed chunk states — O(T Lc) work and O(T/Lc)
scan length instead of a length-T scan, which keeps both compile time and
activation memory small at 4k-512k tokens.  Decode keeps the [H, P, N]
state and applies one recurrence step.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int              # H * P
    n_heads: int
    d_state: int              # N
    d_conv: int = 4
    chunk: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def init(key, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    d, di, h, n = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.d_state
    conv_ch = di + 2 * n
    return {
        # separate projections so the big ones (wx, wz: d -> d_inner)
        # shard cleanly over 'model' while the small B/C/dt heads stay
        # replicated (see distributed/sharding.py)
        "wx": L.dense_init(ks[0], d, di, dtype),
        "wbc": L.dense_init(ks[1], d, 2 * n, dtype),
        "wdt": L.dense_init(ks[2], d, h, dtype),
        "wz": L.dense_init(ks[3], d, di, dtype),
        "conv_w": (jax.random.normal(ks[4], (cfg.d_conv, conv_ch),
                                     jnp.float32) * 0.2).astype(dtype),
        "a_log": jnp.zeros((h,), jnp.float32),        # A = -exp(a_log)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ~ 0.12
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(ks[5], di, d, dtype),
    }


def _project(params: dict, cfg: SSMConfig, u: Array):
    n = cfg.d_state
    x = u @ params["wx"].astype(u.dtype)
    bc = u @ params["wbc"].astype(u.dtype)
    dt = u @ params["wdt"].astype(u.dtype)
    z = u @ params["wz"].astype(u.dtype)
    return x, bc[..., :n], bc[..., n:], dt, z


def _ssd_chunked(cfg: SSMConfig, xh: Array, b: Array, c: Array,
                 la: Array, dt: Array, s0: Array | None
                 ) -> tuple[Array, Array]:
    """Chunked scan.  xh: [B,T,H,P], b/c: [B,T,N], la/dt: [B,T,H].

    Returns (y [B,T,H,P], final_state [B,H,P,N]).
    """
    bs, t, h, p = xh.shape
    n = b.shape[-1]
    lc = min(cfg.chunk, t)
    assert t % lc == 0, "sequence length must divide the SSD chunk"
    nc = t // lc

    xh = xh.reshape(bs, nc, lc, h, p)
    bc = b.reshape(bs, nc, lc, n)
    cc = c.reshape(bs, nc, lc, n)
    la = la.reshape(bs, nc, lc, h)
    dt = dt.reshape(bs, nc, lc, h)

    cum = jnp.cumsum(la, axis=2)                       # [B,NC,LC,H]
    # intra-chunk: y[l] = sum_{l'<=l} exp(cum_l - cum_l') dt_l' (C_l.B_l')
    #                     * x_l'
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,NC,L,L,H]
    mask = jnp.tril(jnp.ones((lc, lc), bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    scores = jnp.einsum("bcln,bcmn->bclm", cc, bc)          # [B,NC,L,L]
    w = scores[..., None] * gate * dt[:, :, None, :, :]     # [B,NC,L,L,H]
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", w, xh)

    # chunk summary state: S_c = sum_l exp(cum_L - cum_l) dt_l x_l B_l^T
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dt            # [B,NC,L,H]
    s_chunk = jnp.einsum("bclh,bclhp,bcln->bchpn", tail, xh, bc)
    a_chunk = jnp.exp(cum[:, :, -1, :])                     # [B,NC,H]

    # inter-chunk scan:  S_c_out = a_c * S_{c-1} + S_c
    def op(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2[..., None, None] * s1 + s2

    a_sc, s_sc = jax.lax.associative_scan(
        op, (a_chunk, s_chunk), axis=1)                     # [B,NC,H,P,N]
    if s0 is not None:
        s_sc = s_sc + a_sc[..., None, None] * s0[:, None]
    # state entering chunk c: s0 for c = 0, scanned state of c-1 otherwise
    first = (s0[:, None] if s0 is not None
             else jnp.zeros_like(s_sc[:, :1]))
    s_prev = jnp.concatenate([first, s_sc[:, :-1]], axis=1)

    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         cc, jnp.exp(cum), s_prev)
    y = (y_intra + y_inter).reshape(bs, t, h, p)
    return y, s_sc[:, -1]


def forward(params: dict, cfg: SSMConfig, u: Array,
            state: dict | None = None) -> tuple[Array, dict]:
    """Full-sequence forward.  u: [B, T, d_model]."""
    bs, t, _ = u.shape
    h, p, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    x, b, c, dt_raw, z = _project(params, cfg, u)

    conv_in = jnp.concatenate([x, b, c], axis=-1)
    conv_state = None if state is None else state["conv"]
    conv_out, new_conv = L.causal_conv1d(conv_in, params["conv_w"],
                                         conv_state)
    conv_out = jax.nn.silu(conv_out)
    x, b, c = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                   # [B,T,H]
    a = -jnp.exp(params["a_log"])                               # [H]
    la = dt * a                                                 # log decay
    xh = x.reshape(bs, t, h, p).astype(jnp.float32)

    s0 = None if state is None else state["ssm"]
    y, s_last = _ssd_chunked(cfg, xh, b.astype(jnp.float32),
                             c.astype(jnp.float32), la, dt, s0)
    y = y + params["d_skip"][None, None, :, None] * xh
    y = y.reshape(bs, t, cfg.d_inner).astype(u.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["out_norm"])
    out = y @ params["out_proj"].astype(u.dtype)
    return out, {"conv": new_conv, "ssm": s_last}


def init_state(cfg: SSMConfig, batch: int, dtype=jnp.float32) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.d_state
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                         jnp.float32),
    }


def decode_step(params: dict, cfg: SSMConfig, u: Array, state: dict
                ) -> tuple[Array, dict]:
    """One-token step.  u: [B, 1, d_model]."""
    bs = u.shape[0]
    h, p, n = cfg.n_heads, cfg.head_dim, cfg.d_state
    x, b, c, dt_raw, z = _project(params, cfg, u)

    conv_in = jnp.concatenate([x, b, c], axis=-1)
    conv_out, new_conv = L.causal_conv1d(conv_in, params["conv_w"],
                                         state["conv"])
    conv_out = jax.nn.silu(conv_out)
    x, b, c = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])[:, 0]             # [B,H]
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                                     # [B,H]
    xh = x.reshape(bs, h, p).astype(jnp.float32)
    bv = b[:, 0].astype(jnp.float32)                            # [B,N]
    cv = c[:, 0].astype(jnp.float32)

    s_new = (decay[..., None, None] * state["ssm"]
             + dt[..., None, None] * xh[..., None] * bv[:, None, None, :])
    y = jnp.einsum("bhpn,bn->bhp", s_new, cv) \
        + params["d_skip"][None, :, None] * xh
    y = y.reshape(bs, 1, cfg.d_inner).astype(u.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), params["out_norm"])
    out = y @ params["out_proj"].astype(u.dtype)
    return out, {"conv": new_conv, "ssm": s_new}
