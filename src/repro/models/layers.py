"""Shared neural-net layers (pure functional JAX, pytree params)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = object


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> Array:
    if scale is None:
        scale = d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array,
               eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [B, H, S, D]; positions: [B, S] or [S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,D/2]
    cos = jnp.cos(angles)[:, None, :, :]
    sin = jnp.sin(angles)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu_mlp(params: dict, x: Array) -> Array:
    gate = jax.nn.silu(x @ params["w_gate"].astype(x.dtype))
    up = x @ params["w_up"].astype(x.dtype)
    return (gate * up) @ params["w_down"].astype(x.dtype)


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: dict, x: Array) -> Array:
    h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype)
                    + params["b_up"].astype(x.dtype))
    return h @ params["w_down"].astype(x.dtype) \
        + params["b_down"].astype(x.dtype)


def cross_entropy_loss(logits: Array, labels: Array,
                       ignore_index: int = -100) -> Array:
    """Mean token NLL in f32; labels == ignore_index are masked."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def causal_conv1d(x: Array, w: Array, state: Array | None = None
                  ) -> tuple[Array, Array]:
    """Depthwise causal conv over time.  x: [B, S, C], w: [K, C].

    Returns (y, new_state) where state is the trailing K-1 inputs
    [B, K-1, C] for streaming decode."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y, new_state
