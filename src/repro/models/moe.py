"""Mixture-of-Experts layer: top-k routing, capacity dispatch, EP sharding.

GShard-style capacity dispatch implemented with scatter/gather (memory
O(tokens * top_k), never the [tokens, E, C] one-hot cube):

  1. router logits -> top-k (prob, expert id) per token,
  2. position-in-expert via a cumulative sum over the flattened
     (token, k) slots; slots past the expert capacity C are dropped,
  3. scatter tokens into the [E, C, d] dispatch buffer, run all experts
     as one stacked einsum, gather back weighted by router probs.

Sharding (applied by the planner): dispatch buffer [G, E, C, d] with the
group axis G on 'data' and experts E on 'model' — dispatch/combine then
induce exactly one model-axis collective each (the MoE all-to-all
analogue), matching the paper's "stream tokens, reuse (expert) kernels"
flow.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import layers as L

Array = jax.Array


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def init(key, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": L.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * f ** -0.5).astype(dtype),
    }


def capacity(cfg: MoEConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.n_experts)
    return max(4, c)


def forward(params: dict, cfg: MoEConfig, x: Array
            ) -> tuple[Array, dict]:
    """x: [G, S, d] (G = routing groups, sharded on 'data').

    Returns (y [G, S, d], aux) with aux = load-balance loss terms.
    """
    g, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = x.astype(jnp.float32) @ params["router"]           # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                      # [G,S,K]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) slot within its expert, per group
    flat_i = top_i.reshape(g, s * k)                            # [G,SK]
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)         # [G,SK,E]
    pos = jnp.cumsum(onehot, axis=1) - 1                        # [G,SK,E]
    pos_in_e = jnp.take_along_axis(
        pos, flat_i[..., None], axis=-1)[..., 0]                # [G,SK]
    keep = pos_in_e < c
    # dropped slots are masked to zero and clamped onto slot 0 (inert:
    # zero contribution) so the buffer shape is exactly [G, E*C, d] —
    # an OOB dump row would make E*C+1 unshardable over the expert axis
    # and forces XLA's scatter fallback (all-reduce of the whole buffer)
    slot = jnp.where(keep, flat_i * c + pos_in_e, 0)

    # scatter tokens into the dispatch buffer [G, E*C, d]
    xk = jnp.repeat(x, k, axis=1) * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((g, e * c, d), x.dtype)
    buf = buf.at[jnp.arange(g)[:, None], slot].add(xk)
    h = constrain(buf.reshape(g, e, c, d), "moe_dispatch")

    # stacked expert FFN (SwiGLU)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h,
                                  params["w_gate"].astype(x.dtype)))
    up = jnp.einsum("gecd,edf->gecf", h, params["w_up"].astype(x.dtype))
    out = jnp.einsum("gecf,efd->gecd", gate * up,
                     params["w_down"].astype(x.dtype))          # [G,E,C,d]

    # combine: gather each slot's expert output, weight by router prob
    # (dropped slots read slot 0 but their weight is masked to zero)
    out_flat = out.reshape(g, e * c, d)
    yk = out_flat[jnp.arange(g)[:, None], slot]                 # [G,SK,d]
    w = (top_p.reshape(g, s * k) * keep).astype(x.dtype)
    y = constrain((yk * w[..., None]).reshape(g, s, k, d).sum(axis=2),
                  "moe_combine")

    # Switch-style load-balance aux loss
    me = probs.mean(axis=(0, 1))                                # [E]
    ce = (onehot.sum(axis=(0, 1)) / (g * s * k)).astype(jnp.float32)
    aux = {
        "lb_loss": e * jnp.sum(me * ce),
        "dropped_frac": 1.0 - keep.mean(),
    }
    return y, aux


# ---------------------------------------------------------------------------
# Explicit expert-parallel path (shard_map)
# ---------------------------------------------------------------------------

def _local_expert_ffn(params, cfg: MoEConfig, x: Array, e_lo: int,
                      e_loc: int, w_gate, w_up, w_down) -> Array:
    """Per-device body: dispatch local tokens to the E_loc experts this
    model shard owns (capacity buffers are device-LOCAL, so the scatter
    never crosses shards — the fix for the SPMD scatter fallback), run
    the local expert einsums, combine, and leave the cross-shard sum to
    one psum over 'model' (TP-like: a single [G,S,d] all-reduce/layer).
    """
    g, s, d = x.shape
    k = cfg.top_k
    c = capacity(cfg, s)

    logits = x.astype(jnp.float32) @ params["router"]           # [G,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_i = top_i.reshape(g, s * k)
    local = (flat_i >= e_lo) & (flat_i < e_lo + e_loc)
    loc_i = jnp.where(local, flat_i - e_lo, 0)
    onehot = jax.nn.one_hot(loc_i, e_loc, dtype=jnp.int32) \
        * local[..., None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos_in_e = jnp.take_along_axis(pos, loc_i[..., None], -1)[..., 0]
    keep = local & (pos_in_e < c)
    slot = jnp.where(keep, loc_i * c + pos_in_e, 0)

    xk = jnp.repeat(x, k, axis=1) * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((g, e_loc * c, d), x.dtype)
    buf = buf.at[jnp.arange(g)[:, None], slot].add(xk)
    h = buf.reshape(g, e_loc, c, d)

    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h,
                                  w_gate.astype(x.dtype)))
    up = jnp.einsum("gecd,edf->gecf", h, w_up.astype(x.dtype))
    out = jnp.einsum("gecf,efd->gecd", gate * up,
                     w_down.astype(x.dtype)).reshape(g, e_loc * c, d)

    yk = out[jnp.arange(g)[:, None], slot]
    w = (top_p.reshape(g, s * k) * keep).astype(x.dtype)
    return (yk * w[..., None]).reshape(g, s, k, d).sum(axis=2)


def forward_ep(params: dict, cfg: MoEConfig, x: Array, *, mesh,
               data_axes: tuple[str, ...], model_axis: str = "model",
               fsdp_axes: tuple[str, ...] = ()) -> tuple[Array, dict]:
    """shard_map expert parallelism: experts sharded over ``model_axis``,
    tokens over ``data_axes``; each shard serves only its experts and one
    psum('model') per layer combines — total cross-shard traffic is one
    [G, S, d] all-reduce instead of buffer-wide scatter collectives."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    e = cfg.n_experts
    m_ways = 1
    for ax in ([model_axis] if isinstance(model_axis, str) else model_axis):
        m_ways *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(ax, 1)
    e_loc = e // m_ways

    def device_fn(router, w_gate, w_up, w_down, x_loc):
        if fsdp_axes:
            for ax in fsdp_axes:
                w_gate = jax.lax.all_gather(w_gate, ax, axis=1,
                                            tiled=True)
                w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
                w_down = jax.lax.all_gather(w_down, ax, axis=2,
                                            tiled=True)
        m_idx = jax.lax.axis_index(model_axis)
        p = {"router": router}
        y_part = _local_expert_ffn(p, cfg, x_loc, m_idx * e_loc, e_loc,
                                   w_gate, w_up, w_down)
        return jax.lax.psum(y_part, model_axis)

    wa = tuple(fsdp_axes) if fsdp_axes else None
    fn = shard_map(
        device_fn, mesh=mesh,
        in_specs=(P(), P(model_axis, wa, None), P(model_axis, wa, None),
                  P(model_axis, None, wa), P(data_axes, None, None)),
        out_specs=P(data_axes, None, None),
        check_rep=False)
    y = fn(params["router"], params["w_gate"], params["w_up"],
           params["w_down"], x)
    return y, {"lb_loss": jnp.zeros(()), "dropped_frac": jnp.zeros(())}
