"""Deterministic synthetic data pipeline (sharded, prefetching).

Properties required at fleet scale and tested here:

  * deterministic as a pure function of (seed, step, host) — a restarted
    or replaced host resumes mid-epoch at the exact batch, which is what
    makes checkpoint/restart and straggler replacement exact;
  * host-sliced: each host materializes only its rows of the global
    batch (``host_id``/``n_hosts``), never the full batch;
  * double-buffered: a background thread generates batch ``step+1``
    while ``step`` is being consumed.

The "corpus" is a counter-based PRNG stream (threefry via jax on host
numpy here) shaped like an LM token stream with next-token labels; the
audio variant emits stub frame embeddings for the whisper backbone.
Tokens carry learnable bigram structure (each position repeats the
previous token with probability 1/2) so that cross-entropy genuinely
decreases under training — an i.i.d. uniform stream starts AT the
optimum and convergence tests can only pass by noise.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    frames_dim: int = 0        # >0: also emit [b, s, dim] frame embeddings

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    """The pipeline's defining property: batch is a pure function of
    (seed, step, host_id)."""
    out = {}
    rows = []
    labels = []
    for r in range(cfg.host_batch):
        global_row = cfg.host_id * cfg.host_batch + r
        rng = np.random.default_rng(
            (cfg.seed, step, global_row))
        n = cfg.seq_len + 1
        stream = rng.integers(1, cfg.vocab, size=n, dtype=np.int32)
        # learnable structure: repeat the previous token with prob 1/2
        # (segment-copy via running max of the last freshly-drawn index)
        fresh = rng.random(n) >= 0.5
        fresh[0] = True
        src = np.maximum.accumulate(np.where(fresh, np.arange(n), 0))
        stream = stream[src]
        rows.append(stream[:-1])
        labels.append(stream[1:])
    out["tokens"] = np.stack(rows)
    out["labels"] = np.stack(labels)
    if cfg.frames_dim:
        rng = np.random.default_rng((cfg.seed, step, cfg.host_id, 7))
        out["frames"] = rng.standard_normal(
            (cfg.host_batch, cfg.seq_len, cfg.frames_dim)
        ).astype(np.float32)
    return out


class Prefetcher:
    """Background-thread double buffering over ``batch_at``."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = batch_at(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
