"""launch subpackage."""
