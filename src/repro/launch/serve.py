"""Serving driver: continuous-batching decode over the unified model API.

A miniature production server loop:
  * requests arrive with a prompt and a target token count;
  * prefill produces the first logits + (for stateful families) the
    per-request state; decode steps run the whole active batch each tick;
  * finished requests retire and free their slots for queued requests
    (continuous batching);
  * per-tick latency statistics are reported (the paper's metric of
    merit is single-stream latency — Table 3).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


class Server:
    """Fixed-slot continuous-batching decoder."""

    def __init__(self, arch: str, slots: int = 4, max_len: int = 256,
                 config_set: str = "smoke", seed: int = 0):
        self.cfg = (configs.get_smoke_config(arch)
                    if config_set == "smoke" else configs.get_config(arch))
        # continuous batching with per-slot positions needs position-
        # addressable caches; recurrent families need slot-isolated state
        # resets instead (future work — slot reuse would corrupt state)
        assert self.cfg.family in ("dense", "moe"), \
            "continuous-batching server supports KV-cache families"
        self.slots = slots
        self.max_len = max_len
        self.params = api.init(jax.random.PRNGKey(seed), self.cfg)
        self.cache = api.init_cache(self.cfg, slots, max_len)
        self.active: list[Request | None] = [None] * slots
        self.pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode(p, self.cfg, t, c, pos))
        self.tick_times: list[float] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill free slots; prefill runs as decode steps on the new slot
        (other slots re-write their current position, which the next real
        tick overwrites before it is ever read)."""
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # positions 0..L-2; the final prompt token is fed by the
                # next tick so its logits become the first sampled token
                for t, tok in enumerate(req.prompt[:-1]):
                    token = jnp.zeros((self.slots, 1), jnp.int32
                                      ).at[i, 0].set(int(tok))
                    pos = jnp.asarray(self.pos).at[i].set(t)
                    _, self.cache = self._decode(
                        self.params, self.cache, token, pos)
                self.pos[i] = len(req.prompt) - 1

    def tick(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._admit()
        act = [i for i in range(self.slots) if self.active[i] is not None]
        if not act:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in act:
            req = self.active[i]
            tokens[i, 0] = (req.prompt[-1] if not req.out else req.out[-1])
        t0 = time.time()
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(self.pos))
        nxt = np.asarray(greedy(logits))
        self.tick_times.append(time.time() - t0)
        for i in act:
            req = self.active[i]
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            if len(req.out) >= req.max_new \
                    or self.pos[i] >= self.max_len - 1:
                req.done = True
                self.active[i] = None
        return len(act)

    def run_until_drained(self, max_ticks: int = 10_000) -> dict:
        ticks = 0
        while (any(self.active) or self.queue) and ticks < max_ticks:
            self.tick()
            ticks += 1
        times = np.asarray(self.tick_times[1:] or [0.0])
        return {
            "ticks": ticks,
            "mean_tick_ms": float(times.mean() * 1e3),
            "p95_tick_ms": float(np.percentile(times, 95) * 1e3),
        }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--new-tokens", type=int, default=16)
    args = p.parse_args()
    srv = Server(args.arch, slots=args.slots)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(1, srv.cfg.vocab, size=8).astype(np.int32)
        srv.submit(Request(rid, prompt, args.new_tokens))
    stats = srv.run_until_drained()
    print(f"[serve] {args.requests} requests drained in {stats['ticks']} "
          f"ticks; mean {stats['mean_tick_ms']:.1f} ms "
          f"p95 {stats['p95_tick_ms']:.1f} ms")


if __name__ == "__main__":
    main()
