"""Serving driver: continuous-batching decode over the unified model API.

A miniature production server loop:
  * requests arrive with a prompt and a target token count;
  * prefill produces the first logits + (for stateful families) the
    per-request state; decode steps run the whole active batch each tick;
  * finished requests retire and free their slots for queued requests
    (continuous batching);
  * per-tick latency statistics are reported (the paper's metric of
    merit is single-stream latency — Table 3);
  * per-request failures are ISOLATED: a malformed request (empty
    prompt, out-of-vocab tokens, prompt longer than the cache) or a
    prefill/decode exception retires that request with a structured
    ``Request.error`` record and a log line — it never kills the serve
    loop or the other requests in flight — and an optional per-request
    timeout (``request_timeout_s``) retires stragglers the same way.
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api

_LOG = logging.getLogger("repro.serve")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # failure response: {'code': ..., 'message': ...} when the request
    # was retired unsuccessfully, None on success/in-flight
    error: dict | None = None
    admitted_at: float | None = None   # wall time of slot admission

    @property
    def failed(self) -> bool:
        return self.error is not None


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


class Server:
    """Fixed-slot continuous-batching decoder."""

    def __init__(self, arch: str, slots: int = 4, max_len: int = 256,
                 config_set: str = "smoke", seed: int = 0,
                 request_timeout_s: float | None = None,
                 tick_window: int = 1024,
                 clock=time.time):
        self.cfg = (configs.get_smoke_config(arch)
                    if config_set == "smoke" else configs.get_config(arch))
        # continuous batching with per-slot positions needs position-
        # addressable caches; recurrent families need slot-isolated state
        # resets instead (future work — slot reuse would corrupt state)
        assert self.cfg.family in ("dense", "moe"), \
            "continuous-batching server supports KV-cache families"
        self.slots = slots
        self.max_len = max_len
        # wall-clock budget per admitted request (None = unlimited);
        # exceeded -> the request retires with a 'timeout' failure
        # response instead of occupying its slot forever
        self.request_timeout_s = request_timeout_s
        self.params = api.init(jax.random.PRNGKey(seed), self.cfg)
        self.cache = api.init_cache(self.cfg, slots, max_len)
        self.active: list[Request | None] = [None] * slots
        self.pos = np.zeros(slots, np.int32)
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, c, t, pos: api.decode(p, self.cfg, t, c, pos))
        # injectable time source (tests drive timeouts deterministically)
        self.clock = clock
        # bounded: a long-running server must not grow per-tick history
        # without limit; stats are computed over the trailing window
        self.tick_times: collections.deque[float] = collections.deque(
            maxlen=tick_window)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fail(self, req: Request, code: str, message: str,
              slot: int | None = None) -> None:
        """Retire one request with a structured failure response; the
        serve loop and the other in-flight requests are untouched."""
        req.error = {"code": code, "message": message}
        req.done = True
        if slot is not None and self.active[slot] is req:
            self.active[slot] = None
        _LOG.error("[serve] request %s failed code=%s: %s",
                   req.rid, code, message)

    def _validate(self, req: Request) -> None:
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(f"prompt must be a non-empty 1-D token "
                             f"array, got shape {prompt.shape}")
        if req.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {req.max_new}")
        if prompt.size >= self.max_len:
            raise ValueError(f"prompt length {prompt.size} >= server "
                             f"max_len {self.max_len}")
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= self.cfg.vocab:
            # the embedding lookup would silently clamp these — a
            # silent wrong answer, the one failure mode never allowed
            raise ValueError(f"token ids outside [0, {self.cfg.vocab}): "
                             f"min={lo} max={hi}")

    def _admit(self) -> None:
        """Fill free slots; prefill runs as decode steps on the new slot
        (other slots re-write their current position, which the next real
        tick overwrites before it is ever read).  A request that fails
        validation or prefill retires with a failure response and its
        slot is offered to the next queued request."""
        for i in range(self.slots):
            while self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                try:
                    self._validate(req)
                    self.active[i] = req
                    req.admitted_at = self.clock()
                    # positions 0..L-2; the final prompt token is fed by
                    # the next tick so its logits become the first
                    # sampled token
                    for t, tok in enumerate(req.prompt[:-1]):
                        token = jnp.zeros((self.slots, 1), jnp.int32
                                          ).at[i, 0].set(int(tok))
                        pos = jnp.asarray(self.pos).at[i].set(t)
                        _, self.cache = self._decode(
                            self.params, self.cache, token, pos)
                    self.pos[i] = len(req.prompt) - 1
                except Exception as e:  # noqa: BLE001 — isolation edge
                    # slot state is safe to reuse: the next occupant
                    # overwrites its positions before they are read
                    self._fail(req, "bad_request"
                               if isinstance(e, ValueError)
                               else "prefill_error",
                               f"{type(e).__name__}: {e}", slot=i)

    def _expire(self) -> None:
        if self.request_timeout_s is None:
            return
        now = self.clock()
        for i in range(self.slots):
            req = self.active[i]
            if req is not None and req.admitted_at is not None \
                    and now - req.admitted_at > self.request_timeout_s:
                self._fail(req, "timeout",
                           f"exceeded request_timeout_s="
                           f"{self.request_timeout_s} after "
                           f"{len(req.out)} tokens", slot=i)

    def tick(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._admit()
        self._expire()
        act = [i for i in range(self.slots) if self.active[i] is not None]
        if not act:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in act:
            req = self.active[i]
            tokens[i, 0] = (req.prompt[-1] if not req.out else req.out[-1])
        t0 = self.clock()
        try:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens),
                                              jnp.asarray(self.pos))
            nxt = np.asarray(greedy(logits))
        except Exception as e:  # noqa: BLE001 — isolation edge
            # a decode-step failure cannot be attributed to one request;
            # fail the batch in flight, keep the loop (and queue) alive
            for i in act:
                self._fail(self.active[i], "decode_error",
                           f"{type(e).__name__}: {e}", slot=i)
            return 0
        self.tick_times.append(self.clock() - t0)
        for i in act:
            req = self.active[i]
            req.out.append(int(nxt[i]))
            self.pos[i] += 1
            if len(req.out) >= req.max_new \
                    or self.pos[i] >= self.max_len - 1:
                req.done = True
                self.active[i] = None
        return len(act)

    def run_until_drained(self, max_ticks: int = 10_000) -> dict:
        ticks = 0
        # keyed by rid: object ids can be reused after GC, so two
        # distinct requests could collide under id(req) on a long run
        seen: dict[int, Request] = {}

        def _track(req: Request | None):
            if req is not None:
                seen.setdefault(req.rid, req)

        for r in list(self.queue):
            _track(r)
        while (any(self.active) or self.queue) and ticks < max_ticks:
            for r in list(self.queue):
                _track(r)
            for r in self.active:
                _track(r)
            self.tick()
            ticks += 1
        completed = sum(r.done and not r.failed for r in seen.values())
        failed = sum(r.failed for r in seen.values())
        times = np.asarray(list(self.tick_times)[1:] or [0.0])
        return {
            "ticks": ticks,
            "completed": completed,
            "failed": failed,
            "mean_tick_ms": float(times.mean() * 1e3),
            "p95_tick_ms": float(np.percentile(times, 95) * 1e3),
        }


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--seed", type=int, default=0,
                   help="seeds both model init and the synthetic "
                   "prompts, so drained-run stats are reproducible")
    p.add_argument("--json", default=None,
                   help="write drained-run stats JSON to this path "
                   "('-' for stdout) for deterministic CI gating")
    args = p.parse_args()
    srv = Server(args.arch, slots=args.slots, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(1, srv.cfg.vocab, size=8).astype(np.int32)
        srv.submit(Request(rid, prompt, args.new_tokens))
    stats = srv.run_until_drained()
    print(f"[serve] {args.requests} requests drained in {stats['ticks']} "
          f"ticks; mean {stats['mean_tick_ms']:.1f} ms "
          f"p95 {stats['p95_tick_ms']:.1f} ms")
    if args.json:
        payload = json.dumps({"arch": args.arch, "seed": args.seed,
                              "requests": args.requests, **stats},
                             indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")


if __name__ == "__main__":
    main()
