"""Jitted step builders + abstract input specs for every dry-run cell.

``input_specs`` follows the shannon/kernels pattern: every model input is
a ShapeDtypeStruct (weak-type-correct, shardable, no device allocation),
so ``jax.jit(step).lower(**specs).compile()`` exercises the full SPMD
pipeline without touching memory.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ShapeConfig
from repro.distributed import sharding
from repro.models import api
from repro.models.config import ModelConfig
from repro.optim import adamw as optim

PyTree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: optim.OptimizerConfig
                    ) -> Callable:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(api.loss_fn)(params, cfg, batch)
        params, opt_state, gnorm = optim.update(opt_cfg, grads, opt_state,
                                                params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, token, pos):
        logits, cache = api.decode(params, cfg, token, cache, pos)
        return logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh: Mesh | None = None, spec: P | None = None):
    sh = None
    if mesh is not None and spec is not None:
        sh = jax.sharding.NamedSharding(mesh, spec)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                plan: sharding.ShardingPlan) -> dict:
    b = shape.global_batch
    ba = P(plan.batch_axes)
    if cfg.family == "encdec":
        s_dec = min(cfg.dec_train_len, shape.seq_len)
        return {
            "frames": _sds((b, shape.seq_len, cfg.d_model), cfg.cdt,
                           mesh, P(plan.batch_axes, None, None)),
            "tokens": _sds((b, s_dec), jnp.int32, mesh,
                           P(plan.batch_axes, None)),
            "labels": _sds((b, s_dec), jnp.int32, mesh,
                           P(plan.batch_axes, None)),
        }
    return {
        "tokens": _sds((b, shape.seq_len), jnp.int32, mesh,
                       P(plan.batch_axes, None)),
        "labels": _sds((b, shape.seq_len), jnp.int32, mesh,
                       P(plan.batch_axes, None)),
    }


def abstract_params(cfg: ModelConfig, mesh: Mesh,
                    plan: sharding.ShardingPlan):
    aparams = api.init_abstract(cfg)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = sharding.params_pspec(plan, aparams, axis_sizes)
    return sharding.attach(aparams, sharding.named(mesh, pspecs)), pspecs


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh,
                       plan: sharding.ShardingPlan, aparams, pspecs,
                       opt_cfg: optim.OptimizerConfig):
    aopt = jax.eval_shape(functools.partial(optim.init, opt_cfg), aparams)
    ospecs = sharding.opt_state_pspec(plan, pspecs, aparams, opt_cfg.name)
    return sharding.attach(aopt, sharding.named(mesh, ospecs))


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   plan: sharding.ShardingPlan):
    b = shape.global_batch
    enc_len = shape.seq_len if cfg.family == "encdec" else 0
    acache = jax.eval_shape(
        functools.partial(api.init_cache, cfg, b, shape.seq_len,
                          enc_len=enc_len))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cspecs = sharding.cache_pspec(plan, acache, b, axis_sizes)
    return sharding.attach(acache, sharding.named(mesh, cspecs))


def cell_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   plan: sharding.ShardingPlan,
                   opt_cfg: optim.OptimizerConfig | None = None):
    """Returns (jitted_fn, kwargs-of-ShapeDtypeStructs) for a cell.

    NOTE: the returned fn must be .lower()'d inside
    ``ctx.use(shard_ctx(plan))`` (and the mesh context) so the model's
    activation sharding constraints bind — dryrun does this.
    """
    opt_cfg = opt_cfg or optim.OptimizerConfig(name=plan.optimizer)
    cfg = cfg.replace(remat=plan.remat, remat_policy=plan.remat_policy)
    if shape.kind == "train":
        aparams, pspecs = abstract_params(cfg, mesh, plan)
        aopt = abstract_opt_state(cfg, mesh, plan, aparams, pspecs, opt_cfg)
        fn = jax.jit(make_train_step(cfg, opt_cfg),
                     donate_argnums=(0, 1))
        args = (aparams, aopt, batch_specs(cfg, shape, mesh, plan))
        return fn, args
    if shape.kind == "prefill":
        aparams, _ = abstract_params(cfg, mesh, plan)
        batch = batch_specs(cfg, shape, mesh, plan)
        batch.pop("labels")
        fn = jax.jit(make_prefill_step(cfg))
        return fn, (aparams, batch)
    # decode
    aparams, _ = abstract_params(cfg, mesh, plan)
    acache = abstract_cache(cfg, shape, mesh, plan)
    token = _sds((shape.global_batch, 1), jnp.int32, mesh,
                 P(plan.batch_axes if shape.global_batch > 1 else None,
                   None))
    pos = _sds((), jnp.int32, mesh, P())
    fn = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
    return fn, (aparams, acache, token, pos)
