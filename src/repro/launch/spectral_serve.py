"""Overload-resilient spectral serving front end (images in, logits out).

A request-queue server over the compile-once LayerPlan stack
(``core.plan.build_network_plan`` + ``models.cnn.forward_spectral``)
built around one principle: **a latency number only matters if it holds
at the tail, under bursty load and partial failure**.  The paper's
figure of merit is single-stream latency; this module is what keeps
that figure meaningful when requests arrive faster than they drain.

Four mechanisms compose:

  1. **Admission control + load shedding.**  The queue is bounded
     (``queue_limit``); a request that arrives to a full queue is
     rejected *immediately* with a structured ``overloaded`` response
     instead of queuing unboundedly.  Every request carries an optional
     relative deadline; a request still queued past its deadline
     retires with ``deadline_exceeded`` before ever touching a kernel.
     Every request reaches exactly one terminal response code:
     ``ok`` | ``overloaded`` | ``deadline_exceeded`` | ``failed``.

  2. **Batch bucketing over a keyed plan cache.**  Pending requests are
     batched into the smallest bucket of ``buckets`` (default
     {1, 2, 4, 8}) that fits, padded with zero images, and executed
     with a ``NetworkPlan`` cached per (config, alpha, bucket) in a
     ``core.plan.PlanCache`` warmed at startup — no request ever pays
     ``plan_build_s`` (~2 min on full VGG16, see BENCH_e2e.json).
     Plans are tuned *at* their bucket's batch with the interpret-mode
     per-step overhead priced in (``dataflow.INTERPRET_STEP_S``), so
     the batch-8 bucket gets batch-8 blocks instead of inheriting
     batch-1 choices (PR 8).  Dispatch is double-buffered: while the
     current batch's kernels run, the *next* batch's padded input is
     already being uploaded (``jax.device_put`` is async), so the
     host->device copy overlaps kernel time instead of serializing
     ahead of it — ``staged_uploads``/``staged_hits`` counters surface
     the overlap in ``health_report()``.

  3. **A load-triggered degradation ladder.**  The PR-6 ladder demoted
     layers on *faults*; here the same backend rungs
     (``resilience.BACKEND_RUNGS``: fused -> staged -> einsum, demoted
     via ``plan_at_backend_rung`` with provenance) are driven by
     *load*: a pressure signal (queue-depth fill fraction max'd with
     the fraction of queued requests whose deadline slack is below the
     current service-time estimate) demotes execution one rung after
     ``demote_patience`` high-pressure ticks and promotes one rung back
     after ``promote_patience`` low-pressure ticks.  Independently, a
     per-backend ``resilience.CircuitBreaker`` (consecutive-failure
     open, half-open recovery probes) skips rungs that keep failing, so
     a kernel fault mid-request costs one in-batch retry a rung down —
     never a dead loop.  Every rung transition and breaker state change
     is surfaced in ``health_report()``.

  4. **Deterministic chaos sites.**  The server consults three
     serve-level fault sites (``repro.testing.faults``):
     ``serve_kernel`` (raise at batch dispatch on a matching backend),
     ``serve_plan_cache`` (corrupt the plan fetched from the cache —
     caught by ``validate_plan`` on fetch, served via the einsum
     terminal rung, never executed silently), and ``serve_slow``
     (inject extra seconds of service time, creating deadline
     pressure).  ``faults.chaos_soak`` drives a 4x-capacity burst
     through all of them; ``benchmarks/serve_bench.py --chaos`` gates
     CI on it.

Run a synthetic burst from the CLI::

    PYTHONPATH=src python -m repro.launch.spectral_serve --requests 32 \
        --queue-limit 8 --json -

Timing is injectable (``clock=``, any zero-arg callable returning
seconds; ``ManualClock`` for deterministic tests) so deadlines, breaker
cooldowns and the ladder are all testable without wall-clock sleeps.
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import logging
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflow as df
from repro.core import resilience as res
from repro.core.plan import PlanCache, plan_cache_key
from repro.models import cnn

_LOG = logging.getLogger("repro.spectral_serve")

#: Terminal response codes — every submitted request ends on exactly one.
RESPONSE_CODES = ("ok", "overloaded", "deadline_exceeded", "failed")

#: Default batch buckets (requests are padded up to the nearest).
DEFAULT_BUCKETS = (1, 2, 4, 8)

SERVE_RUNGS = res.BACKEND_RUNGS          # ("fused", "staged", "einsum")


class ManualClock:
    """Deterministic virtual clock: callable like ``time.monotonic``,
    advanced explicitly (tests) or by injected ``serve_slow`` seconds
    (the server calls ``advance`` when its clock supports it)."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass
class InferenceRequest:
    """One image-classification request.

    ``deadline_s`` is a *relative* latency budget from submission (None
    = the server default; the default default is unlimited).  On
    completion exactly one of the terminal ``code`` values is set; for
    ``ok`` the class ``logits`` and the serving ``rung`` (backend that
    produced them) are filled in.
    """

    rid: int
    image: np.ndarray                     # [C, H, W] f32
    deadline_s: float | None = None
    submitted_at: float | None = None
    completed_at: float | None = None
    code: str | None = None               # terminal response code
    logits: np.ndarray | None = None
    error: str | None = None
    rung: str | None = None               # backend that served it

    @property
    def terminal(self) -> bool:
        return self.code is not None

    @property
    def ok(self) -> bool:
        return self.code == "ok"

    @property
    def latency_s(self) -> float | None:
        if self.completed_at is None or self.submitted_at is None:
            return None
        return self.completed_at - self.submitted_at

    def response(self) -> dict:
        """The structured wire response (logits elided for failures)."""
        out = {"rid": self.rid, "code": self.code}
        if self.code == "ok":
            out["rung"] = self.rung
            out["latency_s"] = self.latency_s
        elif self.error:
            out["error"] = self.error
        return out


class SpectralServer:
    """Bounded-queue batch-bucketing server over the LayerPlan stack.

    See the module docstring for the mechanism overview.  The main
    loop is ``tick()`` (expire -> ladder update -> batch -> execute);
    ``run_until_drained`` drives it to completion plus a bounded
    cool-down so the ladder can promote back once pressure clears.
    """

    def __init__(self, cfg=None, *,
                 buckets=DEFAULT_BUCKETS,
                 queue_limit: int = 16,
                 default_deadline_s: float | None = None,
                 demote_pressure: float = 0.8,
                 promote_pressure: float = 0.25,
                 demote_patience: int = 1,
                 promote_patience: int = 2,
                 breaker_failures: int = 3,
                 breaker_cooldown_s: float = 1.0,
                 clock=time.monotonic,
                 seed: int = 0,
                 warm: bool = True,
                 warm_forward: bool = False,
                 guards: res.NumericGuards | None = None,
                 interpret: bool | None = None,
                 plan_cache: PlanCache | None = None,
                 plan_kwargs: dict | None = None,
                 mesh_shape: tuple[int, ...] | None = None):
        if cfg is None:
            from repro.configs import vgg16_spectral
            cfg = vgg16_spectral.SMOKE
        self.cfg = cfg
        self.buckets = tuple(sorted(int(b) for b in buckets))
        if not self.buckets:
            raise ValueError("need at least one batch bucket")
        self.max_bucket = self.buckets[-1]
        self.queue_limit = int(queue_limit)
        self.default_deadline_s = default_deadline_s
        self.demote_pressure = demote_pressure
        self.promote_pressure = promote_pressure
        self.demote_patience = int(demote_patience)
        self.promote_patience = int(promote_patience)
        self.clock = clock
        self.interpret = interpret
        self.guards = guards
        self.plan_kwargs = dict(plan_kwargs or {})
        # Per-bucket plans should minimize the wall clock of the backend
        # that actually runs; everywhere but real TPU that is the
        # interpret-mode kernel, whose time is dominated by grid steps.
        if interpret is not False:
            self.plan_kwargs.setdefault("step_overhead_s",
                                        df.INTERPRET_STEP_S)

        first = list(cfg.layers)[0]
        self.image_shape = (first.c_in, first.h_in, first.w_in)
        self.params = cnn.init(jax.random.PRNGKey(seed), cfg)

        # The device topology this server executes on, folded into every
        # plan-cache key.  A cache shared across servers (or a server
        # whose mesh changed across restarts with a persistent cache)
        # must never hand a plan built for one topology to another —
        # sharded plans bake shard geometry and collective shapes, so a
        # cross-mesh hit is silent wrong math, not an error.
        self.mesh_shape = (tuple(int(d) for d in mesh_shape)
                           if mesh_shape is not None else None)
        self.plans = plan_cache if plan_cache is not None else PlanCache()
        if warm:
            self.plans.warm(self.params, cfg, self.buckets,
                            mesh_shape=self.mesh_shape,
                            **self.plan_kwargs)

        # per-rung circuit breakers; the terminal einsum rung is never
        # gated (it must always execute)
        self.breakers: dict[str, res.CircuitBreaker] = {
            b: res.CircuitBreaker(name=b,
                                  failure_threshold=breaker_failures,
                                  cooldown_s=breaker_cooldown_s,
                                  clock=self.clock)
            for b in SERVE_RUNGS[:-1]}

        self.queue: collections.deque[InferenceRequest] = collections.deque()
        self._staged: dict | None = None   # next batch's in-flight upload
        self._variants: dict[int, dict] = {}
        self._validated_plan: dict[int, object] = {}
        self._corrupt_buckets: set[int] = set()
        self._service_ema: dict[str, float] = {}

        self._load_rung = 0
        self._demote_streak = 0
        self._promote_streak = 0
        self._last_pressure = {"pressure": 0.0, "queue_fill": 0.0,
                               "deadline_risk": 0.0, "queue_depth": 0}
        self.transitions: list[dict] = []
        self.n_demotions = 0
        self.n_promotions = 0

        self._ticks = 0
        self.batches = 0
        self.loop_deaths = 0
        self.latencies: list[float] = []
        self.served_by = {b: 0 for b in SERVE_RUNGS}
        self.counters = {c: 0 for c in ("submitted",) + RESPONSE_CODES}
        self.counters.update(kernel_faults=0, plan_cache_corruptions=0,
                             slow_injections=0, staged_uploads=0,
                             staged_hits=0)
        self._first_submit_t: float | None = None
        self._last_completion_t: float | None = None

        if warm_forward and warm:
            self.warm_forward()

    # -- plumbing ------------------------------------------------------

    def _now(self) -> float:
        return self.clock()

    def warm_forward(self) -> None:
        """Run one zero batch per bucket at the fused rung so no
        request pays trace/compile time either."""
        for b in self.buckets:
            plan = self.plans.get(self.params, self.cfg, b,
                                  mesh_shape=self.mesh_shape,
                                  **self.plan_kwargs)
            x = jnp.zeros((b,) + self.image_shape, jnp.float32)
            jax.block_until_ready(cnn.forward_spectral(
                self.params, plan, x, backend="pallas_fused",
                interpret=self.interpret))

    # -- admission control --------------------------------------------

    def submit(self, req: InferenceRequest) -> InferenceRequest:
        """Admit one request, or shed it with a structured response.

        Returns the request with either ``submitted_at`` set (queued)
        or a terminal ``overloaded`` / ``failed`` code.
        """
        now = self._now()
        req.submitted_at = now
        if self._first_submit_t is None:
            self._first_submit_t = now
        if req.deadline_s is None:
            req.deadline_s = self.default_deadline_s
        self.counters["submitted"] += 1
        img = np.asarray(req.image, np.float32)
        if img.shape != self.image_shape:
            self._finish(req, "failed",
                         error=f"bad_request: image shape {img.shape} "
                               f"!= {self.image_shape}")
            return req
        req.image = img
        if len(self.queue) >= self.queue_limit:
            self._finish(req, "overloaded",
                         error=f"queue full ({len(self.queue)}/"
                               f"{self.queue_limit}); request shed")
            return req
        self.queue.append(req)
        return req

    def _finish(self, req: InferenceRequest, code: str, *,
                error: str | None = None, rung: str | None = None,
                completed_at: float | None = None) -> None:
        req.code = code
        req.error = error
        req.rung = rung
        req.completed_at = (completed_at if completed_at is not None
                            else self._now())
        self.counters[code] += 1
        if code == "ok":
            self._last_completion_t = req.completed_at
            if req.latency_s is not None:
                self.latencies.append(req.latency_s)
        else:
            _LOG.warning("[spectral-serve] request %s -> %s: %s",
                         req.rid, code, error)

    # -- load signal + ladder -----------------------------------------

    def _service_estimate_s(self) -> float | None:
        """Per-batch service-time estimate at the current load rung
        (EMA of observed batch wall times, injected slowness included),
        falling back to the worst known backend."""
        est = self._service_ema.get(SERVE_RUNGS[self._load_rung])
        if est is None and self._service_ema:
            est = max(self._service_ema.values())
        return est

    def _pressure(self, now: float) -> tuple[float, dict]:
        fill = (len(self.queue) / self.queue_limit
                if self.queue_limit else 0.0)
        risk = 0.0
        est = self._service_estimate_s()
        if self.queue and est is not None:
            at_risk = sum(
                1 for r in self.queue
                if r.deadline_s is not None
                and (r.submitted_at + r.deadline_s) - now < est)
            risk = at_risk / len(self.queue)
        p = min(1.0, max(fill, risk))
        return p, {"pressure": p, "queue_fill": fill,
                   "deadline_risk": risk, "queue_depth": len(self.queue)}

    def _transition(self, to_rung: int, direction: str, reason: str,
                    pressure: float) -> None:
        self.transitions.append({
            "tick": self._ticks, "t": self._now(),
            "direction": direction,
            "from": SERVE_RUNGS[self._load_rung],
            "to": SERVE_RUNGS[to_rung],
            "reason": reason, "pressure": pressure})
        if direction == "demote":
            self.n_demotions += 1
        else:
            self.n_promotions += 1
        _LOG.info("[spectral-serve] %s %s -> %s (%s)", direction,
                  SERVE_RUNGS[self._load_rung], SERVE_RUNGS[to_rung],
                  reason)
        self._load_rung = to_rung

    def _update_ladder(self, now: float) -> None:
        pressure, detail = self._pressure(now)
        self._last_pressure = detail
        if pressure >= self.demote_pressure:
            self._demote_streak += 1
            self._promote_streak = 0
        elif pressure <= self.promote_pressure:
            self._promote_streak += 1
            self._demote_streak = 0
        else:
            self._demote_streak = self._promote_streak = 0
        if (self._demote_streak >= self.demote_patience
                and self._load_rung < len(SERVE_RUNGS) - 1):
            self._transition(
                self._load_rung + 1, "demote",
                f"pressure {pressure:.2f} >= {self.demote_pressure} "
                f"for {self._demote_streak} tick(s)", pressure)
            self._demote_streak = 0
        elif self._promote_streak >= self.promote_patience \
                and self._load_rung > 0:
            target = self._load_rung - 1
            brk = self.breakers.get(SERVE_RUNGS[target])
            if brk is None or brk.allow():
                self._transition(
                    target, "promote",
                    f"pressure {pressure:.2f} <= "
                    f"{self.promote_pressure} for "
                    f"{self._promote_streak} tick(s)", pressure)
                self._promote_streak = 0
            # else: keep the streak; retry once the breaker cools down

    # -- batching ------------------------------------------------------

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_bucket

    def _take_batch(self, now: float) -> list[InferenceRequest]:
        """Expire queued requests past their deadline, then pop up to
        ``max_bucket`` requests in FIFO order."""
        kept: collections.deque[InferenceRequest] = collections.deque()
        while self.queue:
            r = self.queue.popleft()
            if r.deadline_s is not None \
                    and now > r.submitted_at + r.deadline_s:
                self._finish(r, "deadline_exceeded",
                             error=f"deadline {r.deadline_s:.3f}s "
                                   f"exceeded before execution")
            else:
                kept.append(r)
        self.queue = kept
        batch = []
        while self.queue and len(batch) < self.max_bucket:
            batch.append(self.queue.popleft())
        return batch

    # -- plan fetch + variants ----------------------------------------

    def _fetch_plan(self, bucket: int):
        """Fetch the bucket's plan through the cache and the
        ``serve_plan_cache`` fault site; a fetched plan that fails
        ``validate_plan`` is never executed on an aggressive rung —
        the batch is forced onto the terminal einsum rung (which
        consumes only the pruned kernels, not the corrupt tables) and
        the corruption is counted + surfaced in ``health_report()``.

        Returns (plan, force_einsum).
        """
        plan = self.plans.get(self.params, self.cfg, bucket,
                              mesh_shape=self.mesh_shape,
                              **self.plan_kwargs)
        fetched = res.fault_corrupt("serve_plan_cache", plan,
                                    bucket=bucket)
        if fetched is not self._validated_plan.get(bucket):
            try:
                res.validate_plan(fetched, raise_on_error=True)
                self._validated_plan[bucket] = fetched
            except res.PlanValidationError as e:
                self.counters["plan_cache_corruptions"] += 1
                self._corrupt_buckets.add(bucket)
                _LOG.error("[spectral-serve] corrupt plan for bucket "
                           "%d; serving via einsum rung: %s", bucket,
                           str(e).splitlines()[0])
                return fetched, True
        self._corrupt_buckets.discard(bucket)
        return fetched, False

    def _variant(self, plan, bucket: int, rung: int):
        """The bucket's plan demoted to the given ladder rung (lazily
        derived via ``resilience.plan_at_backend_rung``, provenance
        stamped, cached per pristine plan object)."""
        ent = self._variants.get(bucket)
        if ent is None or ent["base"] is not plan:
            ent = {"base": plan, "rungs": {0: plan}}
            self._variants[bucket] = ent
        if rung not in ent["rungs"]:
            ent["rungs"][rung] = res.plan_at_backend_rung(
                plan, SERVE_RUNGS[rung],
                reason=f"load ladder rung {rung}")
        return ent["rungs"][rung]

    # -- execution -----------------------------------------------------

    def _note_service(self, backend: str, dt: float) -> None:
        prev = self._service_ema.get(backend)
        self._service_ema[backend] = (dt if prev is None
                                      else 0.5 * prev + 0.5 * dt)

    def _pad_batch(self, batch: list[InferenceRequest], bucket: int
                   ) -> np.ndarray:
        x = np.zeros((bucket,) + self.image_shape, np.float32)
        for i, req in enumerate(batch):
            x[i] = req.image
        return x

    def _upload(self, batch: list[InferenceRequest], bucket: int):
        """Start the (async) host->device copy of one padded batch; the
        double-buffered dispatch path consumes a copy started while the
        previous batch's kernels were still running."""
        key = (tuple(r.rid for r in batch), bucket)
        if self._staged is not None and self._staged["key"] == key:
            self.counters["staged_hits"] += 1
            xj = self._staged["xj"]
        else:
            xj = jax.device_put(self._pad_batch(batch, bucket))
        self._staged = None
        return xj

    def _stage_next(self) -> None:
        """Peek (don't pop) the head of the queue and start uploading
        what the *next* tick will execute, overlapping the copy with
        the kernel currently in flight.  Best-effort: a stale stage is
        simply ignored by ``_upload``'s key check."""
        if not self.queue:
            return
        nxt = list(self.queue)[:self.max_bucket]
        bucket = self._bucket_for(len(nxt))
        key = (tuple(r.rid for r in nxt), bucket)
        if self._staged is not None and self._staged["key"] == key:
            return
        self._staged = {"key": key,
                        "xj": jax.device_put(self._pad_batch(nxt, bucket))}
        self.counters["staged_uploads"] += 1

    def _execute(self, batch: list[InferenceRequest], bucket: int
                 ) -> str | None:
        """Run one padded batch, walking ladder rungs from the current
        load rung down until one succeeds; returns the serving backend
        or None when even the terminal rung failed (requests then carry
        a ``failed`` response — still a terminal outcome)."""
        xj = self._upload(batch, bucket)
        plan, force_einsum = self._fetch_plan(bucket)
        if force_einsum:
            order = [len(SERVE_RUNGS) - 1]
        else:
            order = list(range(self._load_rung, len(SERVE_RUNGS)))
        errors: list[str] = []
        for r in order:
            backend = SERVE_RUNGS[r]
            brk = self.breakers.get(backend)
            if brk is not None and not brk.allow():
                errors.append(f"{backend}: breaker open")
                continue
            try:
                res.fault_check("serve_kernel", backend=backend,
                                bucket=bucket)
                t0 = time.perf_counter()
                if force_einsum:
                    y = cnn.forward_spectral(self.params, plan, xj,
                                             backend="einsum")
                else:
                    y = cnn.forward_spectral(
                        self.params, self._variant(plan, bucket, r), xj,
                        backend="pallas_fused", interpret=self.interpret,
                        guards=self.guards)
                # kernels are dispatched but not awaited: start the next
                # batch's upload now so the copy rides under them
                self._stage_next()
                y = np.asarray(jax.block_until_ready(y))
                dt = time.perf_counter() - t0
            except Exception as e:      # noqa: BLE001 — isolation edge
                self.counters["kernel_faults"] += 1
                if brk is not None:
                    brk.record_failure(type(e).__name__)
                errors.append(f"{backend}: {type(e).__name__}: "
                              f"{str(e).splitlines()[0] if str(e) else ''}")
                _LOG.error("[spectral-serve] bucket %d failed on rung "
                           "%s: %s", bucket, backend, errors[-1])
                continue
            extra = float(res.fault_corrupt("serve_slow", 0.0,
                                            backend=backend,
                                            bucket=bucket))
            if extra:
                self.counters["slow_injections"] += 1
                if hasattr(self.clock, "advance"):
                    self.clock.advance(extra)
                dt += extra
            if brk is not None:
                brk.record_success()
            self._note_service(backend, dt)
            done = self._now()
            for i, req in enumerate(batch):
                req.logits = y[i]
                self._finish(req, "ok", rung=backend, completed_at=done)
            self.served_by[backend] += len(batch)
            self.batches += 1
            return backend
        msg = "; ".join(errors) or "no execution rung available"
        for req in batch:
            self._finish(req, "failed", error=msg)
        return None

    # -- main loop -----------------------------------------------------

    def tick(self) -> int:
        """One serve step: expire deadlines, update the load ladder,
        form one bucket batch and execute it.  Returns the number of
        requests served a terminal outcome this tick."""
        self._ticks += 1
        now = self._now()
        self._update_ladder(now)
        batch = self._take_batch(now)
        if not batch:
            return 0
        bucket = self._bucket_for(len(batch))
        self._execute(batch, bucket)
        return len(batch)

    def run_until_drained(self, max_ticks: int = 10_000,
                          cooldown_ticks: int | None = None) -> dict:
        """Tick until the queue drains (bounded by ``max_ticks``), then
        keep ticking up to ``cooldown_ticks`` idle steps so the ladder
        can promote back once pressure clears.  A tick that raises is a
        *loop death* — counted, the queue head is failed to guarantee
        progress, and the loop continues (the burst still drains)."""
        if cooldown_ticks is None:
            cooldown_ticks = 4 * self.promote_patience + 4
        ticks = 0
        while self.queue and ticks < max_ticks:
            try:
                self.tick()
            except Exception as e:      # noqa: BLE001 — loop must live
                self.loop_deaths += 1
                _LOG.exception("[spectral-serve] tick died: %s", e)
                if self.queue:
                    self._finish(self.queue.popleft(), "failed",
                                 error=f"loop exception: {e}")
            ticks += 1
        for _ in range(cooldown_ticks):
            if self._load_rung == 0 and all(
                    b.state == "closed" for b in self.breakers.values()):
                break
            try:
                self.tick()
            except Exception:           # noqa: BLE001
                self.loop_deaths += 1
            ticks += 1
        return self.stats()

    # -- reporting -----------------------------------------------------

    def stats(self) -> dict:
        lat = np.asarray(self.latencies, np.float64)
        out: dict = {
            "ticks": self._ticks,
            "batches": self.batches,
            "loop_deaths": self.loop_deaths,
            "queue_depth": len(self.queue),
            "counters": dict(self.counters),
            "served_by_rung": dict(self.served_by),
            "demotions": self.n_demotions,
            "promotions": self.n_promotions,
        }
        if lat.size:
            out["latency_ms"] = {
                "mean": float(lat.mean() * 1e3),
                "p50": float(np.percentile(lat, 50) * 1e3),
                "p95": float(np.percentile(lat, 95) * 1e3),
                "p99": float(np.percentile(lat, 99) * 1e3),
            }
        if (self._first_submit_t is not None
                and self._last_completion_t is not None):
            span = self._last_completion_t - self._first_submit_t
            if span > 0:
                out["throughput_img_s"] = self.counters["ok"] / span
        return out

    def health_report(self) -> dict:
        """Serve-level resilience status: the active rung, EVERY ladder
        transition (load demotions and promotions, with the pressure
        that drove them), breaker snapshots, queue/pressure state, the
        plan-cache counters and the per-bucket demotion provenance of
        the active plan variants."""
        plans = {}
        for bucket, ent in self._variants.items():
            active = ent["rungs"].get(self._load_rung, ent["base"])
            plans[f"bucket{bucket}"] = {
                "backends": sorted({lp.backend for lp in active.layers}),
                "demoted_layers": [lp.layer.name for lp in active.layers
                                   if lp.provenance],
                "provenance_sample": list(
                    active.layers[0].provenance),
            }
        return {
            "rung": SERVE_RUNGS[self._load_rung],
            "load_rung": self._load_rung,
            "pressure": dict(self._last_pressure),
            "transitions": list(self.transitions),
            "demotions": self.n_demotions,
            "promotions": self.n_promotions,
            "breakers": {n: b.snapshot()
                         for n, b in self.breakers.items()},
            "queue": {"depth": len(self.queue),
                      "limit": self.queue_limit},
            "counters": dict(self.counters),
            "plan_cache": {**self.plans.stats(),
                           "corrupt_buckets":
                               sorted(self._corrupt_buckets)},
            "plans": plans,
        }


def synthetic_requests(n: int, cfg, *, seed: int = 0,
                       deadline_s: float | None = None,
                       rid0: int = 0) -> list[InferenceRequest]:
    """Deterministic request batch for benchmarks/tests: seeded normal
    images at the config's input shape."""
    first = list(cfg.layers)[0]
    rng = np.random.default_rng(seed)
    return [InferenceRequest(
        rid=rid0 + i,
        image=rng.standard_normal(
            (first.c_in, first.h_in, first.w_in)).astype(np.float32),
        deadline_s=deadline_s)
        for i in range(n)]


def main() -> None:
    from repro.configs import vgg16_spectral

    ap = argparse.ArgumentParser(
        description="overload-resilient spectral serving front end "
                    "(synthetic burst driver)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--queue-limit", type=int, default=8)
    ap.add_argument("--buckets", type=int, nargs="+",
                    default=list(DEFAULT_BUCKETS))
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline (default: unlimited)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="write stats+health JSON to this path "
                         "('-' for stdout)")
    args = ap.parse_args()

    srv = SpectralServer(vgg16_spectral.SMOKE, buckets=args.buckets,
                         queue_limit=args.queue_limit, seed=args.seed,
                         default_deadline_s=(
                             args.deadline_ms / 1e3
                             if args.deadline_ms is not None else None))
    reqs = synthetic_requests(args.requests, srv.cfg, seed=args.seed)
    for r in reqs:
        srv.submit(r)
    stats = srv.run_until_drained()
    health = srv.health_report()
    print(f"[spectral-serve] {args.requests} requests -> "
          f"{stats['counters']['ok']} ok / "
          f"{stats['counters']['overloaded']} shed / "
          f"{stats['counters']['deadline_exceeded']} deadline / "
          f"{stats['counters']['failed']} failed in "
          f"{stats['ticks']} ticks on rung {health['rung']} "
          f"({stats['demotions']} demotions, "
          f"{stats['promotions']} promotions)")
    if "latency_ms" in stats:
        lm = stats["latency_ms"]
        print(f"[spectral-serve] latency ms p50 {lm['p50']:.1f} "
              f"p95 {lm['p95']:.1f} p99 {lm['p99']:.1f}; throughput "
              f"{stats.get('throughput_img_s', float('nan')):.1f} img/s")
    if args.json:
        payload = json.dumps({"stats": stats, "health": health},
                             indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")


if __name__ == "__main__":
    main()
