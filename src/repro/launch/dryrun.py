import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * the sharding planner picks the strategy (Alg-1 analogue),
  * ``jax.jit(step).lower(*ShapeDtypeStructs).compile()`` must succeed on
    the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh,
  * ``memory_analysis()`` proves the cell fits per-chip HBM,
  * ``cost_analysis()`` + HLO collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import contextlib
import dataclasses
import gc
import json
import pathlib
import time
import traceback

import jax

from repro import configs
from repro.distributed import ctx, planner
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.roofline import analysis, hlo_parse


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, keep_hlo: bool = False,
             variant: str = "", plan_overrides: dict | None = None,
             cfg_overrides: dict | None = None) -> dict:
    cfg = configs.get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = configs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    msd = mesh_shape_dict(mesh)
    n_dev = mesh.devices.size
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_{variant}" if variant else ""

    best, all_costs = planner.plan_cell(cfg, shape, msd)
    # shard_map expert parallelism is the default for MoE (EXPERIMENTS.md
    # §Perf Cell C: 852 s -> 7.2 s); --no-moe-ep reproduces the ablation
    moe_ep = cfg.family == "moe"
    if plan_overrides and "_moe_ep" in plan_overrides:
        moe_ep = bool(plan_overrides.pop("_moe_ep"))
    if plan_overrides:
        best = dataclasses.replace(
            best, plan=dataclasses.replace(best.plan, **plan_overrides))
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": n_dev,
        "plan": {
            "fsdp_axes": list(best.plan.fsdp_axes),
            "optimizer": best.plan.optimizer,
            "remat": best.plan.remat,
            "seq_shard": best.plan.seq_shard,
            "fits": best.fits,
            "predicted_mem_gib": best.total_bytes_per_chip / 2 ** 30,
            "predicted_coll_gib": best.collective_bytes_per_step / 2 ** 30,
        },
        "planner_candidates": [c.summary() for c in all_costs],
    }
    record["variant"] = variant
    t0 = time.time()
    try:
        shard_ctx = (ctx.ShardCtx(best.plan.batch_axes,
                                  seq_parallel=best.plan.seq_parallel,
                                  moe_ep=moe_ep, mesh=mesh,
                                  fsdp_axes=best.plan.fsdp_axes
                                  if best.plan.fsdp else ())
                     if best.plan.constraints else None)
        cm = ctx.use(shard_ctx) if shard_ctx else contextlib.nullcontext()
        with mesh, cm:
            fn, args = steps.cell_lowerable(cfg, shape, mesh, best.plan)
            lowered = fn.lower(*args)
            record["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = time.time() - t1

            mem = compiled.memory_analysis()
            if mem is not None:
                record["memory_analysis"] = {
                    k: getattr(mem, k) for k in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes")
                    if hasattr(mem, k)}
                print(f"[{arch}/{shape_name}/{mesh_name}] memory_analysis:",
                      record["memory_analysis"])
            cost = analysis.cost_analysis_dict(compiled)
            record["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds")}
            print(f"[{arch}/{shape_name}/{mesh_name}] cost_analysis:",
                  record["cost_analysis"])

            hlo = compiled.as_text()
            parsed = hlo_parse.parse(hlo, n_dev)
            coll = parsed.collectives
            record["collectives"] = {
                "counts": coll.counts,
                "operand_bytes": coll.operand_bytes,
                "wire_bytes_per_chip": coll.wire_bytes_per_chip,
                "loop_multipliers": {k: v for k, v in
                                     sorted(parsed.loop_multipliers.items())
                                     if "region" in k},
                "unknown_trip_loops": parsed.unknown_trip_loops,
            }
            # trip-corrected compute term from parsed dot ops; analytic
            # HBM traffic (cost_analysis bytes are loop-body-once floors)
            mf = analysis.model_flops(cfg, shape)
            hbm = analysis.analytic_hbm_bytes(cfg, shape, best.plan, msd)
            cost_corrected = dict(record["cost_analysis"])
            cost_corrected["flops"] = parsed.dot_flops
            cost_corrected["bytes accessed"] = max(
                hbm, cost_corrected.get("bytes accessed", 0.0))
            roof = analysis.roofline_terms(cost_corrected, coll, n_dev, mf)
            record["roofline"] = roof.as_dict()
            record["roofline"]["raw_cost_flops"] = \
                record["cost_analysis"].get("flops")
            record["roofline"]["analytic_hbm_bytes"] = hbm
            if keep_hlo:
                (out_dir /
                 f"{arch}_{shape_name}_{mesh_name}{suffix}.hlo.txt"
                 ).write_text(hlo)
            del compiled, lowered, hlo
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = time.time() - t0
    out_path = out_dir / f"{arch}_{shape_name}_{mesh_name}{suffix}.json"
    out_path.write_text(json.dumps(record, indent=1, default=str))
    gc.collect()
    status = record["status"]
    extra = "" if status == "ok" else f" ({record.get('error', '')[:120]})"
    print(f"[{arch}/{shape_name}/{mesh_name}] {status} "
          f"in {record['total_s']:.1f}s{extra}", flush=True)
    return record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="single",
                   choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--keep-hlo", action="store_true")
    p.add_argument("--variant", default="",
                   help="suffix for perf-experiment output files")
    p.add_argument("--no-constraints", action="store_true")
    p.add_argument("--seq-parallel", action="store_true")
    p.add_argument("--force-fsdp", default=None,
                   help="comma list of fsdp axes, or 'off'")
    p.add_argument("--dp-only", action="store_true",
                   help="force pure weight-streaming (no TP)")
    p.add_argument("--remat-policy", default=None,
                   choices=["full", "dots"])
    p.add_argument("--kv-quant", action="store_true",
                   help="int8 KV cache for decode cells")
    p.add_argument("--moe-ep", action="store_true",
                   help="force shard_map expert-parallel MoE dispatch")
    p.add_argument("--no-moe-ep", action="store_true",
                   help="disable the shard_map MoE path (ablation)")
    args = p.parse_args()
    overrides = {}
    if args.no_constraints:
        overrides["constraints"] = False
    if args.seq_parallel:
        overrides["seq_parallel"] = True
    if args.dp_only:
        overrides["tp"] = False
        overrides["fsdp"] = True
    if args.remat_policy:
        overrides["remat_policy"] = args.remat_policy
    cfg_overrides = {"kv_quant": True} if args.kv_quant else None
    if args.moe_ep:
        overrides["_moe_ep"] = True
    if args.no_moe_ep:
        overrides["_moe_ep"] = False
    if args.force_fsdp is not None:
        if args.force_fsdp == "off":
            overrides.update(fsdp=False, fsdp_axes=(), tp=True)
        else:
            axes = tuple(a for a in args.force_fsdp.split(",") if a)
            overrides.update(fsdp=True, fsdp_axes=axes)
    out = pathlib.Path(args.out)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        cells = [(a, s) for a, s, skipped in configs.cells()
                 if not skipped]
    else:
        shapes = [args.shape] if args.shape else list(configs.SHAPES)
        archs = [args.arch] if args.arch else list(configs.ARCHS)
        cells = [(a, s) for a in archs for s in shapes
                 if not (s == "long_500k"
                         and a not in configs.LONG_CONTEXT_ARCHS)]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, out, keep_hlo=args.keep_hlo,
                           variant=args.variant,
                           plan_overrides=overrides or None,
                           cfg_overrides=cfg_overrides)
            failures += rec["status"] != "ok"
    print(f"dry-run done: {len(cells) * len(meshes) - failures} ok, "
          f"{failures} failed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
