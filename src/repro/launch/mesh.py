"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state; the dry-run sets XLA_FLAGS for 512 host devices before any
jax import and then calls this.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh(shape: tuple[int, ...] = (1, 1),
                   axes: tuple[str, ...] = ("data", "model")):
    """Mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh(shape, axes)
