"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state; the dry-run sets XLA_FLAGS for 512 host devices before any
jax import and then calls this.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_host_mesh(shape: tuple[int, ...] = (1, 1),
                   axes: tuple[str, ...] = ("data", "model")):
    """Mesh over however many (host) devices exist — tests/examples."""
    return jax.make_mesh(shape, axes)


def make_spectral_mesh(n_shards: int, axis: str = "shard"):
    """1-D mesh for sharded spectral inference (ISSUE 9).

    Uses the FIRST ``n_shards`` devices so a plan built for a small
    mesh runs on a machine exposing more (e.g. a 2-shard plan on the
    CI's forced 8-device CPU mesh).  The axis name must match
    ``ShardedNetworkPlan.axis`` — the executor's collectives
    (ppermute/psum) are written against it.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices for the spectral mesh, have "
            f"{len(devs)} (forced host meshes: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} "
            f"BEFORE importing jax)")
    return Mesh(np.asarray(devs[:n_shards]), (axis,))
