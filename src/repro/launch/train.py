"""Training driver: data pipeline -> sharded train step -> checkpoints.

Fault-tolerance posture (tested in tests/test_train_loop.py):
  * checkpoint every ``ckpt_every`` steps (async, atomic, checksummed);
  * on start, auto-resume from the latest checkpoint — a crashed/killed
    job restarts bit-exactly (deterministic data pipeline keyed by step);
  * ``--simulate-failure N`` kills the process at step N to exercise the
    restart path end to end;
  * straggler accounting: per-step wall times are recorded; steps slower
    than ``straggler_factor``x the running median are counted and logged
    (on real fleets this signal feeds the replacement policy).

Runs the reduced ("smoke") configs on CPU by default; full configs are
for real accelerator fleets — same code path, different --config-set.
"""

from __future__ import annotations

import argparse
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher
from repro.launch import steps as steps_lib
from repro.models import api
from repro.optim import adamw as optim
from repro.optim.schedule import cosine_with_warmup


def train(arch: str = "smollm-135m", *, steps: int = 50,
          batch: int = 8, seq_len: int = 128, lr: float = 3e-4,
          ckpt_dir: str = "checkpoints/train", ckpt_every: int = 20,
          config_set: str = "smoke", seed: int = 0,
          simulate_failure: int | None = None,
          straggler_factor: float = 3.0,
          log_every: int = 10) -> dict:
    cfg = (configs.get_smoke_config(arch) if config_set == "smoke"
           else configs.get_config(arch))
    opt_cfg = optim.OptimizerConfig(lr=lr)
    ckpt = Checkpointer(ckpt_dir, keep=3)

    params = api.init(jax.random.PRNGKey(seed), cfg)
    opt_state = optim.init(opt_cfg, params)
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        start_step, restored = ckpt.restore(
            latest, {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start_step}", flush=True)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=batch,
                      seed=seed,
                      frames_dim=cfg.d_model if cfg.family == "encdec"
                      else 0)
    data = Prefetcher(dcfg, start_step=start_step)

    base_step = steps_lib.make_train_step(cfg, opt_cfg)
    train_step = jax.jit(base_step, donate_argnums=(0, 1))

    times: list[float] = []
    stragglers = 0
    losses = []
    try:
        while start_step < steps:
            step, host_batch = next(data)
            assert step == start_step, "pipeline out of sync"
            batch_dev = {k: jnp.asarray(v) for k, v in host_batch.items()}
            if cfg.family == "encdec":
                batch_dev["tokens"] = batch_dev["tokens"][:, :64]
                batch_dev["labels"] = batch_dev["labels"][:, :64]
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state,
                                                    batch_dev)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            if len(times) > 5:
                med = statistics.median(times)
                if dt > straggler_factor * med:
                    stragglers += 1
                    print(f"[train] straggler step {step}: {dt:.3f}s vs "
                          f"median {med:.3f}s", flush=True)
            losses.append(loss)
            start_step = step + 1
            if start_step % log_every == 0:
                print(f"[train] step {start_step} loss {loss:.4f} "
                      f"({dt*1000:.0f} ms)", flush=True)
            if start_step % ckpt_every == 0 or start_step == steps:
                ckpt.save(start_step,
                          {"params": params, "opt": opt_state})
            if simulate_failure is not None \
                    and start_step >= simulate_failure:
                ckpt.wait()
                print(f"[train] SIMULATED FAILURE at step {start_step}",
                      flush=True)
                sys.exit(42)
    finally:
        data.close()
        ckpt.wait()
    return {"final_step": start_step, "losses": losses,
            "stragglers": stragglers,
            "median_step_s": statistics.median(times) if times else 0.0}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default="checkpoints/train")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--config-set", default="smoke",
                   choices=["smoke", "full"])
    p.add_argument("--simulate-failure", type=int, default=None)
    args = p.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch,
                seq_len=args.seq_len, lr=args.lr, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, config_set=args.config_set,
                simulate_failure=args.simulate_failure)
    print(f"[train] done: step {out['final_step']} "
          f"loss {out['losses'][-1]:.4f} stragglers {out['stragglers']}")


if __name__ == "__main__":
    main()
