"""Version compatibility for jax.experimental.pallas.tpu.

The TPU compiler-params dataclass was renamed across JAX releases
(``TPUCompilerParams`` -> ``CompilerParams``).  Resolve whichever this
JAX ships so the kernels import cleanly on both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
