"""Pallas TPU kernels for the compute hot spots.

- fused_spectral_conv: ONE pallas_call per conv layer — tile-FFT ->
  Karatsuba Hadamard -> IFFT with psums in VMEM scratch; spectral
  intermediates never touch HBM (the production spectral-conv path,
  configured per layer by core.autotune)
- spectral_hadamard: frequency-binned batched complex GEMM (Eq 3) with
  the paper's three dataflows as grid-order variants (staged path)
- sparse_hadamard:   INDEX/VALUE-table (Fig 6) scheduled sparse execution
- fft8:              2-D (I)FFT as MXU DFT matmuls (staged path)
- flash_attention:   blocked online-softmax attention (LM pillar)

ops.py holds the jit'd public wrappers, ref.py the pure-jnp oracles.
Kernels run with interpret=True on CPU; TPU is the lowering target.
"""
