"""Pallas TPU kernels for the compute hot spots.

- spectral_hadamard: frequency-binned batched complex GEMM (Eq 3) with
  the paper's three dataflows as grid-order variants
- sparse_hadamard:   INDEX/VALUE-table (Fig 6) scheduled sparse execution
- fft8:              2-D (I)FFT as MXU DFT matmuls
- flash_attention:   blocked online-softmax attention (LM pillar)

ops.py holds the jit'd public wrappers, ref.py the pure-jnp oracles.
Kernels run with interpret=True on CPU; TPU is the lowering target.
"""
