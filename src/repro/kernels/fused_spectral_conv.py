"""Pallas TPU kernel: ONE pallas_call for a whole spectral conv layer.

The staged Pallas path (``ops.spectral_conv2d_pallas``) launches three
kernels per layer — fft8 -> spectral_hadamard -> ifft8 — and round-trips
the complex spectral tensors ``X~``/``Y~`` ([B, M, T, K, K], 2 f32 planes)
through HBM between stages.  That inter-stage traffic is the TPU analogue
of exactly the off-chip communication the paper's dataflow eliminates
(§4): the FPGA design pipelines FFT -> Hadamard -> IFFT through on-chip
buffers, touching DDR only for spatial inputs, spectral kernels and
spatial outputs.

This kernel restores that property.  Per grid step it performs, entirely
in VMEM:

  1. tile-FFT   — the DFT-matmul form of ``fft8``, collapsed to a single
     MXU GEMM: with D = kron(W, W)[:, :t^2] ([K^2, t^2], W the K-point DFT
     matrix restricted to the tile's t x t support),
        X~[f, m, p] = sum_s D[f, s] x[s, m, p]
     so the zero-padding of tiles to K x K is folded into D and the
     spatial tiles are stored s-leading ([S, M, P]) — the contraction is
     over the *leading* dim and needs no in-kernel transposes;
  2. Hadamard   — the frequency-batched complex GEMM of
     ``spectral_hadamard`` in 3-multiplication Karatsuba form,
        Y~[f, n, p] = sum_m W~[f, n, m] X~[f, m, p];
  3. IFFT      — Re(Dinv @ Y~) with Dinv = kron(Winv, Winv) [K^2, K^2],
     writing real K x K output tiles ([S2, N, P]) for host-side OaA.

The contraction over input channels M runs across a grid dimension; the
paper's three reuse choices map onto grid iteration orders exactly as in
``spectral_hadamard`` (which operand block Pallas keeps resident between
consecutive grid steps):

  * ``output_stationary``  grid (n, p, m): f32 psums accumulate in VMEM
    scratch across the innermost m loop; HBM sees each output once and
    never sees X~/Y~ at all.
  * ``weight_stationary``  grid (n, m, p) (Flow #1, reuse kernels): the
    W~ block is constant across the inner p loop so it loads exactly
    once, but partial outputs are read-modify-written per m block.
    IFFT is linear, so partial Y~ blocks are IFFT'd eagerly and the RMW
    traffic is *spatial* psums (K^2 real words/tile) — spectral
    intermediates still never reach HBM.
  * ``input_stationary``   grid (p, m, n) (Flow #2, reuse activations):
    the raw tile block is constant across the inner n loop and its FFT
    is computed once into VMEM scratch (at n-block 0) and reused;
    kernels re-stream per p block, same spatial-psum RMW.

Hardware caveat (Pallas TPU pipelining): reading an *output* window that
was last written in a NON-consecutive grid step is undefined on real TPU
(windows are only kept while the block index is unchanged between
consecutive steps).  The RMW flows therefore require the accumulation
revisit to be consecutive on hardware: ``weight_stationary`` needs a
single p block (block_p >= P) and ``input_stationary`` a single n block
(block_n >= N) — then the psum window simply stays resident in VMEM
across the m loop and is flushed once.  The wrapper enforces this when
``interpret=False``; interpret mode (CPU validation) emulates per-step
window copies and runs any block shape.  ``core.autotune`` only emits
hardware-safe configurations.  (Streaming psums through HBM with
arbitrary blocks, as the FPGA does through DDR, needs a manual-DMA
kernel — ROADMAP open item.)

HBM traffic per flow is modeled by ``repro.core.dataflow.tpu_fused_flow_cost``
and block sizes / flow are chosen per layer by ``repro.core.autotune``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.core.dataflow import FLOWS
from repro.core.spectral import (SpectralGeometry, extract_tiles,
                                 overlap_add)
from repro.kernels.fft8 import dft_matrices

Array = jax.Array


# ---------------------------------------------------------------------------
# DFT operators in flattened (kron) form
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dft_kron(fft_size: int, tile: int) -> tuple[np.ndarray, np.ndarray]:
    """Forward 2-D DFT as one matrix on flattened t x t tiles.

    D[f, s] with f = u*K + v, s = a*t + b equals W[u, a] * W[v, b]; the
    restriction to a < t, b < t folds the zero-padding of tiles to K x K
    into the operator.  Returns (real, imag) [K^2, t^2] f32.
    """
    cr, ci = dft_matrices(fft_size)
    w = cr + 1j * ci
    d = np.kron(w[:, :tile], w[:, :tile])
    return (np.ascontiguousarray(d.real, np.float32),
            np.ascontiguousarray(d.imag, np.float32))


@functools.lru_cache(maxsize=None)
def _idft_kron(fft_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Inverse 2-D DFT on flattened K x K spectra: [K^2, K^2] (re, im)."""
    cr, ci = dft_matrices(fft_size)
    winv = (cr - 1j * ci) / fft_size          # conj(W) / K
    d = np.kron(winv, winv)
    return (np.ascontiguousarray(d.real, np.float32),
            np.ascontiguousarray(d.imag, np.float32))


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _tile_fft(x_ref, dfr_ref, dfi_ref):
    """Stage 1: one GEMM against the kron'd DFT operator.
    [S, bm, bp] real tiles -> (re, im) [F, bm, bp] spectral planes."""
    s, bm, bp = x_ref.shape
    f = dfr_ref.shape[0]
    x2 = x_ref[...].reshape(s, bm * bp)
    xfr = jnp.dot(dfr_ref[...], x2,
                  preferred_element_type=jnp.float32).reshape(f, bm, bp)
    xfi = jnp.dot(dfi_ref[...], x2,
                  preferred_element_type=jnp.float32).reshape(f, bm, bp)
    return xfr, xfi


def _hadamard(wr_ref, wi_ref, xfr, xfi):
    """Stage 2: frequency-batched Karatsuba complex GEMM.
    W [F, bn, bm] x X~ [F, bm, bp] -> (re, im) [F, bn, bp]."""
    def bmm(a, b):
        return jax.lax.dot_general(
            a, b, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)

    wr, wi = wr_ref[...], wi_ref[...]
    m1 = bmm(wr, xfr)
    m2 = bmm(wi, xfi)
    m3 = bmm(wr + wi, xfr + xfi)
    return m1 - m2, m3 - m1 - m2


def _ifft_real(re, im, dvr_ref, dvi_ref, bn, bp):
    """Stage 3: Re(Dinv @ Y~) -> [S2, bn, bp] real output tiles."""
    f = re.shape[0]
    s2 = dvr_ref.shape[0]
    y = (jnp.dot(dvr_ref[...], re.reshape(f, bn * bp),
                 preferred_element_type=jnp.float32)
         - jnp.dot(dvi_ref[...], im.reshape(f, bn * bp),
                   preferred_element_type=jnp.float32))
    return y.reshape(s2, bn, bp)


def _kernel_os(x_ref, wr_ref, wi_ref, dfr_ref, dfi_ref, dvr_ref, dvi_ref,
               y_ref, acc_r, acc_i, *, n_m_blocks: int):
    """Output-stationary: psums live in VMEM scratch across the innermost
    m grid dim; IFFT + output write happen once, at the last m block."""
    gm = pl.program_id(2)

    @pl.when(gm == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    re, im = _hadamard(wr_ref, wi_ref,
                       *_tile_fft(x_ref, dfr_ref, dfi_ref))
    acc_r[...] += re
    acc_i[...] += im

    @pl.when(gm == n_m_blocks - 1)
    def _flush():
        bn, bp = acc_r.shape[1], acc_r.shape[2]
        y_ref[...] = _ifft_real(acc_r[...], acc_i[...], dvr_ref, dvi_ref,
                                bn, bp)


def _kernel_ws(x_ref, wr_ref, wi_ref, dfr_ref, dfi_ref, dvr_ref, dvi_ref,
               y_ref):
    """Weight-stationary, grid (n, m, p): each m block's partial Y~ is
    IFFT'd eagerly (IFFT is linear) and the real spatial psum is read-
    modify-written — spectral intermediates never reach HBM."""
    gm = pl.program_id(1)
    re, im = _hadamard(wr_ref, wi_ref,
                       *_tile_fft(x_ref, dfr_ref, dfi_ref))
    bn, bp = re.shape[1], re.shape[2]
    y = _ifft_real(re, im, dvr_ref, dvi_ref, bn, bp)

    @pl.when(gm == 0)
    def _first():
        y_ref[...] = y

    @pl.when(gm > 0)
    def _rest():
        y_ref[...] += y


def _kernel_is(x_ref, wr_ref, wi_ref, dfr_ref, dfi_ref, dvr_ref, dvi_ref,
               y_ref, xfr_s, xfi_s):
    """Input-stationary, grid (p, m, n): the tile block is constant
    across the inner n loop, so its FFT is computed once (n-block 0)
    into VMEM scratch and reused — the reuse the flow is named for."""
    gm = pl.program_id(1)
    gn = pl.program_id(2)

    @pl.when(gn == 0)
    def _fft_once():
        xfr, xfi = _tile_fft(x_ref, dfr_ref, dfi_ref)
        xfr_s[...] = xfr
        xfi_s[...] = xfi

    re, im = _hadamard(wr_ref, wi_ref, xfr_s[...], xfi_s[...])
    bn, bp = re.shape[1], re.shape[2]
    y = _ifft_real(re, im, dvr_ref, dvi_ref, bn, bp)

    @pl.when(gm == 0)
    def _first():
        y_ref[...] = y

    @pl.when(gm > 0)
    def _rest():
        y_ref[...] += y


# ---------------------------------------------------------------------------
# pallas_call wrapper
# ---------------------------------------------------------------------------

def _pad_axis(x: Array, axis: int, mult: int) -> Array:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("flow", "block_n", "block_m", "block_p", "interpret"))
def fused_spectral_pipeline(xt: Array, wr: Array, wi: Array, *,
                            flow: str = "output_stationary",
                            block_n: int = 64, block_m: int = 64,
                            block_p: int = 128,
                            interpret: bool = True) -> Array:
    """FFT -> Hadamard -> IFFT in one pallas_call.

    xt: [S, M, P] f32   spatial tiles, s-leading (S = tile^2, P = B*T)
    wr/wi: [F, N, M] f32 spectral kernel planes (F = K^2)
    returns [S2, N, P] f32 real output tiles (S2 = K^2).
    """
    if flow not in FLOWS:
        raise ValueError(f"flow must be one of {FLOWS}")
    s, m, p = xt.shape
    f, n, _ = wr.shape
    k = int(round(f ** 0.5))
    t = int(round(s ** 0.5))
    assert k * k == f and t * t == s, (f, s)

    bn, bm, bp = min(block_n, n), min(block_m, m), min(block_p, p)
    xt_ = _pad_axis(_pad_axis(xt, 1, bm), 2, bp)
    wr_ = _pad_axis(_pad_axis(wr, 1, bn), 2, bm)
    wi_ = _pad_axis(_pad_axis(wi, 1, bn), 2, bm)
    np_, mp_, pp_ = wr_.shape[1], wr_.shape[2], xt_.shape[2]
    gn, gm, gp = np_ // bn, mp_ // bm, pp_ // bp

    dfr, dfi = (jnp.asarray(a) for a in _dft_kron(k, t))
    dvr, dvi = (jnp.asarray(a) for a in _idft_kron(k))

    if not interpret:
        # Pallas TPU keeps an output window only across CONSECUTIVE grid
        # steps; the RMW flows accumulate into y across the m axis, so on
        # hardware the revisit must be consecutive (see module docstring).
        if flow == "weight_stationary" and gp > 1:
            raise NotImplementedError(
                "weight_stationary on TPU hardware needs block_p >= P "
                f"(got {bp} < {pp_}); use output_stationary or a "
                "hardware-safe autotune plan")
        if flow == "input_stationary" and gn > 1:
            raise NotImplementedError(
                "input_stationary on TPU hardware needs block_n >= N "
                f"(got {bn} < {np_}); use output_stationary or a "
                "hardware-safe autotune plan")

    if flow == "output_stationary":
        grid = (gn, gp, gm)
        x_map = lambda a, b, c: (0, c, b)
        w_map = lambda a, b, c: (0, a, c)
        y_map = lambda a, b, c: (0, a, b)
        kernel = functools.partial(_kernel_os, n_m_blocks=gm)
        scratch = [pltpu.VMEM((f, bn, bp), jnp.float32)] * 2
        semantics = ("parallel", "parallel", "arbitrary")
    elif flow == "weight_stationary":
        grid = (gn, gm, gp)
        x_map = lambda a, c, b: (0, c, b)
        w_map = lambda a, c, b: (0, a, c)
        y_map = lambda a, c, b: (0, a, b)
        kernel = _kernel_ws
        scratch = []
        semantics = ("parallel", "arbitrary", "arbitrary")
    else:  # input_stationary
        grid = (gp, gm, gn)
        x_map = lambda b, c, a: (0, c, b)
        w_map = lambda b, c, a: (0, a, c)
        y_map = lambda b, c, a: (0, a, b)
        kernel = _kernel_is
        scratch = [pltpu.VMEM((f, bm, bp), jnp.float32)] * 2
        semantics = ("parallel", "arbitrary", "arbitrary")

    x_spec = pl.BlockSpec((s, bm, bp), x_map)
    w_spec = pl.BlockSpec((f, bn, bm), w_map)
    y_spec = pl.BlockSpec((f, bn, bp), y_map)
    d_spec = lambda rows, cols: pl.BlockSpec(
        (rows, cols), lambda *_: (0, 0))

    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, w_spec,
                  d_spec(f, s), d_spec(f, s), d_spec(f, f), d_spec(f, f)],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((f, np_, pp_), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(xt_.astype(jnp.float32), wr_, wi_, dfr, dfi, dvr, dvi)
    return y[:, :n, :p]


@functools.partial(
    jax.jit,
    static_argnames=("geo", "flow", "block_n", "block_m", "block_p",
                     "interpret"))
def _fused_conv(x: Array, w_f: Array, *, geo: SpectralGeometry, flow: str,
                block_n: int, block_m: int, block_p: int,
                interpret: bool) -> Array:
    """Jitted body: tile extraction, layout, pipeline, OaA — one compiled
    program per (geo, flow, blocks), so the host-side relayout is not
    re-dispatched eagerly on every forward call."""
    b, m = x.shape[:2]
    n, _, k, _ = w_f.shape

    tiles = extract_tiles(x, geo)                       # [B, M, T, t, t]
    t_cnt = tiles.shape[2]
    s = geo.tile * geo.tile
    # s-leading layout: [S, M, B*T] — the in-kernel FFT contracts the
    # leading dim with one GEMM, no transposes on the TPU side.
    xt = (tiles.reshape(b, m, t_cnt, s)
          .transpose(3, 1, 0, 2).reshape(s, m, b * t_cnt))

    fdim = k * k
    wr = jnp.transpose(w_f.real.reshape(n, m, fdim), (2, 0, 1))
    wi = jnp.transpose(w_f.imag.reshape(n, m, fdim), (2, 0, 1))

    y = fused_spectral_pipeline(
        xt, wr.astype(jnp.float32), wi.astype(jnp.float32), flow=flow,
        block_n=block_n, block_m=block_m, block_p=block_p,
        interpret=interpret)                            # [S2, N, B*T]

    y_tiles = (y.reshape(fdim, n, b, t_cnt).transpose(2, 1, 3, 0)
               .reshape(b, n, t_cnt, k, k))
    return overlap_add(y_tiles.astype(x.dtype), geo)


def fused_spectral_conv2d(x: Array, w_f: Array, geo: SpectralGeometry, *,
                          flow: str = "output_stationary",
                          block_n: int = 64, block_m: int = 64,
                          block_p: int = 128,
                          interpret: bool | None = None) -> Array:
    """Full spectral conv layer through the single fused pallas_call.

    x: [B, M, H, W] real NCHW; w_f: complex [N, M, K, K] (possibly pruned,
    e.g. a ``SparseSpectralKernels``, whose dense ``.values`` are used).
    Host side does only the layout work the paper's DMA engine does:
    tile extraction going in, Overlap-and-Add coming out.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if hasattr(w_f, "values"):            # SparseSpectralKernels duck-type
        w_f = w_f.values
    assert w_f.shape[-1] == geo.fft_size
    return _fused_conv(x, w_f, geo=geo, flow=flow, block_n=block_n,
                       block_m=block_m, block_p=block_p,
                       interpret=interpret)
