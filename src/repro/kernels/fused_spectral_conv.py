"""Pallas TPU kernel: ONE pallas_call for a whole spectral conv layer.

The staged Pallas path (``ops.spectral_conv2d_pallas``) launches three
kernels per layer — fft8 -> spectral_hadamard -> ifft8 — and round-trips
the complex spectral tensors ``X~``/``Y~`` ([B, M, T, K, K], 2 f32 planes)
through HBM between stages.  That inter-stage traffic is the TPU analogue
of exactly the off-chip communication the paper's dataflow eliminates
(§4): the FPGA design pipelines FFT -> Hadamard -> IFFT through on-chip
buffers, touching DDR only for spatial inputs, spectral kernels and
spatial outputs.

This kernel restores that property, and (PR 3) adds the paper's other
two contributions to the same pallas_call:

  * **Overlap-save tiling + fused epilogue.**  Input windows are K x K
    with stride t = K-k+1 (``core.spectral.extract_tiles_overlapping``),
    so every tile's t x t valid output rows are *complete* full-conv
    results — no cross-tile Overlap-and-Add sums remain.  That makes a
    non-linear epilogue inside the kernel mathematically exact: the
    flush step applies bias + ReLU before the single output write, and
    post-conv elementwise work never round-trips HBM.  The inverse
    operator keeps only the t^2 valid rows, so output traffic *drops*
    from K^2 to t^2 words per tile relative to the OaA formulation.
  * **Active-frequency-bin compaction (Alg 2 meets the MXU).**  For
    pruned kernels the spectral GEMM batch is restricted to the Fa <= K^2
    frequency bins that are non-zero in ANY kernel — the bin set the
    exact-cover schedule touches (``scheduler.active_bins_from_tables``;
    by the exact-cover property it equals the union of non-zero kernel
    bins, which ``core.plan`` precomputes).  Forward DFT rows, kernel
    planes, Karatsuba Hadamard batch, IFFT columns and the psum scratch
    all shrink by Fa/K^2.  When nnz ~= K^2 (padded Fa >= K^2) the caller
    falls back to dense — compaction would buy nothing.
  * **In-kernel halo gather (PR 5 — true activation reuse).**  The
    windowed input path consumes a host-materialized [B, M, T, K, K]
    overlapping-window tensor: one full HBM relayout pass plus a
    ~(K/t)^2 duplicated stream before any flow-level reuse happens.
    ``input_mode='halo'`` eliminates it: the kernel reads the RAW NCHW
    activation through overlapping halo input blocks — element-offset
    (``pl.Unblocked``) index maps hand each grid step ``bth*t + (k-1)``
    rows x ``btw*t + (k-1)`` cols covering its bth x btw tiles plus
    their shared halo, clamped at the image edges — and two one-hot MXU
    matmuls (``spectral.halo_gather_matrices``) assemble the stride-t
    K x K windows in VMEM, with all-zero selector rows supplying the
    'same' zero-padding.  The gather is numerically exact, so the halo
    path is bit-identical to the windowed one (which stays as the
    fallback/oracle); HBM sees raw-plus-halo words only.  Available for
    every flow and Hadamard mode; ``core.plan`` ranks the two input
    modes per layer as a fourth Alg-1 axis (DESIGN.md adaptation
    note 7, docs/DATAFLOW.md section 2).
  * **Element-granular scheduled sparse Hadamard (Alg 2 proper).**  The
    Hadamard stage has three modes.  'dense' and 'bin' stream kernel
    PLANES ([Fa, N, M] complex) and run the Karatsuba GEMM above.
    'scheduled' instead streams the exact-cover schedule's INDEX/VALUE
    tables (``scheduler.compile_layer_tables``) and executes them with
    the one-hot-matmul datapath of ``kernels.sparse_hadamard`` — gather
    r replicas per cycle, route through the sel crossbar, complex-MAC,
    scatter — *inside the same pallas_call*, between the tile-FFT and
    the IFFT/epilogue.  Kernel-operand traffic drops from O(Fa*N*M)
    plane words toward O(nnz) table words (~3*T*N' words per group and
    channel, T ~= nnz/mu cycles), which is what the paper streams; the
    price is one-hot MXU work, so ``core.autotune`` ranks the mode per
    layer against bin compaction with ``dataflow.tpu_fused_flow_cost
    (hadamard=...)`` and falls back to dense/bin when the schedule
    degenerates (alpha ~= 1).

Per grid step the kernel performs, entirely in VMEM:

  1. tile-FFT   — one MXU GEMM against D = kron(W, W)[active, :]
     ([Fa, K^2], W the K-point DFT matrix): the K x K windows are stored
     s-leading ([S=K^2, M, P]) so the contraction is over the *leading*
     dim and needs no in-kernel transposes;
  2. Hadamard   — the frequency-batched complex GEMM in
     3-multiplication Karatsuba form over the Fa active bins,
        Y~[f, n, p] = sum_m W~[f, n, m] X~[f, m, p];
  3. IFFT + epilogue (flush) — Re(Dinv @ Y~) with Dinv restricted to the
     t^2 valid output rows and Fa active columns ([t^2, Fa]), then
     y = relu(y + bias) (both optional).  The finished rectangle is
     DMA'd to the output buffer by the kernel itself (PR 8): the halo
     path re-lays its tiles into the spatial output canvas *in VMEM*
     before the copy, so the host keeps only the final 'same'-crop
     slice and ``assemble_valid_tiles`` is off the fused hot path.

The contraction over input channels M runs across a grid dimension; the
paper's three reuse choices map onto grid iteration orders exactly as in
``spectral_hadamard`` (which operand block Pallas keeps resident between
consecutive grid steps):

  * ``output_stationary``  grid (n, p, m): f32 psums accumulate in VMEM
    scratch across the innermost m loop; HBM sees each output once and
    never sees X~/Y~ at all.
  * ``weight_stationary``  grid (n, m, p) (Flow #1, reuse kernels): the
    W~ block is constant across the inner p loop so it loads exactly
    once, but partial outputs are read-modify-written per m block.
    IFFT is linear, so partial Y~ blocks are IFFT'd eagerly and the RMW
    traffic is *spatial* psums (t^2 real words/tile) — spectral
    intermediates still never reach HBM.  The epilogue fires on the
    final m visit, after the last accumulation.
  * ``input_stationary``   grid (p, m, n) (Flow #2, reuse activations):
    the raw window block is constant across the inner n loop and its FFT
    is computed once into VMEM scratch (at n-block 0) and reused;
    kernels re-stream per p block, same spatial-psum RMW + final-visit
    epilogue.

Output side (PR 8 — manual-DMA psum streaming): the kernels do NOT use
a pipelined output BlockSpec.  The output buffer lives in ANY memory
space (HBM) and every kernel moves its finished or partial rectangles
itself with ``pltpu.make_async_copy`` through ``dataflow.DMA_SLOTS``
double-buffered VMEM accumulator tiles + DMA semaphores.  The RMW flows
(weight/input-stationary) prefetch the accumulator rectangle *before*
the step's FFT/Hadamard/IFFT compute — the inbound DMA overlaps the MXU
work — then add the step's partial spatial psum and copy it back; the
first m visit is a pure write and the last applies the epilogue.  This
is exactly the FPGA design's psum stream through DDR, and it removes
the old hardware restriction that the accumulation revisit be
CONSECUTIVE in the grid: any (block_n, block_m, block_p) is now valid
on hardware for every flow, including halo + weight_stationary at
batch > 1.  (The former ``_check_hw_safe`` guard and the autotuner's
hw-safe candidate filters are gone; ``core.resilience.validate_plan``
instead checks the DMA accumulator geometry — rectangle bounds, revisit
count, slot budget — at plan-build time.)

HBM traffic per flow is modeled by ``repro.core.dataflow.tpu_fused_flow_cost``
(sparsity-aware since PR 3); flow/blocks are chosen per layer by
``repro.core.autotune`` and precompiled into a ``core.plan.LayerPlan``
whose operands ``execute_layer_plan`` consumes without re-deriving any
of this per call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.core import resilience as res
from repro.core import sparse as sp
from repro.core.dataflow import DMA_SLOTS, FLOWS, INPUT_MODES
from repro.core.spectral import (HaloGeometry, SpectralGeometry,
                                 assemble_tile_canvas,
                                 assemble_valid_tiles,
                                 extract_tiles_overlapping,
                                 halo_block_geometry, halo_gather_matrices)
from repro.kernels.fft8 import dft_matrices

Array = jax.Array


# ---------------------------------------------------------------------------
# DFT operators in flattened (kron) form, overlap-save + active-bin layout
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def overlap_save_operators(fft_size: int, ksize: int,
                           active: tuple[int, ...] | None = None
                           ) -> tuple[np.ndarray, ...]:
    """(dfr, dfi, dvr, dvi) for the fused kernel.

    dfr/dfi [Fa, K^2]: forward 2-D DFT on flattened K x K windows,
        rows restricted to the active frequency bins.
    dvr/dvi [t^2, Fa]: inverse 2-D DFT restricted to the t^2
        wraparound-free output rows (u, v in [k-1, K)) and the active
        columns — the only spectra Y~ can be non-zero at.
    """
    cr, ci = dft_matrices(fft_size)
    w = cr + 1j * ci
    d = np.kron(w, w)                                   # [K^2, K^2]
    winv = (cr - 1j * ci) / fft_size                    # conj(W) / K
    dv = np.kron(winv, winv)                            # [K^2, K^2]
    valid = [u * fft_size + v
             for u in range(ksize - 1, fft_size)
             for v in range(ksize - 1, fft_size)]
    dv = dv[valid]                                      # [t^2, K^2]
    if active is not None:
        a = np.asarray(active)
        d = d[a]
        dv = dv[:, a]
    return tuple(np.ascontiguousarray(p, np.float32)
                 for p in (d.real, d.imag, dv.real, dv.imag))


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _tile_fft(x_ref, dfr_ref, dfi_ref):
    """Stage 1: one GEMM against the kron'd DFT operator.
    [S, bm, bp] real windows -> (re, im) [Fa, bm, bp] spectral planes."""
    s, bm, bp = x_ref.shape
    fa = dfr_ref.shape[0]
    x2 = x_ref[...].reshape(s, bm * bp)
    xfr = jnp.dot(dfr_ref[...], x2,
                  preferred_element_type=jnp.float32).reshape(fa, bm, bp)
    xfi = jnp.dot(dfi_ref[...], x2,
                  preferred_element_type=jnp.float32).reshape(fa, bm, bp)
    return xfr, xfi


def _hadamard(wr_ref, wi_ref, xfr, xfi):
    """Stage 2: frequency-batched Karatsuba complex GEMM over active bins.
    W [Fa, bn, bm] x X~ [Fa, bm, bp] -> (re, im) [Fa, bn, bp]."""
    def bmm(a, b):
        return jax.lax.dot_general(
            a, b, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)

    wr, wi = wr_ref[...], wi_ref[...]
    m1 = bmm(wr, xfr)
    m2 = bmm(wi, xfi)
    m3 = bmm(wr + wi, xfr + xfi)
    return m1 - m2, m3 - m1 - m2


def _ifft_real(re, im, dvr_ref, dvi_ref, bn, bp):
    """Stage 3: Re(Dinv @ Y~) -> [S2, bn, bp] finished spatial outputs."""
    fa = re.shape[0]
    s2 = dvr_ref.shape[0]
    y = (jnp.dot(dvr_ref[...], re.reshape(fa, bn * bp),
                 preferred_element_type=jnp.float32)
         - jnp.dot(dvi_ref[...], im.reshape(fa, bn * bp),
                   preferred_element_type=jnp.float32))
    return y.reshape(s2, bn, bp)


def _epilogue(y, b_ref, relu: bool):
    """Fused bias + ReLU on [S2, bn, bp]; bias block is [1, bn]."""
    y = y + b_ref[0][None, :, None]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def _ifft_real_nf(re, im, dvr_ref, dvi_ref):
    """Stage 3 for the scheduled datapath: Re(Dinv @ Y~) on n-leading
    psums.  re/im [N', Fa, bp] -> [S2, N', bp] finished spatial rows."""
    dn = (((1,), (1,)), ((), ()))
    return (jax.lax.dot_general(dvr_ref[...], re, dn,
                                preferred_element_type=jnp.float32)
            - jax.lax.dot_general(dvi_ref[...], im, dn,
                                  preferred_element_type=jnp.float32))


def _scheduled_hadamard(idx_ref, sel_ref, vr_ref, vi_ref, xfr, xfi):
    """Stage 2, 'scheduled' mode: execute the Alg-2 INDEX/VALUE tables
    (``scheduler.LayerTables`` blocks) with MXU one-hot matmuls.

    Per cycle t, vectorized over the bm channels of the block and the
    bp tiles: gather the r replica rows of X~ (one-hot [r, Fa] @ X~),
    route them to the N' PE lanes (sel one-hot [N', r] @ replicas),
    complex-MAC against the VALUE plane (idle lanes carry zero weights),
    and scatter into the psum — the scatter one-hot is the ROUTED gather
    one-hot (sel @ gather), which is exactly ``out_index ==
    index_table[t, sel]`` of Fig 6, so the out-index plane never needs
    streaming.

    idx_ref [1, bm, T, r] int32 (compacted-bin coords), sel_ref /
    vr_ref / vi_ref [1, bm, T, N']; xfr/xfi [Fa, bm, bp] spectral
    planes.  Returns (re, im) psum contributions [N', Fa, bp] summed
    over the block's channels and cycles.
    """
    _, bm, n_cycles, r = idx_ref.shape
    n_pe = sel_ref.shape[3]
    fa, _, bp = xfr.shape
    xr = jnp.transpose(xfr, (1, 0, 2))                  # [bm, Fa, bp]
    xi = jnp.transpose(xfi, (1, 0, 2))
    idx, sel = idx_ref[0], sel_ref[0]
    vr, vi = vr_ref[0], vi_ref[0]
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, fa), 2)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, r), 2)

    def bmm(a, b):                                      # batch over bm
        return jax.lax.dot_general(a, b, (((2,), (1,)), ((0,), (0,))),
                                   preferred_element_type=jnp.float32)

    def cycle(t, carry):
        ar, ai = carry
        take = lambda a: jax.lax.dynamic_index_in_dim(a, t, 1,
                                                      keepdims=False)
        g = (take(idx)[:, :, None] == f_iota).astype(jnp.float32)
        s = (take(sel)[:, :, None] == r_iota).astype(jnp.float32)
        rep_r = bmm(g, xr)                              # [bm, r, bp]
        rep_i = bmm(g, xi)
        in_r = bmm(s, rep_r)                            # [bm, N', bp]
        in_i = bmm(s, rep_i)
        wr = take(vr)[:, :, None]
        wi = take(vi)[:, :, None]
        pr = wr * in_r - wi * in_i
        pi = wr * in_i + wi * in_r
        o = bmm(s, g)                                   # [bm, N', Fa]
        dn = (((0,), (0,)), ((1,), (1,)))               # sum channels
        ar = ar + jax.lax.dot_general(o, pr, dn,
                                      preferred_element_type=jnp.float32)
        ai = ai + jax.lax.dot_general(o, pi, dn,
                                      preferred_element_type=jnp.float32)
        return ar, ai

    zero = jnp.zeros((n_pe, fa, bp), jnp.float32)
    return jax.lax.fori_loop(0, n_cycles, cycle, (zero, zero))


def _halo_windows(x_ref, gr_ref, gc_ref, *, bth: int, btw: int,
                  fft_size: int):
    """In-kernel halo gather (input_mode='halo'): raw activation block ->
    overlap-save windows, entirely in VMEM.

    x_ref [1, bm, rh, rw] is a clamped raw-image block covering
    bth x btw tiles plus their shared k-1 halo (``pl.Unblocked``
    element offsets — consecutive blocks overlap in HBM, nothing is
    duplicated).  gr_ref [1, bth*K, rh] / gc_ref [1, btw*K, rw] are this
    block's one-hot window selectors (``spectral.halo_gather_matrices``;
    all-zero rows encode the 'same' zero-padding and the tile-grid
    padding, and make the clamp-shift at image edges exact).  Two MXU
    matmuls select rows then columns; one-hot f32 operands make the
    gather numerically exact, so the halo path equals the windowed path
    bit for bit.  Returns [S, bm, bth*btw] windows, s-leading — the
    layout ``_tile_fft`` contracts.
    """
    k = fft_size
    x = x_ref[0]                                        # [bm, rh, rw]
    bm = x.shape[0]
    rows = jax.lax.dot_general(                         # [bth*K, bm, rw]
        gr_ref[0], x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    win = jax.lax.dot_general(                          # [bth*K, bm, btw*K]
        rows, gc_ref[0], (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    win = win.reshape(bth, k, bm, btw, k)
    win = win.transpose(1, 4, 2, 0, 3)                  # [K, K, bm, bth, btw]
    return win.reshape(k * k, bm, bth * btw)


class _LazyWindows:
    """Ref-like stand-in for the gathered windows: ``.shape`` is known
    statically, the gather itself traces at the ``[...]`` read site.
    That makes the gather *conditional* wherever the body's window read
    is — in the input-stationary kernels the read sits inside the
    ``pl.when(gn == 0)`` FFT-once guard, so the flow's n-block revisits
    skip the gather matmuls too (matching the cost model's refft = 1
    for that flow)."""

    def __init__(self, fn, shape):
        self._fn = fn
        self.shape = shape

    def __getitem__(self, idx):
        return self._fn()[idx]


def _halo_kernel(body, *, bth: int, btw: int, fft_size: int):
    """Wrap a flow kernel body so its window operand is gathered in-kernel
    from a raw halo block instead of read pre-materialized.  The body's
    first argument only ever sees ``x[...]``/``x.shape``, so the lazy
    gather substitutes for the windowed Ref unchanged."""
    def kernel(x_ref, gr_ref, gc_ref, *rest):
        shape = (fft_size * fft_size, x_ref.shape[1], bth * btw)
        body(_LazyWindows(
            lambda: _halo_windows(x_ref, gr_ref, gc_ref, bth=bth,
                                  btw=btw, fft_size=fft_size),
            shape), *rest)
    return kernel


# ---------------------------------------------------------------------------
# Manual-DMA output accumulators (PR 8)
# ---------------------------------------------------------------------------
#
# The output operand of every fused kernel lives in ANY memory space
# (HBM); the kernel moves rectangles itself with ``pltpu.make_async_copy``
# through DMA_SLOTS double-buffered VMEM staging tiles.  A *sink* object
# describes the output layout: where a (n-block, p-block) rectangle
# lives in the buffer (``dst``), how a computed [S2, bn, bp] spatial
# block is re-laid before staging (``stage``), and how bias/ReLU apply
# in that layout (``epilogue``).  The same three flow bodies then serve
# both output layouts — the windowed [S2, Np, Pp] tile stream and the
# halo path's assembled spatial canvas.

class _TileSink:
    """Windowed output layout [S2, Np, Pp]: rectangle (n, p) is the
    [S2, bn, bp] slab at (n*bn, p*bp); no in-VMEM relayout.

    ``_sc`` (set by ``_residual_kernel``) is an optional shortcut Ref in
    the SAME output layout whose current block is added after bias and
    before ReLU — the residual-fused epilogue of ISSUE 10.  The sc
    BlockSpec indexes on (n, p) only, so at the flush step (the only
    epilogue site) the prefetched block is exactly this rectangle's
    shortcut."""

    _sc = None

    def __init__(self, s2: int, bn: int, bp: int):
        self.bn, self.bp = bn, bp
        self.stage_shape = (s2, bn, bp)

    def dst(self, y_hbm, n_idx, p_idx):
        return y_hbm.at[:, pl.ds(n_idx * self.bn, self.bn),
                        pl.ds(p_idx * self.bp, self.bp)]

    def stage(self, y):
        return y

    def epilogue(self, y, b_ref, relu: bool):
        y = y + b_ref[0][None, :, None]
        if self._sc is not None:
            y = y + self._sc[...]
        if relu:
            y = jnp.maximum(y, 0.0)
        return y


class _CanvasSink:
    """Halo output layout [B, Np, nbh*bth*t, nbw*btw*t] — the spatial
    output canvas of ``assemble_valid_tiles``, assembled IN-KERNEL.
    The p grid axis enumerates (image, block-row, block-col); a computed
    [S2=t^2, bn, bth*btw] block is re-laid in VMEM to its
    [bn, bth*t, btw*t] canvas rectangle before the DMA, so tile (i, j)'s
    t x t valid rows land at canvas (i*t, j*t) exactly as the host
    relayout used to place them.  The host keeps only the final
    'same'-crop slice (``_crop_canvas``).

    ``_sc`` (set by ``_residual_kernel``): optional shortcut Ref in the
    same canvas layout, block (1, bn, bth*t, btw*t) — added after bias,
    before ReLU at the flush step."""

    _sc = None

    def __init__(self, hg: HaloGeometry, tile: int, bn: int):
        self.hg, self.t, self.bn = hg, tile, bn
        self.stage_shape = (bn, hg.bth * tile, hg.btw * tile)

    def dst(self, y_hbm, n_idx, p_idx):
        hg, t = self.hg, self.t
        nb = hg.n_blocks
        b = p_idx // nb
        ib = (p_idx % nb) // hg.nbw
        jb = p_idx % hg.nbw
        return y_hbm.at[b, pl.ds(n_idx * self.bn, self.bn),
                        pl.ds(ib * hg.bth * t, hg.bth * t),
                        pl.ds(jb * hg.btw * t, hg.btw * t)]

    def stage(self, y):
        hg, t, bn = self.hg, self.t, self.bn
        # [t^2, bn, bth*btw] -> (u, v, n, ith, jtw) -> canvas rows
        # ith*t + u, cols jtw*t + v (tile axis is bth-major, matching
        # _halo_windows; s2 rows are u-major, matching the dv operator).
        y = y.reshape(t, t, bn, hg.bth, hg.btw)
        y = y.transpose(2, 3, 0, 4, 1)
        return y.reshape(bn, hg.bth * t, hg.btw * t)

    def epilogue(self, y, b_ref, relu: bool):
        y = y + b_ref[0][:, None, None]
        if self._sc is not None:
            y = y + self._sc[0]
        if relu:
            y = jnp.maximum(y, 0.0)
        return y


def _residual_kernel(body, sink):
    """Wrap a flow kernel body so a residual-shortcut operand — the
    LEADING input ref, laid out exactly like the output — is peeled off
    and attached to the sink before the body runs.  The sink's epilogue
    then adds the shortcut block after bias and before ReLU, so the
    residual add costs one extra VMEM operand on the flush path and
    nothing anywhere else (the six flow bodies are untouched).  Composes
    outside ``_halo_kernel``: pallas hands (sc, x, gr, gc, ...) and each
    wrapper peels from the front."""
    def kernel(sc_ref, *rest):
        sink._sc = sc_ref
        return body(*rest)
    return kernel


def _dma_slot():
    """Staging slot for this grid step: the linearized step index mod
    DMA_SLOTS, alternating VMEM tiles/semaphores between consecutive
    steps (double buffering)."""
    step = ((pl.program_id(0) * pl.num_programs(1) + pl.program_id(1))
            * pl.num_programs(2) + pl.program_id(2))
    return step % DMA_SLOTS


def _dma_rmw_start(dst, acc, sem, slot, gm):
    """RMW prologue: start the inbound accumulator DMA for a revisit
    step.  Called BEFORE the step's FFT/Hadamard/IFFT compute, which
    does not depend on it — the copy-in overlaps the MXU work and
    ``_dma_rmw_finish`` waits on it only at accumulation time."""
    @pl.when(gm > 0)
    def _prefetch():
        pltpu.make_async_copy(dst, acc.at[slot], sem.at[slot]).start()


def _dma_rmw_finish(sink, dst, acc, sem, y, b_ref, *, slot, gm,
                    n_m_blocks: int, relu: bool):
    """Spatial-psum RMW across the m grid axis through the manual-DMA
    accumulator: first visit writes, middle visits add + write back,
    the final visit applies the epilogue.  Write-backs complete before
    the step ends, so a revisit (any number of grid steps later — the
    revisit no longer needs to be consecutive) always reads finished
    data."""
    def write_back():
        cp = pltpu.make_async_copy(acc.at[slot], dst, sem.at[slot])
        cp.start()
        cp.wait()

    if n_m_blocks == 1:
        acc[slot] = sink.epilogue(sink.stage(y), b_ref, relu)
        write_back()
        return
    last = n_m_blocks - 1

    @pl.when(gm == 0)
    def _first():
        acc[slot] = sink.stage(y)
        write_back()

    @pl.when((gm > 0) & (gm < last))
    def _mid():
        pltpu.make_async_copy(dst, acc.at[slot], sem.at[slot]).wait()
        acc[slot] += sink.stage(y)
        write_back()

    @pl.when(gm == last)
    def _last():
        pltpu.make_async_copy(dst, acc.at[slot], sem.at[slot]).wait()
        acc[slot] = sink.epilogue(acc[slot] + sink.stage(y), b_ref, relu)
        write_back()


def _dma_flush(sink, dst, acc, sem, y, b_ref, *, slot, relu: bool):
    """Output-stationary flush: one staged + epilogued write per
    rectangle, at the last m visit (psums accumulated in spectral
    scratch, not through HBM)."""
    acc[slot] = sink.epilogue(sink.stage(y), b_ref, relu)
    cp = pltpu.make_async_copy(acc.at[slot], dst, sem.at[slot])
    cp.start()
    cp.wait()


def _kernel_os(x_ref, wr_ref, wi_ref, dfr_ref, dfi_ref, dvr_ref, dvi_ref,
               b_ref, y_hbm, acc_r, acc_i, ydma, sem, *,
               n_m_blocks: int, relu: bool, sink):
    """Output-stationary, grid (n, p, m): psums live in VMEM scratch
    across the innermost m grid dim; IFFT + epilogue + the single DMA
    write happen once, at the last m block."""
    gm = pl.program_id(2)
    slot = _dma_slot()
    dst = sink.dst(y_hbm, pl.program_id(0), pl.program_id(1))

    @pl.when(gm == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    re, im = _hadamard(wr_ref, wi_ref,
                       *_tile_fft(x_ref, dfr_ref, dfi_ref))
    acc_r[...] += re
    acc_i[...] += im

    @pl.when(gm == n_m_blocks - 1)
    def _flush():
        bn, bp = acc_r.shape[1], acc_r.shape[2]
        y = _ifft_real(acc_r[...], acc_i[...], dvr_ref, dvi_ref, bn, bp)
        _dma_flush(sink, dst, ydma, sem, y, b_ref, slot=slot, relu=relu)


def _kernel_ws(x_ref, wr_ref, wi_ref, dfr_ref, dfi_ref, dvr_ref, dvi_ref,
               b_ref, y_hbm, ydma, sem, *, n_m_blocks: int, relu: bool,
               sink):
    """Weight-stationary, grid (n, m, p): each m block's partial Y~ is
    IFFT'd eagerly (IFFT is linear) and the real spatial psum is read-
    modify-written through the manual-DMA accumulator — spectral
    intermediates never reach HBM.  The epilogue fires on the final m
    visit, after the last accumulation."""
    gm = pl.program_id(1)
    slot = _dma_slot()
    dst = sink.dst(y_hbm, pl.program_id(0), pl.program_id(2))
    _dma_rmw_start(dst, ydma, sem, slot, gm)
    re, im = _hadamard(wr_ref, wi_ref,
                       *_tile_fft(x_ref, dfr_ref, dfi_ref))
    bn, bp = re.shape[1], re.shape[2]
    y = _ifft_real(re, im, dvr_ref, dvi_ref, bn, bp)
    _dma_rmw_finish(sink, dst, ydma, sem, y, b_ref, slot=slot, gm=gm,
                    n_m_blocks=n_m_blocks, relu=relu)


def _kernel_is(x_ref, wr_ref, wi_ref, dfr_ref, dfi_ref, dvr_ref, dvi_ref,
               b_ref, y_hbm, xfr_s, xfi_s, ydma, sem, *,
               n_m_blocks: int, relu: bool, sink):
    """Input-stationary, grid (p, m, n): the window block is constant
    across the inner n loop, so its FFT is computed once (n-block 0)
    into VMEM scratch and reused — the reuse the flow is named for."""
    gm = pl.program_id(1)
    gn = pl.program_id(2)
    slot = _dma_slot()
    dst = sink.dst(y_hbm, gn, pl.program_id(0))
    _dma_rmw_start(dst, ydma, sem, slot, gm)

    @pl.when(gn == 0)
    def _fft_once():
        xfr, xfi = _tile_fft(x_ref, dfr_ref, dfi_ref)
        xfr_s[...] = xfr
        xfi_s[...] = xfi

    re, im = _hadamard(wr_ref, wi_ref, xfr_s[...], xfi_s[...])
    bn, bp = re.shape[1], re.shape[2]
    y = _ifft_real(re, im, dvr_ref, dvi_ref, bn, bp)
    _dma_rmw_finish(sink, dst, ydma, sem, y, b_ref, slot=slot, gm=gm,
                    n_m_blocks=n_m_blocks, relu=relu)


def _kernel_os_sched(x_ref, idx_ref, sel_ref, vr_ref, vi_ref,
                     dfr_ref, dfi_ref, dvr_ref, dvi_ref, b_ref, y_hbm,
                     acc_r, acc_i, ydma, sem, *, n_m_blocks: int,
                     relu: bool, sink):
    """Output-stationary, scheduled Hadamard: n-leading psums [N', Fa, bp]
    accumulate in VMEM scratch across the m grid dim."""
    gm = pl.program_id(2)
    slot = _dma_slot()
    dst = sink.dst(y_hbm, pl.program_id(0), pl.program_id(1))

    @pl.when(gm == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    re, im = _scheduled_hadamard(idx_ref, sel_ref, vr_ref, vi_ref,
                                 *_tile_fft(x_ref, dfr_ref, dfi_ref))
    acc_r[...] += re
    acc_i[...] += im

    @pl.when(gm == n_m_blocks - 1)
    def _flush():
        y = _ifft_real_nf(acc_r[...], acc_i[...], dvr_ref, dvi_ref)
        _dma_flush(sink, dst, ydma, sem, y, b_ref, slot=slot, relu=relu)


def _kernel_ws_sched(x_ref, idx_ref, sel_ref, vr_ref, vi_ref,
                     dfr_ref, dfi_ref, dvr_ref, dvi_ref, b_ref, y_hbm,
                     ydma, sem, *, n_m_blocks: int, relu: bool, sink):
    """Weight-stationary, scheduled Hadamard: the table block (the
    'kernel' operand of this mode) is constant across the inner p loop;
    partial psums are IFFT'd eagerly and RMW'd as spatial rows."""
    gm = pl.program_id(1)
    slot = _dma_slot()
    dst = sink.dst(y_hbm, pl.program_id(0), pl.program_id(2))
    _dma_rmw_start(dst, ydma, sem, slot, gm)
    re, im = _scheduled_hadamard(idx_ref, sel_ref, vr_ref, vi_ref,
                                 *_tile_fft(x_ref, dfr_ref, dfi_ref))
    y = _ifft_real_nf(re, im, dvr_ref, dvi_ref)
    _dma_rmw_finish(sink, dst, ydma, sem, y, b_ref, slot=slot, gm=gm,
                    n_m_blocks=n_m_blocks, relu=relu)


def _kernel_is_sched(x_ref, idx_ref, sel_ref, vr_ref, vi_ref,
                     dfr_ref, dfi_ref, dvr_ref, dvi_ref, b_ref, y_hbm,
                     xfr_s, xfi_s, ydma, sem, *, n_m_blocks: int,
                     relu: bool, sink):
    """Input-stationary, scheduled Hadamard: the window block's FFT is
    computed once (n-block 0) into VMEM scratch and reused while table
    blocks re-stream."""
    gm = pl.program_id(1)
    gn = pl.program_id(2)
    slot = _dma_slot()
    dst = sink.dst(y_hbm, gn, pl.program_id(0))
    _dma_rmw_start(dst, ydma, sem, slot, gm)

    @pl.when(gn == 0)
    def _fft_once():
        xfr, xfi = _tile_fft(x_ref, dfr_ref, dfi_ref)
        xfr_s[...] = xfr
        xfi_s[...] = xfi

    re, im = _scheduled_hadamard(idx_ref, sel_ref, vr_ref, vi_ref,
                                 xfr_s[...], xfi_s[...])
    y = _ifft_real_nf(re, im, dvr_ref, dvi_ref)
    _dma_rmw_finish(sink, dst, ydma, sem, y, b_ref, slot=slot, gm=gm,
                    n_m_blocks=n_m_blocks, relu=relu)


# ---------------------------------------------------------------------------
# pallas_call wrapper
# ---------------------------------------------------------------------------

def _pad_axis(x: Array, axis: int, mult: int) -> Array:
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def _flow_layout(flow: str, gn: int, gm: int, gp: int):
    """(grid, canon, dimension_semantics) for a reuse flow.

    ``canon`` maps the flow's grid arguments back to canonical
    (n, p, m) block indices, so every operand's BlockSpec index map can
    be written once against the canonical order."""
    if flow == "output_stationary":
        grid = (gn, gp, gm)
        canon = lambda n, p, m: (n, p, m)
        semantics = ("parallel", "parallel", "arbitrary")
    elif flow == "weight_stationary":
        grid = (gn, gm, gp)
        canon = lambda n, m, p: (n, p, m)
        semantics = ("parallel", "arbitrary", "arbitrary")
    elif flow == "input_stationary":
        grid = (gp, gm, gn)
        canon = lambda p, m, n: (n, p, m)
        semantics = ("parallel", "arbitrary", "arbitrary")
    else:
        raise ValueError(f"flow must be one of {FLOWS}")
    return grid, canon, semantics


def _const_spec(rows: int, cols: int) -> pl.BlockSpec:
    """Whole-array BlockSpec for the VMEM-resident DFT operators."""
    return pl.BlockSpec((rows, cols), lambda *_: (0, 0))


def _dma_scratch(sink):
    """The manual-DMA output scratch every fused kernel appends: the
    DMA_SLOTS double-buffered staging tiles (in the sink's output
    layout) and their DMA-completion semaphores."""
    return [pltpu.VMEM((DMA_SLOTS,) + sink.stage_shape, jnp.float32),
            pltpu.SemaphoreType.DMA((DMA_SLOTS,))]


def _plane_kernel_scratch(flow: str, gm: int, relu: bool, fa: int,
                          bn: int, bm: int, bp: int, sink, wrap=None):
    """(kernel, scratch_shapes) of one flow's plane-Hadamard body —
    shared by the windowed and halo pipeline builders (``wrap`` is the
    halo gather applied around the body when given)."""
    body = {"output_stationary": _kernel_os,
            "weight_stationary": _kernel_ws,
            "input_stationary": _kernel_is}[flow]
    kernel = functools.partial(body, n_m_blocks=gm, relu=relu, sink=sink)
    if wrap is not None:
        kernel = wrap(kernel)
    scratch = {"output_stationary": [pltpu.VMEM((fa, bn, bp),
                                                jnp.float32)] * 2,
               "weight_stationary": [],
               "input_stationary": [pltpu.VMEM((fa, bm, bp),
                                               jnp.float32)] * 2}[flow]
    return kernel, scratch + _dma_scratch(sink)


def _sched_kernel_scratch(flow: str, gm: int, relu: bool, fa: int,
                          n_pe: int, bm: int, bp: int, sink, wrap=None):
    """Scheduled-Hadamard sibling of ``_plane_kernel_scratch`` (the
    output-stationary psums are n-leading [N', Fa, bp])."""
    body = {"output_stationary": _kernel_os_sched,
            "weight_stationary": _kernel_ws_sched,
            "input_stationary": _kernel_is_sched}[flow]
    kernel = functools.partial(body, n_m_blocks=gm, relu=relu, sink=sink)
    if wrap is not None:
        kernel = wrap(kernel)
    scratch = {"output_stationary": [pltpu.VMEM((n_pe, fa, bp),
                                                jnp.float32)] * 2,
               "weight_stationary": [],
               "input_stationary": [pltpu.VMEM((fa, bm, bp),
                                               jnp.float32)] * 2}[flow]
    return kernel, scratch + _dma_scratch(sink)


@functools.partial(
    jax.jit,
    static_argnames=("flow", "block_n", "block_m", "block_p", "relu",
                     "interpret"))
def fused_spectral_pipeline(xt: Array, wr: Array, wi: Array,
                            dfr: Array, dfi: Array,
                            dvr: Array, dvi: Array, bias: Array, *,
                            flow: str = "output_stationary",
                            block_n: int = 64, block_m: int = 64,
                            block_p: int = 128, relu: bool = False,
                            interpret: bool = True,
                            shortcut: Array | None = None) -> Array:
    """FFT -> Hadamard -> IFFT (+ bias/ReLU epilogue) in one pallas_call.

    xt: [S, M, P] f32     overlap-save windows, s-leading (S = K^2,
                          P = B*T)
    wr/wi: [Fa, N, M] f32 spectral kernel planes on active bins
    dfr/dfi: [Fa, S]      forward DFT rows (active bins)
    dvr/dvi: [S2, Fa]     inverse DFT, valid rows x active columns
                          (S2 = t^2)
    bias: [1, N] f32      per-output-channel bias (zeros disable)
    shortcut: optional [S2, N, P] f32 residual operand in the OUTPUT
        tile layout (``_shortcut_tiles`` relayout of the producer's
        activation): one extra input streamed on the flush path and
        added after bias, before ReLU, inside the kernel.
    returns [S2, N, P] f32 finished spatial outputs (epilogue applied).
    """
    if flow not in FLOWS:
        raise ValueError(f"flow must be one of {FLOWS}")
    s, m, p = xt.shape
    fa, n, _ = wr.shape
    s2 = dvr.shape[0]
    assert dfr.shape == (fa, s) and dvr.shape == (s2, fa), \
        (dfr.shape, dvr.shape, (fa, s, s2))
    assert bias.shape == (1, n), (bias.shape, n)

    bn, bm, bp = min(block_n, n), min(block_m, m), min(block_p, p)
    xt_ = _pad_axis(_pad_axis(xt, 1, bm), 2, bp)
    wr_ = _pad_axis(_pad_axis(wr, 1, bn), 2, bm)
    wi_ = _pad_axis(_pad_axis(wi, 1, bn), 2, bm)
    bias_ = _pad_axis(bias, 1, bn)
    np_, mp_, pp_ = wr_.shape[1], wr_.shape[2], xt_.shape[2]
    gn, gm, gp = np_ // bn, mp_ // bm, pp_ // bp
    grid, canon, semantics = _flow_layout(flow, gn, gm, gp)
    sink = _TileSink(s2, bn, bp)
    kernel, scratch = _plane_kernel_scratch(flow, gm, relu, fa, bn, bm,
                                            bp, sink)

    x_spec = pl.BlockSpec(
        (s, bm, bp), lambda *g: (0, canon(*g)[2], canon(*g)[1]))
    w_spec = pl.BlockSpec(
        (fa, bn, bm), lambda *g: (0, canon(*g)[0], canon(*g)[2]))
    b_spec = pl.BlockSpec((1, bn), lambda *g: (0, canon(*g)[0]))

    in_specs = [x_spec, w_spec, w_spec,
                _const_spec(fa, s), _const_spec(fa, s),
                _const_spec(s2, fa), _const_spec(s2, fa), b_spec]
    operands = [xt_.astype(jnp.float32), wr_, wi_, dfr, dfi, dvr, dvi,
                bias_]
    if shortcut is not None:
        assert shortcut.shape == (s2, n, p), (shortcut.shape, (s2, n, p))
        kernel = _residual_kernel(kernel, sink)
        sc_spec = pl.BlockSpec(
            (s2, bn, bp), lambda *g: (0, canon(*g)[0], canon(*g)[1]))
        in_specs = [sc_spec] + in_specs
        operands = [_pad_axis(_pad_axis(shortcut.astype(jnp.float32),
                                        1, bn), 2, bp)] + operands

    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((s2, np_, pp_), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(*operands)
    return y[:, :n, :p]


def _halo_specs(geo: SpectralGeometry, hg: HaloGeometry, bm: int, canon):
    """(x, gr, gc) BlockSpecs of the halo input path.

    The x spec uses element-offset (``pl.Unblocked``) indexing: the p
    grid axis enumerates (image, block-row, block-col) and the offset
    formula is the traced twin of ``spectral.halo_block_starts`` —
    consecutive blocks' reads overlap by the k-1 halo, clamped at the
    image edges.  gr/gc stream the block's one-hot window selectors
    (standard blocked indexing on their leading block axis)."""
    t, ov = geo.tile, geo.ksize - 1
    nb = hg.n_blocks
    h_hi, w_hi = geo.h_in - hg.rh, geo.w_in - hg.rw

    def decomp(p):
        return p // nb, (p % nb) // hg.nbw, p % hg.nbw

    def x_idx(*g):
        _, p, m = canon(*g)
        b, ib, jb = decomp(p)
        # + pre_halo_h: sharded bands carry their top halo in-buffer,
        # shifting every H-axis block start down by the halo rows
        # (traced twin of spectral.halo_block_starts).
        return (b, m * bm,
                jnp.clip(ib * hg.bth * t - ov + geo.pre_halo_h, 0, h_hi),
                jnp.clip(jb * hg.btw * t - ov, 0, w_hi))

    x_spec = pl.BlockSpec((1, bm, hg.rh, hg.rw), x_idx,
                          indexing_mode=pl.Unblocked())
    gr_spec = pl.BlockSpec(
        (1, hg.bth * geo.fft_size, hg.rh),
        lambda *g: (decomp(canon(*g)[1])[1], 0, 0))
    gc_spec = pl.BlockSpec(
        (1, hg.btw * geo.fft_size, hg.rw),
        lambda *g: (decomp(canon(*g)[1])[2], 0, 0))
    return x_spec, gr_spec, gc_spec


def _canvas_sc_spec(hg: HaloGeometry, bn: int, tile: int, canon):
    """BlockSpec of the halo path's shortcut operand: the output-canvas
    rectangle of the current (n, p) grid position — the same (image,
    block-row, block-col) decomposition ``_CanvasSink.dst`` uses, as a
    blocked index map."""
    nb = hg.n_blocks

    def sc_idx(*g):
        n_, p, _ = canon(*g)
        return (p // nb, n_, (p % nb) // hg.nbw, p % hg.nbw)

    return pl.BlockSpec((1, bn, hg.bth * tile, hg.btw * tile), sc_idx)


def _shortcut_canvas(sc: Array, geo: SpectralGeometry, hg: HaloGeometry,
                     bn: int) -> Array:
    """RAW [B, N, H_out, W_out] shortcut -> the halo pipeline's output
    canvas layout [B, Np, nbh*bth*t, nbw*btw*t]: the valid 'same'-crop
    window of the canvas holds the shortcut, everything else is zero
    (those canvas positions are wraparound garbage and are cropped by
    ``_crop_canvas`` anyway)."""
    b, n, h, w = sc.shape
    t = geo.tile
    start = geo.ksize - 1 - geo.pad
    canvas = jnp.zeros((b, n, hg.nbh * hg.bth * t, hg.nbw * hg.btw * t),
                       jnp.float32)
    canvas = canvas.at[:, :, start:start + h,
                       start:start + w].set(sc.astype(jnp.float32))
    return _pad_axis(canvas, 1, bn)


def _shortcut_tiles(sc: Array, geo: SpectralGeometry, t_cnt: int) -> Array:
    """RAW [B, N, H_out, W_out] shortcut -> the windowed pipelines'
    output tile layout [S2, N, B*T] (the exact inverse of
    ``_assemble_output``): embed into valid-tile canvas coordinates,
    split into t x t tiles, u-major rows."""
    b, n, h, w = sc.shape
    t = geo.tile
    start = geo.ksize - 1 - geo.pad
    canvas = jnp.zeros((b, n, geo.n_tiles_h * t, geo.n_tiles_w * t),
                       jnp.float32)
    canvas = canvas.at[:, :, start:start + h,
                       start:start + w].set(sc.astype(jnp.float32))
    tiles = (canvas.reshape(b, n, geo.n_tiles_h, t, geo.n_tiles_w, t)
             .transpose(0, 1, 2, 4, 3, 5)        # [b, n, ith, jtw, u, v]
             .reshape(b, n, t_cnt, t * t))
    return tiles.transpose(3, 1, 0, 2).reshape(t * t, n, b * t_cnt)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "hg", "flow", "block_n", "block_m", "relu",
                     "interpret"))
def fused_spectral_pipeline_halo(x: Array, wr: Array, wi: Array,
                                 dfr: Array, dfi: Array,
                                 dvr: Array, dvi: Array, bias: Array, *,
                                 geo: SpectralGeometry, hg: HaloGeometry,
                                 flow: str = "output_stationary",
                                 block_n: int = 64, block_m: int = 64,
                                 relu: bool = False,
                                 interpret: bool = True,
                                 shortcut: Array | None = None) -> Array:
    """The halo-input sibling of ``fused_spectral_pipeline``: gather ->
    FFT -> Hadamard -> IFFT (+ epilogue) in one pallas_call, reading the
    RAW activation.  ``shortcut`` is an optional RAW [B, N, H_out,
    W_out] residual operand, embedded into the output-canvas layout
    host-side and streamed as one extra flush-path input (added after
    bias, before ReLU, in-kernel).

    x: [B, M, H, W] f32      raw NCHW activation (no windowing, no
                             padding — the gather encodes both)
    wr/wi/dfr/dfi/dvr/dvi/bias: as ``fused_spectral_pipeline``.
    geo/hg: tile + halo-block geometry (``halo_block_geometry``); the
        effective block_p is ``hg.block_tiles`` and the p grid axis is
        B * hg.n_blocks.
    Returns the assembled spatial output canvas
    [B, Np, nbh*bth*t, nbw*btw*t] (Np = N padded to block_n): the
    kernel's flush re-lays each finished tile rectangle into canvas
    position in VMEM and DMAs it there directly, so the only host-side
    work left is the 'same'-crop slice (``_crop_canvas``) —
    ``assemble_valid_tiles`` never runs on this path.
    """
    if flow not in FLOWS:
        raise ValueError(f"flow must be one of {FLOWS}")
    b, m, h, w_px = x.shape
    assert (h, w_px) == (geo.h_in, geo.w_in), (x.shape, geo)
    fa, n, _ = wr.shape
    s = geo.fft_size * geo.fft_size
    s2 = dvr.shape[0]
    assert dfr.shape == (fa, s) and dvr.shape == (s2, fa), \
        (dfr.shape, dvr.shape, (fa, s, s2))
    assert bias.shape == (1, n), (bias.shape, n)

    bt = hg.block_tiles
    bn, bm = min(block_n, n), min(block_m, m)
    x_ = _pad_axis(x, 1, bm)
    wr_ = _pad_axis(_pad_axis(wr, 1, bn), 2, bm)
    wi_ = _pad_axis(_pad_axis(wi, 1, bn), 2, bm)
    bias_ = _pad_axis(bias, 1, bn)
    np_, mp_ = wr_.shape[1], wr_.shape[2]
    gn, gm, gp = np_ // bn, mp_ // bm, b * hg.n_blocks
    grid, canon, semantics = _flow_layout(flow, gn, gm, gp)
    gr, gc = (jnp.asarray(a) for a in halo_gather_matrices(geo, hg))
    wrap = functools.partial(_halo_kernel, bth=hg.bth, btw=hg.btw,
                             fft_size=geo.fft_size)
    sink = _CanvasSink(hg, geo.tile, bn)
    kernel, scratch = _plane_kernel_scratch(flow, gm, relu, fa, bn, bm,
                                            bt, sink, wrap=wrap)

    x_spec, gr_spec, gc_spec = _halo_specs(geo, hg, bm, canon)
    w_spec = pl.BlockSpec(
        (fa, bn, bm), lambda *g: (0, canon(*g)[0], canon(*g)[2]))
    b_spec = pl.BlockSpec((1, bn), lambda *g: (0, canon(*g)[0]))

    canvas = (b, np_, hg.nbh * hg.bth * geo.tile,
              hg.nbw * hg.btw * geo.tile)
    in_specs = [x_spec, gr_spec, gc_spec, w_spec, w_spec,
                _const_spec(fa, s), _const_spec(fa, s),
                _const_spec(s2, fa), _const_spec(s2, fa), b_spec]
    operands = [x_.astype(jnp.float32), gr, gc, wr_, wi_, dfr, dfi, dvr,
                dvi, bias_]
    if shortcut is not None:
        kernel = _residual_kernel(kernel, sink)
        in_specs = [_canvas_sc_spec(hg, bn, geo.tile, canon)] + in_specs
        operands = [_shortcut_canvas(shortcut, geo, hg, bn)] + operands
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(canvas, jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "hg", "n_out", "flow", "block_m", "relu",
                     "interpret"))
def fused_spectral_pipeline_scheduled_halo(
        x: Array, idx: Array, sel: Array, vr: Array, vi: Array,
        dfr: Array, dfi: Array, dvr: Array, dvi: Array, bias: Array, *,
        geo: SpectralGeometry, hg: HaloGeometry, n_out: int,
        flow: str = "output_stationary", block_m: int = 64,
        relu: bool = False, interpret: bool = True,
        shortcut: Array | None = None) -> Array:
    """Halo-input sibling of ``fused_spectral_pipeline_scheduled``: the
    in-kernel window gather feeding the Alg-2 scheduled datapath.
    ``shortcut``: optional RAW [B, N, H_out, W_out] residual operand
    (see ``fused_spectral_pipeline_halo``).
    Operand contracts are the scheduled pipeline's (tables padded for
    ``m_pad_to == min(block_m, M)``, block_n implied == N'), except the
    input is the raw [B, M, H, W] activation and the output is the
    assembled spatial canvas [B, GN*N', nbh*bth*t, nbw*btw*t] (see
    ``fused_spectral_pipeline_halo``)."""
    b, m, h, w_px = x.shape
    assert (h, w_px) == (geo.h_in, geo.w_in), (x.shape, geo)
    gn, mp_t, t_cycles, r = idx.shape
    n_pe = sel.shape[3]
    fa = dfr.shape[0]
    s = geo.fft_size * geo.fft_size
    s2 = dvr.shape[0]
    assert sel.shape == (gn, mp_t, t_cycles, n_pe), (sel.shape, idx.shape)
    assert vr.shape == sel.shape and vi.shape == sel.shape
    assert n_out <= gn * n_pe, (n_out, gn, n_pe)
    assert bias.shape == (1, n_out), (bias.shape, n_out)

    bt = hg.block_tiles
    bm = min(block_m, m)
    x_ = _pad_axis(x, 1, bm)
    bias_ = _pad_axis(bias, 1, n_pe)
    mp_ = x_.shape[1]
    assert mp_ == mp_t, \
        (f"tables padded for {mp_t} channels but raw input pads to "
         f"{mp_}; compile_layer_tables(m_pad_to=block_m) must use the "
         f"same block_m (= {bm})")
    np_ = gn * n_pe
    gm, gp = mp_ // bm, b * hg.n_blocks
    grid, canon, semantics = _flow_layout(flow, gn, gm, gp)
    gr, gc = (jnp.asarray(a) for a in halo_gather_matrices(geo, hg))
    wrap = functools.partial(_halo_kernel, bth=hg.bth, btw=hg.btw,
                             fft_size=geo.fft_size)
    sink = _CanvasSink(hg, geo.tile, n_pe)
    kernel, scratch = _sched_kernel_scratch(flow, gm, relu, fa, n_pe,
                                            bm, bt, sink, wrap=wrap)

    x_spec, gr_spec, gc_spec = _halo_specs(geo, hg, bm, canon)
    t_spec = lambda lanes: pl.BlockSpec(
        (1, bm, t_cycles, lanes),
        lambda *g: (canon(*g)[0], canon(*g)[2], 0, 0))
    b_spec = pl.BlockSpec((1, n_pe), lambda *g: (0, canon(*g)[0]))

    canvas = (b, np_, hg.nbh * hg.bth * geo.tile,
              hg.nbw * hg.btw * geo.tile)
    in_specs = [x_spec, gr_spec, gc_spec, t_spec(r), t_spec(n_pe),
                t_spec(n_pe), t_spec(n_pe),
                _const_spec(fa, s), _const_spec(fa, s),
                _const_spec(s2, fa), _const_spec(s2, fa), b_spec]
    operands = [x_.astype(jnp.float32), gr, gc, idx, sel, vr, vi, dfr,
                dfi, dvr, dvi, bias_]
    if shortcut is not None:
        kernel = _residual_kernel(kernel, sink)
        in_specs = [_canvas_sc_spec(hg, n_pe, geo.tile, canon)] + in_specs
        operands = [_shortcut_canvas(shortcut, geo, hg, n_pe)] + operands
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(canvas, jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit,
    static_argnames=("n_out", "flow", "block_m", "block_p", "relu",
                     "interpret"))
def fused_spectral_pipeline_scheduled(xt: Array, idx: Array, sel: Array,
                                      vr: Array, vi: Array,
                                      dfr: Array, dfi: Array,
                                      dvr: Array, dvi: Array,
                                      bias: Array, *, n_out: int,
                                      flow: str = "output_stationary",
                                      block_m: int = 64,
                                      block_p: int = 128,
                                      relu: bool = False,
                                      interpret: bool = True,
                                      shortcut: Array | None = None
                                      ) -> Array:
    """FFT -> SCHEDULED sparse Hadamard -> IFFT (+ epilogue) in one
    pallas_call — the element-granular sibling of
    ``fused_spectral_pipeline``.  ``shortcut``: optional [S2, n_out, P]
    residual operand in the output tile layout (see
    ``fused_spectral_pipeline``).

    The kernel operand is not a plane stack but the Alg-2 INDEX/VALUE
    tables of ``scheduler.LayerTables`` (already padded/remapped):

    xt: [S, M, P] f32          overlap-save windows, s-leading
    idx: [GN, Mp, T, r] int32  replica read addresses (compacted coords)
    sel: [GN, Mp, T, N'] int32 crossbar selects
    vr/vi: [GN, Mp, T, N'] f32 PE weight planes (zero = idle lane)
    dfr/dfi: [Fa, S], dvr/dvi: [S2, Fa], bias: [1, n_out]

    block_n is implied: it equals the schedule's PE-group size N' (the
    tables were compiled for it); the table channel padding Mp must
    equal M padded to block_m — both are enforced.  Returns
    [S2, n_out, P] finished spatial outputs.
    """
    s, m, p = xt.shape
    gn, mp_t, t_cycles, r = idx.shape
    n_pe = sel.shape[3]
    fa = dfr.shape[0]
    s2 = dvr.shape[0]
    assert sel.shape == (gn, mp_t, t_cycles, n_pe), (sel.shape, idx.shape)
    assert vr.shape == sel.shape and vi.shape == sel.shape
    assert dfr.shape == (fa, s) and dvr.shape == (s2, fa), \
        (dfr.shape, dvr.shape, (fa, s, s2))
    assert n_out <= gn * n_pe, (n_out, gn, n_pe)
    assert bias.shape == (1, n_out), (bias.shape, n_out)

    bm, bp = min(block_m, m), min(block_p, p)
    xt_ = _pad_axis(_pad_axis(xt, 1, bm), 2, bp)
    bias_ = _pad_axis(bias, 1, n_pe)
    mp_, pp_ = xt_.shape[1], xt_.shape[2]
    assert mp_ == mp_t, \
        (f"tables padded for {mp_t} channels but windows pad to {mp_}; "
         f"compile_layer_tables(m_pad_to=block_m) must use the same "
         f"block_m (= {bm})")
    np_ = gn * n_pe
    gm, gp = mp_ // bm, pp_ // bp
    grid, canon, semantics = _flow_layout(flow, gn, gm, gp)
    sink = _TileSink(s2, n_pe, bp)
    kernel, scratch = _sched_kernel_scratch(flow, gm, relu, fa, n_pe,
                                            bm, bp, sink)

    x_spec = pl.BlockSpec(
        (s, bm, bp), lambda *g: (0, canon(*g)[2], canon(*g)[1]))
    t_spec = lambda lanes: pl.BlockSpec(
        (1, bm, t_cycles, lanes),
        lambda *g: (canon(*g)[0], canon(*g)[2], 0, 0))
    b_spec = pl.BlockSpec((1, n_pe), lambda *g: (0, canon(*g)[0]))

    in_specs = [x_spec, t_spec(r), t_spec(n_pe), t_spec(n_pe),
                t_spec(n_pe),
                _const_spec(fa, s), _const_spec(fa, s),
                _const_spec(s2, fa), _const_spec(s2, fa), b_spec]
    operands = [xt_.astype(jnp.float32), idx, sel, vr, vi, dfr, dfi,
                dvr, dvi, bias_]
    if shortcut is not None:
        assert shortcut.shape == (s2, n_out, p), \
            (shortcut.shape, (s2, n_out, p))
        kernel = _residual_kernel(kernel, sink)
        sc_spec = pl.BlockSpec(
            (s2, n_pe, bp), lambda *g: (0, canon(*g)[0], canon(*g)[1]))
        in_specs = [sc_spec] + in_specs
        operands = [_pad_axis(_pad_axis(shortcut.astype(jnp.float32),
                                        1, n_pe), 2, bp)] + operands
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((s2, np_, pp_), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(*operands)
    return y[:, :n_out, :p]


def _windows_layout(x: Array, geo: SpectralGeometry) -> tuple[Array, int]:
    """Overlap-save window extraction + s-leading layout [S, M, B*T] —
    the in-kernel FFT contracts the leading dim with one GEMM, no
    transposes on the TPU side."""
    b, m = x.shape[:2]
    windows = extract_tiles_overlapping(x, geo)         # [B, M, T, K, K]
    t_cnt = windows.shape[2]
    s = geo.fft_size * geo.fft_size
    xt = (windows.reshape(b, m, t_cnt, s)
          .transpose(3, 1, 0, 2).reshape(s, m, b * t_cnt))
    return xt, t_cnt


def _assemble_output(y: Array, geo: SpectralGeometry, b: int, n: int,
                     t_cnt: int, dtype) -> Array:
    """[t^2, N, B*T] pipeline output -> assembled [B, N, H, W]."""
    s2 = geo.tile * geo.tile
    y_tiles = (y.reshape(s2, n, b, t_cnt).transpose(2, 1, 3, 0)
               .reshape(b, n, t_cnt, geo.tile, geo.tile))
    return assemble_valid_tiles(y_tiles.astype(dtype), geo)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "flow", "block_n", "block_m", "block_p",
                     "relu", "interpret"))
def _fused_conv(x: Array, wr: Array, wi: Array, dfr: Array, dfi: Array,
                dvr: Array, dvi: Array, bias: Array,
                shortcut: Array | None = None, *,
                geo: SpectralGeometry, flow: str,
                block_n: int, block_m: int, block_p: int,
                relu: bool, interpret: bool) -> Array:
    """Jitted body: overlap-save window extraction, layout, pipeline,
    valid-tile assembly — one compiled program per (geo, flow, blocks,
    relu), so the host-side relayout is not re-dispatched eagerly on
    every forward call.  All spectral operands arrive precomputed (by
    ``core.plan`` or the ad-hoc wrapper below); nothing geometric or
    sparsity-related is derived in here.  ``shortcut`` is an optional
    RAW [B, N, H_out, W_out] residual operand, relaid to the output
    tile layout and added in-kernel (after bias, before ReLU)."""
    b, m = x.shape[:2]
    n = wr.shape[1]
    xt, t_cnt = _windows_layout(x, geo)
    sc = (None if shortcut is None
          else _shortcut_tiles(shortcut, geo, t_cnt))
    y = fused_spectral_pipeline(
        xt, wr, wi, dfr, dfi, dvr, dvi, bias, flow=flow,
        block_n=block_n, block_m=block_m, block_p=block_p, relu=relu,
        interpret=interpret, shortcut=sc)               # [t^2, N, B*T]
    return _assemble_output(y, geo, b, n, t_cnt, x.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "n_out", "flow", "block_m", "block_p",
                     "relu", "interpret"))
def _fused_conv_scheduled(x: Array, idx: Array, sel: Array, vr: Array,
                          vi: Array, dfr: Array, dfi: Array, dvr: Array,
                          dvi: Array, bias: Array,
                          shortcut: Array | None = None, *,
                          geo: SpectralGeometry, n_out: int, flow: str,
                          block_m: int, block_p: int,
                          relu: bool, interpret: bool) -> Array:
    """Jitted body of the scheduled-Hadamard fused conv (same relayout
    contract as ``_fused_conv``; kernel operands are Alg-2 tables)."""
    b = x.shape[0]
    xt, t_cnt = _windows_layout(x, geo)
    sc = (None if shortcut is None
          else _shortcut_tiles(shortcut, geo, t_cnt))
    y = fused_spectral_pipeline_scheduled(
        xt, idx, sel, vr, vi, dfr, dfi, dvr, dvi, bias, n_out=n_out,
        flow=flow, block_m=block_m, block_p=block_p, relu=relu,
        interpret=interpret, shortcut=sc)
    return _assemble_output(y, geo, b, n_out, t_cnt, x.dtype)


def _crop_canvas(y: Array, geo: SpectralGeometry, n: int, dtype) -> Array:
    """[B, Np, nbh*bth*t, nbw*btw*t] halo-pipeline canvas -> [B, N,
    H_out, W_out]: the kernel already assembled tiles in canvas order
    (tile (i, j) at (i*t, j*t)), so all that remains is the channel
    crop and the 'same'-crop slice of ``assemble_valid_tiles`` — a pure
    slice, zero relayout FLOPs or copies on the host."""
    start = geo.ksize - 1 - geo.pad
    h_out = geo.h_in + 2 * geo.pad - geo.ksize + 1
    w_out = geo.w_in + 2 * geo.pad - geo.ksize + 1
    return y[:, :n, start:start + h_out,
             start:start + w_out].astype(dtype)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "flow", "block_n", "block_m", "block_p",
                     "relu", "interpret"))
def _fused_conv_halo(x: Array, wr: Array, wi: Array, dfr: Array,
                     dfi: Array, dvr: Array, dvi: Array, bias: Array,
                     shortcut: Array | None = None, *,
                     geo: SpectralGeometry, flow: str,
                     block_n: int, block_m: int, block_p: int,
                     relu: bool, interpret: bool) -> Array:
    """Jitted body of the halo-input fused conv: NO host-side window
    materialization — the raw activation goes straight into the
    pallas_call (the in-kernel gather does the windowing) — and NO
    host-side output relayout either: the kernel DMAs assembled canvas
    rectangles and only the 'same'-crop slice runs outside.  ``block_p``
    is split into the 2-D halo block by ``halo_block_geometry``."""
    n = wr.shape[1]
    hg = halo_block_geometry(geo, block_p)
    y = fused_spectral_pipeline_halo(
        x, wr, wi, dfr, dfi, dvr, dvi, bias, geo=geo, hg=hg, flow=flow,
        block_n=block_n, block_m=block_m, relu=relu, interpret=interpret,
        shortcut=shortcut)
    return _crop_canvas(y, geo, n, x.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "n_out", "flow", "block_m", "block_p",
                     "relu", "interpret"))
def _fused_conv_scheduled_halo(x: Array, idx: Array, sel: Array,
                               vr: Array, vi: Array, dfr: Array,
                               dfi: Array, dvr: Array, dvi: Array,
                               bias: Array,
                               shortcut: Array | None = None, *,
                               geo: SpectralGeometry,
                               n_out: int, flow: str, block_m: int,
                               block_p: int, relu: bool,
                               interpret: bool) -> Array:
    """Jitted body of the halo-input scheduled fused conv (same contract
    as ``_fused_conv_scheduled``, raw activation in)."""
    hg = halo_block_geometry(geo, block_p)
    y = fused_spectral_pipeline_scheduled_halo(
        x, idx, sel, vr, vi, dfr, dfi, dvr, dvi, bias, geo=geo, hg=hg,
        n_out=n_out, flow=flow, block_m=block_m, relu=relu,
        interpret=interpret, shortcut=shortcut)
    return _crop_canvas(y, geo, n_out, x.dtype)


def fused_spectral_conv2d(x: Array, w_f, geo: SpectralGeometry, *,
                          flow: str = "output_stationary",
                          block_n: int = 64, block_m: int = 64,
                          block_p: int = 128, bias: Array | None = None,
                          relu: bool = False,
                          input_mode: str = "windowed",
                          interpret: bool | None = None) -> Array:
    """Full spectral conv layer through the single fused pallas_call.

    x: [B, M, H, W] real NCHW; w_f: complex [N, M, K, K] dense, or a
    ``SparseSpectralKernels`` whose active-bin set drives the spectral
    GEMM compaction (dense fallback when nnz ~= K^2).  ``bias``/``relu``
    select the fused epilogue.  ``input_mode`` selects the input path
    (``dataflow.INPUT_MODES``): 'windowed' materializes the overlap-save
    window tensor host-side (the PR-3 formulation, kept as fallback and
    oracle), 'halo' reads the raw activation through overlapping halo
    blocks and gathers the windows in VMEM — numerically identical, one
    whole HBM materialization pass cheaper plus the (K/t)^2 halo
    duplication.  In windowed mode the host does only the layout work
    the paper's DMA engine does; in halo mode not even that.

    NOTE: this ad-hoc entry recomputes compaction + DFT operators per
    call; the compile-once path is ``core.plan.build_network_plan`` +
    ``execute_layer_plan``.
    """
    if input_mode not in INPUT_MODES:
        raise ValueError(f"input_mode must be one of {INPUT_MODES}, "
                         f"got {input_mode!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if hasattr(w_f, "values"):            # SparseSpectralKernels duck-type
        active = sp.compacted_active_bins(w_f)
        wr, wi = sp.compact_planes(w_f, active)
        n = w_f.n_out
        assert w_f.fft_size == geo.fft_size
    else:
        assert w_f.shape[-1] == geo.fft_size
        active = None
        n, m = w_f.shape[:2]
        flat = w_f.reshape(n, m, geo.fft_size * geo.fft_size)
        wr = jnp.transpose(flat.real, (2, 0, 1)).astype(jnp.float32)
        wi = jnp.transpose(flat.imag, (2, 0, 1)).astype(jnp.float32)
    ops = overlap_save_operators(
        geo.fft_size, geo.ksize,
        tuple(int(a) for a in active) if active is not None else None)
    dfr, dfi, dvr, dvi = (jnp.asarray(a) for a in ops)
    if bias is None:
        bias_arr = jnp.zeros((1, n), jnp.float32)
    else:
        bias_arr = jnp.asarray(bias, jnp.float32).reshape(1, n)
    conv = _fused_conv_halo if input_mode == "halo" else _fused_conv
    return conv(x, wr, wi, dfr, dfi, dvr, dvi, bias_arr, geo=geo,
                flow=flow, block_n=block_n, block_m=block_m,
                block_p=block_p, relu=relu, interpret=interpret)


def fused_spectral_conv2d_scheduled(x: Array, sk, geo: SpectralGeometry,
                                    *, r: int = 10, n_par: int = 64,
                                    flow: str = "output_stationary",
                                    block_m: int = 64, block_p: int = 128,
                                    bias: Array | None = None,
                                    relu: bool = False,
                                    method: str = "exact_cover",
                                    tables=None,
                                    input_mode: str = "windowed",
                                    interpret: bool | None = None
                                    ) -> Array:
    """Full spectral conv layer through the SCHEDULED fused pallas_call.

    x: [B, M, H, W] real NCHW; sk: ``SparseSpectralKernels`` whose Alg-2
    exact-cover schedule (group size ``n_par`` == the kernel's block_n,
    ``r`` BRAM-replica analogue) is compiled to INDEX/VALUE tables here
    and executed element-granularly inside the fused kernel.  Pass a
    precompiled ``scheduler.LayerTables`` via ``tables`` to skip the
    per-call scheduling (it must have been built with the same
    ``active`` set and ``m_pad_to == min(block_m, M)``) — repeated
    calls (e.g. the measured autotune pass) should not re-run, let
    alone re-time, the host-side scheduler.

    NOTE: without ``tables`` this ad-hoc entry runs the scheduler per
    call (one schedule per kernel-group x channel); the compile-once
    path is ``core.plan.build_network_plan(hadamard='scheduled'|'auto')``
    + ``execute_layer_plan``.
    """
    from repro.core import scheduler as sch

    if input_mode not in INPUT_MODES:
        raise ValueError(f"input_mode must be one of {INPUT_MODES}, "
                         f"got {input_mode!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    assert sk.fft_size == geo.fft_size
    k2 = geo.fft_size * geo.fft_size
    n, m = sk.n_out, sk.n_in
    bm = min(block_m, m)
    n_par = min(n_par, n)
    active = sp.compacted_active_bins(sk)
    tabs = tables
    if tabs is None:
        vals = np.asarray(sk.values).reshape(n, m, k2)
        tabs = sch.compile_layer_tables(
            np.asarray(sk.indices), vals, k2, r, n_par,
            method=method, active=active, m_pad_to=bm)
    ops = overlap_save_operators(
        geo.fft_size, geo.ksize,
        tuple(int(a) for a in active) if active is not None else None)
    dfr, dfi, dvr, dvi = (jnp.asarray(a) for a in ops)
    if bias is None:
        bias_arr = jnp.zeros((1, n), jnp.float32)
    else:
        bias_arr = jnp.asarray(bias, jnp.float32).reshape(1, n)
    conv = (_fused_conv_scheduled_halo if input_mode == "halo"
            else _fused_conv_scheduled)
    return conv(
        x, jnp.asarray(tabs.idx), jnp.asarray(tabs.sel),
        jnp.asarray(tabs.vr), jnp.asarray(tabs.vi),
        dfr, dfi, dvr, dvi, bias_arr, geo=geo, n_out=n,
        flow=flow, block_m=bm, block_p=block_p, relu=relu,
        interpret=interpret)


def execute_layer_plan(x: Array, lp, *, interpret: bool | None = None,
                       shortcut: Array | None = None) -> Array:
    """Run one conv layer from a precompiled ``core.plan.LayerPlan``.

    Consumes the plan's precomputed operands and dispatches on the
    plan's Hadamard mode: 'dense'/'bin' execute the Karatsuba-GEMM
    pipeline on the (possibly compacted) kernel planes; 'scheduled'
    executes the precompiled Alg-2 INDEX/VALUE tables element-
    granularly.  Nothing is re-derived per call — no scheduling,
    compaction or geometry work — so repeated forwards hit the jit
    cache of ``_fused_conv``/``_fused_conv_scheduled`` (or their halo
    siblings, when the plan's ``input_mode`` is 'halo') directly.
    Pooling (``lp.epilogue.pool``) is spatial and stays with the
    caller.

    ``shortcut``: RAW [B, N, H_out, W_out] residual operand for plans
    whose epilogue is residual-FUSED (``lp.epilogue.residual ==
    'fused'``); the DAG executor passes the producer node's activation
    and the kernel adds it after bias, before ReLU.  Ignored epilogue
    states ('add' / None) never pass one — the add happens in XLA.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tn = lp.tuning
    halo = getattr(lp, "input_mode", "windowed") == "halo"
    # Fault-injection sites (no-ops without an installed fault).  They
    # live HERE — outside the jitted pipelines — so a warm jit cache can
    # never bypass them; this is also where a real Mosaic lowering
    # failure or VMEM RESOURCE_EXHAUSTED would surface on hardware.
    ctx = dict(layer=lp.layer.name, backend="fused", flow=tn.flow,
               hadamard=getattr(lp, "hadamard", None),
               input_mode=getattr(lp, "input_mode", "windowed"),
               residual=getattr(lp.epilogue, "residual", None))
    res.fault_check("lowering", **ctx)
    res.fault_check("vmem_overflow", **ctx)
    bias = lp.bias if lp.epilogue.bias else jnp.zeros_like(lp.bias)
    if getattr(lp, "hadamard", None) == "scheduled":
        tb = lp.tables
        conv = _fused_conv_scheduled_halo if halo else _fused_conv_scheduled
        y = conv(
            x, tb.idx, tb.sel, tb.vr, tb.vi,
            lp.dfr, lp.dfi, lp.dvr, lp.dvi, bias, shortcut, geo=lp.geo,
            n_out=lp.layer.c_out, flow=tn.flow, block_m=tn.block_m,
            block_p=tn.block_p, relu=lp.epilogue.relu,
            interpret=interpret)
        return res.fault_corrupt("nan_activations", y, **ctx)
    conv = _fused_conv_halo if halo else _fused_conv
    y = conv(x, lp.wr, lp.wi, lp.dfr, lp.dfi, lp.dvr, lp.dvi,
             bias, shortcut, geo=lp.geo, flow=tn.flow,
             block_n=tn.block_n, block_m=tn.block_m,
             block_p=tn.block_p, relu=lp.epilogue.relu,
             interpret=interpret)
    return res.fault_corrupt("nan_activations", y, **ctx)


# ---------------------------------------------------------------------------
# Sharded-band execution (ISSUE 9): uncropped canvas contract
# ---------------------------------------------------------------------------

def _assemble_band_canvas(y: Array, geo: SpectralGeometry, b: int, n: int,
                          t_cnt: int, dtype) -> Array:
    """[t^2, N, B*T] pipeline output -> UNCROPPED [B, N, h_pad, w_pad]
    band canvas (``_assemble_output`` without the 'same' crop — sharded
    bands crop only after cross-shard concatenation)."""
    s2 = geo.tile * geo.tile
    y_tiles = (y.reshape(s2, n, b, t_cnt).transpose(2, 1, 3, 0)
               .reshape(b, n, t_cnt, geo.tile, geo.tile))
    return assemble_tile_canvas(y_tiles.astype(dtype), geo)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "flow", "block_n", "block_m", "block_p",
                     "relu", "interpret"))
def _band_conv(x: Array, wr: Array, wi: Array, dfr: Array, dfi: Array,
               dvr: Array, dvi: Array, bias: Array, *,
               geo: SpectralGeometry, flow: str,
               block_n: int, block_m: int, block_p: int,
               relu: bool, interpret: bool) -> Array:
    """``_fused_conv`` returning the uncropped band canvas."""
    b, m = x.shape[:2]
    n = wr.shape[1]
    xt, t_cnt = _windows_layout(x, geo)
    y = fused_spectral_pipeline(
        xt, wr, wi, dfr, dfi, dvr, dvi, bias, flow=flow,
        block_n=block_n, block_m=block_m, block_p=block_p, relu=relu,
        interpret=interpret)
    return _assemble_band_canvas(y, geo, b, n, t_cnt, x.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "n_out", "flow", "block_m", "block_p",
                     "relu", "interpret"))
def _band_conv_scheduled(x: Array, idx: Array, sel: Array, vr: Array,
                         vi: Array, dfr: Array, dfi: Array, dvr: Array,
                         dvi: Array, bias: Array, *,
                         geo: SpectralGeometry, n_out: int, flow: str,
                         block_m: int, block_p: int,
                         relu: bool, interpret: bool) -> Array:
    """``_fused_conv_scheduled`` returning the uncropped band canvas."""
    b = x.shape[0]
    xt, t_cnt = _windows_layout(x, geo)
    y = fused_spectral_pipeline_scheduled(
        xt, idx, sel, vr, vi, dfr, dfi, dvr, dvi, bias, n_out=n_out,
        flow=flow, block_m=block_m, block_p=block_p, relu=relu,
        interpret=interpret)
    return _assemble_band_canvas(y, geo, b, n_out, t_cnt, x.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "flow", "block_n", "block_m", "block_p",
                     "relu", "interpret"))
def _band_conv_halo(x: Array, wr: Array, wi: Array, dfr: Array,
                    dfi: Array, dvr: Array, dvi: Array, bias: Array, *,
                    geo: SpectralGeometry, flow: str,
                    block_n: int, block_m: int, block_p: int,
                    relu: bool, interpret: bool) -> Array:
    """``_fused_conv_halo`` returning the uncropped band canvas: the
    halo pipeline already assembles tiles in canvas order, so the band
    contract is the channel/padding crop WITHOUT the 'same' slice."""
    n = wr.shape[1]
    hg = halo_block_geometry(geo, block_p)
    y = fused_spectral_pipeline_halo(
        x, wr, wi, dfr, dfi, dvr, dvi, bias, geo=geo, hg=hg, flow=flow,
        block_n=block_n, block_m=block_m, relu=relu, interpret=interpret)
    return y[:, :n, :geo.h_pad, :geo.w_pad].astype(x.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("geo", "n_out", "flow", "block_m", "block_p",
                     "relu", "interpret"))
def _band_conv_scheduled_halo(x: Array, idx: Array, sel: Array,
                              vr: Array, vi: Array, dfr: Array,
                              dfi: Array, dvr: Array, dvi: Array,
                              bias: Array, *, geo: SpectralGeometry,
                              n_out: int, flow: str, block_m: int,
                              block_p: int, relu: bool,
                              interpret: bool) -> Array:
    """``_fused_conv_scheduled_halo`` returning the uncropped band
    canvas."""
    hg = halo_block_geometry(geo, block_p)
    y = fused_spectral_pipeline_scheduled_halo(
        x, idx, sel, vr, vi, dfr, dfi, dvr, dvi, bias, geo=geo, hg=hg,
        n_out=n_out, flow=flow, block_m=block_m, relu=relu,
        interpret=interpret)
    return y[:, :n_out, :geo.h_pad, :geo.w_pad].astype(x.dtype)


def execute_band_plan(x_ext: Array, lp, *, interpret: bool | None = None
                      ) -> Array:
    """Run one conv layer's SHARD-LOCAL band from a per-shard
    ``core.plan.LayerPlan`` whose geometry is a ``make_band_geometry``
    result (pre_halo_h = k-1).

    ``x_ext`` is the extended band [B, M, (k-1) + tr*t, W] — the shard's
    raw rows prefixed by the halo rows its mesh neighbour sent
    (``lax.ppermute`` inside the sharded executor; zeros on shard 0).
    Returns the UNCROPPED band canvas [B, N, tr*t, w_pad]: the 'same'
    crop is global, so it runs after the shards' canvases are
    concatenated (``spectral.crop_canvas_same``).  Same fault sites and
    dispatch as ``execute_layer_plan``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    tn = lp.tuning
    halo = getattr(lp, "input_mode", "windowed") == "halo"
    ctx = dict(layer=lp.layer.name, backend="fused", flow=tn.flow,
               hadamard=getattr(lp, "hadamard", None),
               input_mode=getattr(lp, "input_mode", "windowed"))
    res.fault_check("lowering", **ctx)
    res.fault_check("vmem_overflow", **ctx)
    bias = lp.bias if lp.epilogue.bias else jnp.zeros_like(lp.bias)
    if getattr(lp, "hadamard", None) == "scheduled":
        tb = lp.tables
        conv = _band_conv_scheduled_halo if halo else _band_conv_scheduled
        y = conv(
            x_ext, tb.idx, tb.sel, tb.vr, tb.vi,
            lp.dfr, lp.dfi, lp.dvr, lp.dvi, bias, geo=lp.geo,
            n_out=lp.layer.c_out, flow=tn.flow, block_m=tn.block_m,
            block_p=tn.block_p, relu=lp.epilogue.relu,
            interpret=interpret)
        return res.fault_corrupt("nan_activations", y, **ctx)
    conv = _band_conv_halo if halo else _band_conv
    y = conv(x_ext, lp.wr, lp.wi, lp.dfr, lp.dfi, lp.dvr, lp.dvi,
             bias, geo=lp.geo, flow=tn.flow,
             block_n=tn.block_n, block_m=tn.block_m,
             block_p=tn.block_p, relu=lp.epilogue.relu,
             interpret=interpret)
    return res.fault_corrupt("nan_activations", y, **ctx)
