"""Public jit'd wrappers over the Pallas kernels.

``interpret`` defaults to True unless running on a real TPU backend —
the kernels are written for TPU (BlockSpec VMEM tiling, MXU-shaped
matmuls) and validated on CPU via the Pallas interpreter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.scheduler import SCHEDULERS, build_tables
from repro.core.spectral import (SpectralGeometry, assemble_valid_tiles,
                                 extract_tiles_overlapping, make_geometry)
from repro.kernels import fft8, flash_attention as fa, ref
from repro.kernels import sparse_hadamard as sh
from repro.kernels import spectral_hadamard as shad

Array = jax.Array


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def hadamard(w_f: Array, x_f: Array, *, flow: str = "output_stationary",
             block_n: int = 128, block_m: int = 128, block_p: int = 128,
             interpret: bool | None = None) -> Array:
    """Eq 3 via the Pallas kernel.

    w_f: complex [N, M, K, K];  x_f: complex [B, M, T, K, K]
    returns complex [B, N, T, K, K].
    """
    if interpret is None:
        interpret = default_interpret()
    b, m, t, kk, _ = x_f.shape
    n = w_f.shape[0]
    f = kk * kk
    wr = jnp.transpose(w_f.real.reshape(n, m, f), (2, 0, 1))
    wi = jnp.transpose(w_f.imag.reshape(n, m, f), (2, 0, 1))
    x = x_f.reshape(b, m, t, f)
    xr = jnp.transpose(x.real, (3, 1, 0, 2)).reshape(f, m, b * t)
    xi = jnp.transpose(x.imag, (3, 1, 0, 2)).reshape(f, m, b * t)
    yr, yi = shad.spectral_hadamard(
        wr.astype(jnp.float32), wi.astype(jnp.float32),
        xr.astype(jnp.float32), xi.astype(jnp.float32),
        flow=flow, block_n=block_n, block_m=block_m, block_p=block_p,
        interpret=interpret)
    y = (yr + 1j * yi).reshape(f, n, b, t)
    return jnp.transpose(y, (2, 1, 3, 0)).reshape(b, n, t, kk, kk)


def spectral_conv2d_pallas(x: Array, w_f: Array, geo: SpectralGeometry, *,
                           flow: str = "output_stationary",
                           interpret: bool | None = None) -> Array:
    """Full spectral conv forward on the Pallas path:
    fft8 -> spectral_hadamard -> fft8(inverse) -> valid-tile assembly.

    Overlap-save tiling, matching ``spectral_conv2d_pretransformed`` —
    the three pallas_calls round-trip spectral planes through HBM (the
    traffic the fused kernel eliminates) but compute the same function.
    """
    if interpret is None:
        interpret = default_interpret()
    b, m = x.shape[:2]
    n = w_f.shape[0]
    windows = extract_tiles_overlapping(x, geo)                 # [B,M,T,K,K]
    t = windows.shape[2]
    kk = geo.fft_size
    flat = windows.reshape(b * m * t, kk, kk)
    xr, xi = fft8.fft2_tiles(flat, fft_size=kk, interpret=interpret)
    x_f = (xr + 1j * xi).reshape(b, m, t, kk, kk)
    y_f = hadamard(w_f, x_f, flow=flow, interpret=interpret)
    y_flat = y_f.reshape(b * n * t, kk, kk)
    y_sp = fft8.ifft2_tiles(y_flat.real.astype(jnp.float32),
                            y_flat.imag.astype(jnp.float32),
                            interpret=interpret)
    ov = geo.ksize - 1
    y_tiles = y_sp.reshape(b, n, t, kk, kk)[..., ov:, ov:]
    return assemble_valid_tiles(y_tiles.astype(x.dtype), geo)


def scheduled_sparse_conv_group(sk_values, sk_indices, x_f: Array, *,
                                r: int = 10, method: str = "exact_cover",
                                interpret: bool | None = None
                                ) -> tuple[Array, dict]:
    """Sparse Hadamard for ONE group of N' kernels across all channels,
    executed through the exact-cover schedule's INDEX/VALUE tables.

    sk_values: complex [N', M, K, K]; sk_indices: int [N', M, nnz];
    x_f: complex [B=1 folded, M, T, K, K] -> returns [N', T, K, K] complex
    plus schedule stats.
    """
    import numpy as np
    if interpret is None:
        interpret = default_interpret()
    n_pe, m, kk, _ = sk_values.shape
    f = kk * kk
    vals = np.asarray(sk_values).reshape(n_pe, m, f)
    idx = np.asarray(sk_indices)
    fn = SCHEDULERS[method]
    tables = []
    cycles = 0
    ops = 0
    for mm in range(m):
        s = fn(idx[:, mm, :], f, r)
        tables.append(build_tables(s, vals[:, mm, :], idx[:, mm, :]))
        cycles += s.n_cycles
        ops += s.total_ops
    packed = sh.stack_tables(tables)

    b, _, t = x_f.shape[:3]
    assert b == 1
    x = x_f.reshape(m, t, f)
    xr = jnp.transpose(x.real, (0, 2, 1)).astype(jnp.float32)  # [M,F,T]
    xi = jnp.transpose(x.imag, (0, 2, 1)).astype(jnp.float32)
    yr, yi = sh.scheduled_sparse_hadamard(*packed, xr, xi,
                                          interpret=interpret)
    y = (yr + 1j * yi)                                          # [N',F,T]
    y = jnp.transpose(y, (0, 2, 1)).reshape(n_pe, t, kk, kk)
    stats = {"cycles": cycles, "ops": ops,
             "utilization": ops / max(1, cycles * n_pe)}
    return y, stats


def attention(q: Array, k: Array, v: Array, *, causal: bool = True,
              window: int | None = None, block_q: int = 128,
              block_k: int = 128, interpret: bool | None = None) -> Array:
    if interpret is None:
        interpret = default_interpret()
    return fa.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
