"""Pallas TPU kernel: 2-D (I)FFT of small tiles as DFT matmuls.

For the paper's tile sizes (K = 8 or 16) an FFT butterfly network is the
wrong tool on TPU — the MXU prefers the dense DFT form

    Y = W X W^T,      W[j, k] = exp(-2*pi*i*j*k / K)

which for a batch of B tiles is a pair of small GEMMs packed as
[K, B*K] matrices.  The forward transform maps real tiles to complex
(re, im) planes; the inverse returns the real part only (the spectral
conv consumes Re(IFFT)).

This replaces the FPGA's dedicated 2-D FFT pipeline stage (paper Fig 1)
with MXU work that fuses into the Hadamard stage's pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

Array = jax.Array


def dft_matrices(fft_size: int) -> tuple[np.ndarray, np.ndarray]:
    """Real/imag parts of the DFT matrix W = exp(-2 pi i jk / K)."""
    j, k = np.meshgrid(np.arange(fft_size), np.arange(fft_size),
                       indexing="ij")
    theta = 2.0 * np.pi * j * k / fft_size
    return (np.cos(theta).astype(np.float32),
            (-np.sin(theta)).astype(np.float32))


def _fft_kernel(x_ref, cr_ref, ci_ref, yr_ref, yi_ref, *, k: int, bb: int):
    x = x_ref[...].reshape(bb * k, k)          # [B*K, K]
    cr, ci = cr_ref[...], ci_ref[...]
    # stage 1: A = X @ W^T   (X real)
    ar = jnp.dot(x, cr.T, preferred_element_type=jnp.float32)
    ai = jnp.dot(x, ci.T, preferred_element_type=jnp.float32)
    # stage 2: Y = W @ A  per tile; pack as [K, B*K]
    ar = ar.reshape(bb, k, k).transpose(1, 0, 2).reshape(k, bb * k)
    ai = ai.reshape(bb, k, k).transpose(1, 0, 2).reshape(k, bb * k)
    yr = (jnp.dot(cr, ar, preferred_element_type=jnp.float32)
          - jnp.dot(ci, ai, preferred_element_type=jnp.float32))
    yi = (jnp.dot(cr, ai, preferred_element_type=jnp.float32)
          + jnp.dot(ci, ar, preferred_element_type=jnp.float32))
    yr_ref[...] = yr.reshape(k, bb, k).transpose(1, 0, 2)
    yi_ref[...] = yi.reshape(k, bb, k).transpose(1, 0, 2)


def _ifft_kernel(xr_ref, xi_ref, vr_ref, vi_ref, y_ref, *, k: int, bb: int):
    xr = xr_ref[...].reshape(bb * k, k)
    xi = xi_ref[...].reshape(bb * k, k)
    vr, vi = vr_ref[...], vi_ref[...]
    # stage 1: A = X @ V^T  (X complex)
    ar = (jnp.dot(xr, vr.T, preferred_element_type=jnp.float32)
          - jnp.dot(xi, vi.T, preferred_element_type=jnp.float32))
    ai = (jnp.dot(xr, vi.T, preferred_element_type=jnp.float32)
          + jnp.dot(xi, vr.T, preferred_element_type=jnp.float32))
    ar = ar.reshape(bb, k, k).transpose(1, 0, 2).reshape(k, bb * k)
    ai = ai.reshape(bb, k, k).transpose(1, 0, 2).reshape(k, bb * k)
    # stage 2: y = Re(V @ A)
    y = (jnp.dot(vr, ar, preferred_element_type=jnp.float32)
         - jnp.dot(vi, ai, preferred_element_type=jnp.float32))
    y_ref[...] = y.reshape(k, bb, k).transpose(1, 0, 2)


def _pad_batch(x: Array, bb: int) -> tuple[Array, int]:
    b = x.shape[0]
    rem = (-b) % bb
    if rem:
        x = jnp.pad(x, ((0, rem),) + ((0, 0),) * (x.ndim - 1))
    return x, b


@functools.partial(jax.jit, static_argnames=("fft_size", "block_b",
                                              "interpret"))
def fft2_tiles(x: Array, *, fft_size: int, block_b: int = 256,
               interpret: bool = True) -> tuple[Array, Array]:
    """[B, t, t] real tiles (t <= K, zero-padded here) -> [B, K, K] planes."""
    k = fft_size
    b, t, _ = x.shape
    if t < k:
        x = jnp.pad(x, ((0, 0), (0, k - t), (0, k - t)))
    x, b_orig = _pad_batch(x, block_b)
    grid = (x.shape[0] // block_b,)
    cr, ci = (jnp.asarray(a) for a in dft_matrices(k))
    spec_x = pl.BlockSpec((block_b, k, k), lambda i: (i, 0, 0))
    spec_d = pl.BlockSpec((k, k), lambda i: (0, 0))
    yr, yi = pl.pallas_call(
        functools.partial(_fft_kernel, k=k, bb=block_b),
        grid=grid,
        in_specs=[spec_x, spec_d, spec_d],
        out_specs=[spec_x, spec_x],
        out_shape=[jax.ShapeDtypeStruct(x.shape, jnp.float32)] * 2,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x.astype(jnp.float32), cr, ci)
    return yr[:b_orig], yi[:b_orig]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def ifft2_tiles(yr: Array, yi: Array, *, block_b: int = 256,
                interpret: bool = True) -> Array:
    """[B, K, K] complex planes -> [B, K, K] real (Re of the 2-D IFFT)."""
    k = yr.shape[-1]
    yr, b_orig = _pad_batch(yr, block_b)
    yi, _ = _pad_batch(yi, block_b)
    cr, ci = dft_matrices(k)
    vr = jnp.asarray(cr / k)
    vi = jnp.asarray(-ci / k)
    grid = (yr.shape[0] // block_b,)
    spec_x = pl.BlockSpec((block_b, k, k), lambda i: (i, 0, 0))
    spec_d = pl.BlockSpec((k, k), lambda i: (0, 0))
    y = pl.pallas_call(
        functools.partial(_ifft_kernel, k=k, bb=block_b),
        grid=grid,
        in_specs=[spec_x, spec_x, spec_d, spec_d],
        out_specs=spec_x,
        out_shape=jax.ShapeDtypeStruct(yr.shape, jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(yr.astype(jnp.float32), yi.astype(jnp.float32), vr, vi)
    return y[:b_orig]
