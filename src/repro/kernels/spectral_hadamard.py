"""Pallas TPU kernel: frequency-binned batched complex GEMM (Eq 3).

The Hadamard-accumulate stage of a spectral conv layer is, per frequency
bin f:

    Y[f, n, p] = sum_m W[f, n, m] * X[f, m, p]          (complex)

i.e. a batch (over the K^2 frequency bins) of complex GEMMs contracting
input channels.  This is the TPU-native re-derivation of the paper's PE
array: on the FPGA each (kernel n, tile p) pair owns a scalar MAC PE and
channels stream serially (M' = 1) to avoid BRAM write conflicts; on TPU
the MXU wants the channel contraction inside the systolic array, so we
tile (n, p) across the grid and contract m in VMEM.

The paper's three dataflows map onto grid iteration orders (which operand
block stays resident in VMEM between grid steps):

  * ``output_stationary`` (= Flow-opt psum reuse): grid (F, n, p, m) with
    the contraction innermost; a float32 VMEM scratch accumulates the psum
    and HBM sees each output exactly once.
  * ``weight_stationary``  (= Flow #1, reuse kernels): grid (F, n, m, p);
    the W block's index map is constant in the inner p loop so Pallas keeps
    it resident, but psums must be read-modified-written in HBM once per m
    block — the Flow #3-like psum traffic the paper warns about.
  * ``input_stationary``   (= Flow #2, reuse activations): grid (F, p, m, n);
    X block resident across the n loop, same psum traffic.

Complex arithmetic uses the 3-multiplication Karatsuba form (real MXU
passes): m1 = ar.br, m2 = ai.bi, m3 = (ar+ai)(br+bi);
re = m1 - m2, im = m3 - m1 - m2.

Layouts are F-leading so the two minor dims of every block are the GEMM
dims (hardware-tileable 8x128 / 128x128):
  W: [F, N, M]   X: [F, M, P]   Y: [F, N, P]   (real+imag planes)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.core.dataflow import FLOWS

Array = jax.Array


def _karatsuba(wr, wi, xr, xi):
    m1 = jnp.dot(wr, xr, preferred_element_type=jnp.float32)
    m2 = jnp.dot(wi, xi, preferred_element_type=jnp.float32)
    m3 = jnp.dot(wr + wi, xr + xi, preferred_element_type=jnp.float32)
    return m1 - m2, m3 - m1 - m2


def _kernel_os(wr_ref, wi_ref, xr_ref, xi_ref, yr_ref, yi_ref,
               acc_r, acc_i, *, n_m_blocks: int):
    """Output-stationary: accumulate over the innermost m grid dim."""
    gm = pl.program_id(3)

    @pl.when(gm == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    re, im = _karatsuba(wr_ref[0], wi_ref[0], xr_ref[0], xi_ref[0])
    acc_r[...] += re
    acc_i[...] += im

    @pl.when(gm == n_m_blocks - 1)
    def _flush():
        yr_ref[0] = acc_r[...]
        yi_ref[0] = acc_i[...]


def _kernel_rmw(wr_ref, wi_ref, xr_ref, xi_ref, yr_ref, yi_ref, *,
                m_axis: int):
    """Weight/input-stationary: psums read-modify-written across m blocks."""
    gm = pl.program_id(m_axis)
    re, im = _karatsuba(wr_ref[0], wi_ref[0], xr_ref[0], xi_ref[0])

    @pl.when(gm == 0)
    def _first():
        yr_ref[0] = re
        yi_ref[0] = im

    @pl.when(gm > 0)
    def _rest():
        yr_ref[0] += re
        yi_ref[0] += im


def _pad_to(x: Array, axis: int, mult: int) -> Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("flow", "block_n", "block_m", "block_p", "interpret"))
def spectral_hadamard(wr: Array, wi: Array, xr: Array, xi: Array, *,
                      flow: str = "output_stationary",
                      block_n: int = 128, block_m: int = 128,
                      block_p: int = 128,
                      interpret: bool = True) -> tuple[Array, Array]:
    """Batched complex GEMM  Y[f,n,p] = sum_m W[f,n,m] X[f,m,p].

    wr/wi: [F, N, M], xr/xi: [F, M, P].  Returns (yr, yi): [F, N, P] f32.
    """
    if flow not in FLOWS:
        raise ValueError(f"flow must be one of {FLOWS}")
    f, n, m = wr.shape
    _, _, p = xr.shape
    bn, bm, bp = min(block_n, n), min(block_m, m), min(block_p, p)

    wr_, wi_ = (_pad_to(_pad_to(a, 1, bn), 2, bm) for a in (wr, wi))
    xr_, xi_ = (_pad_to(_pad_to(a, 1, bm), 2, bp) for a in (xr, xi))
    np_, mp_, pp_ = wr_.shape[1], wr_.shape[2], xr_.shape[2]
    gn, gm_, gp = np_ // bn, mp_ // bm, pp_ // bp

    out_shape = [jax.ShapeDtypeStruct((f, np_, pp_), jnp.float32)] * 2

    if flow == "output_stationary":
        grid = (f, gn, gp, gm_)
        w_map = lambda gf, a, b, c: (gf, a, c)
        x_map = lambda gf, a, b, c: (gf, c, b)
        y_map = lambda gf, a, b, c: (gf, a, b)
        kernel = functools.partial(_kernel_os, n_m_blocks=gm_)
        scratch = [pltpu.VMEM((bn, bp), jnp.float32)] * 2
        semantics = ("arbitrary", "parallel", "parallel", "arbitrary")
    elif flow == "weight_stationary":
        grid = (f, gn, gm_, gp)
        w_map = lambda gf, a, c, b: (gf, a, c)
        x_map = lambda gf, a, c, b: (gf, c, b)
        y_map = lambda gf, a, c, b: (gf, a, b)
        kernel = functools.partial(_kernel_rmw, m_axis=2)
        scratch = []
        semantics = ("arbitrary", "parallel", "arbitrary", "arbitrary")
    else:  # input_stationary
        grid = (f, gp, gm_, gn)
        w_map = lambda gf, b, c, a: (gf, a, c)
        x_map = lambda gf, b, c, a: (gf, c, b)
        y_map = lambda gf, b, c, a: (gf, a, b)
        kernel = functools.partial(_kernel_rmw, m_axis=2)
        scratch = []
        semantics = ("arbitrary", "parallel", "arbitrary", "arbitrary")

    w_spec = pl.BlockSpec((1, bn, bm), w_map)
    x_spec = pl.BlockSpec((1, bm, bp), x_map)
    y_spec = pl.BlockSpec((1, bn, bp), y_map)

    yr, yi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[w_spec, w_spec, x_spec, x_spec],
        out_specs=[y_spec, y_spec],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
    )(wr_, wi_, xr_, xi_)
    return yr[:, :n, :p], yi[:, :n, :p]
