"""Pallas TPU kernel: blocked online-softmax (flash) attention.

LM-pillar hot spot for the prefill shapes (32 k tokens).  Standard
single-pass streaming softmax: grid (batch*heads, q_blocks, kv_blocks)
with the kv loop innermost; running (max, denom, acc) state in VMEM
scratch; causal and sliding-window masks applied from global indices.

GQA is handled in the BlockSpec index maps — the K/V block index maps
divide the head index by the group size, so no materialized KV repeat.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

Array = jax.Array

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 block_q: int, block_k: int, n_kv_blocks: int):
    gq = pl.program_id(1)
    gk = pl.program_id(2)

    @pl.when(gk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0].astype(jnp.float32)            # [bk, d]
    v = v_ref[0].astype(jnp.float32)            # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_idx = gq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_idx = gk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # fully-masked rows: keep them inert (exp(NEG_INF - NEG_INF) = 1 trap)
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(gk == n_kv_blocks - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                              "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> Array:
    """q: [B, Hq, S, D], k/v: [B, Hkv, S, D] with Hq % Hkv == 0."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(block_q, s)
    bk = min(block_k, s)
    s_pad = -(-s // max(bq, bk)) * max(bq, bk)
    if s_pad != s:
        pad = ((0, 0), (0, 0), (0, s_pad - s), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    # padded KV rows must never be attended to: they sit at indices >= s and
    # a query at index < s is protected by the causal mask; for non-causal
    # use we mask below via window=None + causal=False only with s == s_pad.
    if not causal and s_pad != s:
        raise NotImplementedError("non-causal requires s % block == 0")

    qf = q.reshape(b * hq, s_pad, d)
    kf = k.reshape(b * hkv, s_pad, d)
    vf = v.reshape(b * hkv, s_pad, d)
    n_q, n_k = s_pad // bq, s_pad // bk

    q_spec = pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))
    kv_spec = pl.BlockSpec((1, bk, d), lambda h, i, j: (h // group, j, 0))
    o_spec = pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))

    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=d ** -0.5, causal=causal,
                          window=window, block_q=bq, block_k=bk,
                          n_kv_blocks=n_k),
        grid=(b * hq, n_q, n_k),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b * hq, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, s_pad, d)[:, :, :s, :]
