"""Pallas TPU kernel executing the exact-cover schedule's INDEX/VALUE tables.

This is the TPU datapath for the paper's Fig 6 storage layout.  For one
group of N' sparse kernels, the scheduler (repro.core.scheduler) emits per
input channel m a table of T cycles:

  index_table[m, t, :]  r replica read addresses (frequency indices),
  sel[m, t, n]          which replica column feeds PE n,
  valid[m, t, n]        whether PE n is active,
  val_{r,i}[m, t, n]    the complex weight fed to PE n,
  out_index[m, t, n]    frequency bin PE n accumulates into.

On the FPGA each cycle performs r BRAM reads, a sel crossbar, N' scalar
MACs and a scatter into the psum buffer.  On TPU we execute the *same
tables* with MXU-native one-hot matmuls (gather == one-hot x X, routing ==
one-hot x gathered, scatter == outer product with the out-index one-hot),
vectorized over P parallel tiles and accumulated over channels in VMEM —
so the schedule's utilization win (T ~= nnz / (mu N') cycles instead of
K^2) becomes a work reduction rather than a port-conflict fix (DESIGN.md
hardware-adaptation notes).

Shapes:
  index_table int32 [M, T, r]; sel int32 [M, T, N']; valid f32 [M, T, N'];
  val_r/val_i f32 [M, T, N']; out_index int32 [M, T, N'];
  xr/xi f32 [M, F, P]   ->   yr/yi f32 [N', F, P]   (summed over M, T).

Since PR 4 the same datapath also runs INSIDE the fused conv kernel
(``fused_spectral_conv.fused_spectral_pipeline_scheduled``, Hadamard
mode 'scheduled'), between the in-kernel tile-FFT and IFFT/epilogue and
without the ``valid``/``out_index`` planes (see ``scheduler.LayerTables``).
This standalone kernel remains the direct Fig-6 table executor for an
externally-provided spectral input.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

from repro.core.scheduler import ScheduleTables

Array = jax.Array


def _kernel(idx_ref, sel_ref, valid_ref, vr_ref, vi_ref, oidx_ref,
            xr_ref, xi_ref, yr_ref, yi_ref, acc_r, acc_i, *,
            n_cycles: int, n_channels: int, n_pe: int, f_dim: int, r: int):
    gm = pl.program_id(1)

    @pl.when(gm == 0)
    def _init():
        acc_r[...] = jnp.zeros_like(acc_r)
        acc_i[...] = jnp.zeros_like(acc_i)

    xr = xr_ref[0]            # [F, bp]
    xi = xi_ref[0]
    f_iota = jax.lax.broadcasted_iota(jnp.int32, (1, f_dim), 1)
    r_iota = jax.lax.broadcasted_iota(jnp.int32, (1, r), 1)

    def body(t, carry):
        ar, ai = carry
        # gather: one-hot [r, F] @ X [F, bp] -> replicas [r, bp]
        g = (idx_ref[0, t][:, None] == f_iota).astype(jnp.float32)
        rep_r = jnp.dot(g, xr, preferred_element_type=jnp.float32)
        rep_i = jnp.dot(g, xi, preferred_element_type=jnp.float32)
        # route: one-hot [N', r] @ replicas -> per-PE input [N', bp]
        s = (sel_ref[0, t][:, None] == r_iota).astype(jnp.float32)
        in_r = jnp.dot(s, rep_r, preferred_element_type=jnp.float32)
        in_i = jnp.dot(s, rep_i, preferred_element_type=jnp.float32)
        # complex MAC, masked by valid
        v = valid_ref[0, t][:, None]
        wr = vr_ref[0, t][:, None]
        wi = vi_ref[0, t][:, None]
        pr = v * (wr * in_r - wi * in_i)
        pi = v * (wr * in_i + wi * in_r)
        # scatter: outer product with out-index one-hot [N', F]
        o = (oidx_ref[0, t][:, None] == f_iota).astype(jnp.float32)
        ar = ar + o[:, :, None] * pr[:, None, :]
        ai = ai + o[:, :, None] * pi[:, None, :]
        return ar, ai

    ar, ai = jax.lax.fori_loop(0, n_cycles, body, (acc_r[...], acc_i[...]))
    acc_r[...] = ar
    acc_i[...] = ai

    @pl.when(gm == n_channels - 1)
    def _flush():
        yr_ref[...] = acc_r[...]
        yi_ref[...] = acc_i[...]


@functools.partial(jax.jit,
                   static_argnames=("block_p", "interpret"))
def scheduled_sparse_hadamard(index_table: Array, sel: Array, valid: Array,
                              val_r: Array, val_i: Array, out_index: Array,
                              xr: Array, xi: Array, *,
                              block_p: int = 128,
                              interpret: bool = True
                              ) -> tuple[Array, Array]:
    m, t, r = index_table.shape
    n_pe = sel.shape[2]
    _, f, p = xr.shape
    bp = min(block_p, p)
    rem = (-p) % bp
    if rem:
        xr = jnp.pad(xr, ((0, 0), (0, 0), (0, rem)))
        xi = jnp.pad(xi, ((0, 0), (0, 0), (0, rem)))
    gp = xr.shape[2] // bp

    tab_spec = lambda shape: pl.BlockSpec(
        (1,) + shape, lambda gpp, gm: (gm,) + (0,) * len(shape))
    x_spec = pl.BlockSpec((1, f, bp), lambda gpp, gm: (gm, 0, gpp))
    y_spec = pl.BlockSpec((n_pe, f, bp), lambda gpp, gm: (0, 0, gpp))

    kern = functools.partial(_kernel, n_cycles=t, n_channels=m,
                             n_pe=n_pe, f_dim=f, r=r)
    yr, yi = pl.pallas_call(
        kern,
        grid=(gp, m),
        in_specs=[tab_spec((t, r)), tab_spec((t, n_pe)), tab_spec((t, n_pe)),
                  tab_spec((t, n_pe)), tab_spec((t, n_pe)),
                  tab_spec((t, n_pe)), x_spec, x_spec],
        out_specs=[y_spec, y_spec],
        out_shape=[jax.ShapeDtypeStruct((n_pe, f, xr.shape[2]),
                                        jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((n_pe, f, bp), jnp.float32)] * 2,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(index_table, sel, valid.astype(jnp.float32), val_r, val_i,
      out_index, xr, xi)
    return yr, yi


def stack_tables(tables: list[ScheduleTables]
                 ) -> tuple[Array, Array, Array, Array, Array, Array]:
    """Stack per-channel ScheduleTables, padding to the max cycle count
    (padded cycles have valid == 0 and are inert)."""
    t_max = max(tb.n_cycles for tb in tables)
    n = tables[0].sel.shape[1]
    r = tables[0].index_table.shape[1]

    def pad(a, rows):
        return np.pad(a, ((0, rows - a.shape[0]),) + ((0, 0),) * (a.ndim - 1))

    idx = np.stack([pad(tb.index_table, t_max) for tb in tables])
    sel = np.stack([pad(tb.sel, t_max) for tb in tables])
    valid = np.stack([pad(tb.valid, t_max) for tb in tables])
    vals = np.stack([pad(tb.values, t_max) for tb in tables])
    oidx = np.stack([pad(tb.out_index, t_max) for tb in tables])
    return (jnp.asarray(idx, jnp.int32), jnp.asarray(sel, jnp.int32),
            jnp.asarray(valid, jnp.float32),
            jnp.asarray(vals.real, jnp.float32),
            jnp.asarray(vals.imag, jnp.float32),
            jnp.asarray(oidx, jnp.int32))
