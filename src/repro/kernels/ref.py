"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def spectral_hadamard_ref(wr: Array, wi: Array, xr: Array, xi: Array
                          ) -> tuple[Array, Array]:
    """Y[f,n,p] = sum_m W[f,n,m] X[f,m,p]  (complex, f32 planes)."""
    w = wr.astype(jnp.float32) + 1j * wi.astype(jnp.float32)
    x = xr.astype(jnp.float32) + 1j * xi.astype(jnp.float32)
    y = jnp.einsum("fnm,fmp->fnp", w, x)
    return y.real, y.imag


def sparse_hadamard_ref(values: Array, mask: Array, xr: Array, xi: Array
                        ) -> tuple[Array, Array]:
    """Masked dense Hadamard for one channel: out[n,f,p] = W[n,f]*X[f,p].

    values: complex [N, F] (zeros off-pattern), x: [F, P] planes.
    """
    w = values * mask
    x = xr.astype(jnp.float32) + 1j * xi.astype(jnp.float32)
    y = w[:, :, None] * x[None, :, :]
    return y.real, y.imag


def fft2_tiles_ref(tiles: Array, fft_size: int) -> tuple[Array, Array]:
    """2-D FFT of zero-padded square tiles: [..., t, t] -> [..., K, K]."""
    pad = fft_size - tiles.shape[-1]
    tiles = jnp.pad(tiles,
                    [(0, 0)] * (tiles.ndim - 2) + [(0, pad), (0, pad)])
    y = jnp.fft.fft2(tiles.astype(jnp.float32))
    return y.real.astype(jnp.float32), y.imag.astype(jnp.float32)


def ifft2_tiles_ref(yr: Array, yi: Array) -> Array:
    """Real part of the 2-D inverse FFT."""
    return jnp.fft.ifft2(yr + 1j * yi).real.astype(jnp.float32)


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                  window: int | None = None, scale: float | None = None
                  ) -> Array:
    """[B, H, S, D] attention oracle with optional sliding window."""
    s = q.shape[2]
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((s, k.shape[2]), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
