"""Deterministic fault injection for the resilience layer.

The degradation ladder (``core.resilience``) exists for failures we
cannot reproduce off-hardware: Mosaic lowering errors, VMEM
RESOURCE_EXHAUSTED, silently corrupted Alg-2 tables.  This module makes
those failures *reproducible*: ``inject(site, ...)`` installs a fault at
one of the named sites production code consults
(``resilience.fault_check`` / ``fault_corrupt``), so tests drive every
edge of the ladder with plain CPU runs.

Sites (``FAULT_SITES``):

  'lowering'        raise at kernel dispatch — simulates a Mosaic
                    lowering/compile failure of the chosen variant.
                    Match kwargs (e.g. ``input_mode='halo'``,
                    ``hadamard='scheduled'``, ``backend='fused'``)
                    restrict which variants fail, selecting WHICH rung
                    of the ladder the probe exercises.
  'vmem_overflow'   raise at kernel dispatch with a RESOURCE_EXHAUSTED-
                    style RuntimeError — simulates the VMEM OOM real
                    hardware produces for over-budget blocks.
  'oob_index'       corrupt the Alg-2 INDEX table during
                    ``scheduler.compile_layer_tables`` (an entry pushed
                    far out of the active-bin range) — must be caught
                    by plan validation at BUILD time.
  'corrupt_value'   corrupt the Alg-2 VALUE plane (finite but wrong) —
                    invisible to static validation, caught by the
                    runtime parity guard.
  'nan_activations' corrupt a fused layer's output with a NaN — caught
                    by the runtime NaN/Inf scan.
  'shard_tables'    shard-scoped fault in a SHARDED plan (match on
                    ``shard=<int>``, ``layer=...``, ``strategy=...``).
                    Raise-site by default (one shard's operands fail to
                    stage); pass ``corrupt=`` to mutate that shard's
                    staged Alg-2 tables instead.  Consulted host-side —
                    operand staging in ``distributed.executor`` and the
                    probe in ``resilience.harden_sharded_plan`` — so an
                    injected shard fault surfaces as a structured
                    demotion BEFORE any device enters a collective
                    (never as a mesh hang).

Serve-level sites (consulted by ``launch.spectral_serve``):

  'serve_kernel'     raise at batch dispatch inside the serving loop —
                     a kernel fault mid-request.  Match on
                     ``backend='fused'|'staged'|'einsum'`` (the ladder
                     rung being attempted) and/or ``bucket=<int>``.
                     Drives the per-backend circuit breaker and the
                     in-batch retry a rung down.
  'serve_plan_cache' corrupt the NetworkPlan fetched from the serving
                     plan cache (default: one scheduled layer's Alg-2
                     INDEX table pushed out of range via
                     ``corrupt_plan_tables`` — the plan must contain a
                     scheduled layer, e.g. built with
                     ``hadamard='scheduled'``).  The server must catch
                     it with ``validate_plan`` on fetch and serve via
                     the einsum terminal rung (which never reads the
                     tables) — never execute it silently.
  'serve_slow'       add ``SLOW_EXTRA_S`` seconds to a batch's service
                     time (advancing the server's virtual clock when it
                     has one) — creates deadline pressure without
                     wall-clock sleeps.

Usage::

    from repro.testing import faults

    with faults.inject("lowering", input_mode="halo") as fault:
        plan = resilience.harden_network_plan(plan)   # halo -> windowed
    assert fault.fires > 0

Faults are matched on the call-site context and removed when the
context manager exits; nesting composes (all active faults are
consulted).  Everything is deterministic — no randomness, no wall
clock.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Iterator

import numpy as np

from repro.core import resilience as res

FAULT_SITES = res.FAULT_SITES

# A value far outside any active-bin range (K^2 <= 64 in this repo).
OOB_INDEX = 1_000_000
# Injected extra service seconds for 'serve_slow' — large relative to
# any test deadline, small enough that a soak stays fast.
SLOW_EXTRA_S = 0.25
# Finite perturbation of one VALUE entry: large enough that the sampled
# parity guard (default tol 1e-4) trips on channel 0, small enough to
# stay finite through the whole net.
VALUE_DELTA = 32.0


def _default_exc(site: str, match: dict) -> Callable[[], Exception]:
    """Raw, un-taxonomized errors — like the real failures they mimic.
    The resilience layer must translate them into structured ones."""
    if site == "vmem_overflow":
        return lambda: RuntimeError(
            "RESOURCE_EXHAUSTED: Ran out of memory in memory space "
            f"vmem (injected fault, match={match})")
    if site == "serve_kernel":
        return lambda: RuntimeError(
            f"kernel fault mid-request (injected fault, match={match})")
    return lambda: RuntimeError(
        f"Mosaic lowering failed (injected fault at {site!r}, "
        f"match={match})")


def _corrupt_oob_index(idx):
    out = np.array(idx, copy=True)
    out.flat[0] = OOB_INDEX
    return out


def _corrupt_value(vr):
    out = np.array(vr, copy=True)
    out.flat[0] += VALUE_DELTA
    return out


def _corrupt_nan(y):
    import jax.numpy as jnp
    return y.at[(0,) * y.ndim].set(jnp.nan)


def _corrupt_served_plan(plan):
    # OOB INDEX corruption: loud to validate_plan, invisible to the
    # einsum rung (which consumes pruned kernels, never the tables) —
    # so the server's corruption fallback stays oracle-exact.
    return corrupt_plan_tables(plan, kind="oob_index")


def _corrupt_slow(dt):
    return float(dt) + SLOW_EXTRA_S


_DEFAULT_CORRUPT = {
    "oob_index": _corrupt_oob_index,
    "corrupt_value": _corrupt_value,
    "nan_activations": _corrupt_nan,
    "serve_plan_cache": _corrupt_served_plan,
    "serve_slow": _corrupt_slow,
}


@contextlib.contextmanager
def inject(site: str, *, exc: Callable[[], Exception] | None = None,
           corrupt: Callable | None = None,
           **match) -> Iterator[res.InjectedFault]:
    """Install one deterministic fault at ``site`` for the duration of
    the ``with`` block.

    ``match`` kwargs restrict the fault to call sites whose context
    carries every key with an equal value (see module doc).  ``exc``
    overrides the raised exception factory for raise-sites;
    ``corrupt`` overrides the value transform for corruption-sites.
    Yields the ``InjectedFault`` so tests can assert ``fault.fires``.
    """
    if site in ("lowering", "vmem_overflow", "serve_kernel"):
        fault = res.InjectedFault(site=site, match=dict(match),
                                  exc=exc or _default_exc(site, match))
    elif site == "shard_tables":
        # dual-use: raise-site unless a corruption transform is given
        if corrupt is not None:
            fault = res.InjectedFault(site=site, match=dict(match),
                                      corrupt=corrupt)
        else:
            fault = res.InjectedFault(site=site, match=dict(match),
                                      exc=exc or _default_exc(site, match))
    elif site in _DEFAULT_CORRUPT:
        fault = res.InjectedFault(site=site, match=dict(match),
                                  corrupt=corrupt or _DEFAULT_CORRUPT[site])
    else:
        raise ValueError(f"unknown fault site {site!r}; must be one of "
                         f"{FAULT_SITES}")
    res.install_fault(fault)
    try:
        yield fault
    finally:
        res.remove_fault(fault)


def corrupt_plan_tables(plan, *, layer: str | None = None,
                        kind: str = "oob_index"):
    """Return a copy of ``plan`` with one scheduled layer's Alg-2 tables
    mutated (``kind`` in 'oob_index' | 'corrupt_value') — for direct
    tests that a corrupted built plan is rejected by ``validate_plan``.
    """
    import dataclasses

    import jax.numpy as jnp

    from repro.core.plan import PlanTables

    mutate = _DEFAULT_CORRUPT[kind]
    new_layers = []
    done = False
    for lp in plan.layers:
        eligible = (lp.tables is not None
                    and (layer is None or lp.layer.name == layer))
        if eligible and not done:
            tb = lp.tables
            if kind == "oob_index":
                tb = PlanTables(jnp.asarray(mutate(tb.idx)), tb.sel,
                                tb.vr, tb.vi)
            else:
                tb = PlanTables(tb.idx, tb.sel,
                                jnp.asarray(mutate(tb.vr)), tb.vi)
            lp = dataclasses.replace(lp, tables=tb)
            done = True
        new_layers.append(lp)
    if not done:
        raise ValueError(f"no scheduled layer matching {layer!r} in plan")
    return dataclasses.replace(plan, layers=tuple(new_layers))


def chaos_soak(*, cfg=None, queue_limit: int = 16, seed: int = 0,
               oracle_tol: float = 1e-5, log=None) -> dict:
    """Deterministic fault-injected soak of ``launch.spectral_serve``.

    Submits >= 4x ``queue_limit`` requests in bursts while walking the
    server through every serve-level fault site on a virtual clock:

      wave 1  2x-capacity burst (excess MUST be shed) with
              ``serve_kernel`` faults on the staged rung — the load
              ladder demotes under queue pressure, the staged breaker
              opens, batches retry a rung down in-flight;
      wave 2  1x burst through a ``serve_plan_cache`` corruption window
              — corrupt plans must be caught on fetch and served via
              the einsum terminal rung;
      wave 3  tight-deadline requests stuck behind a ``serve_slow``
              window — they MUST retire ``deadline_exceeded``, never
              execute late;
      wave 4  clean recovery burst after cooldown — the ladder promotes
              back to fused and serves on it.

    Gates (report ``failed_gates`` must be empty; the serve-bench CI
    job exits nonzero otherwise):

      all_terminal                every request reached a terminal code
      zero_loop_deaths            no tick exception ever killed a loop
      shed_nonzero                overload was shed, not queued
      deadline_exceeded_nonzero   expired requests retired structurally
      demotion_and_promotion      >= 1 load demotion AND >= 1 promotion
      kernel_faults_exercised     the serve_kernel site actually fired
      plan_cache_corruption_exercised / slow_injection_exercised
      recovered_to_fused          final rung is the fast path again
      no_silent_wrong_answers     every 'ok' logits row within
                                  ``oracle_tol`` of the einsum oracle

    Returns the full report dict (gates, stats, health_report).
    """
    import jax.numpy as jnp

    from repro.launch import spectral_serve as ss
    from repro.models import cnn

    if cfg is None:
        from repro.configs import vgg16_spectral
        cfg = vgg16_spectral.SMOKE
    say = log or (lambda *_: None)
    clock = ss.ManualClock()
    srv = ss.SpectralServer(
        cfg, queue_limit=queue_limit, clock=clock, seed=seed,
        plan_kwargs={"hadamard": "scheduled"},
        demote_pressure=0.75, promote_pressure=0.25,
        demote_patience=1, promote_patience=2,
        breaker_failures=2, breaker_cooldown_s=0.5)

    reqs: list = []

    def burst(n: int, deadline_s: float | None = None) -> None:
        wave = ss.synthetic_requests(n, cfg, seed=seed + len(reqs),
                                     deadline_s=deadline_s,
                                     rid0=len(reqs))
        for r in wave:
            srv.submit(r)
        reqs.extend(wave)

    def drive(n: int, dt: float = 0.05) -> None:
        for _ in range(n):
            try:
                srv.tick()
            except Exception as e:        # noqa: BLE001 — soak must live
                srv.loop_deaths += 1
                say(f"loop death: {type(e).__name__}: {e}")
            clock.advance(dt)

    say(f"wave 1: 2x burst ({2 * queue_limit}) + staged kernel faults")
    burst(2 * queue_limit)
    with inject("serve_kernel", backend="staged"):
        drive(2)
    drive(4)  # faults cleared; idle ticks let the ladder promote

    say(f"wave 2: 1x burst ({queue_limit}) + plan-cache corruption")
    burst(queue_limit)
    with inject("serve_plan_cache"):
        drive(1)

    say("wave 3: tight deadlines behind a slow-service window")
    burst(queue_limit // 2, deadline_s=0.01)
    burst(queue_limit // 2)        # clean requests behind the tight ones
    clock.advance(0.05)            # tight deadlines expire while queued
    with inject("serve_slow"):
        drive(1)

    clock.advance(1.0)  # past the breaker cooldown
    srv.run_until_drained(max_ticks=20 * queue_limit)
    for _ in range(8 * srv.promote_patience):
        if srv._load_rung == 0 and not srv.queue:
            break
        drive(1, dt=0.1)

    say(f"wave 4: clean recovery burst ({queue_limit // 2}) on "
        f"rung {ss.SERVE_RUNGS[srv._load_rung]}")
    burst(queue_limit // 2)
    srv.run_until_drained(max_ticks=4 * queue_limit)

    stats = srv.stats()
    health = srv.health_report()

    # oracle parity for every completed answer, pristine plan, einsum
    ok_reqs = [r for r in reqs if r.ok]
    bucket = srv.buckets[-1]
    plan = srv.plans.get(srv.params, cfg, bucket, **srv.plan_kwargs)
    worst = 0.0
    for i in range(0, len(ok_reqs), bucket):
        chunk = ok_reqs[i:i + bucket]
        x = np.zeros((bucket,) + srv.image_shape, np.float32)
        for j, r in enumerate(chunk):
            x[j] = r.image
        ref = np.asarray(cnn.forward_spectral(srv.params, plan,
                                              jnp.asarray(x),
                                              backend="einsum"))
        for j, r in enumerate(chunk):
            worst = max(worst, float(np.max(np.abs(ref[j] - r.logits))))

    c = stats["counters"]
    gates = {
        "all_terminal": all(r.terminal for r in reqs),
        "zero_loop_deaths": stats["loop_deaths"] == 0,
        "shed_nonzero": c["overloaded"] > 0,
        "deadline_exceeded_nonzero": c["deadline_exceeded"] > 0,
        "demotion_and_promotion": (stats["demotions"] >= 1
                                   and stats["promotions"] >= 1),
        "kernel_faults_exercised": c["kernel_faults"] > 0,
        "plan_cache_corruption_exercised":
            c["plan_cache_corruptions"] > 0,
        "slow_injection_exercised": c["slow_injections"] > 0,
        "recovered_to_fused": health["rung"] == "fused",
        "no_silent_wrong_answers": worst <= oracle_tol,
    }
    failed = sorted(k for k, v in gates.items() if not v)
    say(f"{len(reqs)} requests: {c['ok']} ok / {c['overloaded']} shed "
        f"/ {c['deadline_exceeded']} deadline / {c['failed']} failed; "
        f"max |err| {worst:.2e}; failed gates: {failed or 'none'}")
    return {
        "requests": len(reqs),
        "queue_limit": queue_limit,
        "gates": gates,
        "failed_gates": failed,
        "oracle_max_abs_err": worst,
        "oracle_tol": oracle_tol,
        "stats": stats,
        "health": health,
    }


def corrupt_shard_tables(splan, *, layer: str | None = None,
                         shard: int = 0, kind: str = "oob_index"):
    """Return a copy of a ``ShardedNetworkPlan`` with ONE shard's Alg-2
    tables mutated (``kind`` in 'oob_index' | 'corrupt_value') — for
    direct tests that per-shard validation
    (``resilience.validate_sharded_plan``) catches a single rotten
    shard while its siblings stay healthy.
    """
    import dataclasses

    import jax.numpy as jnp

    from repro.core.plan import PlanTables

    mutate = _DEFAULT_CORRUPT[kind]
    new_layers = []
    done = False
    for slp in splan.layers:
        eligible = (not done and len(slp.shards) > shard
                    and slp.shards[shard].tables is not None
                    and (layer is None or slp.base.layer.name == layer))
        if eligible:
            shards = list(slp.shards)
            sh = shards[shard]
            tb = sh.tables
            if kind == "oob_index":
                tb = PlanTables(jnp.asarray(mutate(tb.idx)), tb.sel,
                                tb.vr, tb.vi)
            else:
                tb = PlanTables(tb.idx, tb.sel,
                                jnp.asarray(mutate(tb.vr)), tb.vi)
            shards[shard] = dataclasses.replace(sh, tables=tb)
            slp = dataclasses.replace(slp, shards=tuple(shards))
            done = True
        new_layers.append(slp)
    if not done:
        raise ValueError(
            f"no sharded layer matching layer={layer!r} with tables on "
            f"shard {shard} (build with hadamard='scheduled' and a "
            f"channel/spatial strategy)")
    return dataclasses.replace(splan, layers=tuple(new_layers))
