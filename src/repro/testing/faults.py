"""Deterministic fault injection for the resilience layer.

The degradation ladder (``core.resilience``) exists for failures we
cannot reproduce off-hardware: Mosaic lowering errors, VMEM
RESOURCE_EXHAUSTED, silently corrupted Alg-2 tables.  This module makes
those failures *reproducible*: ``inject(site, ...)`` installs a fault at
one of the named sites production code consults
(``resilience.fault_check`` / ``fault_corrupt``), so tests drive every
edge of the ladder with plain CPU runs.

Sites (``FAULT_SITES``):

  'lowering'        raise at kernel dispatch — simulates a Mosaic
                    lowering/compile failure of the chosen variant.
                    Match kwargs (e.g. ``input_mode='halo'``,
                    ``hadamard='scheduled'``, ``backend='fused'``)
                    restrict which variants fail, selecting WHICH rung
                    of the ladder the probe exercises.
  'vmem_overflow'   raise at kernel dispatch with a RESOURCE_EXHAUSTED-
                    style RuntimeError — simulates the VMEM OOM real
                    hardware produces for over-budget blocks.
  'oob_index'       corrupt the Alg-2 INDEX table during
                    ``scheduler.compile_layer_tables`` (an entry pushed
                    far out of the active-bin range) — must be caught
                    by plan validation at BUILD time.
  'corrupt_value'   corrupt the Alg-2 VALUE plane (finite but wrong) —
                    invisible to static validation, caught by the
                    runtime parity guard.
  'nan_activations' corrupt a fused layer's output with a NaN — caught
                    by the runtime NaN/Inf scan.

Usage::

    from repro.testing import faults

    with faults.inject("lowering", input_mode="halo") as fault:
        plan = resilience.harden_network_plan(plan)   # halo -> windowed
    assert fault.fires > 0

Faults are matched on the call-site context and removed when the
context manager exits; nesting composes (all active faults are
consulted).  Everything is deterministic — no randomness, no wall
clock.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Iterator

import numpy as np

from repro.core import resilience as res

FAULT_SITES = res.FAULT_SITES

# A value far outside any active-bin range (K^2 <= 64 in this repo).
OOB_INDEX = 1_000_000
# Finite perturbation of one VALUE entry: large enough that the sampled
# parity guard (default tol 1e-4) trips on channel 0, small enough to
# stay finite through the whole net.
VALUE_DELTA = 32.0


def _default_exc(site: str, match: dict) -> Callable[[], Exception]:
    """Raw, un-taxonomized errors — like the real failures they mimic.
    The resilience layer must translate them into structured ones."""
    if site == "vmem_overflow":
        return lambda: RuntimeError(
            "RESOURCE_EXHAUSTED: Ran out of memory in memory space "
            f"vmem (injected fault, match={match})")
    return lambda: RuntimeError(
        f"Mosaic lowering failed (injected fault at {site!r}, "
        f"match={match})")


def _corrupt_oob_index(idx):
    out = np.array(idx, copy=True)
    out.flat[0] = OOB_INDEX
    return out


def _corrupt_value(vr):
    out = np.array(vr, copy=True)
    out.flat[0] += VALUE_DELTA
    return out


def _corrupt_nan(y):
    import jax.numpy as jnp
    return y.at[(0,) * y.ndim].set(jnp.nan)


_DEFAULT_CORRUPT = {
    "oob_index": _corrupt_oob_index,
    "corrupt_value": _corrupt_value,
    "nan_activations": _corrupt_nan,
}


@contextlib.contextmanager
def inject(site: str, *, exc: Callable[[], Exception] | None = None,
           corrupt: Callable | None = None,
           **match) -> Iterator[res.InjectedFault]:
    """Install one deterministic fault at ``site`` for the duration of
    the ``with`` block.

    ``match`` kwargs restrict the fault to call sites whose context
    carries every key with an equal value (see module doc).  ``exc``
    overrides the raised exception factory for raise-sites;
    ``corrupt`` overrides the value transform for corruption-sites.
    Yields the ``InjectedFault`` so tests can assert ``fault.fires``.
    """
    if site in ("lowering", "vmem_overflow"):
        fault = res.InjectedFault(site=site, match=dict(match),
                                  exc=exc or _default_exc(site, match))
    elif site in _DEFAULT_CORRUPT:
        fault = res.InjectedFault(site=site, match=dict(match),
                                  corrupt=corrupt or _DEFAULT_CORRUPT[site])
    else:
        raise ValueError(f"unknown fault site {site!r}; must be one of "
                         f"{FAULT_SITES}")
    res.install_fault(fault)
    try:
        yield fault
    finally:
        res.remove_fault(fault)


def corrupt_plan_tables(plan, *, layer: str | None = None,
                        kind: str = "oob_index"):
    """Return a copy of ``plan`` with one scheduled layer's Alg-2 tables
    mutated (``kind`` in 'oob_index' | 'corrupt_value') — for direct
    tests that a corrupted built plan is rejected by ``validate_plan``.
    """
    import dataclasses

    import jax.numpy as jnp

    from repro.core.plan import PlanTables

    mutate = _DEFAULT_CORRUPT[kind]
    new_layers = []
    done = False
    for lp in plan.layers:
        eligible = (lp.tables is not None
                    and (layer is None or lp.layer.name == layer))
        if eligible and not done:
            tb = lp.tables
            if kind == "oob_index":
                tb = PlanTables(jnp.asarray(mutate(tb.idx)), tb.sel,
                                tb.vr, tb.vi)
            else:
                tb = PlanTables(tb.idx, tb.sel,
                                jnp.asarray(mutate(tb.vr)), tb.vi)
            lp = dataclasses.replace(lp, tables=tb)
            done = True
        new_layers.append(lp)
    if not done:
        raise ValueError(f"no scheduled layer matching {layer!r} in plan")
    return dataclasses.replace(plan, layers=tuple(new_layers))
