"""Test-support tooling shipped with the library (fault injection)."""
