"""Trip-count-aware HLO analysis.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports)
counts each ``while`` body ONCE — so any lax.scan'd layer stack or
chunked-attention loop is undercounted by its trip count, and so are the
collectives inside it.  The optimized HLO, however, annotates every scan
loop with ``backend_config={"known_trip_count":{"n":...}}``.

This module parses the HLO text into computations, propagates loop
multipliers through while bodies/conditions (nested loops multiply), and
produces:

  * ``dot_flops``  — MXU FLOPs with loop multipliers applied (the
    dominant compute term; elementwise ops excluded, which understates
    by a few % on LM workloads),
  * trip-corrected collective statistics (op counts, operand bytes and
    ring-model wire bytes per chip).

Validated against analytic counts in tests/test_roofline.py.
"""

from __future__ import annotations

import dataclasses
import re

from repro.roofline.analysis import (_DTYPE_BYTES, _SHAPE_RE,
                                     CollectiveStats)

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_WHILE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|to_apply|condition|body|true_computation|"
                    r"false_computation)=%?([\w\.\-]+)")
_DEF = re.compile(r"^\s*(?:ROOT )?%([\w\.\-]+)\s*=\s*(.*)$")
# operand may carry an inline type: "dot(f32[64,64]{1,0} %lhs, ..." —
# newer HLO text — or be bare: "dot(%lhs, ..." (older text).
_DOT = re.compile(r"\bdot\((?:\S+\s+)?%?([\w\.\-]+),")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLL = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,]+\})")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]")


def _first_shape(text: str) -> tuple[str, tuple[int, ...]] | None:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


def _tensor_bytes_all(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        base = _DTYPE_BYTES.get(dt)
        if base is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += base * n
    return total


@dataclasses.dataclass
class HLOAnalysis:
    dot_flops: float
    collectives: CollectiveStats
    loop_multipliers: dict
    unknown_trip_loops: int


def parse(hlo_text: str, n_devices: int) -> HLOAnalysis:
    # ---- split into computations -----------------------------------------
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HDR.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                continue
            if line.startswith("}"):
                cur = None
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    # ---- call graph + loop trip counts ------------------------------------
    parents: dict[str, list[tuple[str, int]]] = {}
    unknown = 0
    for name, lines in comps.items():
        for line in lines:
            w = _WHILE.search(line)
            if w:
                t = _TRIP.search(line)
                trip = int(t.group(1)) if t else 1
                if not t:
                    unknown += 1
                for callee in w.groups():
                    parents.setdefault(callee, []).append((name, trip))
            else:
                for callee in _CALLS.findall(line):
                    if callee != name:
                        parents.setdefault(callee, []).append((name, 1))

    mult: dict[str, float] = {}

    def resolve(name: str, seen=frozenset()) -> float:
        if name in mult:
            return mult[name]
        if name in seen:
            return 1.0
        ps = parents.get(name)
        if not ps:
            m = 1.0
        else:
            # a computation is invoked from (normally) one site
            caller, trip = ps[0]
            m = resolve(caller, seen | {name}) * trip
        mult[name] = m
        return m

    for name in comps:
        resolve(name)

    # ---- dot FLOPs ---------------------------------------------------------
    dot_flops = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 1.0)
        shapes: dict[str, tuple[str, tuple[int, ...]]] = {}
        for line in lines:
            d = _DEF.match(line)
            if not d:
                continue
            op_name, rhs = d.groups()
            sh = _first_shape(rhs)
            if sh:
                shapes[op_name] = sh
            dm = _DOT.search(rhs)
            if dm:
                out = _first_shape(rhs)
                lhs_name = dm.group(1)
                lhs = shapes.get(lhs_name)
                cm = _CONTRACT.search(rhs)
                if out is None or lhs is None or cm is None:
                    continue
                out_elems = 1
                for dim in out[1]:
                    out_elems *= dim
                contract = 1
                if cm.group(1):
                    for ci in cm.group(1).split(","):
                        contract *= lhs[1][int(ci)]
                dot_flops += m * 2.0 * out_elems * contract

    # ---- collectives (trip-corrected) --------------------------------------
    counts: dict[str, float] = {}
    op_bytes: dict[str, float] = {}
    wire = 0.0
    for name, lines in comps.items():
        cmult = mult.get(name, 1.0)
        for line in lines:
            eq = line.find("=")
            if eq < 0:
                continue
            rhs = line[eq + 1:]
            cm = _COLL.search(rhs)
            if cm is None:
                continue
            kind = cm.group(1)
            # output tensor type(s) sit between '=' and the op token
            out_bytes = _tensor_bytes_all(rhs[:cm.start()])
            if out_bytes == 0:
                continue
            # XLA:CPU promotes bf16 all-reduces to f32 ("..._promoted"
            # reducers); a TPU lowering keeps them bf16 — count the
            # operand's true width, not the CPU artifact's.
            if "_promoted" in rhs and "f32[" in rhs[:cm.start()]:
                out_bytes /= 2
            g = n_devices
            gm = _GROUPS_RE.search(line)
            if gm:
                g = len(gm.group(1).strip("{}").split(","))
            else:
                gm = _GROUPS_IOTA_RE.search(line)
                if gm:
                    g = int(gm.group(2))
            g = max(2, g)
            counts[kind] = counts.get(kind, 0) + cmult
            if kind == "all-gather":
                operand, w = out_bytes / g, out_bytes * (g - 1) / g
            elif kind == "reduce-scatter":
                operand, w = out_bytes * g, out_bytes * (g - 1)
            elif kind == "all-reduce":
                operand, w = out_bytes, 2 * out_bytes * (g - 1) / g
            elif kind == "all-to-all":
                operand, w = out_bytes, out_bytes * (g - 1) / g
            else:
                operand, w = out_bytes, out_bytes
            op_bytes[kind] = op_bytes.get(kind, 0.0) + cmult * operand
            wire += cmult * w

    loops = {k: v for k, v in mult.items() if v > 1}
    return HLOAnalysis(dot_flops, CollectiveStats(counts, op_bytes, wire),
                       loops, unknown)
