"""Roofline terms from a compiled dry-run artifact.

compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
memory term     = HLO_bytes_per_chip / HBM_bw
collective term = wire_bytes_per_chip / (links * link_bw)

``cost_analysis()`` yields per-chip FLOPs/bytes of the SPMD module.
Collective bytes are parsed from the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op's tensor sizes, converted to per-chip wire bytes
with ring-algorithm factors over the op's replica-group size.
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e-class constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
ICI_LINKS = 3                # usable links per chip in a 2-D/3-D torus

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[sufbc]\w*?\d+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[0-9,]+\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    operand_bytes: dict          # sum of tensor bytes by op kind
    wire_bytes_per_chip: float   # ring-model bytes a single chip moves

    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())


def _tensor_bytes(lhs: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(lhs):
        base = _DTYPE_BYTES.get(dt)
        if base is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += base * n
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).strip("{}").split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: dict[str, int] = {}
    op_bytes: dict[str, float] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        eq = line.find("=")
        if eq < 0:
            continue
        rhs = line[eq + 1:]
        m = _COLL_RE.search(rhs)
        if m is None:
            continue
        kind = m.group(1)
        out_bytes = _tensor_bytes(rhs[:m.start()])
        if out_bytes == 0:
            continue
        g = max(2, _group_size(line, n_devices))
        counts[kind] = counts.get(kind, 0) + 1
        if kind == "all-gather":
            operand = out_bytes / g
            w = out_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            operand = out_bytes * g
            w = out_bytes * (g - 1)
        elif kind == "all-reduce":
            operand = out_bytes
            w = 2 * out_bytes * (g - 1) / g
        elif kind == "all-to-all":
            operand = out_bytes
            w = out_bytes * (g - 1) / g
        else:  # collective-permute
            operand = out_bytes
            w = out_bytes
        op_bytes[kind] = op_bytes.get(kind, 0.0) + operand
        wire += w
    return CollectiveStats(counts, op_bytes, wire)


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_frac: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions:
    older releases return ``[dict]``, newer return ``dict``."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def roofline_terms(cost: dict, coll: CollectiveStats, n_devices: int,
                   model_flops_total: float) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    wire = coll.wire_bytes_per_chip
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = wire / (ICI_LINKS * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    model_per_chip = model_flops_total / n_devices
    frac = model_per_chip / flops if flops else 0.0
    return Roofline(flops, hbm, wire, compute_s, memory_s, collective_s,
                    bottleneck, model_per_chip, frac)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D forward/decode, MoE uses N_active."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per sequence


def analytic_hbm_bytes(cfg, shape, plan, mesh_shape: dict) -> float:
    """Per-chip HBM traffic estimate for one step (documented model).

    XLA's 'bytes accessed' counts loop bodies once, so it is only a floor;
    this closed-form estimate is what the §Roofline memory term uses:

      train:   2x weight reads (fwd+bwd) + grad write + optimizer state
               read/write + activation save/reload (remat ~ one residual
               stream per layer each way)
      prefill: 1x weight read + activation stream
      decode:  1x weight read (N_active for MoE) + full KV cache read +
               one KV slot write
    """
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = model * data
    bpp = 2 if cfg.param_dtype == "bfloat16" else 4
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    # weights streamed through a chip per step: TP reads the local shard;
    # under FSDP the all-gathered layer weights transit HBM anyway, so
    # the per-chip weight traffic is the model-sharded volume either way.
    w_local = n_total * bpp / model
    tokens_local = shape.seq_len * shape.global_batch / max(data, 1)

    if shape.kind == "train":
        opt_mult = 8.0 if plan.optimizer == "adamw" else 0.2
        fsdp_ways = 1
        for ax in plan.fsdp_axes:
            fsdp_ways *= mesh_shape.get(ax, 1)
        opt_local = n_total * opt_mult / model / max(fsdp_ways, 1)
        acts = tokens_local * cfg.d_model * 2 * cfg.n_layers * 4
        return 3 * w_local + 2 * opt_local + acts
    if shape.kind == "prefill":
        acts = tokens_local * cfg.d_model * 2 * cfg.n_layers * 2
        return w_local + acts
    # decode: weights (active only for MoE) + KV cache scan
    w_read = n_active * bpp / model
    kv_len = min(shape.seq_len, cfg.window or shape.seq_len)
    if cfg.family in ("xlstm",):
        kv_len = 1
    layers = cfg.n_layers if cfg.family != "hybrid" else \
        -(-cfg.n_layers // cfg.attn_every)
    kv_bytes_per_el = (1.0 + 1.0 / cfg.hd) if getattr(
        cfg, "kv_quant", False) else 2.0
    kv = (2 * layers * cfg.n_kv_heads * cfg.hd * kv_len
          * shape.global_batch * kv_bytes_per_el) / chips
    return w_read + kv
