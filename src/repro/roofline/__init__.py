"""roofline subpackage."""
