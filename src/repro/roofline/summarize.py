"""Aggregate dry-run JSONs into the §Roofline markdown table.

  PYTHONPATH=src python -m repro.roofline.summarize [dir] [--mesh single]
"""

from __future__ import annotations

import json
import pathlib
import sys

from repro import configs

HINTS = {
    ("compute", "train"): "more useful-FLOP fraction: lighter remat "
                          "policy / fused attention kernel",
    ("compute", "prefill"): "flash-attention Pallas kernel to cut "
                            "softmax/elementwise overhead around the dots",
    ("compute", "decode"): "batch more sequences per chip; MXU is idle "
                           "at batch-per-chip this small",
    ("memory", "decode"): "KV/weight streaming dominates: quantize KV "
                          "cache, shard KV further, or grow batch",
    ("memory", "train"): "recompute less / raise arithmetic intensity "
                         "with larger per-chip batch",
    ("memory", "prefill"): "activation traffic: fuse norms into matmuls",
    ("collective", "train"): "bf16 collectives + sharding constraints to "
                             "kill resharding; overlap via async "
                             "collectives; sequence-parallel norms",
    ("collective", "prefill"): "same as train fwd: bf16 + constraints",
    ("collective", "decode"): "replicate small weights (collective "
                              "latency-bound at 1-token steps)",
}


def load(out_dir: str, mesh: str) -> list[dict]:
    rows = []
    for arch, shape, skipped in configs.cells(include_skipped=True):
        path = pathlib.Path(out_dir) / f"{arch}_{shape}_{mesh}.json"
        if skipped:
            rows.append({"arch": arch, "shape": shape, "skipped": True})
            continue
        if not path.exists():
            rows.append({"arch": arch, "shape": shape, "missing": True})
            continue
        rows.append(json.loads(path.read_text()))
    return rows


def fmt_row(r: dict) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                f"(full attention; DESIGN.md §Arch-applicability) | | |")
    if r.get("missing") or r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"{r.get('status', 'missing')} | | |")
    rf = r["roofline"]
    kind = configs.SHAPES[r["shape"]].kind
    hint = HINTS.get((rf["bottleneck"], kind), "")
    # recompute MODEL_FLOPS/HLO fraction from the config (records may
    # predate the active-param fix); stored flops_per_chip is unchanged
    from repro.roofline import analysis
    cfg = configs.get_config(r["arch"])
    mf = analysis.model_flops(cfg, configs.SHAPES[r["shape"]])
    frac = mf / r["n_devices"] / max(rf["flops_per_chip"], 1e-9)
    note = hint
    if not r["plan"].get("fits", True):
        note = "DOES NOT FIT this mesh (planner); " + hint
    return ("| {arch} | {shape} | {c:.3f} | {m:.3f} | {x:.3f} | "
            "**{b}** | {f:.2f} | {hint} |").format(
        arch=r["arch"], shape=r["shape"], c=rf["compute_s"],
        m=rf["memory_s"], x=rf["collective_s"], b=rf["bottleneck"],
        f=frac, hint=note)


def main() -> None:
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    mesh = "single"
    rows = load(out_dir, mesh)
    print("| arch | shape | compute s | memory s | collective s | "
          "bottleneck | MODEL/HLO flops | next lever |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))

    ok = [r for r in rows if r.get("status") == "ok"]
    worst = sorted(
        (r for r in ok if r["roofline"]["useful_flops_frac"] > 0),
        key=lambda r: min(1.0, r["roofline"]["useful_flops_frac"])
        / max(1e-9, 1.0))
    coll_bound = [r for r in ok
                  if r["roofline"]["bottleneck"] == "collective"]
    print(f"\nok={len(ok)}  collective-bound={len(coll_bound)}")


if __name__ == "__main__":
    main()
