"""Parameter/activation sharding rules for every architecture family.

Rules are name+rank based over the parameter pytree paths, producing a
PartitionSpec tree that mirrors the params.  Two strategies per tensor
class (the paper's reuse question at mesh scale, DESIGN.md §4):

  * ``tp``   — weights resident: shard only over 'model' (Flow #1:
               reuse kernels, stream activations through collectives);
  * ``fsdp`` — weights streamed: additionally shard over the batch axes
               ('data' [+ 'pod']), all-gathered per layer (Flow #2:
               reuse activations, stream kernels).

The planner (repro.distributed.planner) chooses per arch x shape which
strategy fits HBM at minimum collective traffic — Alg 1 re-targeted.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

MODEL = "model"


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Resolved strategy for one (arch, mesh, shape) cell."""

    batch_axes: tuple[str, ...]          # ('data',) or ('pod', 'data')
    fsdp: bool = False                   # shard weights over batch axes too
    fsdp_axes: tuple[str, ...] = ()      # subset of batch_axes for weights
    seq_shard: bool = False              # shard long KV/sequence over data
    optimizer: str = "adamw"
    remat: bool = True
    constraints: bool = True             # activation sharding constraints
    seq_parallel: bool = False           # sequence-parallel boundaries
    tp: bool = True                      # False = pure weight-streaming
    #                                      (FSDP over every mesh axis; the
    #                                      Flow-#2 answer to the title)
    remat_policy: str = "full"           # full | dots

    @property
    def wa(self) -> tuple[str, ...] | None:
        """Weight FSDP axes (None when pure TP)."""
        return self.fsdp_axes if self.fsdp else None


def _last2(spec_head: tuple, d_in, d_out) -> P:
    return P(*spec_head, d_in, d_out)


def param_spec(plan: ShardingPlan, path: tuple, leaf) -> P:
    """Sharding rule for one parameter leaf, by name (+ MoE path)."""
    keys = [getattr(e, "key", None) or getattr(e, "name", None)
            for e in path]
    names = [k for k in keys if isinstance(k, str)]
    name = names[-1] if names else None
    is_moe = "moe" in names
    rank = len(leaf.shape)
    head = (None,) * (rank - 2)          # stacked layer/group dims
    wa = plan.wa
    MODEL = "model" if plan.tp else None
    if not plan.tp:
        # weight-streaming: every weight fully sharded over the fsdp axes
        wa = plan.fsdp_axes or None

    if name in ("embed",):
        if not plan.tp:
            return P(None, wa)           # d_model over all axes
        return P(MODEL, wa)              # vocab over model
    if name in ("unembed",):
        return P(wa, MODEL)
    if name in ("wq", "wk", "wv", "w_up", "w_gate", "w", "wx", "wz",
                "fc1", "fc2", "fc3"):
        if is_moe and rank >= 3:         # experts [L, E, d, f]
            return P(*head[:-1], MODEL, wa, None)
        if rank >= 2:
            return _last2(head, wa, MODEL)
        return P()
    if name in ("wo", "w_down", "out_proj"):
        if is_moe and rank >= 3:         # experts [L, E, f, d]
            return P(*head[:-1], MODEL, None, wa)
        if rank >= 2:
            return _last2(head, MODEL, wa)
        return P()
    # router, small projections (wbc, wdt, w_if), conv_w, sLSTM block-diag
    # recurrence, norms, biases, gates: replicate
    return P()


def _divisibility_guard(spec: P, shape: tuple[int, ...],
                        axis_sizes: dict[str, int]) -> P:
    """Replicate any dim whose sharding would not divide evenly — the
    production fallback for odd vocab sizes / tiny gate dims."""
    out = []
    for i, entry in enumerate(tuple(spec) + (None,) * (len(shape)
                                                       - len(tuple(spec)))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        ways = 1
        for a in axes:
            ways *= axis_sizes.get(a, 1)
        out.append(entry if shape[i] % ways == 0 else None)
    return P(*out)


def params_pspec(plan: ShardingPlan, abstract_params: PyTree,
                 axis_sizes: dict[str, int] | None = None) -> PyTree:
    def one(path, leaf):
        spec = param_spec(plan, path, leaf)
        if axis_sizes:
            spec = _divisibility_guard(spec, leaf.shape, axis_sizes)
        return spec

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_pspec(plan: ShardingPlan, param_specs: PyTree,
                    abstract_params: PyTree, opt_name: str,
                    factor_threshold: int = 128) -> PyTree:
    """Optimizer-state spec tree: moments mirror the parameter sharding;
    factored Adafactor statistics drop the reduced axis of the spec."""
    if opt_name == "adamw":
        return {"mu": param_specs, "nu": param_specs, "count": P()}

    def per_leaf(spec: P, p) -> dict:
        s = p.shape
        factored = (len(s) >= 2 and s[-1] >= factor_threshold
                    and s[-2] >= factor_threshold)
        spec = tuple(spec) + (None,) * (len(s) - len(tuple(spec)))
        if factored:
            return {"vr": P(*spec[:-1]),
                    "vc": P(*spec[:-2], spec[-1])}
        return {"v": P(*spec)}

    v = jax.tree.map(per_leaf, param_specs, abstract_params,
                     is_leaf=lambda x: isinstance(x, P))
    return {"v": v, "count": P()}


def batch_pspec(plan: ShardingPlan, batch: PyTree) -> PyTree:
    def per_leaf(path, leaf):
        return P(plan.batch_axes, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(per_leaf, batch)


def cache_pspec(plan: ShardingPlan, abstract_cache: PyTree,
                batch_size: int,
                axis_sizes: dict[str, int] | None = None) -> PyTree:
    """KV caches / recurrent states.  Layout conventions:
    attention k/v [L(, G2), B, H_kv, S, D]; ssm/xlstm states carry B at
    a known axis.  We shard the batch axis over the plan's batch axes
    when divisible; for batch-1 long-context cells we shard the KV
    sequence axis over 'data' instead (seq_shard)."""

    def per_leaf(path, leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        # find the batch axis: first axis whose size == batch_size
        try:
            b_ax = next(i for i, s in enumerate(shape) if s == batch_size)
        except StopIteration:
            b_ax = None
        if b_ax is not None and batch_size > 1:
            spec[b_ax] = plan.batch_axes
        elif plan.seq_shard and len(shape) >= 2:
            # shard the longest axis (the KV sequence) over data
            s_ax = max(range(len(shape)), key=lambda i: shape[i])
            if shape[s_ax] > 1024:
                spec[s_ax] = "data"
        out = P(*spec)
        if axis_sizes:
            out = _divisibility_guard(out, shape, axis_sizes)
        return out

    return jax.tree_util.tree_map_with_path(per_leaf, abstract_cache)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def attach(abstract: PyTree, shardings: PyTree) -> PyTree:
    """ShapeDtypeStructs with shardings attached (dry-run inputs)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


# ---------------------------------------------------------------------------
# Spectral sharded inference (ISSUE 9): the specs the executor maps with
# ---------------------------------------------------------------------------

# The spectral conv stack shards over ONE mesh axis; activations are
# NCHW, so the two strategies of the two-level Alg-1 map cleanly onto
# PartitionSpecs over [B, C, H, W]:
#
#   spatial   split the tile-ROW axis (H) into contiguous bands; each
#             shard receives its band plus k-1 ppermute'd halo rows;
#   channel   every shard sees the FULL activation (P()) and slices its
#             own c_in/D channels by axis_index; the per-shard kernel
#             operands are stacked on a leading device axis (P(axis)).

SPECTRAL_AXIS = "shard"


def spectral_band_spec(axis: str = SPECTRAL_AXIS) -> P:
    """[B, C, H, W] activations split into tile-row bands over ``axis``
    (spatial strategy, in AND out: band canvases concatenate on H)."""
    return P(None, None, axis, None)


def spectral_stacked_spec(axis: str = SPECTRAL_AXIS) -> P:
    """Per-shard operands stacked on a leading device axis (channel
    strategy: sliced kernel planes / Alg-2 tables, one slice each)."""
    return P(axis)


def spectral_replicated_spec() -> P:
    """Fully-replicated operand (channel-strategy activations — every
    shard slices its own channels — and the post-psum output)."""
    return P()


def spectral_specs(strategy: str, axis: str = SPECTRAL_AXIS) -> dict:
    """{'x': ..., 'operand': ..., 'out': ...} PartitionSpecs for one
    strategy of ``core.plan.ShardedLayerPlan`` (see the executor,
    ``distributed.executor``)."""
    if strategy == "spatial":
        return {"x": spectral_band_spec(axis),
                "operand": spectral_replicated_spec(),
                "out": spectral_band_spec(axis)}
    if strategy == "channel":
        return {"x": spectral_replicated_spec(),
                "operand": spectral_stacked_spec(axis),
                "out": spectral_replicated_spec()}
    if strategy == "replicate":
        return {"x": spectral_replicated_spec(),
                "operand": spectral_replicated_spec(),
                "out": spectral_replicated_spec()}
    raise ValueError(f"unknown spectral shard strategy {strategy!r}")
